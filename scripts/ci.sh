#!/usr/bin/env bash
# Tier-1 verification + docs gate + fast allocator benchmark smoke.
#
#   scripts/ci.sh          # full tier-1 suite + docs check + engine smokes
#   scripts/ci.sh --fast   # skip the slow end-to-end model tests
set -euo pipefail
cd "$(dirname "$0")/.."

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

echo "== docs check (links + core API docstrings) =="
PYTHONPATH=src python scripts/check_docs.py

echo "== tier-1 tests =="
python -m pytest "${PYTEST_ARGS[@]}"

echo "== allocator benchmark smoke (batched engine) =="
PYTHONPATH=src python -m benchmarks.allocator_perf --batch --smoke
PYTHONPATH=src python -m benchmarks.allocator_perf --smoke

echo "== streaming admission engine smoke =="
PYTHONPATH=src python -m benchmarks.streaming_perf --smoke
