#!/usr/bin/env bash
# Tier-1 verification + docs gate + engine benchmark smokes + perf gate.
#
#   scripts/ci.sh          # full tier-1 suite + docs check + engine smokes
#   scripts/ci.sh --fast   # skip the slow end-to-end model tests
set -euo pipefail
cd "$(dirname "$0")/.."

# One device topology for EVERYTHING below (tests, smokes, perf gate):
# CPU-only (also skips the minutes-long TPU metadata probe on TPU-library
# machines) with 8 forced host devices so the device-sharding layer
# (core/sharding.py) runs identically here, in hosted CI and on laptops.
# The device count mirrors repro._env.FORCED_HOST_DEVICES (the python
# entry points use that helper; keep the two in sync).
export JAX_PLATFORMS=cpu
if [[ "${XLA_FLAGS:-}" != *"--xla_force_host_platform_device_count"* ]]; then
    export XLA_FLAGS="${XLA_FLAGS:+${XLA_FLAGS} }--xla_force_host_platform_device_count=8"
fi
export PYTHONPATH="src${PYTHONPATH:+:${PYTHONPATH}}"
# In-repo code must be deprecation-clean w.r.t. the legacy allocator shims:
# the benchmarks/examples below run with exactly that warning promoted to an
# error (pytest.ini does the same for the test suite).  The message-prefix
# filter leaves third-party DeprecationWarnings alone.
export PYTHONWARNINGS="error:repro.core.allocator:DeprecationWarning::"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-m "not slow")
fi

# Hosted CI sets BENCH_OUT to a workspace path so the fresh JSONs can be
# uploaded as an artifact; locally they land in a throwaway tmpdir that is
# removed on exit (success OR failure — only the dir we created ourselves;
# a caller-provided BENCH_OUT is the caller's to clean up).  Created up
# front so a crash mid-smoke still leaves the upload path (with whatever
# partial JSONs were written) for the artifact step + check_bench to
# report loudly on, instead of silently skipping the upload.
if [[ -n "${BENCH_OUT:-}" ]]; then
    BENCH_DIR="${BENCH_OUT}"
else
    BENCH_DIR="$(mktemp -d)"
    trap 'rm -rf "${BENCH_DIR}"' EXIT
fi
mkdir -p "${BENCH_DIR}"

echo "== docs check (links + core API docstrings) =="
python scripts/check_docs.py

echo "== tier-1 tests =="
python -m pytest "${PYTEST_ARGS[@]}"

echo "== allocator benchmark smoke (batched + sharded + fused engine) =="
# --fused gates the fused Alg. 4.1 iteration kernel's f64-vs-f64 speedup
# (ISSUE 9); the fused-iter differential test suite itself runs in the
# tier-1 pytest pass above (fast tier included — none of it is slow-marked)
python -m benchmarks.allocator_perf --batch --shard --fused --smoke \
    --json "${BENCH_DIR}/BENCH_allocator.json"
python -m benchmarks.allocator_perf --smoke

if [[ "${1:-}" != "--fast" ]]; then
    echo "== roofline smoke (fused-iteration arithmetic intensity) =="
    # full tier only: informational rows (no gate), skipped under --fast
    python -m benchmarks.roofline --smoke
fi

echo "== streaming admission engine smoke (warm + coalesced + sharded + resident) =="
# --shard measures BOTH residency modes: the host-round-trip shard path and
# the device-resident sessions (ISSUE 7); check_bench gates the resident
# speedup via the shard_resident section's `residency`-tagged record
python -m benchmarks.streaming_perf --coalesce --shard --smoke \
    --json "${BENCH_DIR}/BENCH_streaming.json"

echo "== admission daemon smoke (poisson/flash/diurnal, in-process + wire) =="
# the benchmark re-asserts daemon/offline trace conformance before timing;
# --wire additionally runs every arrival profile over the loopback socket
# transport (end-to-end admission latency, wire_* sections)
python -m benchmarks.allocd_perf --smoke --wire \
    --json "${BENCH_DIR}/BENCH_allocd.json"

echo "== capacity planner smoke (chunked grid sweep, sharded + warm start) =="
# the 48-candidate design-space sweep; check_bench gates candidates/sec on
# both the unsharded and lane-sharded sections (ISSUE 10).  The chunked==
# one-shot bit-equality contract itself is proven in tests/test_planning.py
python -m benchmarks.plan_perf --shard --smoke \
    --json "${BENCH_DIR}/BENCH_plan.json"

if [[ "${1:-}" != "--fast" ]]; then
    echo "== capacity planner full grid (1024 candidates, informational) =="
    # full tier only: the 8x4x4x8 design space at chunk 64 — a larger sweep
    # than the gated smoke, run without --json (no baseline at this size)
    python -m benchmarks.plan_perf --shard
fi

echo "== benchmark regression gate (vs benchmarks/baselines/) =="
python scripts/check_bench.py --fresh-dir "${BENCH_DIR}"
