#!/usr/bin/env python
"""Docs gate for scripts/ci.sh.

1. Link check: every relative markdown link in README.md, benchmarks/README.md
   and docs/*.md must resolve to an existing file (fragments stripped).
2. Anchor check: docs/PAPER_MAP.md (and docs/OPERATIONS.md, whose allocd
   runbook points at daemon code) anchors concepts to code as
   `` `symbol` [src/path.py:line](../src/path.py#Lline) ``.  Line numbers rot
   as code moves, so every symbol-adjacent anchor is verified by IMPORTING
   the module, resolving the symbol, and requiring the anchored line to fall
   inside the symbol's current source span (decorator lines included) — plus
   the link text and target fragment must agree.  A symbol that no longer
   exists fails loudly instead of pointing at unrelated code.
3. Docstring lint for the `repro.core` public API: every public module-level
   function and class needs a docstring; in the modules carrying the paper
   math facade (game, allocator, centralized, streaming, sharding) a
   function's docstring must also mention every one of its parameters by
   name (NumPy-style sections are how; the lint only enforces coverage),
   and public *methods* of public classes are held to the same standard —
   the streaming/sharding engine surface is mostly classes.

Exit code 0 iff all checks pass.  Run from the repo root:

    PYTHONPATH=src python scripts/check_docs.py
"""
import importlib
import inspect
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

DOC_FILES = [ROOT / "README.md", ROOT / "benchmarks" / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]

CORE_MODULES = ["types", "profiles", "game", "centralized", "rounding",
                "streaming", "sharding", "engine", "allocator", "traces",
                "planning"]
PARAM_STRICT = {"game", "centralized", "streaming", "sharding", "engine",
                "allocator", "planning"}

#: anchor-checked docs -> minimum recognized anchors.  Fewer than the
#: minimum means the doc format (or ANCHOR_RE) drifted and the check is
#: silently checking nothing; OPERATIONS.md carries fewer anchors than the
#: paper map, so its floor is lower.
ANCHORED_DOCS = {"docs/PAPER_MAP.md": 15, "docs/OPERATIONS.md": 6,
                 "docs/ARCHITECTURE.md": 6}

LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")

# `symbol` ...few words... [src/path.py:line](target) — the gap may not
# contain backticks or brackets, so each symbol pairs with the next link
ANCHOR_RE = re.compile(
    r"`(?P<sym>~?[A-Za-z_][\w.]*)`[^`\[\]]{0,40}?"
    r"\[(?P<path>src/[\w/]+\.py):(?P<line>\d+)\]\((?P<target>[^)\s]+)\)")


def check_links() -> list:
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for i, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:           # pure in-page anchor
                    continue
                if not (md.parent / path).exists():
                    errors.append(f"{md.relative_to(ROOT)}:{i}: "
                                  f"broken link -> {target}")
    return errors


def _symbol_span(path_str: str, symbol: str):
    """(start, end) source lines of ``symbol`` in the module at ``path_str``.

    The symbol is resolved by import (dotted names walk attributes), then
    unwrapped (``jax.jit`` etc. keep ``__wrapped__``) so the span covers the
    real ``def``/``class`` block including its decorators.  The resolved
    object must actually be *defined* in the anchored file — otherwise an
    anchor into a re-exporting module would be compared against line
    numbers of a different file and the staleness check would be
    meaningless.
    """
    mod_name = path_str[len("src/"):-len(".py")].replace("/", ".")
    obj = importlib.import_module(mod_name)
    for part in symbol.lstrip("~").split("."):
        obj = getattr(obj, part)
    obj = inspect.unwrap(obj)
    src_file = Path(inspect.getsourcefile(obj)).resolve()
    if src_file != (ROOT / path_str).resolve():
        shown = (src_file.relative_to(ROOT) if src_file.is_relative_to(ROOT)
                 else src_file)
        raise ValueError(f"symbol is defined in {shown}, not {path_str} "
                         "(anchor the defining module)")
    lines, start = inspect.getsourcelines(obj)
    return start, start + len(lines) - 1


def check_anchors_in(rel: str, min_anchors: int) -> list:
    errors = []
    md = ROOT / rel
    if not md.exists():
        return [f"{rel}: file missing"]
    n_anchors = 0
    for i, line in enumerate(md.read_text().splitlines(), 1):
        for m in ANCHOR_RE.finditer(line):
            n_anchors += 1
            where = f"{rel}:{i}"
            sym, path_str = m["sym"], m["path"]
            lineno = int(m["line"])
            frag = m["target"].rsplit("#L", 1)
            if (len(frag) != 2 or frag[1] != m["line"]
                    or not frag[0].endswith(path_str)):
                errors.append(f"{where}: anchor text {path_str}:{lineno} "
                              f"disagrees with link target {m['target']}")
                continue
            if not (ROOT / path_str).exists():
                errors.append(f"{where}: anchored file missing: {path_str}")
                continue
            try:
                start, end = _symbol_span(path_str, sym)
            except Exception as e:                       # noqa: BLE001
                errors.append(f"{where}: cannot resolve `{sym}` in "
                              f"{path_str} ({type(e).__name__}: {e})")
                continue
            if not start <= lineno <= end:
                errors.append(
                    f"{where}: stale anchor `{sym}` -> {path_str}:{lineno} "
                    f"(symbol now spans lines {start}-{end})")
    if n_anchors < min_anchors:
        errors.append(
            f"{rel}: only {n_anchors} symbol anchors recognized "
            f"(>= {min_anchors} expected) — doc format or ANCHOR_RE drifted")
    return errors


def check_anchors() -> list:
    errors = []
    for rel, floor in ANCHORED_DOCS.items():
        errors += check_anchors_in(rel, floor)
    return errors


def _params_of(fn) -> list:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return []
    return [p for p in sig.parameters
            if p not in ("self", "cls") and not p.startswith("_")]


def _lint_function(where: str, fn, strict: bool, errors: list) -> None:
    doc = inspect.getdoc(fn)
    if not doc:
        errors.append(f"{where}: missing docstring")
        return
    if strict:
        missing = [p for p in _params_of(fn) if p not in doc]
        if missing:
            errors.append(f"{where}: docstring does not mention "
                          f"parameter(s) {missing}")


def check_docstrings() -> list:
    errors = []
    for name in CORE_MODULES:
        mod = __import__(f"repro.core.{name}", fromlist=[name])
        strict = name in PARAM_STRICT
        for sym, obj in vars(mod).items():
            if sym.startswith("_"):
                continue
            if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                continue
            if getattr(obj, "__module__", None) != mod.__name__:
                continue               # re-export, linted at home
            where = f"repro.core.{name}.{sym}"
            if inspect.isfunction(obj):
                _lint_function(where, obj, strict, errors)
                continue
            if not inspect.getdoc(obj):
                errors.append(f"{where}: missing docstring")
            if not strict:
                continue
            # public methods of public classes carry the same standard
            # (the streaming/sharding engine surface is mostly classes)
            for meth, fn in vars(obj).items():
                if not meth.startswith("_") and inspect.isfunction(fn):
                    _lint_function(f"{where}.{meth}", fn, strict, errors)
    return errors


def main() -> int:
    errors = check_links() + check_anchors() + check_docstrings()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if errors:
        print(f"check_docs: FAILED ({len(errors)} problem(s))",
              file=sys.stderr)
        return 1
    n_links = sum(len(LINK_RE.findall(f.read_text()))
                  for f in DOC_FILES if f.exists())
    n_anchors = sum(len(ANCHOR_RE.findall((ROOT / rel).read_text()))
                    for rel in ANCHORED_DOCS)
    print(f"check_docs: OK ({len(DOC_FILES)} docs, {n_links} links, "
          f"{n_anchors} verified anchors, {len(CORE_MODULES)} core modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
