#!/usr/bin/env python
"""Docs gate for scripts/ci.sh.

1. Link check: every relative markdown link in README.md, benchmarks/README.md
   and docs/*.md must resolve to an existing file (fragments stripped).
2. Docstring lint for the `repro.core` public API: every public module-level
   function and class needs a docstring; in the modules carrying the paper
   math facade (game, allocator, centralized, streaming) a function's
   docstring must also mention every one of its parameters by name
   (NumPy-style sections are how; the lint only enforces coverage).

Exit code 0 iff both checks pass.  Run from the repo root:

    PYTHONPATH=src python scripts/check_docs.py
"""
import inspect
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

DOC_FILES = [ROOT / "README.md", ROOT / "benchmarks" / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]

CORE_MODULES = ["types", "profiles", "game", "centralized", "rounding",
                "streaming", "sharding", "allocator"]
PARAM_STRICT = {"game", "centralized", "streaming", "sharding", "allocator"}

LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")


def check_links() -> list:
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for i, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:           # pure in-page anchor
                    continue
                if not (md.parent / path).exists():
                    errors.append(f"{md.relative_to(ROOT)}:{i}: "
                                  f"broken link -> {target}")
    return errors


def _params_of(fn) -> list:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return []
    return [p for p in sig.parameters
            if p not in ("self", "cls") and not p.startswith("_")]


def check_docstrings() -> list:
    errors = []
    for name in CORE_MODULES:
        mod = __import__(f"repro.core.{name}", fromlist=[name])
        strict = name in PARAM_STRICT
        for sym, obj in vars(mod).items():
            if sym.startswith("_"):
                continue
            if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                continue
            if getattr(obj, "__module__", None) != mod.__name__:
                continue               # re-export, linted at home
            where = f"repro.core.{name}.{sym}"
            doc = inspect.getdoc(obj)
            if not doc:
                errors.append(f"{where}: missing docstring")
                continue
            if strict and inspect.isfunction(obj):
                missing = [p for p in _params_of(obj) if p not in doc]
                if missing:
                    errors.append(f"{where}: docstring does not mention "
                                  f"parameter(s) {missing}")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if errors:
        print(f"check_docs: FAILED ({len(errors)} problem(s))",
              file=sys.stderr)
        return 1
    n_links = sum(len(LINK_RE.findall(f.read_text()))
                  for f in DOC_FILES if f.exists())
    print(f"check_docs: OK ({len(DOC_FILES)} docs, {n_links} links, "
          f"{len(CORE_MODULES)} core modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
