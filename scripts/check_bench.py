#!/usr/bin/env python
"""Benchmark regression gate for scripts/ci.sh.

Compares freshly measured smoke numbers (``benchmarks/*_perf.py --smoke
--json``) against the committed baselines in ``benchmarks/baselines/`` and
fails when a gated metric drops below its tolerance band:

* **ratio metrics** (``speedup``, ``scaling``) are machine-portable-ish
  (both sides of the ratio ran on the same box) — gated at
  ``fresh >= baseline * (1 - RATIO_TOL)``;
* **throughput metrics** (``scenarios_per_sec``, ``events_per_sec``) vary
  wildly across machines, so they only catch order-of-magnitude
  regressions — gated at ``fresh >= baseline * (1 - ABS_TOL)``;
* **latency metrics** (``admission_p50_ms``, ``admission_p99_ms`` from
  ``BENCH_allocd.json``) gate in the OPPOSITE direction — lower is
  better, so the bound is a ceiling: ``fresh <= baseline * (1 +
  LAT_TOL)``, loose enough for CI-box jitter but failing on
  order-of-magnitude admission-latency blowups.

Config keys (B, n, devices, ...) of every gated section must match the
baseline exactly — otherwise the comparison is meaningless and the gate
fails loudly instead of silently passing on easier settings.  The same goes
for the record-level ``solver_config`` fingerprint (``SolverConfig
.fingerprint()``): engine-path numbers are never compared against records
measured under a different solver config or on the pre-redesign facades.

Usage (what scripts/ci.sh does):

    python -m benchmarks.allocator_perf --batch --shard --smoke \
        --json /tmp/bench/BENCH_allocator.json
    python -m benchmarks.streaming_perf --shard --smoke \
        --json /tmp/bench/BENCH_streaming.json
    python scripts/check_bench.py --fresh-dir /tmp/bench

Refresh the committed baselines (after an intentional perf change) by
writing the fresh JSONs into ``benchmarks/baselines/`` instead.
"""
import argparse
import json
import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = ROOT / "benchmarks" / "baselines"

#: metric name -> tolerance class
GATED = {
    "speedup": "ratio",
    "scaling": "ratio",
    "scenarios_per_sec": "throughput",
    "events_per_sec": "throughput",
    "iterations_per_sec": "throughput",
    "candidates_per_sec": "throughput",
    "admission_p50_ms": "latency",
    "admission_p99_ms": "latency",
}
#: config keys that must match between baseline and fresh for a section
#: ("path" tags which engine path a section measured — per-event vs
#: coalesced-epochs vs shard-coalesced events/sec are not comparable;
#: "residency" tags whether window state stayed device-resident across
#: flushes — resident and host-round-trip records are different machines
#: and must never be silently compared; "arrival" tags the allocd arrival
#: process — Poisson vs flash-crowd vs diurnal latency records are never
#: comparable, nor are runs at different tenant counts, rates or queue
#: bounds; "transport" tags in-process vs wire-socket daemon records —
#: end-to-end socket latency and in-process latency are different
#: quantities and must never be silently compared; "iter" /
#: "dtype_policy" / "steps" tag the fused-iteration section — a
#: fused-kernel speedup measured under a different iter_fn, element-width
#: policy or pinned iteration count is a different experiment and must
#: hard-fail the compare instead of silently passing; "grid" / "profile" /
#: "fleet" tag the capacity-planner sections of BENCH_plan.json — a
#: candidates/sec number over a different design-space size, workload
#: profile or fleet axis shape is a different sweep and must never be
#: silently compared)
CONFIG_KEYS = ("B", "n", "n_events", "chunk", "coalesce", "max_devices",
               "ragged", "path", "residency", "arrival", "transport",
               "tenants", "rate", "flush_k", "queue_limit",
               "iter", "dtype_policy", "steps", "grid", "profile", "fleet")


class TruncatedBenchError(Exception):
    """A BENCH_*.json exists but is empty or cut off mid-write.

    A smoke crashing after opening its output leaves exactly this; the
    gate must fail loudly on it instead of crashing with a bare
    JSONDecodeError (or, worse, skipping the file).
    """


def load(path: Path) -> dict:
    text = path.read_text()
    if not text.strip():
        raise TruncatedBenchError(f"{path.name}: empty file (benchmark "
                                  "crashed before writing results?)")
    try:
        data = json.loads(text)
    except json.JSONDecodeError as exc:
        raise TruncatedBenchError(
            f"{path.name}: truncated/corrupt JSON at char {exc.pos} of "
            f"{len(text)} (benchmark crashed mid-write?)")
    if not isinstance(data, dict) or "results" not in data:
        raise TruncatedBenchError(
            f"{path.name}: no 'results' section (partial write?)")
    return data


def compare_section(name, base: dict, fresh: dict, tols: dict,
                    rows: list) -> list:
    errors = []
    for k in CONFIG_KEYS:
        if base.get(k) != fresh.get(k):
            errors.append(f"{name}: config mismatch {k}: baseline="
                          f"{base.get(k)!r} fresh={fresh.get(k)!r}")
    if errors:
        return errors
    for metric, klass in GATED.items():
        if metric not in base:
            continue
        if metric not in fresh:
            errors.append(f"{name}.{metric}: missing from fresh results")
            continue
        tol = tols[klass]
        if klass == "latency":                   # lower is better: ceiling
            bound = base[metric] * (1.0 + tol)
            ok = fresh[metric] <= bound
            kind = "ceil"
        else:                                    # higher is better: floor
            bound = base[metric] * (1.0 - tol)
            ok = fresh[metric] >= bound
            kind = "floor"
        status = "ok" if ok else "FAIL"
        rows.append({"name": name, "metric": metric, "klass": klass,
                     "base": base[metric], "fresh": fresh[metric],
                     "bound": bound, "kind": kind, "ok": ok})
        print(f"  {name}.{metric:<20} baseline={base[metric]:>10.2f} "
              f"fresh={fresh[metric]:>10.2f} {kind}={bound:>10.2f} "
              f"[{klass}] {status}")
        if not ok:
            sign = ">" if klass == "latency" else "<"
            errors.append(
                f"{name}.{metric}: {fresh[metric]:.2f} {sign} {kind} "
                f"{bound:.2f} (baseline {base[metric]:.2f}, tol {tol:.0%})")
    return errors


def write_step_summary(rows: list, errors: list) -> None:
    """Mirror the gate outcome into $GITHUB_STEP_SUMMARY (if set).

    Perf drift becomes visible on the PR page itself — the
    fresh-vs-baseline delta per gated metric plus the pass/fail verdict —
    without downloading the bench artifacts.
    """
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    verdict = ("✅ bench gate passed" if not errors
               else f"❌ bench gate FAILED ({len(errors)} problem(s))")
    lines = ["## Benchmark regression gate", "", verdict, ""]
    if rows:
        lines += ["| section.metric | class | baseline | fresh | Δ | "
                  "bound | status |",
                  "|---|---|---:|---:|---:|---:|---|"]
        for r in rows:
            delta = ((r["fresh"] - r["base"]) / r["base"] * 100.0
                     if r["base"] else float("nan"))
            lines.append(
                f"| {r['name']}.{r['metric']} | {r['klass']} "
                f"| {r['base']:.2f} | {r['fresh']:.2f} | {delta:+.1f}% "
                f"| {r['kind']} {r['bound']:.2f} "
                f"| {'ok' if r['ok'] else '**FAIL**'} |")
        lines.append("")
    if errors:
        lines += ["```"] + [str(e) for e in errors] + ["```", ""]
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh-dir", required=True,
                    help="directory holding the just-measured BENCH_*.json")
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--ratio-tol", type=float,
                    default=float(os.environ.get("CHECK_BENCH_RATIO_TOL",
                                                 0.6)),
                    help="allowed drop for speedup/scaling ratios "
                         "(loose: 2-core CI boxes jitter ~2x)")
    ap.add_argument("--throughput-tol", type=float,
                    default=float(os.environ.get("CHECK_BENCH_ABS_TOL",
                                                 0.8)),
                    help="allowed drop for absolute throughput "
                         "(looser still: machines differ)")
    ap.add_argument("--latency-tol", type=float,
                    default=float(os.environ.get("CHECK_BENCH_LAT_TOL",
                                                 4.0)),
                    help="allowed INCREASE for latency percentiles "
                         "(ceiling = baseline * (1 + tol); admission "
                         "latency is wall-clock and CI boxes jitter)")
    args = ap.parse_args()
    tols = {"ratio": args.ratio_tol, "throughput": args.throughput_tol,
            "latency": args.latency_tol}

    baselines = sorted(Path(args.baseline_dir).glob("BENCH_*.json"))
    if not baselines:
        print(f"check_bench: no baselines in {args.baseline_dir}",
              file=sys.stderr)
        return 1

    errors = []
    rows = []
    for bpath in baselines:
        fpath = Path(args.fresh_dir) / bpath.name
        if not fpath.exists():
            errors.append(f"{bpath.name}: fresh file missing "
                          f"(benchmark not run?)")
            continue
        try:
            base, fresh = load(bpath), load(fpath)
        except TruncatedBenchError as exc:
            errors.append(str(exc))
            continue
        print(f"{bpath.name} (baseline sha {base.get('git_sha')}, "
              f"fresh sha {fresh.get('git_sha')}):")
        if base.get("device_count") != fresh.get("device_count"):
            errors.append(
                f"{bpath.name}: device_count mismatch baseline="
                f"{base.get('device_count')} fresh={fresh.get('device_count')}"
                " — run under the same forced host-device topology "
                "(scripts/ci.sh exports it)")
            continue
        if base.get("smoke") != fresh.get("smoke"):
            errors.append(
                f"{bpath.name}: smoke mismatch baseline={base.get('smoke')} "
                f"fresh={fresh.get('smoke')} — smoke and full runs use "
                "different problem sizes")
            continue
        if base.get("solver_config") != fresh.get("solver_config"):
            errors.append(
                f"{bpath.name}: solver_config mismatch baseline="
                f"{base.get('solver_config')!r} fresh="
                f"{fresh.get('solver_config')!r} — numbers measured under "
                "different SolverConfigs (or on the pre-redesign facades, "
                "which recorded none) are not comparable; refresh the "
                "baseline alongside the config change")
            continue
        bad_env = [k for k in ("backend", "x64")
                   if base.get(k) != fresh.get(k)]
        if bad_env:
            errors.append(
                f"{bpath.name}: " + "; ".join(
                    f"{k} mismatch baseline={base.get(k)!r} "
                    f"fresh={fresh.get(k)!r}" for k in bad_env)
                + " — throughputs across backends are not comparable")
            continue
        for section, bvals in base.get("results", {}).items():
            fvals = fresh.get("results", {}).get(section)
            if fvals is None:
                errors.append(f"{bpath.name}: results.{section} missing "
                              f"from fresh run")
                continue
            errors += compare_section(f"{bpath.name}:{section}", bvals,
                                      fvals, tols, rows)

    write_step_summary(rows, errors)
    for e in errors:
        print(f"check_bench: {e}", file=sys.stderr)
    if errors:
        print(f"check_bench: FAILED ({len(errors)} problem(s))",
              file=sys.stderr)
        return 1
    print(f"check_bench: OK ({len(baselines)} baseline file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
