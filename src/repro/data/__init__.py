from repro.data.pipeline import MemmapTokens, SyntheticLM, make_source

__all__ = ["MemmapTokens", "SyntheticLM", "make_source"]
