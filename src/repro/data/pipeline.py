"""Deterministic, host-sharded token pipeline.

Two sources behind one interface:
  * SyntheticLM — seed-derived token streams (markov-ish mixture so loss can
    actually decrease); batch content is a pure function of (seed, step,
    host), so restarts resume bit-identically without data-state checkpoints.
  * MemmapTokens — flat binary token file, deterministic shuffled windows.

Each host materializes only its slice of the global batch
([process_index * per_host, ...)), and a background thread prefetches.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Deterministic synthetic LM batches with learnable structure."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab, self.seq = vocab, seq_len
        self.batch = global_batch // n_hosts
        self.seed, self.host = seed, host_id

    def _gen(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host]))
        B, S, V = self.batch, self.seq + 1, self.vocab
        # mixture of a linear-congruential stream (predictable) and noise
        start = rng.integers(0, V, (B, 1))
        ramp = (start + 7 * np.arange(S)[None, :]) % V
        noise = rng.integers(0, V, (B, S))
        take_noise = rng.random((B, S)) < 0.15
        return np.where(take_noise, noise, ramp).astype(np.int32)

    def __call__(self, step: int) -> dict:
        toks = self._gen(step)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class MemmapTokens:
    """Flat token file -> deterministic shuffled (seq+1)-windows."""

    def __init__(self, path: str, seq_len: int, global_batch: int, *,
                 dtype=np.uint16, seed: int = 0, n_hosts: int = 1,
                 host_id: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.seq = seq_len
        self.batch = global_batch // n_hosts
        self.seed, self.host, self.n_hosts = seed, host_id, n_hosts
        self.n_windows = (len(self.data) - 1) // (seq_len + 1)
        assert self.n_windows >= self.batch, "dataset too small"

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # one global permutation draw per step; each host takes its slice
        idx = rng.choice(self.n_windows, self.batch * self.n_hosts,
                         replace=False)
        idx = idx[self.host * self.batch:(self.host + 1) * self.batch]
        W = self.seq + 1
        out = np.stack([np.asarray(self.data[i * W:(i + 1) * W])
                        for i in idx]).astype(np.int32)
        return {"tokens": out[:, :-1], "targets": out[:, 1:]}


def make_source(kind: str, **kw):
    return {"synthetic": SyntheticLM, "memmap": MemmapTokens}[kind](**kw)


def prefetched(source, start_step: int = 0, depth: int = 2) -> Iterator[dict]:
    """Background-thread prefetch of source(step) batches."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(source(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
