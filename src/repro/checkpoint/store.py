"""Fault-tolerant sharded checkpoints (no orbax dependency).

Layout:  <dir>/step_<N>/manifest.json + <leaf-hash>.npy per pytree leaf.
Writes go to a temp dir and are atomically renamed, so a crash mid-save never
corrupts the latest checkpoint; ``restore`` reshards onto any mesh (elastic
re-mesh after a capacity change — the paper's hourly reallocation).

On multi-host, each process would save its addressable shards
(process-suffixed files); this container is single-process, but the API keeps
the (process_index, n_processes) plumbing explicit.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_PENDING: list = []


def _leaf_name(path_str: str) -> str:
    h = hashlib.sha1(path_str.encode()).hexdigest()[:16]
    return f"{h}.npy"


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp)
        out.append((ps, leaf))
    return out


def save(tree: Any, step: int, ckpt_dir: str, *, keep_last: int = 3,
         extra: Optional[dict] = None) -> str:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_step_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for ps, leaf in _paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = _leaf_name(ps)
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_name == "bfloat16":
            # bfloat16 etc. are not native numpy: store raw bits
            dtype_name = str(jax.numpy.asarray(leaf).dtype)
            np.save(tmp / fn, arr.view(np.uint8))
            stored = "raw_u8"
        else:
            np.save(tmp / fn, arr)
            stored = "native"
        manifest["leaves"][ps] = {"file": fn, "shape": list(arr.shape),
                                  "dtype": dtype_name, "stored": stored}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    final = d / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic publish
    _gc(d, keep_last)
    return str(final)


def save_async(tree: Any, step: int, ckpt_dir: str, **kw) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk in a thread."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                       tree)
    t = threading.Thread(target=save, args=(host_tree, step, ckpt_dir),
                         kwargs=kw, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _gc(d: Path, keep_last: int):
    steps = sorted((int(p.name.split("_")[1]) for p in d.glob("step_*")),
                   reverse=True)
    for s in steps[keep_last:]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(tree_like: Any, step: int, ckpt_dir: str, *, shardings=None):
    """Restore into the structure of ``tree_like``; ``shardings`` (same
    structure) reshards onto the current mesh."""
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat = _paths(tree_like)
    sh_flat = (_paths(shardings) if shardings is not None
               else [(ps, None) for ps, _ in flat])
    sh_map = dict(sh_flat)
    leaves = []
    for ps, like in flat:
        info = manifest["leaves"][ps]
        arr = np.load(d / info["file"])
        if info.get("stored") == "raw_u8":
            import ml_dtypes
            arr = arr.view(np.dtype(info["dtype"])
                           if info["dtype"] not in ("bfloat16",)
                           else ml_dtypes.bfloat16)
            arr = arr.reshape(info["shape"])
        sh = sh_map.get(ps)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def manifest_extra(ckpt_dir: str, step: int) -> dict:
    d = Path(ckpt_dir) / f"step_{step}"
    return json.loads((d / "manifest.json").read_text()).get("extra", {})
