"""repro — game-theoretic runtime capacity allocation (GNEP) for multi-pod
TPU fleets, with a 10-architecture JAX model zoo and Pallas kernels.

Public surface:
    repro.core      — the paper (solvers, game, rounding, profiles)
    repro.cluster   — fleet simulation (tenants, failures, elastic epochs)
    repro.models    — model zoo + distribution-aware layers
    repro.configs   — the assigned architectures and input shapes
    repro.launch    — meshes, dry-run, train/serve drivers
"""

__version__ = "1.0.0"
