"""Pure-jnp oracle for the flash-attention kernel (O(S^2) memory)."""
import jax.numpy as jnp

from repro.models.attention import reference as _model_reference


def reference(q, k, v, *, causal=True):
    """q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    return _model_reference(q, k, v, causal=causal)
