"""Jit'd public wrapper: Pallas on TPU, interpret-mode kernel or jnp oracle
elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import reference


def attention(q, k, v, *, causal=True, block_q=128, block_k=128,
              force_pallas=False):
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=not on_tpu)
    return reference(q, k, v, causal=causal)
