"""Blocked GQA flash attention (forward) as a Pallas TPU kernel.

Grid: (B, Hq, Sq/BQ, Skv/BK) — the last axis is sequential ("arbitrary");
online-softmax running stats (m, l, acc) live in VMEM scratch and are
finalized on the last kv step.  Block shapes keep the (BQ x hd) / (BK x hd)
tiles MXU-aligned (hd is 64/128 in every assigned config; BQ/BK default 128
-> ~(128,128) matmuls on the MXU, VMEM footprint ~4 tiles + scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional under interpret=True
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_q, block_k, causal, n_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _final():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=False):
    """q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    n_q, n_k = Sq // block_q, Skv // block_k

    # (B, H, S, hd) layout inside the kernel
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(_kernel, scale=hd ** -0.5, block_q=block_q,
                               block_k=block_k, causal=causal, n_k=n_k)
    grid = (B, Hq, n_q, n_k)
    scratch = ([_VMEM((block_q,), jnp.float32),
                _VMEM((block_q,), jnp.float32),
                _VMEM((block_q, hd), jnp.float32)] if _VMEM is not None else
               [pl.ANY] * 3)
    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary"))
        except Exception:
            pass
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
