# Pallas TPU kernels for the perf-critical hot spots:
#   flash_attention/ - blocked GQA flash attention (prefill/train)
#   rwkv6/           - chunked WKV6 linear-attention scan
#   gnep_sweep/      - the paper's RM candidate-price sweep (P5 inner loop)
#   gnep_iter/       - fused Alg. 4.1 inner iteration (sweep+pick+bids+eps)
# Each has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper) and
# ref.py (pure-jnp oracle); validated on CPU with interpret=True.
