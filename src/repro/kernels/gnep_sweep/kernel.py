"""The paper's hot spot, tiled: the RM (P5) candidate-price sweep.

At N classes the exact RM solve is an O(N^2) masked running-sum: for each of
~N candidate prices, a greedy knapsack fill in fixed p-order.  This kernel
tiles it (BC candidates x BN classes per step); the running per-candidate
cumulative fill is VMEM scratch carried across the sequential class axis, so
each (BC, BN) tile does a cumsum + clip on the VPU with one pass over HBM.

Grid: (Nc/BC, N/BN) with the class axis sequential.

``rm_sweep_batched`` extends the grid to (B, Nc/BC, N/BN) so the price sweep
of a whole ScenarioBatch is ONE kernel launch: batch and candidate axes are
parallel, the class axis stays sequential per (batch, candidate-tile) and
carries the same VMEM running-sum scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(inc_ref, spare_ref, p_ref, fill_ref, sumf_ref, pf_ref,
            cum_scr, sacc_scr, pacc_scr, *, n_blocks):
    ji = pl.program_id(1)

    @pl.when(ji == 0)
    def _init():
        cum_scr[...] = jnp.zeros_like(cum_scr)
        sacc_scr[...] = jnp.zeros_like(sacc_scr)
        pacc_scr[...] = jnp.zeros_like(pacc_scr)

    inc = inc_ref[...].astype(jnp.float32)            # (BC, BN)
    spare = spare_ref[0, 0]
    pv = p_ref[...].astype(jnp.float32)               # (BN,)

    cum_in = cum_scr[...]                             # (BC,)
    local_cum = jnp.cumsum(inc, axis=1)
    before = cum_in[:, None] + local_cum - inc        # filled before each cls
    fill = jnp.clip(spare - before, 0.0, inc)
    fill_ref[...] = fill.astype(fill_ref.dtype)

    cum_scr[...] = cum_in + local_cum[:, -1]
    sacc_scr[...] = sacc_scr[...] + jnp.sum(fill, axis=1)
    pacc_scr[...] = pacc_scr[...] + fill @ pv

    @pl.when(ji == n_blocks - 1)
    def _final():
        sumf_ref[...] = sacc_scr[...].astype(sumf_ref.dtype)
        pf_ref[...] = pacc_scr[...].astype(pf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_n",
                                             "interpret"))
def rm_sweep(inc, spare, p_sorted, *, block_c=128, block_n=512,
             interpret=False):
    """inc: (Nc, N) f32; spare: scalar; p_sorted: (N,).
    Returns (fill (Nc, N), sum_fill (Nc,), p_fill (Nc,))."""
    Nc, N = inc.shape
    block_c = min(block_c, Nc)
    block_n = min(block_n, N)
    # pad to tile multiples (padding classes have inc=0 -> no effect)
    pc = (-Nc) % block_c
    pn = (-N) % block_n
    inc_p = jnp.pad(inc, ((0, pc), (0, pn)))
    p_p = jnp.pad(p_sorted, (0, pn))
    Ncp, Np = Nc + pc, N + pn
    n_blocks = Np // block_n
    spare_arr = jnp.asarray(spare, jnp.float32).reshape(1, 1)

    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary"))
        except Exception:
            pass
    scratch = ([_VMEM((block_c,), jnp.float32)] * 3 if _VMEM is not None
               else [pl.ANY] * 3)
    fill, sumf, pf = pl.pallas_call(
        functools.partial(_kernel, n_blocks=n_blocks),
        grid=(Ncp // block_c, n_blocks),
        in_specs=[
            pl.BlockSpec((block_c, block_n), lambda ci, ji: (ci, ji)),
            pl.BlockSpec((1, 1), lambda ci, ji: (0, 0)),
            pl.BlockSpec((block_n,), lambda ci, ji: (ji,)),
        ],
        out_specs=[
            pl.BlockSpec((block_c, block_n), lambda ci, ji: (ci, ji)),
            pl.BlockSpec((block_c,), lambda ci, ji: (ci,)),
            pl.BlockSpec((block_c,), lambda ci, ji: (ci,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ncp, Np), inc.dtype),
            jax.ShapeDtypeStruct((Ncp,), jnp.float32),
            jax.ShapeDtypeStruct((Ncp,), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(inc_p, spare_arr, p_p)
    return fill[:Nc, :N], sumf[:Nc], pf[:Nc]


def _kernel_batched(inc_ref, spare_ref, p_ref, fill_ref, sumf_ref, pf_ref,
                    cum_scr, sacc_scr, pacc_scr, *, n_blocks):
    ji = pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        cum_scr[...] = jnp.zeros_like(cum_scr)
        sacc_scr[...] = jnp.zeros_like(sacc_scr)
        pacc_scr[...] = jnp.zeros_like(pacc_scr)

    inc = inc_ref[0].astype(jnp.float32)              # (BC, BN)
    spare = spare_ref[0, 0]                           # this batch lane's slack
    pv = p_ref[0].astype(jnp.float32)                 # (BN,)

    cum_in = cum_scr[...]                             # (BC,)
    local_cum = jnp.cumsum(inc, axis=1)
    before = cum_in[:, None] + local_cum - inc        # filled before each cls
    fill = jnp.clip(spare - before, 0.0, inc)
    fill_ref[0] = fill.astype(fill_ref.dtype)

    cum_scr[...] = cum_in + local_cum[:, -1]
    sacc_scr[...] = sacc_scr[...] + jnp.sum(fill, axis=1)
    pacc_scr[...] = pacc_scr[...] + fill @ pv

    @pl.when(ji == n_blocks - 1)
    def _final():
        sumf_ref[0] = sacc_scr[...].astype(sumf_ref.dtype)
        pf_ref[0] = pacc_scr[...].astype(pf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_n",
                                             "interpret"))
def rm_sweep_batched(inc, spare, p_sorted, *, block_c=128, block_n=512,
                     interpret=False):
    """Batched RM price sweep: B instances in one kernel launch.

    inc: (B, Nc, N) f32; spare: (B,); p_sorted: (B, N).
    Returns (fill (B, Nc, N), sum_fill (B, Nc), p_fill (B, Nc))."""
    B, Nc, N = inc.shape
    block_c = min(block_c, Nc)
    block_n = min(block_n, N)
    # pad to tile multiples (padding classes have inc=0 -> no effect)
    pc = (-Nc) % block_c
    pn = (-N) % block_n
    inc_p = jnp.pad(inc, ((0, 0), (0, pc), (0, pn)))
    p_p = jnp.pad(p_sorted, ((0, 0), (0, pn)))
    Ncp, Np = Nc + pc, N + pn
    n_blocks = Np // block_n
    spare_arr = jnp.asarray(spare, jnp.float32).reshape(B, 1)

    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:
            pass
    scratch = ([_VMEM((block_c,), jnp.float32)] * 3 if _VMEM is not None
               else [pl.ANY] * 3)
    fill, sumf, pf = pl.pallas_call(
        functools.partial(_kernel_batched, n_blocks=n_blocks),
        grid=(B, Ncp // block_c, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block_c, block_n), lambda bi, ci, ji: (bi, ci, ji)),
            pl.BlockSpec((1, 1), lambda bi, ci, ji: (bi, 0)),
            pl.BlockSpec((1, block_n), lambda bi, ci, ji: (bi, ji)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_c, block_n), lambda bi, ci, ji: (bi, ci, ji)),
            pl.BlockSpec((1, block_c), lambda bi, ci, ji: (bi, ci)),
            pl.BlockSpec((1, block_c), lambda bi, ci, ji: (bi, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Ncp, Np), inc.dtype),
            jax.ShapeDtypeStruct((B, Ncp), jnp.float32),
            jax.ShapeDtypeStruct((B, Ncp), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(inc_p, spare_arr, p_p)
    return fill[:, :Nc, :N], sumf[:, :Nc], pf[:, :Nc]
