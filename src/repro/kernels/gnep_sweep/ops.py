"""Jit'd wrapper + plug-in for repro.core.game.rm_solve(sweep_fn=...)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gnep_sweep.kernel import rm_sweep, rm_sweep_batched
from repro.kernels.gnep_sweep.ref import reference, reference_batched


def sweep(inc, spare, p_sorted, *, force_pallas=False):
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return rm_sweep(inc.astype(jnp.float32), spare,
                        p_sorted.astype(jnp.float32),
                        interpret=not on_tpu)
    return reference(inc, spare, p_sorted)


@functools.lru_cache(maxsize=None)
def make_sweep_fn(force_pallas=False):
    # memoized: sweep_fn is a *static* jit argument compared by identity in
    # the game solvers, so returning the same object per config keeps
    # repeated solves on the compiled program instead of retracing.
    def fn(inc, spare, p_sorted):
        return sweep(inc, spare, p_sorted, force_pallas=force_pallas)
    # distinct per config: SolverConfig.fingerprint() records this name
    fn.__name__ = f"gnep_sweep(force_pallas={force_pallas})"
    return fn


def sweep_batched(inc, spare, p_sorted, *, force_pallas=False):
    """Batched sweep for ``solve_distributed_batch(sweep_fn=...)``:
    (B, Nc, N) x (B,) x (B, N) -> one kernel launch on TPU, jnp ref off it."""
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return rm_sweep_batched(inc.astype(jnp.float32),
                                spare.astype(jnp.float32),
                                p_sorted.astype(jnp.float32),
                                interpret=not on_tpu)
    return reference_batched(inc, spare, p_sorted)


@functools.lru_cache(maxsize=None)
def make_batched_sweep_fn(force_pallas=False):
    # memoized for the same jit-cache reason as make_sweep_fn: every
    # `solve_batch(..., sweep_fn=make_batched_sweep_fn())` epoch must reuse
    # one function object or the whole batched solver recompiles.
    def fn(inc, spare, p_sorted):
        return sweep_batched(inc, spare, p_sorted, force_pallas=force_pallas)
    # distinct per config: SolverConfig.fingerprint() records this name
    fn.__name__ = f"gnep_sweep_batched(force_pallas={force_pallas})"
    return fn
