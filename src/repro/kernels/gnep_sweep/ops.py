"""Jit'd wrapper + plug-in for repro.core.game.rm_solve(sweep_fn=...)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gnep_sweep.kernel import rm_sweep
from repro.kernels.gnep_sweep.ref import reference


def sweep(inc, spare, p_sorted, *, force_pallas=False):
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return rm_sweep(inc.astype(jnp.float32), spare,
                        p_sorted.astype(jnp.float32),
                        interpret=not on_tpu)
    return reference(inc, spare, p_sorted)


def make_sweep_fn(force_pallas=False):
    def fn(inc, spare, p_sorted):
        return sweep(inc, spare, p_sorted, force_pallas=force_pallas)
    return fn
