"""Oracle for the GNEP RM candidate-price sweep (paper problem P5 inner loop).

Given ``inc`` (Nc candidate prices x N classes, already permuted into
p-descending greedy order) and the slack capacity ``spare``, compute for each
candidate row the greedy knapsack fill, its total, and its p-weighted total.
"""
import jax.numpy as jnp


def reference(inc, spare, p_sorted):
    """inc: (Nc, N); spare: scalar; p_sorted: (N,).

    Returns (fill (Nc,N), sum_fill (Nc,), p_fill (Nc,))."""
    cum = jnp.cumsum(inc, axis=1)
    fill = jnp.clip(spare - (cum - inc), 0.0, inc)
    return fill, jnp.sum(fill, axis=1), fill @ p_sorted


def reference_batched(inc, spare, p_sorted):
    """inc: (B, Nc, N); spare: (B,); p_sorted: (B, N).

    Returns (fill (B,Nc,N), sum_fill (B,Nc), p_fill (B,Nc))."""
    cum = jnp.cumsum(inc, axis=-1)
    fill = jnp.clip(spare[:, None, None] - (cum - inc), 0.0, inc)
    return (fill, jnp.sum(fill, axis=-1),
            jnp.einsum("bcn,bn->bc", fill, p_sorted))
