"""Oracle for the WKV6 kernel: exact per-step recurrence."""
from repro.models.rwkv import wkv_recurrent


def reference(r, k, v, w_log, u, S0):
    """r/k/v/w_log: (B,T,H,K); u: (H,K); S0: (B,H,K,V) -> (y, S_final)."""
    return wkv_recurrent(r, k, v, w_log, u, S0)
