"""Jit'd public wrapper for the WKV6 kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6
from repro.kernels.rwkv6.ref import reference


def wkv(r, k, v, w_log, u, *, chunk=64, force_pallas=False):
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return wkv6(r, k, v, w_log, u, chunk=chunk, interpret=not on_tpu)
    B, H = r.shape[0], r.shape[2]
    K, V = r.shape[3], v.shape[3]
    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    return reference(r, k, v, w_log, u, S0)
