"""Chunked RWKV6 WKV as a Pallas TPU kernel.

Grid: (B, H, T/L) — the chunk axis is sequential; the (K x V) state lives in
VMEM scratch and is carried across chunks.  Within a chunk the recurrence is
evaluated in the matmul ("chunked linear attention") form so the MXU does the
work: one (L x L) intra-chunk attention matmul + two (L x K)@(K x V) matmuls
per chunk, with log-space cumulative decays clamped at +-30 (DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

CLAMP = 30.0


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_scr, *,
            chunk, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)            # (L, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)            # (L, V)
    w = w_ref[0, 0].astype(jnp.float32)            # (L, K) log decay <= 0
    u = u_ref[0].astype(jnp.float32)               # (K,)
    S = s_scr[...]                                 # (K, V)

    LW = jnp.cumsum(w, axis=0)
    LWp = LW - w                                   # LW_{t-1}
    Z = LW[chunk // 2][None, :]
    Q = r * jnp.exp(jnp.clip(LWp - Z, -CLAMP, CLAMP))
    Kf = k * jnp.exp(jnp.clip(Z - LW, -CLAMP, CLAMP))
    A = jax.lax.dot_general(Q, Kf, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(mi < li, A, 0.0)                 # strictly lower triangular
    diag = jnp.sum(r * u[None, :] * k, axis=1)     # (L,)
    inter = jax.lax.dot_general(r * jnp.exp(LWp), S,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = (jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + diag[:, None] * v + inter)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    LW_end = LW[-1]                                # (K,)
    K2 = k * jnp.exp(LW_end[None, :] - LW)         # exponent <= 0
    s_scr[...] = (jnp.exp(LW_end)[:, None] * S
                  + jax.lax.dot_general(K2, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(ci == n_chunks - 1)
    def _final():
        s_out_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w_log, u, *, chunk=64, interpret=False):
    """r/k/v/w_log: (B,T,H,K); u: (H,K) -> (y (B,T,H,V), S (B,H,K,V)).

    Zero initial state (prefill/train form; the decode step is a single
    jnp expression and needs no kernel).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0
    n = T // chunk

    def to_bhtk(x):
        return jnp.swapaxes(x, 1, 2)               # (B,H,T,K)

    args = [to_bhtk(x) for x in (r, k, v, w_log)]
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n)
    scratch = ([_VMEM((K, V), jnp.float32)] if _VMEM is not None
               else [pl.ANY])
    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:
            pass
    y, S = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(*args, u)
    return jnp.swapaxes(y, 1, 2), S
