"""Jit'd wrapper + plug-in for ``SolverConfig.iter_fn`` / game ``iter_fn=``.

``make_fused_iter_fn()`` returns the memoized :class:`FusedIterFn` object
the batched solvers accept as their ``iter_fn`` plug point: ``prepare``
hoists the iteration-invariant tensors out of the while_loop and ``step``
runs one fused Alg. 4.1 inner iteration.  Off-TPU the fused middle is the
pure-jnp formulation of ``ref.py`` (already one fused XLA region — the
win over the unfused chain is the hoisted prep and, under
``dtype_policy="f32_checked"``, the halved element width); on TPU (or
with ``force_pallas=True``, which tests use in interpret mode) the
O(B x Nc x N) middle is the single Pallas launch of ``kernel.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gnep_iter import ref
from repro.kernels.gnep_iter.kernel import fused_iter_sweep


def _middle_pallas(prep: ref.IterPrep, cand, bids_sorted):
    """Pallas middle for ``ref.iter_step``: one launch, then the best-row
    pick.  TPU computes in f32 (no f64 VMEM); off-TPU interpret mode
    keeps the input dtype so the f64 differential tests stay exact.  The
    best-row pick is a one-hot contraction, honoring ``iter_step``'s
    no-gather invariant (the contraction has one nonzero per row, so it
    moves the kernel's bits unchanged)."""
    on_tpu = jax.default_backend() == "tpu"
    dt = bids_sorted.dtype

    def cast(x):
        return x.astype(jnp.float32) if on_tpu else x

    fill, _, best, rho = fused_iter_sweep(
        cast(bids_sorted), cast(prep.inc_max_sorted), cast(prep.p_sorted),
        cast(cand), cast(prep.spare), cast(prep.rho_bar),
        cast(prep.sum_r_low), cast(prep.p_r_low), cast(prep.const),
        interpret=not on_tpu)
    best_onehot = best[:, None] == jnp.arange(fill.shape[1])
    fill_best = jnp.sum(jnp.where(best_onehot[:, :, None], fill, 0.0), axis=1)
    return fill_best.astype(dt), best, rho.astype(dt)


class FusedIterFn:
    """The ``iter_fn`` plug-point object of the batched Alg. 4.1 solvers.

    Hashable by identity and carrying a stable ``__name__`` — it is a
    *static* jit argument in ``game._solve_batch_jit`` and a cache key in
    the sharded solvers, and ``SolverConfig.fingerprint()`` records the
    name.  Always obtain instances via :func:`make_fused_iter_fn` (which
    memoizes per config) so repeated solves reuse one compiled program.

    Parameters
    ----------
    name : str
        Stable identifier recorded in the config fingerprint.
    middle_fn : callable or None
        Override of the O(B x Nc x N) middle passed through to
        ``ref.iter_step`` (None = pure-jnp reference middle).
    """

    def __init__(self, name: str, middle_fn=None):
        self.__name__ = name
        self._middle_fn = middle_fn

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"<FusedIterFn {self.__name__}>"

    def prepare(self, scns, mask) -> ref.IterPrep:
        """Hoist the iteration-invariant prep (see ``ref.prepare``).

        Parameters
        ----------
        scns : Scenario
            Stacked scenario leaves of the batch being solved.
        mask : jnp.ndarray
            (B, n_max) class-validity mask.

        Returns
        -------
        IterPrep
            Invariants to close over the while_loop body.
        """
        return ref.prepare(scns, mask)

    def step(self, prep, scns, mask, r, bids, lam):
        """One fused Alg. 4.1 inner iteration (see ``ref.iter_step``).

        Parameters
        ----------
        prep : IterPrep
            Invariants from :meth:`prepare`.
        scns : Scenario
            Stacked scenario leaves of the batch being solved.
        mask : jnp.ndarray
            (B, n_max) class-validity mask.
        r : jnp.ndarray
            (B, n_max) current allocation.
        bids : jnp.ndarray
            (B, n_max) current CM bids.
        lam : float
            Bid-escalation step.

        Returns
        -------
        tuple
            ``(r_new, rho, bids_new, eps)`` as in ``ref.iter_step``.
        """
        return ref.iter_step(prep, scns, mask, r, bids, lam,
                             middle_fn=self._middle_fn)


@functools.lru_cache(maxsize=None)
def make_fused_iter_fn(force_pallas: bool = False) -> FusedIterFn:
    """Build (and memoize) the fused-iteration plug-in for the solvers.

    Memoized for the same jit-cache reason as
    ``gnep_sweep.ops.make_batched_sweep_fn``: ``iter_fn`` is a static jit
    argument compared by identity, so every solve must see the same
    object per config or the whole batched solver retraces.

    Parameters
    ----------
    force_pallas : bool, optional
        Route the middle through the Pallas kernel even off-TPU (runs in
        interpret mode; the differential kernel tests use this).  The
        default picks Pallas on TPU and the fused jnp middle elsewhere.

    Returns
    -------
    FusedIterFn
        The plug-point object for ``SolverConfig(iter_fn=...)`` /
        ``solve_distributed_batch(iter_fn=...)``.
    """
    on_tpu = jax.default_backend() == "tpu"
    middle = _middle_pallas if (on_tpu or force_pallas) else None
    return FusedIterFn(f"gnep_iter(force_pallas={force_pallas})", middle)
