"""Bit-authoritative reference for the fused Alg. 4.1 inner iteration.

One best-reply iteration of the paper's distributed algorithm is, per lane:
the RM price sweep (problem P5: candidate build -> greedy fill -> objective
-> argmax), the CM best responses (Prop. 4.1 closed form) and the bid
escalation (Alg. 4.1 lines 11-13).  ``repro.core.game._solve_batch_core``
runs that as a chain of vmapped jnp ops, re-deriving the greedy sort order
and every other iteration-invariant quantity inside the while-loop body.

This module is the *fused* formulation the Pallas kernel implements:

* :func:`prepare` hoists everything Algorithm 4.1 never changes across
  iterations (the p-descending greedy permutation and its inverse, the
  permuted fill increments, the slack capacity, the r_low aggregates and
  the constant objective term) into one :class:`IterPrep`, computed once
  per solve *outside* the while_loop;
* :func:`iter_step` is one full inner iteration over the whole batch —
  candidate build, sweep, pick, psi, bid update and the per-lane eps
  check.  Its middle is a single running-sum scan over the class axis
  (the kernel's VMEM-scratch algorithm written in jnp): each column
  updates the per-candidate accumulators ``cum`` / ``sum_fill`` /
  ``p_fill`` in place, so the O(B x Nc x N) ``inc`` / ``fill`` tensors
  of the unfused chain are never materialized, and the winning lane's
  fill row is recomputed exactly afterwards (scan rows are independent,
  so the recomputation is bitwise the row the scan would have emitted).

Numerics contract (``tests/test_fused_iter.py`` enforces both sides):

* the Pallas kernel is bit-equal (f64, interpret mode) to this module at
  ANY tiling — the kernel's per-column tile loop seeded from its scratch
  carries reproduces the scan's accumulation order exactly, which is the
  point of making the scan the reference;
* against the *unfused* dispatch chain the fused path reorders the
  prefix-sum reductions (running scan vs ``jnp.cumsum``/``@``), so f64
  trajectories agree to float rounding, not bitwise — converged
  equilibria match within tight tolerance and the harness pins that
  bound.

One structural rule holds throughout ``iter_step``: the loop body is
*gather-free*.  Permutation moves and winner picks are one-hot masked
sums (bit-exact: one nonzero per row), never ``take_along_axis`` —
gathers composed with the column scan miscompile inside ``while_loop``
under ``shard_map`` on CPU (jax 0.4.37), producing wrong lanes on every
device but the first.  ``tests/test_fused_iter.py`` pins the fused-mesh
trajectory bitwise against the unsharded one as the regression guard.

``iter_step``'s middle is replaceable via ``middle_fn`` — that is where
``repro.kernels.gnep_iter.kernel.fused_iter_sweep`` plugs in; everything
around it stays pure jnp.  This file is the authority: the kernel is
correct exactly when it matches these functions.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.game import _lane_eps, cm_best_response, cm_bid_update


class IterPrep(NamedTuple):
    """Iteration-invariant tensors of the fused Alg. 4.1 inner loop.

    Everything here depends only on the scenario batch and its mask — the
    while_loop state (bids, r) never feeds any of it, so it is computed
    once per solve and closed over by the loop body.

    Attributes
    ----------
    order : jnp.ndarray
        (B, N) p-descending greedy fill permutation (stable argsort of
        ``-p_eff``; padded classes sort last).
    inv : jnp.ndarray
        (B, N) inverse of ``order`` (undoes the greedy permutation).
    mask_sorted : jnp.ndarray
        (B, N) validity mask carried through ``order``.
    inc_max_sorted : jnp.ndarray
        (B, N) per-class fill headroom ``r_up - r_low`` (0 when masked),
        in greedy order.
    p_sorted : jnp.ndarray
        (B, N) masked unit penalty-rates ``p`` in greedy order.
    spare : jnp.ndarray
        (B,) slack capacity ``R - sum(r_low)`` shared by every candidate.
    r_low_eff : jnp.ndarray
        (B, N) masked guaranteed allocation (slot order).
    sum_r_low : jnp.ndarray
        (B,) total guaranteed allocation.
    p_r_low : jnp.ndarray
        (B,) p-weighted guaranteed allocation.
    const : jnp.ndarray
        (B,) constant objective term ``sum(p * r_up)`` of (P5).
    rho_bar : jnp.ndarray
        (B,) on-demand floor price (the objective's reference price).
    order_onehot : jnp.ndarray
        (B, N, N) bool one-hot of ``order`` — ``iter_step`` applies the
        greedy permutation as a contraction with this matrix instead of a
        gather (see the no-gather note in :func:`iter_step`).  O(B N^2)
        bool, cheap at the paper's class counts.
    inv_onehot : jnp.ndarray
        (B, N, N) bool one-hot of ``inv`` (the inverse permutation),
        same role.
    """
    order: jnp.ndarray
    inv: jnp.ndarray
    mask_sorted: jnp.ndarray
    inc_max_sorted: jnp.ndarray
    p_sorted: jnp.ndarray
    spare: jnp.ndarray
    r_low_eff: jnp.ndarray
    sum_r_low: jnp.ndarray
    p_r_low: jnp.ndarray
    const: jnp.ndarray
    rho_bar: jnp.ndarray
    order_onehot: jnp.ndarray
    inv_onehot: jnp.ndarray


def prepare(scns, mask) -> IterPrep:
    """Hoist the iteration-invariant prep of the Alg. 4.1 inner loop.

    Mirrors ``game._rm_candidates`` / ``game._rm_pick`` exactly for the
    quantities that do not depend on the bids (same ops, same reduction
    order), so only the middle's prefix-sum restructuring separates the
    fused trajectory from the unfused one.

    Parameters
    ----------
    scns : Scenario
        Stacked scenario leaves ((B, n_max) per class, (B,) scalars).
    mask : jnp.ndarray
        (B, n_max) class-validity mask.

    Returns
    -------
    IterPrep
        The invariants, ready to close over the while_loop body.
    """
    n = mask.shape[1]
    p_eff = jnp.where(mask, scns.p, 0.0)
    order = jnp.argsort(-p_eff, axis=1)
    inv = jnp.argsort(order, axis=1)
    inc_max = jnp.where(mask, scns.r_up - scns.r_low, 0.0)
    r_low_eff = jnp.where(mask, scns.r_low, 0.0)
    take = jnp.take_along_axis
    return IterPrep(
        order=order,
        inv=inv,
        mask_sorted=take(mask, order, axis=1),
        inc_max_sorted=take(inc_max, order, axis=1),
        p_sorted=take(p_eff, order, axis=1),
        spare=scns.R - jnp.sum(r_low_eff, axis=1),
        r_low_eff=r_low_eff,
        sum_r_low=jnp.sum(r_low_eff, axis=1),
        p_r_low=jnp.sum(p_eff * r_low_eff, axis=1),
        const=jnp.sum(p_eff * jnp.where(mask, scns.r_up, 0.0), axis=1),
        rho_bar=scns.rho_bar,
        order_onehot=order[:, :, None] == jnp.arange(n)[None, None, :],
        inv_onehot=inv[:, :, None] == jnp.arange(n)[None, None, :])


def _columns(prep: IterPrep, bids_sorted):
    """Class-major views of the per-class scan inputs ((N, B) each).

    Masked-out (padded) classes carry ``inc_max_sorted == 0``, so their
    columns contribute exactly ``0.0`` to every accumulator — the scan
    needs no explicit mask term.
    """
    return (jnp.moveaxis(bids_sorted, 1, 0),
            jnp.moveaxis(prep.inc_max_sorted, 1, 0),
            jnp.moveaxis(prep.p_sorted, 1, 0))


def _scan_accumulators(prep: IterPrep, cand, bids_sorted):
    """Run the per-class running-sum scan; return the final accumulators.

    One :func:`jax.lax.scan` step per greedy-ordered class column ``j``:
    admit (``bid_j >= cand``), advance the running admitted sum ``cum``,
    clip the column's fill against the remaining slack, and fold it into
    ``sum_fill`` / ``p_fill``.  No per-column outputs are emitted — the
    O(B x Nc x N) ``fill`` tensor never exists.

    Returns
    -------
    tuple
        ``(cum, sum_fill, p_fill)``, each (B, Nc), after all N columns.
    """
    zeros = jnp.zeros(cand.shape, cand.dtype)

    def step(carry, col):
        cum, sacc, pacc = carry
        b_j, im_j, p_j = col
        inc = jnp.where(b_j[:, None] >= cand, im_j[:, None], 0.0)
        cum = cum + inc
        fill = jnp.clip(prep.spare[:, None] - (cum - inc), 0.0, inc)
        return (cum, sacc + fill, pacc + fill * p_j[:, None]), None

    carries, _ = jax.lax.scan(step, (zeros, zeros, zeros),
                              _columns(prep, bids_sorted))
    return carries


def _objective(prep: IterPrep, cand, sum_fill, p_fill):
    """The (P5) objective of every candidate from the scan accumulators."""
    return ((cand - prep.rho_bar[:, None])
            * (prep.sum_r_low[:, None] + sum_fill)
            + (prep.p_r_low[:, None] + p_fill) - prep.const[:, None])


def _fill_row(prep: IterPrep, rho, bids_sorted):
    """Recompute the winning candidate's fill row ((B, N), greedy order).

    Scan rows are independent (each candidate's accumulators never read
    another's), so replaying the column recurrence for the single price
    ``rho`` reproduces bitwise the row the full scan would have emitted.
    """
    def step(cum, col):
        b_j, im_j, p_j = col
        inc = jnp.where(b_j >= rho, im_j, 0.0)
        cum = cum + inc
        fill = jnp.clip(prep.spare - (cum - inc), 0.0, inc)
        return cum, fill

    B = bids_sorted.shape[0]
    _, fill = jax.lax.scan(step, jnp.zeros((B,), bids_sorted.dtype),
                           _columns(prep, bids_sorted))
    return jnp.moveaxis(fill, 0, 1)


def middle_reference(prep: IterPrep, cand, bids_sorted):
    """The O(B x Nc x N) middle of one iteration: fill -> objective -> pick.

    This is the region the Pallas kernel
    (``repro.kernels.gnep_iter.kernel.fused_iter_sweep``) replaces: the
    candidate admission pattern, the greedy running-sum fill, the (P5)
    objective and its argmax — everything whose cost scales with the
    candidate axis.  Unlike the production middle (which keeps only the
    accumulators), this diagnostic variant also materializes the full
    per-candidate ``fill`` tensor — column by column, in the exact scan
    order — so the differential kernel tests can compare the kernel's
    full ``fill``/``obj`` outputs bitwise.

    Parameters
    ----------
    prep : IterPrep
        Invariants from :func:`prepare`.
    cand : jnp.ndarray
        (B, Nc) candidate prices (all bids + the (P5e) interval ends).
    bids_sorted : jnp.ndarray
        (B, N) effective bids in greedy order.

    Returns
    -------
    fill : jnp.ndarray
        (B, Nc, N) greedy slack fill of every candidate (greedy order).
    obj : jnp.ndarray
        (B, Nc) the (P5) objective of every candidate.
    best : jnp.ndarray
        (B,) winning candidate index (first argmax, like ``jnp.argmax``).
    rho : jnp.ndarray
        (B,) winning candidate price.
    """
    zeros = jnp.zeros(cand.shape, cand.dtype)

    def step(carry, col):
        cum, sacc, pacc = carry
        b_j, im_j, p_j = col
        inc = jnp.where(b_j[:, None] >= cand, im_j[:, None], 0.0)
        cum = cum + inc
        fill = jnp.clip(prep.spare[:, None] - (cum - inc), 0.0, inc)
        return (cum, sacc + fill, pacc + fill * p_j[:, None]), fill

    (_, sum_fill, p_fill), fill_cols = jax.lax.scan(
        step, (zeros, zeros, zeros), _columns(prep, bids_sorted))
    fill = jnp.moveaxis(fill_cols, 0, 2)
    obj = _objective(prep, cand, sum_fill, p_fill)
    best = jnp.argmax(obj, axis=1)
    rho = jnp.take_along_axis(cand, best[:, None], axis=1)[:, 0]
    return fill, obj, best, rho


def iter_step(prep: IterPrep, scns, mask, r, bids, lam,
              middle_fn: Optional[Callable] = None):
    """One full Alg. 4.1 inner iteration over the batch (the fused body).

    Candidate build -> (middle: fill/objective/argmax) -> allocation
    un-permute -> CM best responses -> bid escalation -> per-lane eps.
    With ``middle_fn=None`` the middle is the running column scan
    (:func:`_scan_accumulators` + the exact winning-row replay of
    :func:`_fill_row`); passing the Pallas middle changes only where the
    O(B x Nc x N) region runs — both orders of accumulation are
    identical, so the swap is bitwise invisible.

    Parameters
    ----------
    prep : IterPrep
        Invariants from :func:`prepare` (computed outside the loop).
    scns : Scenario
        Stacked scenario leaves (the per-class/scalar batch layout).
    mask : jnp.ndarray
        (B, n_max) class-validity mask.
    r : jnp.ndarray
        (B, n_max) current allocation (eps is measured against it).
    bids : jnp.ndarray
        (B, n_max) current CM bids.
    lam : float
        Bid-escalation step of ``game.cm_bid_update``.
    middle_fn : callable, optional
        Override of the fill/objective/argmax middle,
        ``middle_fn(prep, cand, bids_sorted) -> (fill_best, best, rho)``
        — the Pallas kernel plugs in here.  ``None`` runs the jnp
        reference middle.

    Returns
    -------
    r_new : jnp.ndarray
        (B, n_max) RM allocation of this iteration.
    rho : jnp.ndarray
        (B,) RM price posted this iteration.
    bids_new : jnp.ndarray
        (B, n_max) escalated bids.
    eps : jnp.ndarray
        (B,) per-lane relative allocation change vs ``r``.
    """
    # No-gather invariant: every indexed move in this body is a one-hot
    # contraction (or masked sum), never ``take_along_axis``.  Gathers
    # composed with the column scan miscompile inside while_loop under
    # shard_map on CPU (jax 0.4.37, check_rep=False): every device but
    # the first computes wrong lanes.  Each one-hot row has exactly one
    # nonzero and fills are finite, so the contractions move the exact
    # same values, bit for bit.
    bids_eff = jnp.where(mask, bids, scns.rho_bar[:, None])
    cand = jnp.concatenate(
        [bids_eff, scns.rho_bar[:, None], scns.rho_hat[:, None]], axis=1)
    bids_sorted = jnp.sum(
        jnp.where(prep.order_onehot, bids_eff[:, None, :], 0.0), axis=2)

    if middle_fn is None:
        _, sum_fill, p_fill = _scan_accumulators(prep, cand, bids_sorted)
        obj = _objective(prep, cand, sum_fill, p_fill)
        best = jnp.argmax(obj, axis=1)
        rho = jnp.sum(jnp.where(best[:, None] == jnp.arange(cand.shape[1]),
                                cand, 0.0), axis=1)
        fill_best = _fill_row(prep, rho, bids_sorted)
    else:
        fill_best, best, rho = middle_fn(prep, cand, bids_sorted)

    r_new = prep.r_low_eff + jnp.sum(
        jnp.where(prep.inv_onehot, fill_best[:, None, :], 0.0), axis=2)

    psi, _, _ = jax.vmap(lambda s, rr, m: cm_best_response(s, rr, mask=m)
                         )(scns, r_new, mask)
    bids_new = jax.vmap(
        lambda s, b, rh, ps, m: cm_bid_update(s, b, rh, ps, lam, mask=m)
    )(scns, bids, rho, psi, mask)
    eps = jax.vmap(_lane_eps)(r_new, r, mask)
    return r_new, rho, bids_new, eps
