"""Fused Alg. 4.1 iteration middle, tiled: build -> fill -> objective -> pick.

``gnep_sweep`` tiles only the greedy fill of an *already materialized*
``inc`` tensor; every iteration of the batched solver still pays a chain
of jnp dispatches around it (admission pattern, objective, argmax,
gathers).  This kernel fuses the whole O(B x Nc x N) middle of one
Alg. 4.1 inner iteration into ONE launch over grid ``(B, Nc/BC, N/BN)``:

* the candidate admission pattern ``y = bids >= cand`` and the increment
  tensor ``inc = y * inc_max`` are built *inside* the kernel from the
  (B, N) bid vector — the (B, Nc, N) tensor never round-trips through HBM;
* the greedy running-sum fill reuses the ``gnep_sweep`` VMEM scratch
  pattern: the class axis is sequential and carries per-candidate
  ``cum`` / ``sum_fill`` / ``p_fill`` accumulators across class tiles,
  and *within* a tile the columns advance one at a time (a fori_loop
  seeded from the scratch carries) — exactly the column recurrence of
  ``ref._scan_accumulators``, so every accumulator sees the same
  additions in the same order at ANY ``(block_c, block_n)`` tiling;
* at the last class tile the (P5) objective of the candidate tile is
  formed from the accumulators and folded into a running argmax scratch
  (best objective / index / price) carried across the *candidate* axis,
  so the winning candidate leaves the kernel as two scalars per lane.

A strictly-greater comparison across candidate tiles reproduces
``jnp.argmax``'s first-maximum semantics exactly; padded candidate
columns replicate the last real candidate (the (P5e) interval end
``rho_hat``) so a padded duplicate can never *strictly* beat the real
column it copies, and padded class columns expose ``inc_max = 0`` so they
are inert in the fill.  All arithmetic runs in the input dtype: off-TPU
(interpret mode) the f64 kernel is bit-equal to
``repro.kernels.gnep_iter.ref`` at any tiling; the TPU path is f32 (see
``ops.py``).  The per-column inner loop trades VPU width for that exact
conformance — the class axis is short (N classes) in every paper
workload, so the trade is cheap.

The psi / bid-update / eps epilogue of the iteration stays jnp (it is
O(B x N) and fuses into the surrounding while-loop body for free); see
``ref.iter_step`` for the exact seam.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(bids_ref, incm_ref, p_ref, cand_ref, scal_ref,
            fill_ref, obj_ref, best_ref, rho_ref,
            cum_scr, sacc_scr, pacc_scr, bobj_scr, brho_scr, bidx_scr,
            *, n_cblocks, n_blocks, block_c, block_n):
    ci = pl.program_id(1)
    ji = pl.program_id(2)

    @pl.when((ci == 0) & (ji == 0))
    def _init_best():
        bobj_scr[...] = jnp.full_like(bobj_scr, -jnp.inf)
        brho_scr[...] = jnp.zeros_like(brho_scr)
        bidx_scr[...] = jnp.zeros_like(bidx_scr)

    @pl.when(ji == 0)
    def _init_acc():
        cum_scr[...] = jnp.zeros_like(cum_scr)
        sacc_scr[...] = jnp.zeros_like(sacc_scr)
        pacc_scr[...] = jnp.zeros_like(pacc_scr)

    bids = bids_ref[0]                                # (BN,)
    incm = incm_ref[0]                                # (BN,)
    pv = p_ref[0]                                     # (BN,)
    cand = cand_ref[0]                                # (BC,)
    spare = scal_ref[0, 0]
    rho_bar = scal_ref[0, 1]
    sum_r_low = scal_ref[0, 2]
    p_r_low = scal_ref[0, 3]
    const = scal_ref[0, 4]

    # Column-by-column greedy fill, seeded from the cross-tile carries.
    # This is ref._scan_accumulators' recurrence verbatim: admit
    # (masked classes have incm = 0 so the validity mask is already
    # folded in), advance the running admitted sum, clip against the
    # remaining slack, fold into the sum/p accumulators.  Sequential
    # per-column adds keep the accumulation order identical to the
    # reference at any tiling.
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (block_c, block_n), 1)
    zero = jnp.zeros((), incm.dtype)

    def _column(j, carry):
        cum, sacc, pacc, fill_acc = carry
        inc = jnp.where(bids[j] >= cand, incm[j], zero)       # (BC,)
        cum = cum + inc
        fill = jnp.clip(spare - (cum - inc), 0.0, inc)
        fill_acc = jnp.where(col_ids == j, fill[:, None], fill_acc)
        return cum, sacc + fill, pacc + fill * pv[j], fill_acc

    cum, sacc, pacc, fill_tile = jax.lax.fori_loop(
        0, block_n, _column,
        (cum_scr[...], sacc_scr[...], pacc_scr[...],
         jnp.zeros((block_c, block_n), incm.dtype)))
    fill_ref[0] = fill_tile.astype(fill_ref.dtype)
    cum_scr[...] = cum
    sacc_scr[...] = sacc
    pacc_scr[...] = pacc

    @pl.when(ji == n_blocks - 1)
    def _pick():
        # (P5) objective of this candidate tile, then fold into the
        # running argmax.  Strictly-greater keeps the earliest maximum,
        # matching jnp.argmax across tile boundaries (and jnp.argmax
        # itself supplies first-max semantics inside the tile).
        obj = ((cand - rho_bar) * (sum_r_low + sacc_scr[...])
               + (p_r_low + pacc_scr[...]) - const)
        obj_ref[0] = obj.astype(obj_ref.dtype)
        tile_best = jnp.argmax(obj)
        tile_max = jnp.max(obj)
        better = tile_max > bobj_scr[0]
        idx = (ci * block_c + tile_best).astype(bidx_scr.dtype)
        bidx_scr[0] = jnp.where(better, idx, bidx_scr[0])
        brho_scr[0] = jnp.where(better, cand[tile_best], brho_scr[0])
        bobj_scr[0] = jnp.maximum(bobj_scr[0], tile_max)

    @pl.when((ci == n_cblocks - 1) & (ji == n_blocks - 1))
    def _final():
        best_ref[0] = bidx_scr[0]
        rho_ref[0] = brho_scr[0]


@functools.partial(jax.jit, static_argnames=("block_c", "block_n",
                                             "interpret"))
def fused_iter_sweep(bids_sorted, inc_max_sorted, p_sorted, cand,
                     spare, rho_bar, sum_r_low, p_r_low, const, *,
                     block_c=128, block_n=512, interpret=False):
    """One-launch fill/objective/argmax middle of an Alg. 4.1 iteration.

    Grid ``(B, Nc/BC, N/BN)``: batch parallel, candidate and class axes
    sequential (both carry scratch).  Inputs are the greedy-order
    invariants of ``ref.prepare`` plus the per-iteration bids/candidates.

    Parameters
    ----------
    bids_sorted : jnp.ndarray
        (B, N) effective bids in greedy (p-descending) order.
    inc_max_sorted : jnp.ndarray
        (B, N) fill headroom per class in greedy order (0 when masked).
    p_sorted : jnp.ndarray
        (B, N) masked unit penalty-rates in greedy order.
    cand : jnp.ndarray
        (B, Nc) candidate prices (bids + the (P5e) interval ends; the
        last column must be the largest-price end ``rho_hat`` — padding
        replicates it).
    spare : jnp.ndarray
        (B,) slack capacity shared by every candidate.
    rho_bar : jnp.ndarray
        (B,) on-demand floor price (objective reference).
    sum_r_low : jnp.ndarray
        (B,) total guaranteed allocation.
    p_r_low : jnp.ndarray
        (B,) p-weighted guaranteed allocation.
    const : jnp.ndarray
        (B,) constant objective term ``sum(p * r_up)``.
    block_c : int, optional
        Candidate-axis tile size.
    block_n : int, optional
        Class-axis tile size.
    interpret : bool, optional
        Run in Pallas interpret mode (the off-TPU path).

    Returns
    -------
    fill : jnp.ndarray
        (B, Nc, N) greedy slack fill of every candidate (greedy order).
    obj : jnp.ndarray
        (B, Nc) the (P5) objective of every candidate.
    best : jnp.ndarray
        (B,) int32 winning candidate index (first maximum).
    rho : jnp.ndarray
        (B,) winning candidate price.
    """
    B, N = bids_sorted.shape
    Nc = cand.shape[1]
    dt = bids_sorted.dtype
    block_c = min(block_c, Nc)
    block_n = min(block_n, N)
    pc = (-Nc) % block_c
    pn = (-N) % block_n
    # candidate padding replicates the last real column (rho_hat): a
    # duplicate ties, never strictly wins, so `best` stays a real index
    cand_p = jnp.pad(cand, ((0, 0), (0, pc)), mode="edge")
    # padded classes are inert: inc_max = 0 kills their fill regardless
    # of how the padded bid compares to any candidate
    bids_p = jnp.pad(bids_sorted, ((0, 0), (0, pn)))
    incm_p = jnp.pad(inc_max_sorted, ((0, 0), (0, pn)))
    p_p = jnp.pad(p_sorted, ((0, 0), (0, pn)))
    Ncp, Np = Nc + pc, N + pn
    n_cblocks = Ncp // block_c
    n_blocks = Np // block_n
    scal = jnp.stack([spare, rho_bar, sum_r_low, p_r_low, const],
                     axis=1).astype(dt)               # (B, 5)

    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary"))
        except Exception:
            pass
    if _VMEM is not None:
        scratch = [_VMEM((block_c,), dt)] * 3 \
            + [_VMEM((1,), dt)] * 2 + [_VMEM((1,), jnp.int32)]
    else:  # pragma: no cover
        scratch = [pl.ANY] * 6
    fill, obj, best, rho = pl.pallas_call(
        functools.partial(_kernel, n_cblocks=n_cblocks, n_blocks=n_blocks,
                          block_c=block_c, block_n=block_n),
        grid=(B, n_cblocks, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda bi, ci, ji: (bi, ji)),
            pl.BlockSpec((1, block_n), lambda bi, ci, ji: (bi, ji)),
            pl.BlockSpec((1, block_n), lambda bi, ci, ji: (bi, ji)),
            pl.BlockSpec((1, block_c), lambda bi, ci, ji: (bi, ci)),
            pl.BlockSpec((1, 5), lambda bi, ci, ji: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_c, block_n),
                         lambda bi, ci, ji: (bi, ci, ji)),
            pl.BlockSpec((1, block_c), lambda bi, ci, ji: (bi, ci)),
            pl.BlockSpec((1,), lambda bi, ci, ji: (bi,)),
            pl.BlockSpec((1,), lambda bi, ci, ji: (bi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Ncp, Np), dt),
            jax.ShapeDtypeStruct((B, Ncp), dt),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), dt),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(bids_p, incm_p, p_p, cand_p, scal)
    return fill[:, :Nc, :N], obj[:, :Nc], best, rho
