"""Training driver: data pipeline -> jitted train step -> checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 200 --global-batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Auto-resumes from the latest checkpoint (fault tolerance: kill it mid-run and
relaunch).  ``--mesh dp,tp`` uses host devices (XLA_FLAGS) for multi-device
runs; default is single-device LOCAL.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config, reduced_config
from repro.data import SyntheticLM
from repro.launch.mesh import dist_for, make_mesh
from repro.launch.steps import jit_train_step, param_shardings
from repro.models import init_params
from repro.models.sharding import LOCAL
from repro.optim import OptConfig, adamw_init


def main(argv=None, cfg_override=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="wsd" if False else "cosine",
                    choices=["cosine", "wsd", "const"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None, help="dp,tp over host devices")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = cfg_override or (reduced_config(args.arch) if args.reduced
                           else get_config(args.arch))
    cfg = cfg.replace(grad_accum=args.grad_accum)
    if args.arch == "minicpm-2b":
        args.schedule = "wsd"        # MiniCPM trains with WSD (DESIGN.md)

    if args.mesh:
        dp, tp = map(int, args.mesh.split(","))
        mesh = make_mesh((dp, tp), ("data", "model"))
        dist = dist_for(mesh, fsdp=cfg.fsdp)
    else:
        dist = LOCAL

    oc = OptConfig(lr=args.lr, schedule=args.schedule,
                   total_steps=args.steps, warmup_steps=min(20, args.steps))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt = adamw_init(params, oc)
    data = SyntheticLM(cfg.vocab, args.seq, args.global_batch,
                       seed=args.seed)

    start = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            shardings = (param_shardings(cfg, params, dist)
                         if dist.mesh is not None else None)
            state, _ = ckpt.restore({"params": params, "opt": opt}, last,
                                    args.ckpt_dir,
                                    shardings={"params": shardings,
                                               "opt": None} if shardings
                                    else None)
            params, opt = state["params"], state["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    batch0 = data(start)
    batch_sds = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0)
    if dist.mesh is not None:
        opt_sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt)
        params_sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        step_fn = jit_train_step(cfg, dist, oc, params_sds, opt_sds,
                                 batch_sds, donate=True)
    else:
        from repro.launch.steps import make_train_step
        step_fn = jax.jit(make_train_step(cfg, dist, oc),
                          donate_argnums=(0, 1))

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, data(step))
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            print(f"[train] step {step+1} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms/step")
            t0 = time.time()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async({"params": params, "opt": opt}, step + 1,
                            args.ckpt_dir)
    if args.ckpt_dir:
        ckpt.wait_pending()
        ckpt.save({"params": params, "opt": opt}, args.steps, args.ckpt_dir)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
