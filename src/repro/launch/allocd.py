"""Admission daemon driver: multi-tenant event load + throughput reporting.

    PYTHONPATH=src python -m repro.launch.allocd --tenants 3 --lanes 3 \
        --classes 4 --events 24 --arrival poisson --rate 500 --conformance

Builds one CapacityEngine, registers N tenant windows with the
AllocDaemon, drives per-tenant random event traces open-loop on a Poisson,
flash-crowd, or diurnal arrival schedule, and reports sustained events/sec
plus p50/p99 admission latency — the allocd counterpart of
``repro.launch.serve``.  ``--conformance`` replays every tenant's trace
through an identically-initialised offline ``WindowSession.stream`` and
asserts the daemon's flush-boundary equilibria are bit-equal.

Server mode (the wire transport; see ``docs/OPERATIONS.md``):

    PYTHONPATH=src python -m repro.launch.allocd --listen 127.0.0.1:8753

serves the daemon over the length-prefixed JSON-frame protocol of
``repro.serving.wire`` instead of driving synthetic local tenants —
remote processes register tenants and submit events with
``repro.serving.client.AllocClient`` (walkthrough:
``examples/wire_client.py``).  ``--quota-events`` / ``--quota-lanes``
set the default per-tenant admission budget applied to wire tenants
that register without one.
"""
from __future__ import annotations

import argparse
import asyncio
import sys

# --resident shards tenant state over a lane mesh; on a bare CPU the forced
# host-device topology must be configured before jax initializes a backend
if "--resident" in sys.argv or "--devices" in sys.argv:
    from repro._env import force_host_devices
    force_host_devices()

import jax
import numpy as np

from repro.core import (AdmissionWindow, CapacityEngine, FlushPolicy,
                        Policies, RoundingPolicy, SolverConfig, lane_mesh,
                        sample_event_trace, sample_scenario)
from repro.core.engine import TenantQuota
from repro.serving.allocd import (ARRIVAL_PROFILES, AllocDaemon,
                                  drive_open_loop, interleave_traces)
from repro.serving.server import AllocServer


def make_engine(args):
    flush = (FlushPolicy.deadline(args.deadline_slack,
                                  max_events=args.flush_every)
             if args.deadline_slack is not None
             else FlushPolicy(max_events=args.flush_every))
    resident = getattr(args, "resident", False)
    devices = getattr(args, "devices", None)
    mesh = lane_mesh(devices) if (resident or devices) else None
    return CapacityEngine(
        SolverConfig(mesh=mesh,
                     residency="resident" if resident else "round-trip"),
        Policies(flush=flush,
                 rounding=RoundingPolicy(enabled=args.round)))


def make_window(args, tenant: int) -> AdmissionWindow:
    key = jax.random.PRNGKey(args.seed)
    lanes = [sample_scenario(jax.random.fold_in(key, tenant * 97 + lane),
                             args.classes, capacity_factor=1.3)
             for lane in range(args.lanes)]
    return AdmissionWindow(lanes, n_max=2 * args.classes)


def make_traces(args):
    return {f"tenant-{t}": sample_event_trace(args.seed + 7919 * t,
                                              make_window(args, t),
                                              args.events)
            for t in range(args.tenants)}


def assert_reports_bitequal(name, got, want):
    assert len(got) == len(want), \
        f"{name}: {len(got)} flushes vs offline {len(want)}"
    for i, (a, b) in enumerate(zip(got, want)):
        la = jax.tree_util.tree_flatten(a.fractional)[0]
        lb = jax.tree_util.tree_flatten(b.fractional)[0]
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"{name}: flush {i} diverged from offline replay")
        np.testing.assert_array_equal(np.asarray(a.mask),
                                      np.asarray(b.mask))


async def run_daemon(engine, args, traces):
    daemon = AllocDaemon(engine, queue_limit=args.queue_limit)
    for t in range(args.tenants):
        daemon.add_tenant(f"tenant-{t}", make_window(args, t))
    total = sum(len(tr) for tr in traces.values())
    times = ARRIVAL_PROFILES[args.arrival](args.seed, total, args.rate)
    schedule = interleave_traces(traces, times)
    await daemon.start()
    tickets = await drive_open_loop(daemon, schedule)
    await daemon.shutdown(drain=True)
    return daemon, tickets


async def run_server(engine, args):
    daemon = AllocDaemon(engine, queue_limit=args.queue_limit)
    quota = None
    if args.quota_events is not None or args.quota_lanes is not None:
        quota = TenantQuota(max_queued=args.quota_events,
                            max_lanes=args.quota_lanes)
    host, _, port = args.listen.rpartition(":")
    server = AllocServer(daemon, host=host or "127.0.0.1", port=int(port),
                         default_quota=quota)
    await server.start()
    print(f"[allocd] listening on {server.address[0]}:{server.address[1]} "
          f"(queue_limit={args.queue_limit}, default quota="
          f"{quota or 'none'})", flush=True)
    try:
        await server._server.serve_forever()
    except asyncio.CancelledError:
        pass
    finally:
        await server.close(drain=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--listen", type=str, default=None, metavar="HOST:PORT",
                    help="serve the daemon over the wire protocol instead "
                         "of driving local synthetic tenants")
    ap.add_argument("--quota-events", type=int, default=None,
                    help="default TenantQuota.max_queued for wire tenants "
                         "registering without a quota")
    ap.add_argument("--quota-lanes", type=int, default=None,
                    help="default TenantQuota.max_lanes for wire tenants "
                         "registering without a quota")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--events", type=int, default=32,
                    help="events per tenant")
    ap.add_argument("--arrival", choices=sorted(ARRIVAL_PROFILES),
                    default="poisson")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="open-loop arrival rate [events/s]")
    ap.add_argument("--flush-every", type=int, default=8)
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="enable FlushPolicy.deadline with this slack [s]")
    ap.add_argument("--queue-limit", type=int, default=4096)
    ap.add_argument("--resident", action="store_true",
                    help="keep tenant window state device-resident on a "
                         "lane mesh across flushes "
                         "(SolverConfig(residency='resident'))")
    ap.add_argument("--devices", type=int, default=None,
                    help="lane-mesh size for --resident / sharded solves "
                         "(default: every addressable device)")
    ap.add_argument("--round", action="store_true",
                    help="run Algorithm 4.2 integerization at every flush")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--conformance", action="store_true",
                    help="assert bit-equality against offline replays")
    args = ap.parse_args(argv)

    if args.listen is not None:
        try:
            asyncio.run(run_server(make_engine(args), args))
        except KeyboardInterrupt:
            pass
        return None

    engine = make_engine(args)
    traces = make_traces(args)
    daemon, _ = asyncio.run(run_daemon(engine, args, traces))
    rep = daemon.report()

    total = int(rep["events_folded"])
    print(f"[allocd] {args.arrival}: {rep['submitted']:.0f} events, "
          f"{args.tenants} tenants -> folded {total} in "
          f"{rep['elapsed_s']:.2f}s "
          f"({rep['events_per_sec']:.1f} ev/s incl. compile)")
    print(f"[allocd] admission latency p50 {rep['admission_p50_ms']:.1f} ms"
          f" / p99 {rep['admission_p99_ms']:.1f} ms; "
          f"flushes {rep['flushes']:.0f}; rejected {rep['rejected']:.0f} "
          f"(penalty {rep['rejection_cost']:.2f})")

    if args.conformance:
        if rep["rejected"]:
            print("[allocd] conformance: SKIPPED (rejections under "
                  "backpressure change the delivered trace)")
        else:
            for name, trace in traces.items():
                t = int(name.split("-")[1])
                offline = engine.open_window(make_window(args, t))
                want = list(offline.stream(trace))
                assert_reports_bitequal(name, daemon.reports(name), want)
            print(f"[allocd] conformance: OK ({args.tenants} tenants "
                  "bit-equal to offline replay)")
    return rep


if __name__ == "__main__":
    main()
