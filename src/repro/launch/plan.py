"""Fleet capacity-planner driver: sweep a design space, print the frontier.

    PYTHONPATH=src python -m repro.launch.plan --classes 4 --profile bursty \
        --cluster-sizes 800,2000,5000 --tiers small:1:6,large:2:10 \
        --deadline-scales 0.8,1.0,1.2

Expands the :class:`repro.core.planning.PlanSpec` grid (cluster sizes x VM
tiers x penalty scalings x deadline tightness, sized against one of the
shared workload-trace profiles), solves every candidate through the
engine's batched Algorithm 4.1 path in fixed-width chunks, and prints the
cheapest feasible design plus the (cost, penalty) Pareto frontier — the
D-SPACE4Cloud loop over the paper's allocator.  ``--shard`` lane-shards the
chunks over a device mesh (on CPU the forced 8-device topology is
configured before jax initializes); ``--warm-start`` seeds each deadline
step from the previous step's equilibrium.  ``--json PATH`` writes the
frontier report machine-readably (see docs/OPERATIONS.md "Capacity
planning").
"""
from __future__ import annotations

import argparse
import json
import sys

# --shard solves on a lane mesh; on a bare CPU the forced host-device
# topology must be configured before jax initializes a backend
if "--shard" in sys.argv or "--devices" in sys.argv:
    from repro._env import force_host_devices
    force_host_devices()

from repro.core import (PlanSpec, SolverConfig, VMTier, lane_mesh,
                        solve_plan)
from repro.core.traces import ARRIVAL_PROFILES


def parse_tier(text: str) -> VMTier:
    """Parse one ``name:slots:price`` tier spec."""
    parts = text.split(":")
    if len(parts) != 3:
        raise argparse.ArgumentTypeError(
            f"tier {text!r} is not name:slots:price")
    return VMTier(parts[0], float(parts[1]), float(parts[2]))


def parse_floats(text: str) -> tuple:
    """Parse a comma-separated float list."""
    return tuple(float(x) for x in text.split(",") if x)


def build_spec(args) -> PlanSpec:
    """The PlanSpec an argparse namespace describes."""
    return PlanSpec(
        n_classes=args.classes, profile=args.profile, rate=args.rate,
        trace_events=args.trace_events,
        cluster_sizes=parse_floats(args.cluster_sizes),
        vm_tiers=tuple(parse_tier(t) for t in args.tiers.split(",") if t),
        deadline_scales=parse_floats(args.deadline_scales),
        penalty_scales=parse_floats(args.penalty_scales),
        seed=args.seed)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--profile", choices=sorted(ARRIVAL_PROFILES),
                    default="poisson",
                    help="workload-trace profile the fleet is sized for")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="mean arrival rate [events/s] of the sizing trace")
    ap.add_argument("--trace-events", type=int, default=512)
    ap.add_argument("--cluster-sizes", type=str, default="1500,3000,6000",
                    help="comma-separated candidate capacities R")
    ap.add_argument("--tiers", type=str, default="small:1:6,large:2:10",
                    help="comma-separated name:slots:price VM tiers")
    ap.add_argument("--deadline-scales", type=str, default="0.8,1.0,1.2",
                    help="comma-separated deadline-tightness multipliers")
    ap.add_argument("--penalty-scales", type=str, default="1.0",
                    help="comma-separated rejection-penalty multipliers")
    ap.add_argument("--chunk", type=int, default=64,
                    help="candidates per solve dispatch (results are "
                         "chunk-independent bit-for-bit)")
    ap.add_argument("--warm-start", action="store_true",
                    help="seed each deadline step from the previous "
                         "step's equilibrium")
    ap.add_argument("--shard", action="store_true",
                    help="lane-shard chunks over a device mesh")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size for --shard (default: all devices)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="write the frontier report as JSON")
    args = ap.parse_args(argv)

    spec = build_spec(args)
    mesh = lane_mesh(args.devices) if (args.shard or args.devices) else None
    cfg = SolverConfig(mesh=mesh)
    report = solve_plan(spec, config=cfg, chunk=args.chunk,
                        warm_start=args.warm_start)

    n_feas = int(report.feasible.sum())
    print(f"[plan] {report.n_candidates} candidates "
          f"({'x'.join(map(str, spec.grid_shape))} grid, "
          f"profile={spec.profile}) solved in {report.elapsed_s:.2f}s "
          f"({report.n_chunks} chunks of {report.chunk}"
          f"{', warm-start' if report.warm_start else ''}"
          f"{', sharded' if mesh is not None else ''})")
    print(f"[plan] {n_feas} feasible / "
          f"{report.n_candidates - n_feas} infeasible")

    cheapest = report.cheapest_feasible()
    if cheapest is None:
        print("[plan] no feasible design in this space — grow the cluster "
              "axis or relax deadlines")
    else:
        p = report.point(cheapest)
        print(f"[plan] cheapest feasible design: R={p['cluster_size']:.0f} "
              f"tier={p['tier']} deadline_scale={p['deadline_scale']} "
              f"penalty_scale={p['penalty_scale']} -> "
              f"cost {p['cost']:.1f} penalty {p['penalty']:.1f}")

    frontier = report.pareto_frontier()
    print(f"[plan] Pareto frontier ({frontier.size} point(s)):")
    for i in frontier:
        p = report.point(int(i))
        print(f"    #{p['index']:>4} R={p['cluster_size']:>7.0f} "
              f"tier={p['tier']:<8} dl={p['deadline_scale']:<4} "
              f"pen_scale={p['penalty_scale']:<4} cost={p['cost']:>10.1f} "
              f"penalty={p['penalty']:>10.1f}")

    if args.json:
        payload = report.to_json()
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[plan] wrote {args.json}")
    return report


if __name__ == "__main__":
    main()
