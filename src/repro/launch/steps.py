"""Jitted step functions + their sharding specs for every cell kind."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, param_specs, prefill)
from repro.models.sharding import Distribution
from repro.optim import OptConfig, adamw_init, adamw_update


# --------------------------------------------------------------------------
# sharding specs
# --------------------------------------------------------------------------

def _ns(dist, spec):
    return NamedSharding(dist.mesh, spec)


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize(shardings, tree, mesh):
    """jit in_shardings require exact divisibility: drop spec axes that do
    not divide the corresponding dim (e.g. odd vocab 122753 on 16-way TP,
    int8 optimizer scale tails).  The dropped dims are replicated — the
    padding waste is reported per-cell in the roofline notes."""
    def one(sh, x):
        spec = tuple(sh.spec)
        spec = spec + (None,) * (x.ndim - len(spec))
        new = tuple(e if x.shape[i] % _axis_size(mesh, e) == 0 else None
                    for i, e in enumerate(spec))
        return NamedSharding(mesh, P(*new))
    return jax.tree_util.tree_map(one, shardings, tree)


def _div(n, dist):
    ts = dist.tp_size()
    return dist.tp if (ts > 1 and n % ts == 0) else None


def batch_specs(cfg, batch_tree, dist: Distribution):
    dp = dist.dp_axes

    def one(path, x):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = keys[-1]
        if name == "mrope_positions":
            return _ns(dist, P(None, dp, None))
        if x.ndim >= 3:                      # embeds / enc_embeds
            return _ns(dist, P(dp, None, None))
        return _ns(dist, P(dp, None))        # tokens / targets
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_specs(cfg, cache_tree, dist: Distribution):
    """KV caches: batch on dp + kv-heads on tp (when divisible); with
    cfg.kv_cache_seq_shard the sequence dim is sharded over the whole mesh
    instead (context-parallel decode — required for long_500k)."""
    dp = dist.dp_axes

    def one(path, x):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        leaf, parent = keys[-1], keys[-2] if len(keys) > 1 else ""
        stacked = keys[0] == "blocks"
        lead = (None,) if stacked else ()
        if parent in ("attn", "cross") or leaf in ("ck", "cv"):
            # (B, S, kv, hd)
            if cfg.kv_cache_seq_shard:
                all_axes = tuple(dp) + ((dist.tp,) if dist.tp else ())
                return _ns(dist, P(*lead, None, all_axes, None, None))
            kv_ax = _div(cfg.n_kv, dist)
            if kv_ax is None and dist.tp is not None:
                # kv heads don't divide TP: shard the sequence over 'model'
                # instead of replicating the cache (context-parallel decode)
                return _ns(dist, P(*lead, dp, dist.tp, None, None))
            return _ns(dist, P(*lead, dp, None, kv_ax, None))
        if leaf == "S":                        # rwkv state (B,H,k,v)
            H = cfg.d_model // cfg.rwkv_head_dim
            return _ns(dist, P(*lead, dp, _div(H, dist), None, None))
        if leaf == "h" and parent == "mamba":  # (B, d_in, N)
            return _ns(dist, P(*lead, dp, _div(cfg.mamba.expand *
                                               cfg.d_model, dist), None))
        if leaf == "conv":                     # (B, dc-1, d_in)
            return _ns(dist, P(*lead, dp, None,
                               _div(cfg.mamba.expand * cfg.d_model, dist)))
        if leaf in ("shift", "cshift"):        # (B, d)
            return _ns(dist, P(*lead, dp, None))
        return _ns(dist, P(*([None] * x.ndim)))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def opt_specs(pspecs, oc: OptConfig, dist: Distribution):
    def one(s):
        if oc.state_dtype == "f32":
            return {"m": s, "v": s, "master": s}
        if oc.state_dtype == "bf16":
            return {"m": s, "v": s}
        return {"m": {"q": s, "scale": s}, "v": {"q": s, "scale": s}}
    mu = jax.tree_util.tree_map(one, pspecs,
                                is_leaf=lambda x: isinstance(x, P))
    return {"mu": mu, "step": P()}


def param_shardings(cfg, params_tree, dist: Distribution):
    specs = param_specs(cfg, params_tree, dist)
    return jax.tree_util.tree_map(lambda s: _ns(dist, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def _stack_micro(batch, n):
    """Reshape every batch leaf (B, ...) -> (n, B/n, ...) for the microbatch
    scan (mrope_positions carries batch on axis 1)."""
    def one(path, x):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if keys[-1] == "mrope_positions":
            r = x.reshape(x.shape[0], n, x.shape[1] // n, *x.shape[2:])
            return jnp.moveaxis(r, 1, 0)
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])
    return jax.tree_util.tree_map_with_path(one, batch)


def make_grad_step(cfg, dist: Distribution, *, loops: str = "scan"):
    """fwd+bwd of one microbatch (no optimizer) — also lowered standalone by
    the dry-run for roofline cost assembly."""
    def step(params, mb):
        def lf(p):
            return loss_fn(cfg, p, mb, dist, loops=loops)
        (loss, metrics), g = jax.value_and_grad(lf, has_aux=True)(params)
        return g, loss, metrics
    return step


def make_opt_step(cfg, oc: OptConfig):
    def step(params, opt_state, grads):
        return adamw_update(params, grads, opt_state, oc)
    return step


def make_train_step(cfg, dist: Distribution, oc: OptConfig, *,
                    loops: str = "scan"):
    """One optimizer step = cfg.grad_accum microbatches via lax.scan (bounds
    activation memory to one microbatch by construction), f32 grad
    accumulation, then AdamW.  Roofline costs are assembled by the dry-run as
    M x grad_step + opt_step (the scan body is counted once by XLA cost
    analysis — DESIGN.md)."""
    M = max(1, cfg.grad_accum)
    gstep = make_grad_step(cfg, dist, loops=loops)
    ostep = make_opt_step(cfg, oc)

    def step(params, opt_state, batch):
        if M == 1:
            g, loss, metrics = gstep(params, batch)
            g32 = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), g)
            params2, opt2, om = ostep(params, opt_state, g32)
            return params2, opt2, {"loss": loss, **metrics, **om}

        stacked = _stack_micro(batch, M)
        g0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(carry, mb):
            grads, loss_sum = carry
            g, loss, _ = gstep(params, mb)
            grads = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), grads, g)
            return (grads, loss_sum + loss), None

        (grads, loss_sum), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32)), stacked)
        grads = jax.tree_util.tree_map(lambda g: g / M, grads)
        params2, opt2, om = ostep(params, opt_state, grads)
        return params2, opt2, {"loss": loss_sum / M, **om}
    return step


def make_prefill_step(cfg, dist: Distribution, *, loops: str = "scan"):
    def step(params, batch):
        return prefill(cfg, params, batch, dist, loops=loops)
    return step


def make_decode_step(cfg, dist: Distribution):
    def step(params, cache, token, pos):
        return decode_step(cfg, params, cache, token, pos, dist)
    return step


def jit_train_step(cfg, dist, oc, params_tree, opt_tree, batch_tree, *,
                   loops="scan", donate=True):
    pspec = param_specs(cfg, params_tree, dist)
    psh = jax.tree_util.tree_map(lambda s: _ns(dist, s), pspec,
                                 is_leaf=lambda x: isinstance(x, P))
    osh = jax.tree_util.tree_map(lambda s: _ns(dist, s),
                                 opt_specs(pspec, oc, dist),
                                 is_leaf=lambda x: isinstance(x, P))
    bsh = batch_specs(cfg, batch_tree, dist)
    psh = sanitize(psh, params_tree, dist.mesh)
    osh = sanitize(osh, opt_tree, dist.mesh)
    bsh = sanitize(bsh, batch_tree, dist.mesh)
    fn = make_train_step(cfg, dist, oc, loops=loops)
    return jax.jit(fn, in_shardings=(psh, osh, bsh),
                   donate_argnums=(0, 1) if donate else ())


def _micro_batch_sds(batch_tree, M):
    def one(path, x):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        ax = 1 if keys[-1] == "mrope_positions" else 0
        shp = list(x.shape)
        shp[ax] //= M
        return jax.ShapeDtypeStruct(tuple(shp), x.dtype)
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def jit_grad_step_micro(cfg, dist, params_tree, batch_tree, M, *,
                        loops="unroll"):
    """Lowered fwd+bwd of ONE microbatch — the dry-run's train cost unit.
    Chunk loops unrolled so FLOPs/collectives are counted exactly."""
    mb = _micro_batch_sds(batch_tree, M)
    pspec = param_specs(cfg, params_tree, dist)
    psh = sanitize(jax.tree_util.tree_map(lambda s: _ns(dist, s), pspec,
                                          is_leaf=lambda x: isinstance(x, P)),
                   params_tree, dist.mesh)
    bsh = sanitize(batch_specs(cfg, mb, dist), mb, dist.mesh)
    fn = make_grad_step(cfg, dist, loops=loops)
    return jax.jit(fn, in_shardings=(psh, bsh)).lower(params_tree, mb)


def jit_opt_step(cfg, dist, oc, params_tree, opt_tree):
    pspec = param_specs(cfg, params_tree, dist)
    psh = sanitize(jax.tree_util.tree_map(lambda s: _ns(dist, s), pspec,
                                          is_leaf=lambda x: isinstance(x, P)),
                   params_tree, dist.mesh)
    osh = sanitize(jax.tree_util.tree_map(lambda s: _ns(dist, s),
                                          opt_specs(pspec, oc, dist),
                                          is_leaf=lambda x: isinstance(x, P)),
                   opt_tree, dist.mesh)
    g32 = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_tree)
    gsh = sanitize(jax.tree_util.tree_map(lambda s: _ns(dist, s), pspec,
                                          is_leaf=lambda x: isinstance(x, P)),
                   g32, dist.mesh)
    fn = make_opt_step(cfg, oc)
    return jax.jit(fn, in_shardings=(psh, osh, gsh)).lower(params_tree,
                                                           opt_tree, g32)


def jit_prefill_step(cfg, dist, params_tree, batch_tree, *, loops="scan"):
    pspec = param_specs(cfg, params_tree, dist)
    psh = jax.tree_util.tree_map(lambda s: _ns(dist, s), pspec,
                                 is_leaf=lambda x: isinstance(x, P))
    bsh = batch_specs(cfg, batch_tree, dist)
    psh = sanitize(psh, params_tree, dist.mesh)
    bsh = sanitize(bsh, batch_tree, dist.mesh)
    return jax.jit(make_prefill_step(cfg, dist, loops=loops),
                   in_shardings=(psh, bsh))


def jit_decode_step(cfg, dist, params_tree, cache_tree, *, donate=True):
    pspec = param_specs(cfg, params_tree, dist)
    psh = jax.tree_util.tree_map(lambda s: _ns(dist, s), pspec,
                                 is_leaf=lambda x: isinstance(x, P))
    csh = cache_specs(cfg, cache_tree, dist)
    psh = sanitize(psh, params_tree, dist.mesh)
    csh = sanitize(csh, cache_tree, dist.mesh)
    # token sharding: dp when batch divides, else replicated
    B = jax.tree_util.tree_leaves(cache_tree)[0].shape[1]
    dpn = 1
    for a in dist.dp_axes:
        dpn *= dist.mesh.shape[a]
    tsh = _ns(dist, P(dist.dp_axes) if B % dpn == 0 else P(None))
    possh = _ns(dist, P())
    return jax.jit(make_decode_step(cfg, dist),
                   in_shardings=(psh, csh, tsh, possh),
                   donate_argnums=(1,) if donate else ())
