"""Production meshes.  A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, cluster-sim sub-meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dist_for(mesh, *, fsdp: bool):
    from repro.models.sharding import Distribution
    axes = mesh.axis_names
    dp_axes = tuple(a for a in axes if a != "model")
    tp = "model" if "model" in axes else None
    return Distribution(mesh=mesh, dp_axes=dp_axes, tp_axis=tp, fsdp=fsdp)
