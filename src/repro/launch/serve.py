"""Serving driver: batched prefill + decode with throughput reporting.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serving import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32)

    t0 = time.time()
    out = jax.block_until_ready(
        generate(cfg, params, prompt, max_new_tokens=args.new_tokens,
                 temperature=args.temperature, **kw))
    dt = time.time() - t0
    n_tok = args.batch * args.new_tokens
    print(f"[serve] {args.arch}: generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", out[0, :12].tolist())
    return out


if __name__ == "__main__":
    main()
