import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes (16x16 single-pod and 2x16x16 multi-pod), print
# memory_analysis / cost_analysis, and emit roofline terms (with the scan
# correction) to JSON for EXPERIMENTS.md.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
#
# NOTE: the os.environ lines above MUST stay the first statements — jax locks
# the device count on first init.

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.specs import cell_is_live, input_specs
from repro.launch import analysis as an
from repro.launch.bodies import scan_bodies
from repro.launch.mesh import dist_for, make_production_mesh
from repro.launch.steps import (jit_decode_step, jit_prefill_step,
                                jit_train_step)
from repro.models import init_params
from repro.models.config import ALL_SHAPES, SHAPES_BY_NAME
from repro.optim import OptConfig, adamw_init

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# optimizer state tier per arch (what makes the big ones fit — DESIGN.md 5)
OPT_TIER = {"kimi-k2-1t-a32b": "int8", "jamba-v0.1-52b": "bf16",
            "qwen3-32b": "bf16", "deepseek-moe-16b": "bf16"}


def count_params(params_sds):
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(params_sds))


def active_params(cfg, total):
    if cfg.moe is None:
        return total
    n_moe = sum(1 for _, f in cfg.layer_kinds() if f == "moe")
    per_layer_routed = cfg.moe.n_experts * 3 * cfg.d_model * cfg.moe.d_ff_expert
    used = cfg.moe.top_k * 3 * cfg.d_model * cfg.moe.d_ff_expert
    return total - n_moe * (per_layer_routed - used)


def lower_cell(arch_id, shape_name, *, multi_pod=False, body_correction=True,
               cfg_override=None, verbose=True):
    """Lower + compile one cell; returns the result record (dict)."""
    shape = SHAPES_BY_NAME[shape_name]
    cfg = cfg_override or get_config(arch_id)
    live, why = cell_is_live(cfg, shape)
    if not live:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": why}
    if shape_name == "long_500k":
        cfg = cfg.replace(kv_cache_seq_shard=True)

    mesh = make_production_mesh(multi_pod=multi_pod)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    fsdp = cfg.fsdp
    if cfg_override is None and shape.kind != "train":
        # serving policy (EXPERIMENTS §Perf P3): TP-only weights when they
        # fit replicated over 'data' — FSDP gathers per decoded token are
        # pure waste.  Sharding strategy is per shape-kind, not per arch.
        tp = mesh.shape.get("model", 1)
        fsdp = count_params(params) * 2 / tp > 8e9
    dist = dist_for(mesh, fsdp=fsdp)
    specs = input_specs(cfg, shape)
    t0 = time.time()

    if shape.kind == "train":
        oc = OptConfig(state_dtype=OPT_TIER.get(arch_id, "f32"))
        opt = jax.eval_shape(partial(adamw_init, oc=oc), params)
        step = jit_train_step(cfg, dist, oc, params, opt, specs["batch"])
        lowered = step.lower(params, opt, specs["batch"])
    elif shape.kind == "prefill":
        step = jit_prefill_step(cfg, dist, params, specs["batch"])
        lowered = step.lower(params, specs["batch"])
    else:
        step = jit_decode_step(cfg, dist, params, specs["cache"])
        lowered = step.lower(params, specs["cache"], specs["token"],
                             specs["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = an.memory_summary(compiled)
    full_cost = an.analyze_compiled(compiled)
    if verbose:
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        ca = an.cost_analysis_dict(compiled)
        print(f"  cost_analysis: flops={ca.get('flops', 0):.4g} "
              f"bytes={ca.get('bytes accessed', 0):.4g}")

    # ---- roofline cost assembly -------------------------------------------
    # train: cost = M x (microbatch grad step, scan-corrected) + optimizer
    #        (the full compile's microbatch scan is counted once by XLA).
    # other: cost = full + (trips - 1) x scan body.
    M = cfg.grad_accum if shape.kind == "train" else 1
    body_records = []
    if shape.kind == "train":
        from repro.launch.steps import (jit_grad_step_micro, jit_opt_step)
        gcomp = jit_grad_step_micro(cfg, dist, params, specs["batch"],
                                    M).compile()
        ocomp = jit_opt_step(cfg, dist, oc, params, opt).compile()
        micro = an.analyze_compiled(gcomp)
        optc = an.analyze_compiled(ocomp)
        corrected = micro.scaled(M) + optc
        body_records.append({"name": "opt", "trips": 1,
                             "flops": optc.flops, "bytes": optc.bytes_accessed,
                             "coll_bytes": optc.coll_bytes})
    else:
        corrected = full_cost
    if body_correction:
        for grp in scan_bodies(cfg, dist, shape, params,
                               cache_sds=specs.get("cache")):
            bcomp = grp["lower"]().compile()
            bcost = an.analyze_compiled(bcomp)
            corrected = corrected + bcost.scaled(M * (grp["trips"] - 1))
            body_records.append({"name": grp["name"], "trips": grp["trips"],
                                 "microbatches": M,
                                 "flops": bcost.flops,
                                 "bytes": bcost.bytes_accessed,
                                 "coll_bytes": bcost.coll_bytes})

    rf = an.roofline(corrected)
    total = count_params(params)
    act = active_params(cfg, total)
    mf = an.model_flops(cfg, shape, total, act)
    chips = int(np.prod(mesh.devices.shape))
    hlo_global_flops = corrected.flops * chips
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "status": "ok", "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "per_device": {"flops": corrected.flops,
                       "bytes": corrected.bytes_accessed,
                       "coll_bytes": corrected.coll_bytes,
                       "coll_by_op": corrected.coll_by_op,
                       "raw_flops_uncorrected": full_cost.flops},
        "bodies": body_records,
        "roofline": {"t_compute": rf.t_compute, "t_memory": rf.t_memory,
                     "t_collective": rf.t_collective,
                     "bottleneck": rf.bottleneck,
                     "compute_fraction": rf.compute_fraction},
        "params_total": total, "params_active": act,
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_global_flops, 1.0),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-body", action="store_true",
                    help="skip the scan-correction body compiles")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for aid in ARCHS:
            for s in ALL_SHAPES:
                cells.append((aid, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for aid, sname in cells:
        tag = f"{aid}:{sname}:{'2x16x16' if args.multi_pod else '16x16'}"
        print(f"[dryrun] {tag}")
        try:
            rec = lower_cell(aid, sname, multi_pod=args.multi_pod,
                             body_correction=not args.no_body)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": aid, "shape": sname, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        fn = out_dir / f"{aid}__{sname}__{'multi' if args.multi_pod else 'single'}.json"
        fn.write_text(json.dumps(rec, indent=1))
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"  -> ok: bottleneck={r['bottleneck']} "
                  f"t=(c {r['t_compute']:.4f}, m {r['t_memory']:.4f}, "
                  f"coll {r['t_collective']:.4f})s "
                  f"useful={rec['useful_ratio']:.2f} "
                  f"peak_mem={rec['memory'].get('peak_gb', -1):.1f}GB "
                  f"compile={rec['compile_s']}s")
        elif rec["status"] == "skipped":
            print(f"  -> skipped: {rec['reason']}")
    print(f"[dryrun] done, {failures} failures / {len(cells)} cells")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
