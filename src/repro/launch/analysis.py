"""Roofline analysis from compiled artifacts.

Terms per (arch x shape x mesh), per device (cost_analysis is reported
post-partitioning per device — verified empirically):

    compute term    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
    memory term     = HLO_bytes / HBM_bw              (819 GB/s)
    collective term = wire_bytes / link_bw            (~50 GB/s/link ICI)

**Scan correction** (DESIGN.md): XLA cost analysis counts a while-loop body
exactly once, so scanned-layer stacks undercount by the trip count.  The
dry-run additionally lowers each scan body standalone (with identical
shardings, chunk loops unrolled) and adds ``(trips - 1) x body_cost``.

Collective wire bytes use a ring model on the parsed HLO:
    all-reduce:          2 (n-1)/n * result
    all-gather:            (n-1)/n * result          (result = gathered full)
    reduce-scatter:        (n-1)   * result          (result = shard)
    all-to-all:            (n-1)/n * result
    collective-permute:               result
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Tuple

# ---- TPU v5e hardware model (assignment constants) -------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (effective, one link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)

    def __add__(self, o):
        by = dict(self.coll_by_op)
        for k, v in o.coll_by_op.items():
            by[k] = by.get(k, 0.0) + v
        return CostSummary(self.flops + o.flops,
                           self.bytes_accessed + o.bytes_accessed,
                           self.coll_bytes + o.coll_bytes, by)

    def scaled(self, k: float):
        return CostSummary(self.flops * k, self.bytes_accessed * k,
                           self.coll_bytes * k,
                           {a: b * k for a, b in self.coll_by_op.items()})


def collective_wire_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    total, by_op = 0.0, {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line:
            continue
        dtype, dims, op = m.groups()
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = int(g.group(2))
        else:
            g2 = _GROUPS_BRACE_RE.search(line)
            if g2:
                n = len(g2.group(1).split(","))
        if op == "collective-permute":
            # participation is via source_target_pairs, not replica_groups
            total += float(nbytes)
            by_op[op] = by_op.get(op, 0.0) + float(nbytes)
            continue
        if n <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif op in ("all-gather", "all-to-all"):
            wire = (n - 1) / n * nbytes
        elif op == "reduce-scatter":
            wire = float(n - 1) * nbytes
        else:   # collective-permute
            wire = float(nbytes)
        total += wire
        by_op[op] = by_op.get(op, 0.0) + wire
    return total, by_op


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() compat: some jax versions return the dict
    wrapped in a one-element list (per-program), newer ones the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def analyze_compiled(compiled) -> CostSummary:
    ca = cost_analysis_dict(compiled)
    coll, by_op = collective_wire_bytes(compiled.as_text())
    return CostSummary(flops=float(ca.get("flops", 0.0)),
                       bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                       coll_bytes=coll, coll_by_op=by_op)


@dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the bound spent on useful math = how close to the
        compute roofline this cell can get (1.0 = perfectly compute-bound)."""
        return self.t_compute / max(self.t_bound, 1e-30)


def roofline(cost: CostSummary) -> Roofline:
    return Roofline(t_compute=cost.flops / PEAK_FLOPS,
                    t_memory=cost.bytes_accessed / HBM_BW,
                    t_collective=cost.coll_bytes / LINK_BW)


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {"argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "alias_gb": ma.alias_size_in_bytes / 1e9,
            "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes
                        - ma.alias_size_in_bytes) / 1e9}


def model_flops(cfg, shape, n_params: int, active_params: int) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (N = active)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active_params * tokens
