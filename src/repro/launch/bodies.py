"""Standalone scan-body lowering for the roofline scan-correction.

XLA cost analysis counts while-loop bodies once (DESIGN.md), so the dry-run
lowers each layer-stack scan body separately — with identical shardings and
all chunk loops unrolled — and adds ``(trips - 1) x body_cost`` to the
full-step cost.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import param_specs
from repro.models import transformer as T
from repro.models.sharding import Distribution


def _strip_lead(spec: P) -> P:
    return P(*tuple(spec)[1:])


def _block_slice(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)


def _ns(dist, tree_of_specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(dist.mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))


def scan_bodies(cfg, dist: Distribution, shape, params_sds,
                cache_sds=None) -> List[Dict[str, Any]]:
    """Returns [{name, trips, lower() -> jax.stages.Lowered}] per scan group."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train" and cfg.grad_accum > 1:
        B = B // cfg.grad_accum          # bodies run at microbatch size
    if shape.kind != "decode" and S > 8192 and not cfg.attn_triangle:
        # cost-only lowering: larger attention chunks = identical FLOPs,
        # far fewer unrolled blocks (compile time)
        cfg = cfg.replace(attn_q_chunk=S // 8, attn_kv_chunk=S // 8)
    adt = cfg.adtype
    dp = dist.dp_axes
    h_sds = jax.ShapeDtypeStruct((B, 1 if shape.kind == "decode" else S,
                                  cfg.d_model), adt)
    h_sh = NamedSharding(dist.mesh, P(dp, None, None))
    pspecs_full = param_specs(cfg, params_sds, dist)

    out = []

    def mk_ctx(positions, cache_pos=None, causal=True, mrope=None):
        return {"dist": dist, "loops": "unroll", "collect": False,
                "causal": causal, "positions": positions,
                "cache_pos": cache_pos, "mrope_positions": mrope}

    def add_group(name, blocks_key, block_kinds, trips, cross=False):
        bp_sds = _block_slice(params_sds[blocks_key])
        bp_spec = jax.tree_util.tree_map(_strip_lead,
                                         pspecs_full[blocks_key],
                                         is_leaf=lambda x: isinstance(x, P))
        bp_sh = _ns(dist, bp_spec)
        mrope_sds = None
        if cfg.mrope_sections and shape.kind != "decode":
            mrope_sds = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        enc_sds = (jax.ShapeDtypeStruct((B, S, cfg.d_model), adt)
                   if cross else None)

        def fwd(bp, h, mrope=None, enc=None, bc=None, pos=None):
            positions = (jnp.arange(h.shape[1])[None, :] if pos is None
                         else jnp.full((1, 1), pos))
            ctx = mk_ctx(positions, cache_pos=pos,
                         causal=(blocks_key != "enc_blocks"), mrope=mrope)
            if enc is not None:
                ctx["cross_kv"] = T._cross_kv(cfg, bp["l0"]["cross"], enc)
            ncache = {}
            for p_ix in range(len(block_kinds)):
                h, _, c = T._apply_layer(
                    cfg, bp[f"l{p_ix}"], h, block_kinds[p_ix], ctx,
                    cache=None if bc is None else bc[f"l{p_ix}"])
                ncache[f"l{p_ix}"] = c
            return h, ncache

        mrope_sh = NamedSharding(dist.mesh, P(None, dp, None))
        if shape.kind == "train":
            def grad_of(f, bp, h, dy):
                f = T._remat_wrap(cfg, f)
                y, vjp = jax.vjp(f, bp, h)
                return (y,) + vjp(dy)
            if mrope_sds is not None:
                def body(bp, h, dy, mrope):
                    return grad_of(lambda bp, h: fwd(bp, h, mrope=mrope)[0],
                                   bp, h, dy)
                args, shards = ([bp_sds, h_sds, h_sds, mrope_sds],
                                [bp_sh, h_sh, h_sh, mrope_sh])
            elif enc_sds is not None:
                def body(bp, h, dy, enc):
                    return grad_of(lambda bp, h: fwd(bp, h, enc=enc)[0],
                                   bp, h, dy)
                args, shards = ([bp_sds, h_sds, h_sds, enc_sds],
                                [bp_sh, h_sh, h_sh, h_sh])
            else:
                def body(bp, h, dy):
                    return grad_of(lambda bp, h: fwd(bp, h)[0], bp, h, dy)
                args, shards = [bp_sds, h_sds, h_sds], [bp_sh, h_sh, h_sh]
        elif shape.kind == "prefill":
            if mrope_sds is not None:
                def body(bp, h, mrope):
                    return fwd(bp, h, mrope=mrope)
                args, shards = ([bp_sds, h_sds, mrope_sds],
                                [bp_sh, h_sh, mrope_sh])
            elif enc_sds is not None:
                def body(bp, h, enc):
                    return fwd(bp, h, enc=enc)
                args, shards = [bp_sds, h_sds, enc_sds], [bp_sh, h_sh, h_sh]
            else:
                def body(bp, h):
                    return fwd(bp, h)
                args, shards = [bp_sds, h_sds], [bp_sh, h_sh]
        else:  # decode
            from repro.launch.steps import cache_specs
            bc_sds = _block_slice(cache_sds["blocks"])
            bc_sh = jax.tree_util.tree_map(
                lambda ns: NamedSharding(dist.mesh, _strip_lead(ns.spec)),
                cache_specs(cfg, cache_sds, dist)["blocks"])

            def body(bp, bc, h, pos):
                return fwd(bp, h, bc=bc, pos=pos)
            args = [bp_sds, bc_sds, h_sds,
                    jax.ShapeDtypeStruct((), jnp.int32)]
            shards = [bp_sh, bc_sh, h_sh, NamedSharding(dist.mesh, P())]

        def lower(body=body, args=args, shards=shards):
            from repro.launch.steps import sanitize
            shards = [sanitize(s, a, dist.mesh)
                      for s, a in zip(shards, args)]
            return jax.jit(body, in_shardings=tuple(shards)).lower(*args)

        out.append({"name": name, "trips": trips, "lower": lower})

    kinds = cfg.layer_kinds()
    if cfg.is_encdec:
        if shape.kind != "decode":
            add_group("enc_block", "enc_blocks", [("attn", "dense")],
                      cfg.encoder_layers)
        add_group("dec_block", "dec_blocks", [("attn", "dense")],
                  cfg.n_layers, cross=(shape.kind != "decode"))
    else:
        first = cfg.moe.first_k_dense if cfg.moe else 0
        bl = cfg.block_len
        add_group("block", "blocks", kinds[first:first + bl],
                  (cfg.n_layers - first) // bl)
    return out
