"""Socket front-end for the admission daemon: asyncio stream server.

Binds :mod:`repro.serving.wire` frames onto a running
:class:`~repro.serving.allocd.AllocDaemon`.  One server owns one daemon;
each connection may register any number of tenants and pipelines ``offer``
frames for them.  Everything — connection handlers, the daemon scheduler,
flush push-backs — shares one event loop, and every daemon call the server
makes is synchronous (no ``await`` between read and reply), so wire
tenants keep the daemon's conformance story: the frames a client receives
describe exactly the same flush-boundary equilibria an offline
``WindowSession.stream`` replay of its accepted events produces.

Protocol-level violations (oversized / malformed / wrong-version frames)
earn one ``error`` frame and a closed connection — after a framing
violation the byte stream cannot be re-synchronized.  Application-level
errors (unknown tenant, duplicate registration, quota-violating window)
earn an ``error`` frame naming the offending request and the connection
stays up.

A connection dying with events still buffered (mid-epoch) triggers
:meth:`AllocDaemon.drain_tenant` for each tenant it registered: the
accepted prefix is folded and flushed, so the daemon-side report list
stays equal to an offline replay of exactly the events the client got
tickets for.
"""
from __future__ import annotations

import asyncio
from typing import Dict, List, Optional, Set, Tuple

from repro.serving import wire
from repro.serving.allocd import AllocDaemon


class AllocServer:
    """Serve an :class:`AllocDaemon` over length-prefixed JSON frames.

    Parameters
    ----------
    daemon : AllocDaemon
        The admission daemon to front.  If it has not been started yet,
        :meth:`start` starts it.
    host : str, optional
        Bind address (default loopback).
    port : int, optional
        Bind port; ``0`` picks an ephemeral port (see :attr:`address`).
    max_frame : int, optional
        Strict frame-size bound enforced on reads and writes.
    default_quota : TenantQuota, optional
        Per-tenant admission budget applied to wire tenants that register
        without one (operator-side quota sizing; a quota carried by the
        ``register_tenant`` frame wins).

    Notes
    -----
    Tenant names are first-registered-wins across connections; a tenant
    registered by a dead connection remains registered (its reports stay
    inspectable) but a later connection cannot re-register the name —
    real deployments namespace tenants per client identity.
    """

    def __init__(self, daemon: AllocDaemon, *, host: str = "127.0.0.1",
                 port: int = 0, max_frame: int = wire.MAX_FRAME_BYTES,
                 default_quota=None):
        self.daemon = daemon
        self.host = host
        self.port = port
        self.max_frame = max_frame
        self.default_quota = default_quota
        self._server: Optional[asyncio.AbstractServer] = None
        self.connections = 0
        self.frame_errors = 0

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        """Start the daemon (if needed) and begin accepting connections."""
        if self.daemon._task is None:
            await self.daemon.start()
        self._server = await asyncio.start_server(
            self._handle, host=self.host, port=self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    @property
    def address(self) -> Tuple[str, int]:
        """The bound ``(host, port)`` — port resolved after :meth:`start`."""
        return (self.host, self.port)

    async def close(self, *, drain: bool = True) -> None:
        """Stop accepting, close listener, shut the daemon down.

        Parameters
        ----------
        drain : bool, optional
            Forwarded to :meth:`AllocDaemon.shutdown` — graceful drain
            (default) or abort.
        """
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.daemon.shutdown(drain=drain)

    # ----------------------------------------------------------- connection
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self.connections += 1
        # per-connection state: which tenants this socket owns, and the
        # daemon-seq -> client-cseq map used to label flush frames
        tenants: Set[str] = set()
        cseq_by_seq: Dict[str, Dict[int, int]] = {}
        try:
            while True:
                try:
                    msg = await wire.read_frame(reader,
                                                max_frame=self.max_frame)
                except asyncio.IncompleteReadError:
                    break                      # disconnect (maybe mid-frame)
                except wire.WireError as exc:
                    # framing violation: stream unrecoverable — error+close
                    self.frame_errors += 1
                    code = ("frame_too_large"
                            if isinstance(exc, wire.FrameTooLargeError)
                            else "bad_version"
                            if isinstance(exc, wire.ProtocolVersionError)
                            else "malformed_frame")
                    self._send(writer, {"type": "error", "code": code,
                                        "message": str(exc)})
                    break
                if not self._dispatch(msg, writer, tenants, cseq_by_seq):
                    break
                await writer.drain()
        except ConnectionError:
            pass
        finally:
            for name in tenants:
                self.daemon.detach_tenant(name)
                self.daemon.drain_tenant(name)
            writer.close()

    def _dispatch(self, msg, writer, tenants: Set[str],
                  cseq_by_seq: Dict[str, Dict[int, int]]) -> bool:
        """Handle one decoded frame; False ends the connection."""
        mtype = msg["type"]
        if mtype == "register_tenant":
            return self._on_register(msg, writer, tenants, cseq_by_seq)
        if mtype == "offer":
            return self._on_offer(msg, writer, tenants, cseq_by_seq)
        if mtype == "flush":
            return self._on_flush_req(msg, writer, tenants)
        if mtype == "drain":
            return self._on_drain(msg, writer, tenants)
        self._send(writer, {"type": "error", "code": "unknown_type",
                            "message": f"unknown message type {mtype!r}",
                            "req": mtype})
        return True

    def _on_register(self, msg, writer, tenants, cseq_by_seq) -> bool:
        name = msg.get("tenant")
        try:
            lanes = [wire.decode_scenario(d) for d in msg["lanes"]]
            quota = wire.decode_quota(msg.get("quota"))
            if quota is None:
                quota = self.default_quota
            n_max = msg.get("n_max")
            self.daemon.add_tenant(
                name, lanes, n_max=n_max, quota=quota,
                on_flush=self._make_push(writer, name, cseq_by_seq))
        except wire.WireError as exc:
            self._send(writer, {"type": "error", "code": "bad_register",
                                "message": str(exc),
                                "req": "register_tenant", "tenant": name})
            return True
        except Exception as exc:   # duplicate name, quota-violating window
            self._send(writer, {"type": "error",
                                "code": type(exc).__name__,
                                "message": str(exc),
                                "req": "register_tenant", "tenant": name})
            return True
        tenants.add(name)
        cseq_by_seq[name] = {}
        self._send(writer, {"type": "register_tenant", "tenant": name,
                            "lanes": len(lanes), "n_max": n_max})
        return True

    def _on_offer(self, msg, writer, tenants, cseq_by_seq) -> bool:
        name, cseq = msg.get("tenant"), msg.get("cseq")
        if name not in tenants:
            self._send(writer, {"type": "error", "code": "unknown_tenant",
                                "message": f"tenant {name!r} not registered "
                                           "on this connection",
                                "req": "offer", "tenant": name,
                                "cseq": cseq})
            return True
        try:
            event = wire.decode_event(msg["event"])
        except (KeyError, wire.WireError) as exc:
            self._send(writer, {"type": "error", "code": "bad_event",
                                "message": str(exc), "req": "offer",
                                "tenant": name, "cseq": cseq})
            return True
        ticket = self.daemon.submit(name, event)
        if ticket.accepted:
            cseq_by_seq[name][ticket.seq] = cseq
            self._send(writer, {"type": "ticket", "tenant": name,
                                "cseq": cseq, "seq": ticket.seq})
        else:
            self._send(writer, {"type": "reject", "tenant": name,
                                "cseq": cseq, "penalty": ticket.penalty})
        return True

    def _on_flush_req(self, msg, writer, tenants) -> bool:
        name = msg.get("tenant")
        if name not in tenants:
            self._send(writer, {"type": "error", "code": "unknown_tenant",
                                "message": f"tenant {name!r} not registered "
                                           "on this connection",
                                "req": "flush", "tenant": name})
            return True
        self.daemon.request_flush(name)
        return True                # the reply is the pushed flush frame

    def _on_drain(self, msg, writer, tenants) -> bool:
        for name in sorted(tenants):
            self.daemon.drain_tenant(name)
        self._send(writer, {"type": "drain", "tenants": sorted(tenants)})
        return True

    # ----------------------------------------------------------- push side
    def _make_push(self, writer, name: str, cseq_by_seq):
        """Build the daemon ``on_flush`` callback for one socket tenant."""
        flush_seq = [0]

        def push(report, tickets):
            seqmap = cseq_by_seq.get(name, {})
            entries = [{"cseq": seqmap.pop(t.seq, None), "slot": t.slot}
                       for t in tickets]
            msg = {"type": "flush", "tenant": name,
                   "flush_seq": flush_seq[0], "tickets": entries,
                   "report": None if report is None
                   else wire.encode_report(report)}
            if report is None:
                msg["error"] = "flush failed (epoch discarded)"
            flush_seq[0] += 1
            try:
                self._send(writer, msg)
            except (wire.WireError, ConnectionError):
                self.daemon.detach_tenant(name)

        return push

    def _send(self, writer, msg) -> None:
        """Write one frame (single synchronous write; no interleaving)."""
        if writer.is_closing():
            return
        writer.write(wire.encode_frame(msg, max_frame=self.max_frame))
