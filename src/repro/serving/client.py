"""Async client for the admission daemon's wire protocol.

The tenant-side half of :mod:`repro.serving.server`: connects to a
``launch/allocd.py --listen`` process (or an in-test
:class:`~repro.serving.server.AllocServer`), registers tenants, pipelines
``offer`` frames, and reassembles the server's pushed ``flush`` frames
into :class:`~repro.serving.wire.WireFlushReport` objects whose arrays
are bit-identical to the daemon's — the property the socket conformance
tests assert against offline ``WindowSession.stream`` replays.

Usage sketch (see ``examples/wire_client.py`` for a runnable version)::

    client = await AllocClient.connect(host, port)
    await client.register_tenant("t0", lanes, quota=TenantQuota(8, 8))
    tickets = [client.offer("t0", ev) for ev in trace]
    for tk in tickets:
        if await tk.ack():            # admitted (vs quota-rejected)?
            report = await tk.result()  # covering flush's equilibrium
    await client.drain()              # fold + flush trailing partials
    await client.close()

``offer`` is deliberately synchronous-send / async-resolve, mirroring
:meth:`AllocDaemon.submit`: the frame goes out immediately, the returned
:class:`WireTicket` resolves in two stages (admission ack, then flush
report) as the server's replies arrive on the background reader task.
"""
from __future__ import annotations

import asyncio
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

from repro.core.engine import TenantQuota
from repro.core.types import Scenario, StreamEvent
from repro.serving import wire


@dataclass
class WireTicket:
    """Client-side admission ticket for one ``offer`` frame.

    Two-stage resolution: :meth:`ack` resolves when the server's
    ``ticket``/``reject`` reply lands (admission decision);
    :meth:`result` resolves when the covering ``flush`` frame lands
    (equilibrium).  A rejected or error-answered offer resolves both
    stages immediately (``result`` -> ``None``).

    Attributes
    ----------
    tenant : str
        Target tenant.
    cseq : int
        Client-side sequence number correlating the replies.
    event : StreamEvent
        The submitted event.
    accepted : bool or None
        Admission decision; ``None`` until the ack arrives.
    penalty : float
        Paper rejection cost (``m * H_up`` for a dropped arrival) when
        rejected.
    seq : int or None
        Daemon-side ticket sequence (accepted offers only).
    slot : int or None
        Granted class slot, from the covering flush frame.
    report : WireFlushReport or None
        The covering flush, once resolved.
    t_submit : float
        Scheduled submission time on the ``time.perf_counter`` clock
        (open-loop drivers pass the intended arrival time so measured
        latency includes queueing delay).
    t_done : float or None
        When the admission outcome resolved client-side — reject reply
        or covering flush frame — so ``t_done - t_submit`` is the
        end-to-end socket admission latency.
    """

    tenant: str
    cseq: int
    event: StreamEvent
    accepted: Optional[bool] = None
    penalty: float = 0.0
    seq: Optional[int] = None
    slot: Optional[int] = None
    report: Optional[wire.WireFlushReport] = None
    t_submit: float = 0.0
    t_done: Optional[float] = None
    _ack: "asyncio.Future" = field(repr=False, default=None)
    _done: "asyncio.Future" = field(repr=False, default=None)

    async def ack(self) -> bool:
        """Await the admission decision.

        Returns
        -------
        bool
            ``True`` if the daemon accepted the event, ``False`` if it
            was rejected (see :attr:`penalty`).

        Raises
        ------
        repro.serving.wire.RemoteError
            If the server answered the offer with an ``error`` frame.
        """
        return await asyncio.shield(self._ack)

    async def result(self) -> Optional[wire.WireFlushReport]:
        """Await the covering flush report.

        Returns
        -------
        WireFlushReport or None
            The flush-boundary equilibrium covering this offer, or
            ``None`` for rejected offers and failed (poisoned) epochs.
        """
        return await asyncio.shield(self._done)


class AllocClient:
    """Wire-protocol client: one connection, any number of tenants.

    Build via :meth:`connect`.  All coroutines must run on the event
    loop that created the client; replies are demultiplexed by a
    background reader task, so offers from several tenants can be
    pipelined without awaiting each other.

    Parameters
    ----------
    reader, writer : asyncio streams
        The established connection.
    max_frame : int, optional
        Frame-size bound (must not exceed the server's).
    """

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, *,
                 max_frame: int = wire.MAX_FRAME_BYTES):
        self._reader = reader
        self._writer = writer
        self.max_frame = max_frame
        self._cseq = 0
        self._tickets: Dict[int, WireTicket] = {}
        self._by_tenant_seq: Dict[str, WireTicket] = {}
        self._reports: Dict[str, List[wire.WireFlushReport]] = \
            defaultdict(list)
        self._rpc: Dict[str, Deque["asyncio.Future"]] = defaultdict(deque)
        self._flush_waiters: Dict[str, List["asyncio.Future"]] = \
            defaultdict(list)
        self._closed = False
        self._error: Optional[BaseException] = None
        #: unsolicited ``error`` frames (no matching request), newest last
        self.errors: List[wire.RemoteError] = []
        self._reader_task = asyncio.get_running_loop().create_task(
            self._read_loop())

    @classmethod
    async def connect(cls, host: str, port: int, *,
                      max_frame: int = wire.MAX_FRAME_BYTES
                      ) -> "AllocClient":
        """Open a connection and start the reply reader.

        Parameters
        ----------
        host, port : str, int
            The server's listen address.
        max_frame : int, optional
            Frame-size bound for both directions.

        Returns
        -------
        AllocClient
            Ready for :meth:`register_tenant`.
        """
        reader, writer = await asyncio.open_connection(host, port)
        return cls(reader, writer, max_frame=max_frame)

    # ------------------------------------------------------------- requests
    async def register_tenant(self, name: str, lanes: Sequence[Scenario], *,
                              n_max: Optional[int] = None,
                              quota: Optional[TenantQuota] = None) -> dict:
        """Register a tenant window on the server.

        Parameters
        ----------
        name : str
            Tenant key (server-wide unique).
        lanes : sequence of Scenario
            Initial lane scenarios, shipped raw and re-derived server-side
            (bit-identical; see :func:`repro.serving.wire.encode_scenario`).
        n_max : int, optional
            Padded class capacity headroom.
        quota : TenantQuota, optional
            Per-tenant admission budget enforced by the daemon.

        Returns
        -------
        dict
            The server's acknowledgement frame.

        Raises
        ------
        repro.serving.wire.RemoteError
            Duplicate name, quota-violating window, or undecodable lanes.
        """
        fut = self._expect("register_tenant")
        self._send({"type": "register_tenant", "tenant": name,
                    "lanes": [wire.encode_scenario(s) for s in lanes],
                    "n_max": n_max, "quota": wire.encode_quota(quota)})
        return await fut

    def offer(self, tenant: str, event: StreamEvent, *,
              t_submit: Optional[float] = None) -> WireTicket:
        """Submit one admission event (pipelined; returns immediately).

        Parameters
        ----------
        tenant : str
            A tenant previously registered on this connection.
        event : StreamEvent
            The event to fold into the tenant's window.
        t_submit : float, optional
            Scheduled arrival time on the ``time.perf_counter`` clock
            (latency origin for open-loop benchmark drivers); defaults
            to now.

        Returns
        -------
        WireTicket
            Resolves in two stages as server replies arrive.
        """
        self._check_alive()
        self._cseq += 1
        loop = asyncio.get_running_loop()
        tk = WireTicket(tenant=tenant, cseq=self._cseq, event=event,
                        t_submit=(time.perf_counter() if t_submit is None
                                  else t_submit),
                        _ack=loop.create_future(),
                        _done=loop.create_future())
        self._tickets[self._cseq] = tk
        self._send({"type": "offer", "tenant": tenant, "cseq": tk.cseq,
                    "event": wire.encode_event(event)})
        return tk

    async def flush(self, tenant: str) -> wire.WireFlushReport:
        """Force the tenant's buffered epoch to flush; await its report.

        Returns the *next* flush frame for the tenant — if a policy-driven
        flush was already in motion, that one answers the request (the
        daemon's epoch boundaries are whatever the flush policy and this
        forcing produce; both are legal ``WindowSession.flush`` points).

        Parameters
        ----------
        tenant : str
            A tenant registered on this connection.

        Returns
        -------
        WireFlushReport
            The next flush-boundary report for the tenant.
        """
        self._check_alive()
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        self._flush_waiters[tenant].append(fut)
        self._send({"type": "flush", "tenant": tenant})
        return await fut

    async def drain(self) -> dict:
        """Fold and flush every trailing partial of this connection.

        Returns
        -------
        dict
            The server's ``drain`` acknowledgement (its trailing ``flush``
            frames are delivered first, so all tickets resolve before
            this returns).
        """
        fut = self._expect("drain")
        self._send({"type": "drain"})
        return await fut

    def reports(self, tenant: str) -> List[wire.WireFlushReport]:
        """Flush reports received so far for `tenant`, in flush order.

        Parameters
        ----------
        tenant : str
            Tenant key.

        Returns
        -------
        list of WireFlushReport
            The client-side mirror of ``AllocDaemon.reports(tenant)``.
        """
        return self._reports[tenant]

    async def close(self) -> None:
        """Close the connection and stop the reader task."""
        self._closed = True
        self._writer.close()
        self._reader_task.cancel()
        try:
            await self._reader_task
        except (asyncio.CancelledError, Exception):
            pass

    # ------------------------------------------------------------ internals
    def _send(self, msg) -> None:
        self._check_alive()
        self._writer.write(wire.encode_frame(msg, max_frame=self.max_frame))

    def _check_alive(self) -> None:
        if self._error is not None:
            raise self._error
        if self._closed:
            raise RuntimeError("client is closed")

    def _expect(self, reply_type: str) -> "asyncio.Future":
        self._check_alive()
        fut = asyncio.get_running_loop().create_future()
        self._rpc[reply_type].append(fut)
        return fut

    async def _read_loop(self) -> None:
        try:
            while True:
                msg = await wire.read_frame(self._reader,
                                            max_frame=self.max_frame)
                self._on_frame(msg)
        except asyncio.IncompleteReadError as exc:
            if exc.partial:           # closed mid-frame: truncation
                self._fail_all(wire.MalformedFrameError(
                    "connection closed mid-frame"))
            else:
                self._fail_all(ConnectionError("server closed connection"))
        except asyncio.CancelledError:
            raise
        except Exception as exc:      # framing violation from server side
            self._fail_all(exc)

    def _on_frame(self, msg) -> None:
        mtype = msg["type"]
        if mtype == "ticket":
            tk = self._tickets.get(msg.get("cseq"))
            if tk is not None:
                tk.accepted, tk.seq = True, msg.get("seq")
                self._by_tenant_seq[f"{tk.tenant}:{tk.seq}"] = tk
                if not tk._ack.done():
                    tk._ack.set_result(True)
        elif mtype == "reject":
            tk = self._tickets.get(msg.get("cseq"))
            if tk is not None:
                tk.accepted = False
                tk.penalty = float(msg.get("penalty", 0.0))
                tk.t_done = time.perf_counter()
                if not tk._ack.done():
                    tk._ack.set_result(False)
                if not tk._done.done():
                    tk._done.set_result(None)
        elif mtype == "flush":
            self._on_flush(msg)
        elif mtype in ("register_tenant", "drain"):
            waiters = self._rpc[mtype]
            if waiters:
                fut = waiters.popleft()
                if not fut.done():
                    fut.set_result(msg)
        elif mtype == "error":
            self._on_error(msg)

    def _on_flush(self, msg) -> None:
        tenant = msg.get("tenant")
        entries = [(e.get("cseq"), e.get("slot"))
                   for e in msg.get("tickets", [])]
        report = wire.decode_report(tenant, int(msg.get("flush_seq", 0)),
                                    msg.get("report"), entries,
                                    error=msg.get("error"))
        self._reports[tenant].append(report)
        for cseq, slot in entries:
            tk = self._tickets.get(cseq)
            if tk is None:
                continue
            tk.slot = slot
            tk.t_done = time.perf_counter()
            tk.report = None if report.error is not None else report
            if not tk._done.done():
                tk._done.set_result(tk.report)
        waiters, self._flush_waiters[tenant] = \
            self._flush_waiters[tenant], []
        for fut in waiters:
            if not fut.done():
                fut.set_result(report)

    def _on_error(self, msg) -> None:
        err = wire.RemoteError(msg.get("code", "error"),
                               msg.get("message", ""))
        req = msg.get("req")
        if req == "offer":
            tk = self._tickets.get(msg.get("cseq"))
            if tk is not None:
                if not tk._ack.done():
                    tk._ack.set_exception(err)
                if not tk._done.done():
                    tk._done.set_result(None)
                return
        waiters = self._rpc[req] if req in self._rpc else None
        if waiters:
            fut = waiters.popleft()
            if not fut.done():
                fut.set_exception(err)
            return
        # unsolicited error: record it — if it was connection-fatal the
        # server closes next and the EOF path fails outstanding futures
        self.errors.append(err)

    def _fail_all(self, exc: BaseException) -> None:
        if self._error is None:
            self._error = exc
        for tk in self._tickets.values():
            if not tk._ack.done():
                tk._ack.set_exception(exc)
            if not tk._done.done():
                tk._done.set_result(None)
        for waiters in self._rpc.values():
            while waiters:
                fut = waiters.popleft()
                if not fut.done():
                    fut.set_exception(exc)
        for tenant, waiters in self._flush_waiters.items():
            for fut in waiters:
                if not fut.done():
                    fut.set_exception(exc)
            self._flush_waiters[tenant] = []
