"""Wire protocol for the admission daemon: length-prefixed JSON frames.

The transport half of the network Resource Manager (``docs/OPERATIONS.md``,
"Running allocd over the wire"): tenants on remote processes submit class
arrivals / SLA edits over a socket and get admission tickets back, while the
daemon end multiplexes them onto its :class:`~repro.serving.allocd.AllocDaemon`.
This module is the *codec* layer only — pure functions over bytes and
dicts, no sockets — so every framing rule is unit-testable without an event
loop (``tests/test_wire.py``); the asyncio halves live in
:mod:`repro.serving.server` and :mod:`repro.serving.client`.

Framing
-------
One frame = a 4-byte big-endian unsigned payload length followed by a UTF-8
JSON object.  Frames larger than ``max_frame`` bytes are rejected without
buffering the payload (:class:`FrameTooLargeError`); payloads that fail to
parse into a JSON object with a string ``type`` are
:class:`MalformedFrameError`s.  Both are *connection-fatal*: after a framing
violation the byte stream cannot be trusted, so the peer sends one
``error`` frame and closes.

Messages
--------
Every message carries ``v`` (:data:`PROTOCOL_VERSION`) and ``type``:

======================  =========  =========================================
type                    direction  meaning
======================  =========  =========================================
``register_tenant``     c -> s     open a tenant window (lanes + quota);
                                   echoed back as the acknowledgement
``offer``               c -> s     submit one admission event (``cseq``
                                   correlates the replies)
``flush``               c -> s     force the tenant's buffered epoch to
                                   re-equilibrate now
``drain``               c -> s     fold + flush every trailing partial of
                                   this connection's tenants; echoed back
``ticket``              s -> c     offer accepted (daemon ``seq`` attached)
``reject``              s -> c     offer rejected: quota / backstop
                                   exhausted, carries the paper's
                                   rejection ``penalty``
``flush``               s -> c     one flush-boundary report: the covered
                                   tickets (``cseq`` + granted ``slot``)
                                   and the bit-exact equilibrium
``error``               s -> c     protocol or application error (``code``,
                                   ``message``, optional ``req``/``cseq``
                                   naming the request it answers)
======================  =========  =========================================

Exactness
---------
Conformance demands socket tenants see the *same bits* an offline
``WindowSession.stream`` replay produces, so arrays never pass through JSON
floats: every array leaf is encoded as ``{dtype, shape, base64(raw bytes)}``
(:func:`encode_array`), and scenarios cross the wire as their raw Table-5
fields with the derived constants recomputed by :func:`~repro.core.types.derive`
on the receiving side — deterministic, hence bit-identical.  Python floats
inside event ``params`` round-trip exactly through JSON (``repr`` <->
``float``).
"""
from __future__ import annotations

import base64
import json
import struct
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import TenantQuota
from repro.core.types import (CapacityChange, ClassArrival, ClassDeparture,
                              Scenario, SLAEdit, Solution, StreamEvent,
                              derive)

#: Protocol version stamped on (and required of) every frame.
PROTOCOL_VERSION = 1

#: Default strict frame-size bound [bytes] — a flush report at daemon scale
#: is a few KiB, so 1 MiB is generous headroom while still rejecting a
#: stream gone insane before buffering it.
MAX_FRAME_BYTES = 1 << 20

_HEADER = struct.Struct(">I")

#: Raw Scenario fields that cross the wire (derived constants recomputed).
_SCENARIO_RAW = ("A", "B", "E", "cM", "cR", "H_up", "H_low", "m", "rho_up",
                 "R", "rho_bar")

#: Solution fields carried by a flush report (the full pytree, in field
#: order, so a decoded report flattens identically to a local one).
_SOLUTION_FIELDS = ("r", "psi", "sM", "sR", "cost", "penalty", "total",
                    "feasible", "iters", "aux")


class WireError(Exception):
    """Base class for every wire-protocol failure."""


class FrameTooLargeError(WireError):
    """Declared frame length exceeds the negotiated ``max_frame`` bound."""


class MalformedFrameError(WireError):
    """Payload is not a JSON object with a string ``type`` field."""


class ProtocolVersionError(WireError):
    """Peer speaks a different :data:`PROTOCOL_VERSION`."""


class RemoteError(WireError):
    """An ``error`` frame from the peer, surfaced locally.

    Parameters
    ----------
    code : str
        Machine-readable error code (``unknown_tenant``, ``bad_version``,
        ``frame_too_large``, ...).
    message : str
        Human-readable detail from the peer.
    """

    def __init__(self, code: str, message: str):
        super().__init__(f"[{code}] {message}")
        self.code = code
        self.message = message


# --------------------------------------------------------------------- frames
def encode_frame(msg: Dict[str, Any], *,
                 max_frame: int = MAX_FRAME_BYTES) -> bytes:
    """Serialize one message dict into a length-prefixed frame.

    Parameters
    ----------
    msg : dict
        JSON-serializable message (``v`` is stamped in if absent).
    max_frame : int, optional
        Size bound the *sender* honors too — a frame we would refuse to
        read is refused at write time, loudly.

    Returns
    -------
    bytes
        4-byte big-endian length header + UTF-8 JSON payload.

    Raises
    ------
    FrameTooLargeError
        When the encoded payload exceeds ``max_frame``.
    """
    payload = json.dumps({"v": PROTOCOL_VERSION, **msg},
                         separators=(",", ":")).encode("utf-8")
    if len(payload) > max_frame:
        raise FrameTooLargeError(
            f"frame of {len(payload)} bytes exceeds max_frame={max_frame}")
    return _HEADER.pack(len(payload)) + payload


def decode_payload(payload: bytes) -> Dict[str, Any]:
    """Parse and validate one frame payload into a message dict.

    Parameters
    ----------
    payload : bytes
        The JSON bytes following a length header.

    Returns
    -------
    dict
        The message, guaranteed to be an object with a string ``type``.

    Raises
    ------
    MalformedFrameError
        Non-JSON, non-object, or missing/non-string ``type``.
    ProtocolVersionError
        ``v`` missing or not :data:`PROTOCOL_VERSION`.
    """
    try:
        msg = json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise MalformedFrameError(f"undecodable frame payload: {exc}")
    if not isinstance(msg, dict) or not isinstance(msg.get("type"), str):
        raise MalformedFrameError(
            "frame payload must be a JSON object with a string 'type'")
    if msg.get("v") != PROTOCOL_VERSION:
        raise ProtocolVersionError(
            f"unsupported protocol version {msg.get('v')!r} "
            f"(this end speaks {PROTOCOL_VERSION})")
    return msg


async def read_frame(reader, *,
                     max_frame: int = MAX_FRAME_BYTES) -> Dict[str, Any]:
    """Read one frame from an asyncio stream reader.

    Partial reads are handled by ``readexactly`` — a frame split across
    arbitrarily many TCP segments reassembles transparently; a connection
    closing mid-frame raises ``asyncio.IncompleteReadError`` (truncation).

    Parameters
    ----------
    reader : asyncio.StreamReader
        The byte stream.
    max_frame : int, optional
        Strict payload bound; an oversized header is rejected *before*
        its payload is buffered.

    Returns
    -------
    dict
        The decoded, version-checked message.

    Raises
    ------
    FrameTooLargeError, MalformedFrameError, ProtocolVersionError
        Framing violations (connection-fatal; see module docstring).
    asyncio.IncompleteReadError
        The peer closed mid-frame (or cleanly at a frame boundary, in
        which case ``partial`` is empty).
    """
    header = await reader.readexactly(_HEADER.size)
    (length,) = _HEADER.unpack(header)
    if length > max_frame:
        raise FrameTooLargeError(
            f"declared frame length {length} exceeds max_frame={max_frame}")
    if length == 0:
        raise MalformedFrameError("zero-length frame")
    return decode_payload(await reader.readexactly(length))


# --------------------------------------------------------------------- arrays
def encode_array(x) -> Dict[str, Any]:
    """Encode one array (or scalar) leaf bit-exactly.

    Parameters
    ----------
    x : array-like
        Anything ``np.asarray`` accepts (jax arrays included).

    Returns
    -------
    dict
        ``{"dtype": str, "shape": [...], "data": base64(raw C-order bytes)}``.
    """
    arr = np.asarray(x)
    return {"dtype": arr.dtype.str, "shape": list(arr.shape),
            "data": base64.b64encode(np.ascontiguousarray(arr).tobytes())
            .decode("ascii")}


def decode_array(d: Dict[str, Any]) -> np.ndarray:
    """Decode :func:`encode_array` output back to a numpy array.

    Parameters
    ----------
    d : dict
        ``{"dtype", "shape", "data"}`` as produced by :func:`encode_array`.

    Returns
    -------
    numpy.ndarray
        Bit-identical to the encoded array.

    Raises
    ------
    MalformedFrameError
        On missing keys, bad base64, or a byte count inconsistent with
        ``dtype``/``shape``.
    """
    try:
        raw = base64.b64decode(d["data"], validate=True)
        arr = np.frombuffer(raw, dtype=np.dtype(d["dtype"]))
        return arr.reshape(d["shape"]).copy()
    except (KeyError, TypeError, ValueError) as exc:
        raise MalformedFrameError(f"bad array encoding: {exc}")


def _encode_value(v):
    """One event-param value: exact floats/ints pass as JSON scalars,
    array-ish values (incl. 0-d numpy/jax scalars) keep their dtype."""
    if isinstance(v, (bool, int, str)) or v is None:
        return v
    if isinstance(v, float):
        return v                      # repr round-trips float64 exactly
    return {"__nd__": encode_array(v)}


def _decode_value(v):
    if isinstance(v, dict) and "__nd__" in v:
        arr = decode_array(v["__nd__"])
        return arr[()] if arr.ndim == 0 else arr
    return v


# ------------------------------------------------------------------ scenarios
def encode_scenario(scn: Scenario) -> Dict[str, Any]:
    """Encode a lane scenario as its raw Table-5 fields.

    Derived constants (``K``, ``xiM``, ``alpha``, ...) are *not* shipped:
    the receiver recomputes them with :func:`repro.core.types.derive`, which
    is deterministic, so both ends hold bit-identical scenarios while the
    frame stays minimal.

    Parameters
    ----------
    scn : Scenario
        The lane to encode.

    Returns
    -------
    dict
        Raw field name -> :func:`encode_array` payload.
    """
    return {f: encode_array(getattr(scn, f)) for f in _SCENARIO_RAW}


def decode_scenario(d: Dict[str, Any]) -> Scenario:
    """Rebuild a :class:`~repro.core.types.Scenario` from raw wire fields.

    Parameters
    ----------
    d : dict
        :func:`encode_scenario` output.

    Returns
    -------
    Scenario
        With derived constants recomputed (bit-identical to the sender's).

    Raises
    ------
    MalformedFrameError
        On missing fields or undecodable arrays.
    """
    try:
        raw = {f: decode_array(d[f]) for f in _SCENARIO_RAW}
    except KeyError as exc:
        raise MalformedFrameError(f"scenario missing raw field {exc}")
    return derive(**raw)


# -------------------------------------------------------------------- events
_EVENT_KINDS = {
    "arrival": ClassArrival,
    "departure": ClassDeparture,
    "sla_edit": SLAEdit,
    "capacity": CapacityChange,
}


def encode_event(ev: StreamEvent) -> Dict[str, Any]:
    """Encode one admission event for an ``offer`` frame.

    Parameters
    ----------
    ev : StreamEvent
        ClassArrival / ClassDeparture / SLAEdit / CapacityChange.

    Returns
    -------
    dict
        ``{"kind", "lane", ...}`` with params/updates value-encoded
        exactly (:func:`_encode_value`).

    Raises
    ------
    TypeError
        For an unknown event class.
    """
    if isinstance(ev, ClassArrival):
        return {"kind": "arrival", "lane": int(ev.lane),
                "params": {k: _encode_value(v) for k, v in ev.params.items()}}
    if isinstance(ev, ClassDeparture):
        return {"kind": "departure", "lane": int(ev.lane),
                "slot": int(ev.slot)}
    if isinstance(ev, SLAEdit):
        return {"kind": "sla_edit", "lane": int(ev.lane), "slot": int(ev.slot),
                "updates": {k: _encode_value(v)
                            for k, v in ev.updates.items()}}
    if isinstance(ev, CapacityChange):
        return {"kind": "capacity", "lane": int(ev.lane), "R": float(ev.R)}
    raise TypeError(f"cannot encode event of type {type(ev).__name__!r}")


def decode_event(d: Dict[str, Any]) -> StreamEvent:
    """Decode an ``offer`` frame's event back into its dataclass.

    Parameters
    ----------
    d : dict
        :func:`encode_event` output.

    Returns
    -------
    StreamEvent
        The event, with params/updates values bit-identical to the
        sender's.

    Raises
    ------
    MalformedFrameError
        On an unknown kind or missing fields.
    """
    try:
        kind = d["kind"]
        if kind == "arrival":
            return ClassArrival(lane=int(d["lane"]),
                                params={k: _decode_value(v)
                                        for k, v in d["params"].items()})
        if kind == "departure":
            return ClassDeparture(lane=int(d["lane"]), slot=int(d["slot"]))
        if kind == "sla_edit":
            return SLAEdit(lane=int(d["lane"]), slot=int(d["slot"]),
                           updates={k: _decode_value(v)
                                    for k, v in d["updates"].items()})
        if kind == "capacity":
            return CapacityChange(lane=int(d["lane"]), R=float(d["R"]))
    except (KeyError, TypeError, ValueError) as exc:
        raise MalformedFrameError(f"bad event encoding: {exc}")
    raise MalformedFrameError(f"unknown event kind {d.get('kind')!r}")


# -------------------------------------------------------------------- quotas
def encode_quota(quota: Optional[TenantQuota]) -> Optional[Dict[str, Any]]:
    """Encode a :class:`~repro.core.engine.TenantQuota` (or None).

    Parameters
    ----------
    quota : TenantQuota or None
        The per-tenant budget.

    Returns
    -------
    dict or None
        ``{"max_queued", "max_lanes"}``.
    """
    if quota is None:
        return None
    return {"max_queued": quota.max_queued, "max_lanes": quota.max_lanes}


def decode_quota(d: Optional[Dict[str, Any]]) -> Optional[TenantQuota]:
    """Decode :func:`encode_quota` output.

    Parameters
    ----------
    d : dict or None
        The wire form.

    Returns
    -------
    TenantQuota or None
        The budget object.
    """
    if d is None:
        return None
    return TenantQuota(max_queued=d.get("max_queued"),
                       max_lanes=d.get("max_lanes"))


# ------------------------------------------------------------------- reports
@dataclass
class WireFlushReport:
    """One flush-boundary equilibrium as decoded on the client side.

    Mirrors the fields the conformance harness compares on a
    :class:`~repro.core.engine.WindowSolveReport` — ``fractional`` (the
    full :class:`~repro.core.types.Solution` pytree), ``mask`` and
    ``iters`` — so the same bit-equality assertions run against wire
    reports and offline replays.

    Attributes
    ----------
    tenant : str
        The tenant this flush belongs to.
    flush_seq : int
        0-based flush index within the tenant (wire frames may interleave
        across tenants; this orders them per tenant).
    fractional : Solution
        The flush's fractional equilibrium (numpy leaves, bit-identical
        to the daemon's).
    mask : numpy.ndarray
        (B, n_max) class-validity mask at the flush boundary.
    iters : numpy.ndarray
        Per-lane Algorithm 4.1 iteration counts.
    feasible : numpy.ndarray
        Per-lane feasibility flags.
    tickets : list of (int or None, int or None)
        ``(cseq, slot)`` per covered offer, in fold order.
    error : str or None
        Set when the covering flush failed (poisoned epoch) — all other
        payload fields are then None.
    """
    tenant: str
    flush_seq: int
    fractional: Optional[Solution]
    mask: Optional[np.ndarray]
    iters: Optional[np.ndarray]
    feasible: Optional[np.ndarray]
    tickets: List[Tuple[Optional[int], Optional[int]]] = field(
        default_factory=list)
    error: Optional[str] = None


def encode_report(report) -> Dict[str, Any]:
    """Encode the conformance-relevant slice of a flush report.

    Parameters
    ----------
    report : WindowSolveReport
        The daemon-side flush result.

    Returns
    -------
    dict
        ``fractional`` (field -> array), ``mask``, ``iters``, ``feasible``
        — every leaf bit-exact via :func:`encode_array`.
    """
    return {
        "fractional": {f: encode_array(getattr(report.fractional, f))
                       for f in _SOLUTION_FIELDS},
        "mask": encode_array(report.mask),
        "iters": encode_array(report.iters),
        "feasible": encode_array(report.feasible),
    }


def decode_report(tenant: str, flush_seq: int, d: Optional[Dict[str, Any]],
                  tickets: List[Tuple[Optional[int], Optional[int]]],
                  error: Optional[str] = None) -> WireFlushReport:
    """Decode a server ``flush`` frame into a :class:`WireFlushReport`.

    Parameters
    ----------
    tenant : str
        Tenant the frame names.
    flush_seq : int
        Per-tenant flush index from the frame.
    d : dict or None
        :func:`encode_report` output (None for a failed flush).
    tickets : list of (cseq, slot)
        Covered offers, in fold order.
    error : str, optional
        Failure text for a poisoned epoch.

    Returns
    -------
    WireFlushReport
        With ``fractional`` rebuilt as a :class:`~repro.core.types.Solution`.

    Raises
    ------
    MalformedFrameError
        On missing solution fields or undecodable arrays.
    """
    if d is None:
        return WireFlushReport(tenant=tenant, flush_seq=flush_seq,
                               fractional=None, mask=None, iters=None,
                               feasible=None, tickets=tickets, error=error)
    try:
        sol = Solution(**{f: decode_array(d["fractional"][f])
                          for f in _SOLUTION_FIELDS})
        return WireFlushReport(
            tenant=tenant, flush_seq=flush_seq, fractional=sol,
            mask=decode_array(d["mask"]), iters=decode_array(d["iters"]),
            feasible=decode_array(d["feasible"]), tickets=tickets,
            error=error)
    except KeyError as exc:
        raise MalformedFrameError(f"flush report missing field {exc}")
