"""Always-on admission daemon over the CapacityEngine session layer.

The runtime half of the paper's story: a long-running Resource Manager
process that many tenants (MapReduce user classes, one
:class:`~repro.core.engine.WindowSession` each) submit admission events
to, multiplexed over ONE shared :class:`~repro.core.engine.CapacityEngine`
so every tenant reuses the same jitted solver programs.

Design contract (what `tests/test_allocd.py` pins down):

* **Bit-equal conformance.**  Per tenant, the daemon produces exactly the
  flush-boundary equilibria of an offline ``WindowSession.stream`` replay
  of that tenant's accepted events.  This holds because (a) intake uses
  ``WindowSession.offer`` which runs the very same flush-policy check as
  ``apply``, (b) once a session is *due* it receives no further events
  until flushed — so epoch boundaries cannot shift, and (c) tenant
  windows are independent, so cross-tenant scheduling order affects
  latency only, never equilibria.
* **Backpressure with rejection cost.**  The request queue is bounded;
  when full, a submitted event is rejected and charged the paper's
  rejection penalty (an arrival rejecting a whole class forfeits
  ``m * H_up`` — the per-job penalty times the upper job concurrency).
* **Deadline-aware cross-session flushing.**  Among due sessions, the one
  whose buffered events carry the tightest SLA slack
  (``WindowSession.pending_slack``) flushes first — the multi-tenant
  generalization of ``FlushPolicy.deadline``.
* **Fairness.**  Intake is round-robin with a one-event quantum and a
  rotating start tenant, so a chatty tenant cannot starve others out of
  the fold order.
* **Graceful drain.**  ``shutdown(drain=True)`` delivers every queued
  event and flushes every trailing partial epoch (the same trailing
  flush ``stream`` performs); ``drain=False`` aborts — queued and
  in-buffer events are discarded and their tickets cancelled, leaving
  each session at its last flushed state.

Everything runs on one asyncio event loop; solves execute inline in the
scheduler task (JAX dispatch is synchronous), with a cooperative yield
between flushes so submitters interleave.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import (CapacityEngine, TenantQuota, WindowSession,
                               WindowSolveReport)
from repro.core.types import ClassArrival, StreamEvent


def rejection_penalty(event: StreamEvent) -> float:
    """Paper rejection cost charged when backpressure drops `event`.

    Rejecting a :class:`~repro.core.types.ClassArrival` forfeits the whole
    class: ``m * H_up`` (per-job rejection penalty times the upper bound on
    concurrent jobs).  Other event kinds mutate classes that were already
    admitted, so dropping them carries no admission penalty (the previous
    equilibrium simply persists).

    Parameters
    ----------
    event : StreamEvent
        The rejected event.

    Returns
    -------
    float
        The forfeited objective value (>= 0).
    """
    if isinstance(event, ClassArrival):
        m = float(event.params.get("m", 0.0))
        h_up = float(event.params.get("H_up", 0.0))
        return abs(m) * abs(h_up)
    return 0.0


@dataclass
class AdmissionTicket:
    """One submitted event's admission outcome, resolvable asynchronously.

    ``accepted`` is decided synchronously at :meth:`AllocDaemon.submit`
    (backpressure); ``slot`` / ``report`` land when the covering flush
    completes.  ``await ticket.wait()`` returns the flush report (``None``
    if the ticket was rejected or cancelled by an abort).
    """

    tenant: str
    event: StreamEvent
    seq: int
    accepted: bool
    penalty: float = 0.0
    t_submit: float = 0.0
    t_done: Optional[float] = None
    slot: Optional[int] = None
    report: Optional[WindowSolveReport] = None
    cancelled: bool = False
    _fut: Optional["asyncio.Future"] = field(default=None, repr=False)

    async def wait(self) -> Optional[WindowSolveReport]:
        """Block until the covering flush resolves this ticket.

        Returns
        -------
        WindowSolveReport or None
            The flush report, or ``None`` for rejected/cancelled tickets.
        """
        if self._fut is None:
            return self.report
        return await self._fut

    def _resolve(self, value) -> None:
        if self._fut is not None and not self._fut.done():
            self._fut.set_result(value)

    def _fail(self, exc: BaseException) -> None:
        if self._fut is not None and not self._fut.done():
            self._fut.set_exception(exc)


@dataclass
class _Tenant:
    """Internal per-tenant scheduling state."""

    name: str
    session: WindowSession
    queue: Deque[AdmissionTicket] = field(default_factory=deque)
    inflight: List[AdmissionTicket] = field(default_factory=list)
    due: bool = False
    reports: List[WindowSolveReport] = field(default_factory=list)
    quota: Optional[TenantQuota] = None
    on_flush: Optional[Callable] = None
    submitted: int = 0
    rejected: int = 0
    rejection_cost: float = 0.0

    @property
    def queued(self) -> int:
        """Not-yet-flushed events charged against this tenant's quota."""
        return len(self.queue) + len(self.inflight)


class AllocDaemon:
    """Asyncio admission daemon: many tenant sessions, one engine.

    Parameters
    ----------
    engine : CapacityEngine
        The shared solver.  Its flush policy decides per-tenant epoch
        boundaries; its compaction/rounding/cross-check policies apply to
        every tenant alike.
    queue_limit : int, optional
        Bound on the total not-yet-folded backlog across all tenants.
        Submits beyond it are rejected with :func:`rejection_penalty`.
        ``None`` disables backpressure.

    Notes
    -----
    All methods must be called from the daemon's event loop (the one
    :meth:`start` ran on).  ``submit`` is synchronous — the backpressure
    decision is immediate; only the flush outcome is awaited via the
    returned ticket.
    """

    def __init__(self, engine: CapacityEngine, *,
                 queue_limit: Optional[int] = 1024):
        self.engine = engine
        self.queue_limit = queue_limit
        self._tenants: Dict[str, _Tenant] = {}
        self._queued = 0
        self._seq = 0
        self._rr = 0
        self._closing = False
        self._abort = False
        self._task: Optional["asyncio.Task"] = None
        self._wake: Optional["asyncio.Event"] = None
        self._t_start: Optional[float] = None
        self._t_last_flush: Optional[float] = None
        # observability (tests + throughput reporting)
        self.latencies_s: List[float] = []
        self.fold_log: List[str] = []           # intake order, by tenant
        self.flush_log: List[Tuple[str, float]] = []  # (tenant, slack) order
        self.submitted = 0
        self.rejected = 0
        self.rejection_cost = 0.0
        self.flush_errors = 0

    # ------------------------------------------------------------ tenants
    def add_tenant(self, name: str, lanes, *,
                   n_max: Optional[int] = None,
                   quota: Optional[TenantQuota] = None,
                   on_flush: Optional[Callable] = None) -> WindowSession:
        """Register a tenant with its own WindowSession over the engine.

        Parameters
        ----------
        name : str
            Tenant key used by :meth:`submit` / :meth:`reports`.
        lanes : AdmissionWindow, Scenario, Sequence[Scenario] or ScenarioBatch
            Initial lane set, coerced by ``CapacityEngine.open_window``.
        n_max : int, optional
            Padded class capacity headroom for a fresh window.
        quota : TenantQuota, optional
            Per-tenant budget: submissions past ``max_queued`` not-yet-
            flushed events are rejected with the paper's rejection penalty
            (accounted per tenant, see :meth:`tenant_stats`), and the
            initial window must fit ``max_lanes``.  The daemon-wide
            ``queue_limit`` remains as a backstop across all tenants.
        on_flush : callable, optional
            ``on_flush(report_or_none, tickets)`` invoked after every flush
            covering this tenant — ``None`` report on a failed (poisoned)
            epoch.  The wire server uses it to push flush frames to socket
            tenants; it runs inline in the scheduler, so keep it cheap.

        Returns
        -------
        WindowSession
            The tenant's session (exposed for inspection; drive it through
            the daemon, not directly, or conformance breaks).

        Raises
        ------
        repro.core.engine.QuotaExceededError
            When the initial lane set already exceeds ``quota.max_lanes``.
        """
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        session = self.engine.open_window(lanes, n_max=n_max, quota=quota)
        if self.engine.config.residency == "resident":
            # opt in at registration, not first flush: placement cost lands
            # here instead of inside the first admission's latency, and the
            # tenant's state stays mesh-resident for the daemon's lifetime
            session.window.make_resident(self.engine.config.mesh)
        self._tenants[name] = _Tenant(name, session, quota=quota,
                                      on_flush=on_flush)
        return session

    def tenant_stats(self, name: str) -> Dict[str, float]:
        """Per-tenant admission accounting (the quota observability hook).

        Parameters
        ----------
        name : str
            Tenant key.

        Returns
        -------
        dict
            ``submitted`` / ``rejected`` / ``rejection_cost`` for this
            tenant alone, plus its live ``queued`` backlog, ``flushes``
            and ``events_folded``.
        """
        t = self._tenants[name]
        return {
            "submitted": float(t.submitted),
            "rejected": float(t.rejected),
            "rejection_cost": float(t.rejection_cost),
            "queued": float(t.queued),
            "flushes": float(t.session.flushes),
            "events_folded": float(t.session.events_folded),
        }

    def reports(self, name: str) -> List[WindowSolveReport]:
        """Flush-boundary reports produced so far for tenant `name`.

        Parameters
        ----------
        name : str
            Tenant key.

        Returns
        -------
        list of WindowSolveReport
            In flush order — the daemon-side sequence the conformance
            harness compares against an offline ``stream`` replay.
        """
        return self._tenants[name].reports

    @property
    def tenants(self) -> Tuple[str, ...]:
        """Registered tenant names, in registration order."""
        return tuple(self._tenants)

    # ------------------------------------------------------------ control
    async def start(self) -> None:
        """Start the scheduler task on the current event loop."""
        if self._task is not None:
            raise RuntimeError("daemon already started")
        self._wake = asyncio.Event()
        self._t_start = time.perf_counter()
        self._task = asyncio.get_running_loop().create_task(self._run())

    def submit(self, tenant: str, event: StreamEvent, *,
               t_submit: Optional[float] = None) -> AdmissionTicket:
        """Submit one event; decide backpressure now, flush later.

        Parameters
        ----------
        tenant : str
            Target tenant (must be registered).
        event : StreamEvent
            The admission event to fold into the tenant's window.
        t_submit : float, optional
            Scheduled arrival time on the ``time.perf_counter`` clock.
            Open-loop drivers pass the *intended* arrival time so measured
            admission latency includes queueing delay; defaults to now.

        Returns
        -------
        AdmissionTicket
            ``accepted=False`` (with ``penalty`` set) when the tenant's
            quota (``TenantQuota.max_queued``) or the daemon-wide backstop
            (``queue_limit``) is exhausted; otherwise the ticket resolves
            at the covering flush.
        """
        if self._closing:
            raise RuntimeError("daemon is shutting down")
        t = self._tenants[tenant]
        now = time.perf_counter()
        self._seq += 1
        self.submitted += 1
        t.submitted += 1
        ticket = AdmissionTicket(
            tenant=tenant, event=event, seq=self._seq, accepted=True,
            t_submit=now if t_submit is None else t_submit)
        over_quota = (t.quota is not None
                      and not t.quota.admits_event(t.queued))
        if over_quota or (self.queue_limit is not None
                          and self._queued >= self.queue_limit):
            ticket.accepted = False
            ticket.penalty = rejection_penalty(event)
            ticket.t_done = now
            self.rejected += 1
            self.rejection_cost += ticket.penalty
            t.rejected += 1
            t.rejection_cost += ticket.penalty
            return ticket
        ticket._fut = asyncio.get_running_loop().create_future()
        t.queue.append(ticket)
        self._queued += 1
        if self._wake is not None:
            self._wake.set()
        return ticket

    async def shutdown(self, *, drain: bool = True) -> None:
        """Stop the daemon, gracefully or not.

        Parameters
        ----------
        drain : bool, optional
            ``True`` (graceful): deliver every queued event, then flush
            every trailing partial epoch — afterwards each tenant's report
            list equals the full offline replay of its accepted events.
            ``False`` (abort): discard queued and buffered events, cancel
            their tickets; each session stays at its last flushed state.
        """
        if self._task is None:
            return
        self._closing = True
        self._abort = not drain
        self._wake.set()
        await self._task
        self._task = None

    def request_flush(self, name: str) -> None:
        """Force one tenant's buffered epoch to flush at the next round.

        Marks the session due, so (by the due-sessions-receive-no-events
        invariant) no further intake lands before the flush — the epoch
        boundary moves *earlier*, exactly like an explicit
        ``WindowSession.flush`` call at this point of the tenant's trace.
        A no-op epoch (nothing pending) still produces a flush report
        (the session echoes its current equilibrium), so a wire ``flush``
        request is always answered by a flush frame.

        Parameters
        ----------
        name : str
            Tenant key.
        """
        t = self._tenants[name]
        t.due = True
        if self._wake is not None:
            self._wake.set()

    def detach_tenant(self, name: str) -> None:
        """Drop a tenant's ``on_flush`` callback (e.g. its socket died).

        The tenant stays registered and its reports remain inspectable;
        only the push channel is severed.

        Parameters
        ----------
        name : str
            Tenant key.
        """
        self._tenants[name].on_flush = None

    def drain_tenant(self, name: str) -> None:
        """Deliver ONE tenant's backlog now and flush its trailing partial.

        The single-tenant analog of a graceful shutdown, replaying exactly
        the scheduler's intake semantics (never offer a due session, flush
        between epochs) so the tenant's report list afterwards equals a
        full offline ``session.stream`` replay of its accepted events.
        The wire server calls this when a socket tenant disconnects
        mid-epoch: the accepted prefix is folded and flushed rather than
        left dangling, and later reconnects find a clean session.

        Parameters
        ----------
        name : str
            Tenant key; other tenants are untouched.
        """
        t = self._tenants[name]
        while t.queue:
            if t.due:
                self._flush(t)
            ticket = t.queue.popleft()
            self._queued -= 1
            t.inflight.append(ticket)
            self.fold_log.append(name)
            if t.session.offer(ticket.event):
                t.due = True
        if t.due or t.inflight or t.session.pending:
            self._flush(t)

    # ---------------------------------------------------------- scheduler
    async def _run(self) -> None:
        while True:
            if self._abort:
                break
            worked = self._step()
            if worked:
                # cooperative yield between solve batches so submitters
                # (and the shutdown call) interleave with the scheduler
                await asyncio.sleep(0)
                continue
            if self._closing:
                break
            self._wake.clear()
            if self._closing or self._abort:   # racing shutdown re-set it
                continue
            await self._wake.wait()
        if self._abort:
            self._cancel_outstanding()
        else:
            self._final_flushes()

    def _step(self) -> bool:
        """One fair intake round + slack-ordered flushes. True if worked."""
        worked = False
        names = list(self._tenants)
        if names:
            start = self._rr % len(names)
            self._rr += 1
            for name in names[start:] + names[:start]:
                t = self._tenants[name]
                if t.due or not t.queue:
                    continue
                ticket = t.queue.popleft()
                self._queued -= 1
                t.inflight.append(ticket)
                self.fold_log.append(name)
                if t.session.offer(ticket.event):
                    t.due = True
                worked = True
        due = [t for t in self._tenants.values() if t.due]
        for t in sorted(due, key=lambda t: (t.session.pending_slack(),
                                            t.name)):
            self._flush(t)
            worked = True
        return worked

    def _flush(self, t: _Tenant) -> None:
        tickets, t.inflight = t.inflight, []
        slack = t.session.pending_slack()
        try:
            report = t.session.flush()
        except Exception as exc:   # poisoned epoch: fail it, stay alive
            t.session.discard_pending()
            t.due = False
            self.flush_errors += 1
            for ticket in tickets:
                ticket.cancelled = True
                ticket._fail(exc)
            if t.on_flush is not None:
                t.on_flush(None, tickets)
            return
        now = time.perf_counter()
        self._t_last_flush = now
        t.due = False
        t.reports.append(report)
        self.flush_log.append((t.name, slack))
        slots = t.session.last_slots
        for i, ticket in enumerate(tickets):
            ticket.slot = slots[i] if i < len(slots) else None
            ticket.report = report
            ticket.t_done = now
            self.latencies_s.append(now - ticket.t_submit)
            ticket._resolve(report)
        if t.on_flush is not None:
            t.on_flush(report, tickets)

    def _final_flushes(self) -> None:
        """Graceful-drain tail: flush every trailing partial epoch."""
        trailing = [t for t in self._tenants.values()
                    if t.inflight or t.session.pending]
        for t in sorted(trailing, key=lambda t: (t.session.pending_slack(),
                                                 t.name)):
            self._flush(t)

    def _cancel_outstanding(self) -> None:
        """Abort tail: cancel queued + in-buffer tickets, drop buffers."""
        for t in self._tenants.values():
            t.session.discard_pending()
            t.due = False
            for ticket in list(t.queue) + t.inflight:
                ticket.cancelled = True
                ticket._resolve(None)
            self._queued -= len(t.queue)
            t.queue.clear()
            t.inflight = []

    # ------------------------------------------------------------- report
    def report(self) -> Dict[str, float]:
        """Throughput / latency summary for the run so far.

        Returns
        -------
        dict
            ``events_per_sec`` (folded events over active wall time),
            ``admission_p50_ms`` / ``admission_p99_ms`` (scheduled-arrival
            to flush-completion latency percentiles), plus counters
            (``submitted``, ``accepted``, ``rejected``,
            ``rejection_cost``, ``events_folded``, ``flushes``).
        """
        folded = sum(t.session.events_folded
                     for t in self._tenants.values())
        flushes = sum(t.session.flushes for t in self._tenants.values())
        elapsed = 0.0
        if self._t_start is not None and self._t_last_flush is not None:
            elapsed = max(self._t_last_flush - self._t_start, 1e-9)
        lat = np.asarray(self.latencies_s, dtype=np.float64)
        return {
            "submitted": float(self.submitted),
            "accepted": float(self.submitted - self.rejected),
            "rejected": float(self.rejected),
            "rejection_cost": float(self.rejection_cost),
            "events_folded": float(folded),
            "flushes": float(flushes),
            "elapsed_s": float(elapsed),
            "events_per_sec": float(folded / elapsed) if elapsed else 0.0,
            "admission_p50_ms": float(np.percentile(lat, 50) * 1e3)
            if lat.size else 0.0,
            "admission_p99_ms": float(np.percentile(lat, 99) * 1e3)
            if lat.size else 0.0,
        }


# ---------------------------------------------------------------- drivers
# The arrival-schedule generators live in repro.core.traces (the shared
# workload-trace library, ISSUE 10) so the capacity planner and the daemon
# are driven by identical workloads.  Re-exported here bit-compatibly —
# same functions, same RNG streams — so existing callers, committed
# BENCH_allocd.json sections and the trace-conformance tests are unchanged.
from repro.core.traces import (            # noqa: E402  (re-export)
    ARRIVAL_PROFILES,
    bursty_times,
    diurnal_times,
    flash_crowd_times,
    poisson_times,
    straggler_times,
)


async def drive_open_loop(daemon: AllocDaemon,
                          schedule: Sequence[Tuple[float, str, StreamEvent]],
                          ) -> List[AdmissionTicket]:
    """Submit a timed schedule open-loop and return the tickets.

    Arrivals are submitted at their scheduled offsets regardless of how
    far behind the daemon is (open-loop: queueing delay shows up in the
    measured admission latency, not in the arrival process).  If the
    submitter itself falls behind wall clock, the scheduled time is still
    used as the latency origin.

    Parameters
    ----------
    daemon : AllocDaemon
        A started daemon.
    schedule : sequence of (t_offset, tenant, event)
        Monotone-by-offset submission plan.

    Returns
    -------
    list of AdmissionTicket
        One per schedule entry, in submission order.
    """
    t0 = time.perf_counter()
    tickets: List[AdmissionTicket] = []
    for t_off, tenant, event in schedule:
        delay = (t0 + t_off) - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        tickets.append(daemon.submit(tenant, event, t_submit=t0 + t_off))
    return tickets


def interleave_traces(traces: Dict[str, Sequence[StreamEvent]],
                      times: np.ndarray,
                      ) -> List[Tuple[float, str, StreamEvent]]:
    """Zip per-tenant traces round-robin onto a global arrival schedule.

    Per-tenant event order is preserved (required for replay validity);
    tenants take turns claiming the next global arrival slot until their
    traces are exhausted.

    Parameters
    ----------
    traces : dict of str to sequence of StreamEvent
        Per-tenant traces, in application order.
    times : numpy.ndarray
        Global arrival offsets, at least ``sum(len(t))`` long.

    Returns
    -------
    list of (float, str, StreamEvent)
        The open-loop schedule for :func:`drive_open_loop`.
    """
    cursors = {name: 0 for name in traces}
    order = list(traces)
    schedule: List[Tuple[float, str, StreamEvent]] = []
    k = 0
    while order:
        for name in list(order):
            seq = traces[name]
            i = cursors[name]
            if i >= len(seq):
                order.remove(name)
                continue
            schedule.append((float(times[k]), name, seq[i]))
            cursors[name] = i + 1
            k += 1
    return schedule
