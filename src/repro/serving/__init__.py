from repro.serving.allocd import (ARRIVAL_PROFILES, AdmissionTicket,
                                  AllocDaemon, diurnal_times, drive_open_loop,
                                  flash_crowd_times, interleave_traces,
                                  poisson_times, rejection_penalty)
from repro.serving.client import AllocClient, WireTicket
from repro.serving.engine import generate, pad_attn_cache
from repro.serving.server import AllocServer
from repro.serving.wire import (MAX_FRAME_BYTES, PROTOCOL_VERSION,
                                FrameTooLargeError, MalformedFrameError,
                                ProtocolVersionError, RemoteError,
                                WireError, WireFlushReport)

__all__ = [
    "ARRIVAL_PROFILES",
    "AdmissionTicket",
    "AllocClient",
    "AllocDaemon",
    "AllocServer",
    "FrameTooLargeError",
    "MAX_FRAME_BYTES",
    "MalformedFrameError",
    "PROTOCOL_VERSION",
    "ProtocolVersionError",
    "RemoteError",
    "WireError",
    "WireFlushReport",
    "WireTicket",
    "diurnal_times",
    "drive_open_loop",
    "flash_crowd_times",
    "generate",
    "interleave_traces",
    "pad_attn_cache",
    "poisson_times",
    "rejection_penalty",
]
