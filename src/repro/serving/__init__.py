from repro.serving.allocd import (AdmissionTicket, AllocDaemon,
                                  drive_open_loop, flash_crowd_times,
                                  interleave_traces, poisson_times,
                                  rejection_penalty)
from repro.serving.engine import generate, pad_attn_cache

__all__ = [
    "AdmissionTicket",
    "AllocDaemon",
    "drive_open_loop",
    "flash_crowd_times",
    "generate",
    "interleave_traces",
    "pad_attn_cache",
    "poisson_times",
    "rejection_penalty",
]
