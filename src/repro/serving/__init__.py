from repro.serving.engine import generate, pad_attn_cache

__all__ = ["generate", "pad_attn_cache"]
