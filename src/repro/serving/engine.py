"""Batched serving: prefill + greedy/sampled decode with managed caches."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, prefill
from repro.models.sharding import LOCAL, Distribution


def pad_attn_cache(cache, extra: int):
    """Grow the self-attention KV cache by ``extra`` positions (axis -3)."""
    def walk(path, x):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if len(keys) >= 2 and keys[-2] == "attn" and keys[-1] in ("k", "v"):
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, extra)
            return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(walk, cache)


def generate(cfg, params, prompt_tokens, *, max_new_tokens: int,
             dist: Distribution = LOCAL, temperature: float = 0.0,
             key: Optional[jax.Array] = None, enc_embeds=None):
    """Greedy (or sampled) generation.  prompt_tokens: (B, S_prompt) int32.

    Returns (B, max_new_tokens) int32.  The decode loop is a single jitted
    lax.scan over steps (cache donated between steps).
    """
    B, S0 = prompt_tokens.shape
    batch = {"tokens": prompt_tokens}
    if enc_embeds is not None:
        batch["enc_embeds"] = enc_embeds
    logits, cache = prefill(cfg, params, batch, dist)
    cache = pad_attn_cache(cache, max_new_tokens)

    def sample(lg, k):
        lg = lg[:, -1].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    tok0 = sample(logits, key)

    @partial(jax.jit, donate_argnums=(1,))
    def step(tok, cache, pos, k):
        lg, cache = decode_step(cfg, params, cache, tok, pos, dist)
        return sample(lg, k), cache

    toks = [tok0]
    tok = tok0
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        tok, cache = step(tok, cache, jnp.int32(S0 + i), sub)
        toks.append(tok)
    return jnp.stack(toks, axis=1)
