"""Shared layers: norms, rotary embeddings (incl. M-RoPE), MLPs, embeddings.

Everything is a pure function over explicit param pytrees.  Matmuls accumulate
in f32 (`preferred_element_type`) and normalizations run in f32, which is the
TPU-idiomatic mixed-precision recipe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dot(x, w):
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def norm_init(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"gamma": jnp.zeros((d,), cfg.pdtype)}
    return {"gamma": jnp.ones((d,), cfg.pdtype),
            "beta": jnp.zeros((d,), cfg.pdtype)}


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["gamma"], cfg.rms_eps)
    return layernorm(x, p["gamma"], p["beta"])


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def _rope_freqs(hd_half, theta, dtype=jnp.float32):
    return (theta ** (-jnp.arange(0, hd_half, dtype=dtype) / hd_half))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd // 2, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    angles = angles[..., None, :]                                   # head axis
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta, sections):
    """Qwen2-VL multimodal RoPE: the half-dim frequency bands are split into
    (temporal, height, width) sections, each rotated by its own position ids.

    x: (B, S, H, hd); positions3: (3, B, S) int32; sum(sections) == hd // 2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, "mrope sections must cover hd/2"
    import numpy as np
    freqs = _rope_freqs(hd // 2, theta)
    # static band -> position-stream map: band j uses positions3[sec_id[j]]
    sec_id = np.repeat(np.arange(len(sections)), np.asarray(sections))
    pos = positions3.astype(jnp.float32)                            # (3,B,S)
    pos_bands = pos[sec_id]                                         # (hd/2,B,S)
    angles = jnp.moveaxis(pos_bands, 0, -1) * freqs                 # (B,S,hd/2)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def mlp_init(cfg, key, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wg": dense_init(k1, d, f, cfg.pdtype),
                "wu": dense_init(k2, d, f, cfg.pdtype),
                "wd": dense_init(k3, f, d, cfg.pdtype)}
    return {"wu": dense_init(k1, d, f, cfg.pdtype),
            "bu": jnp.zeros((f,), cfg.pdtype),
            "wd": dense_init(k2, f, d, cfg.pdtype),
            "bd": jnp.zeros((cfg.d_model,), cfg.pdtype)}


def mlp_apply(cfg, p, x):
    if cfg.act == "swiglu":
        g = dot(x, p["wg"])
        u = dot(x, p["wu"])
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        return dot(h, p["wd"]).astype(x.dtype)
    h = dot(x, p["wu"]) + p["bu"].astype(jnp.float32)
    h = jax.nn.gelu(h).astype(x.dtype)
    return (dot(h, p["wd"]) + p["bd"].astype(jnp.float32)).astype(x.dtype)
