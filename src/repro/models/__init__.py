from repro.models.config import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                 PREFILL_32K, SHAPES_BY_NAME, TRAIN_4K,
                                 MambaConfig, ModelConfig, MoEConfig,
                                 ShapeConfig)
from repro.models.sharding import LOCAL, Distribution, named_shardings, param_specs
from repro.models.transformer import (decode_step, encode, forward,
                                      init_cache, init_params, loss_fn,
                                      prefill)

__all__ = [
    "ALL_SHAPES", "DECODE_32K", "LONG_500K", "PREFILL_32K", "SHAPES_BY_NAME",
    "TRAIN_4K", "MambaConfig", "ModelConfig", "MoEConfig", "ShapeConfig",
    "LOCAL", "Distribution", "named_shardings", "param_specs", "decode_step",
    "encode", "forward", "init_cache", "init_params", "loss_fn", "prefill",
]
