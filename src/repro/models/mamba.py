"""Mamba (S6) selective-state-space mixer, as used by Jamba (arXiv:2403.19887).

Selective scan:  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t,
                 y_t = <C_t, h_t> + D * x_t
with per-channel diagonal A (d_in, N).  The chunked path runs an associative
scan *within* chunks (log-depth, MXU/VPU-friendly, correctly counted by cost
analysis) and a lax.scan *across* chunks carrying (h, conv tail).  Jamba's
dt/B/C RMS-norms are included.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def mamba_init(cfg, key):
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    R = mc.rank(d)
    N = mc.d_state
    ks = jax.random.split(key, 6)
    pd = cfg.pdtype
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (d_in, 1))
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * d_in, pd),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, d_in), jnp.float32)
                   * (1.0 / mc.d_conv)).astype(pd),
        "conv_b": jnp.zeros((d_in,), pd),
        "x_proj": layers.dense_init(ks[2], d_in, R + 2 * N, pd),
        "dt_w": layers.dense_init(ks[3], R, d_in, pd, scale=R ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (d_in,), jnp.float32)
                    * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
        )).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers.dense_init(ks[5], d_in, d, pd),
        "dt_norm": jnp.zeros((R,), pd),
        "b_norm": jnp.zeros((N,), pd),
        "c_norm": jnp.zeros((N,), pd),
    }


def _ssm_scan_chunked(decay, inc, h0, *, chunk, loops):
    """h_t = decay_t * h_{t-1} + inc_t over axis 1.  (B,T,d_in,N) f32."""
    B, T, d_in, N = decay.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    n = T // chunk
    dec = decay.reshape(B, n, chunk, d_in, N)
    inc = inc.reshape(B, n, chunk, d_in, N)

    def combine(a, b):
        (ad, ai), (bd, bi) = a, b
        return ad * bd, ai * bd + bi

    def one_chunk(h, ci):
        dc = jax.lax.dynamic_index_in_dim(dec, ci, 1, keepdims=False)
        ic = jax.lax.dynamic_index_in_dim(inc, ci, 1, keepdims=False)
        cum_d, cum_i = jax.lax.associative_scan(combine, (dc, ic), axis=1)
        h_all = cum_d * h[:, None] + cum_i                 # (B,chunk,d_in,N)
        return h_all[:, -1], h_all

    if loops == "scan":
        h, ys = jax.lax.scan(one_chunk, h0, jnp.arange(n))
        ys = jnp.moveaxis(ys, 0, 1)                        # (B,n,chunk,...)
    else:
        h, parts = h0, []
        for ci in range(n):
            h, y = one_chunk(h, ci)
            parts.append(y)
        ys = jnp.stack(parts, axis=1)
    return ys.reshape(B, T, d_in, N), h


def _causal_conv(x, w, b, tail):
    """Depthwise causal conv1d via shifted adds.  x: (B,T,d_in); w: (dc,d_in);
    tail: (B, dc-1, d_in) history (zeros at sequence start)."""
    dc = w.shape[0]
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = jnp.zeros(x.shape, jnp.float32)
    T = x.shape[1]
    for j in range(dc):
        out = out + xp[:, j:j + T].astype(jnp.float32) * w[j].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return out.astype(x.dtype), xp[:, -(dc - 1):]


def mamba_mixer(cfg, p, x, state, *, loops="scan", chunk=64):
    """x: (B,T,d). state: {"h": (B,d_in,N) f32, "conv": (B,dc-1,d_in)} or None."""
    mc = cfg.mamba
    B, T, d = x.shape
    d_in = mc.expand * d
    N = mc.d_state
    R = mc.rank(d)
    if state is None:
        state = {"h": jnp.zeros((B, d_in, N), jnp.float32),
                 "conv": jnp.zeros((B, mc.d_conv - 1, d_in), x.dtype)}

    xz = layers.dot(x, p["in_proj"]).astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = _causal_conv(xi, p["conv_w"], p["conv_b"], state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    proj = layers.dot(xc, p["x_proj"])                     # (B,T,R+2N) f32
    dt_low, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt_low = layers.rmsnorm(dt_low, p["dt_norm"])
    Bc = layers.rmsnorm(Bc, p["b_norm"]).astype(jnp.float32)
    Cc = layers.rmsnorm(Cc, p["c_norm"]).astype(jnp.float32)
    dt = jax.nn.softplus(layers.dot(dt_low, p["dt_w"])
                         + p["dt_bias"].astype(jnp.float32))  # (B,T,d_in) f32

    A = -jnp.exp(p["A_log"])                               # (d_in,N)
    decay = jnp.exp(dt[..., None] * A[None, None])         # (B,T,d_in,N)
    inc = (dt * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

    h_all, h_last = _ssm_scan_chunked(decay, inc, state["h"],
                                      chunk=chunk, loops=loops)
    y = jnp.einsum("btdn,btn->btd", h_all, Cc)             # f32
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = layers.dot(y, p["out_proj"]).astype(x.dtype)
    return out, {"h": h_last, "conv": conv_tail}
