"""Mixture-of-Experts with shard_map expert parallelism.

Design (DESIGN.md Sec. 5): routing is computed in the GSPMD region (so the
load-balance aux loss is free); dispatch/compute/combine run inside a
``shard_map`` over the whole mesh with experts sharded on the ``model`` axis:

* every model-rank sees the full data-shard's tokens (TP activations are
  replicated over ``model``), routes them redundantly (cheap), and *gathers
  only the tokens destined to its local experts* — no all-to-all and no
  phantom one-hot dispatch FLOPs (cf. GShard dispatch einsums);
* per-expert capacity ``C = ceil(T*k*cf/E)`` bounds the gather buffer — this
  is the paper's admission-control idea applied at the token->expert level:
  over-capacity tokens are "rejected" (dropped) exactly like jobs beyond
  ``H_i^up``;
* local expert outputs scatter-add into a partial (T, d) buffer which is
  ``psum`` over ``model`` — the same collective a Megatron MLP already pays.

With FSDP, expert weights arrive sharded on the hidden dim over ``data`` and
are all-gathered inside the block (per-layer FSDP gather); the backward pass
reduce-scatters automatically through shard_map's collective transposes.

``moe_dense_ref`` is the no-drop oracle used by the tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.sharding import Distribution


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def moe_init(cfg, key):
    mo = cfg.moe
    d, f, E = cfg.d_model, mo.d_ff_expert, mo.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": {"wr_router": layers.dense_init(ks[0], d, E, jnp.float32)},
        "experts": {
            "wg": _expert_init(ks[1], E, d, f, cfg.pdtype),
            "wu": _expert_init(ks[2], E, d, f, cfg.pdtype),
            "wd": _expert_init(ks[3], E, f, d, cfg.pdtype),
        },
    }
    if mo.n_shared:
        p["shared"] = layers.mlp_init(cfg, ks[4], d_ff=mo.n_shared * f)
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    x = jax.random.normal(key, (E, d_in, d_out), jnp.float32) * d_in ** -0.5
    return x.astype(dtype)


# --------------------------------------------------------------------------
# routing (GSPMD region)
# --------------------------------------------------------------------------

def route(cfg, p, x):
    """Top-k routing. x: (B,S,d) -> gates (B,S,k) f32, idx (B,S,k) i32, aux."""
    mo = cfg.moe
    logits = layers.dot(x, p["router"]["wr_router"])       # (B,S,E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, mo.top_k)
    if mo.renorm_top_k:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style load-balance loss
    E = mo.n_experts
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    one_hot_top1 = jax.nn.one_hot(idx[..., 0].reshape(-1), E,
                                  dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = (E * jnp.sum(me * ce)).astype(jnp.float32)
    return gates, idx, aux


# --------------------------------------------------------------------------
# dispatch / compute / combine (per-device body)
# --------------------------------------------------------------------------

def _moe_body(cfg, experts, x, gates, idx, *, n_shards, shard_id,
              dgather_axis=None, psum_axis=None):
    """Per-device MoE over local tokens x: (T, d).

    experts: local slice {"wg": (E_loc,d,f), ...} (hidden dim possibly
    sharded over ``dgather_axis`` -> all-gathered here).
    """
    mo = cfg.moe
    E, k = mo.n_experts, mo.top_k
    E_loc = E // n_shards
    T, d = x.shape

    if dgather_axis is not None:
        experts = {
            "wg": jax.lax.all_gather(experts["wg"], dgather_axis, axis=1,
                                     tiled=True),
            "wu": jax.lax.all_gather(experts["wu"], dgather_axis, axis=1,
                                     tiled=True),
            "wd": jax.lax.all_gather(experts["wd"], dgather_axis, axis=2,
                                     tiled=True),
        }

    cap = int(-(-T * k * mo.capacity_factor // E))
    cap = max(8, -(-cap // 8) * 8)

    e_flat = idx.reshape(-1)                                # (T*k,)
    g_flat = gates.reshape(-1).astype(jnp.float32)
    tok_flat = jnp.repeat(jnp.arange(T), k)

    # position of each (token, expert) pair within its expert's queue
    sort_ix = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[sort_ix]
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[e_sorted]

    tok_sorted = tok_flat[sort_ix]
    g_sorted = g_flat[sort_ix]

    # local-expert slots; invalid -> OOB index (dropped by scatter mode)
    e_local = e_sorted - shard_id * E_loc
    valid = (e_local >= 0) & (e_local < E_loc) & (pos < cap)
    slot = jnp.where(valid, e_local * cap + pos, E_loc * cap)

    # Invert pair->slot so all buffers are (E_loc*cap, ...) — never (T*k, d).
    tok_for_slot = jnp.zeros((E_loc * cap,), jnp.int32).at[slot].set(
        tok_sorted.astype(jnp.int32), mode="drop")
    g_for_slot = jnp.zeros((E_loc * cap,), jnp.float32).at[slot].set(
        g_sorted, mode="drop")

    x_g = x[tok_for_slot].reshape(E_loc, cap, d)   # empty slots read token 0

    g = jnp.einsum("ecd,edf->ecf", x_g, experts["wg"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x_g, experts["wu"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, experts["wd"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = y.reshape(E_loc * cap, d)

    out = jnp.zeros((T, d), x.dtype)
    out = out.at[tok_for_slot].add(
        y * g_for_slot[:, None].astype(x.dtype), mode="drop")
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def moe_apply(cfg, p, x, gates, idx, dist: Distribution):
    """Routed-experts output (+ shared experts if configured).

    x: (B, S, d); gates/idx: (B, S, k).  Under a mesh, runs the dispatch in a
    shard_map with experts on ``model``; without a mesh runs locally.
    """
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    gf, idf = gates.reshape(B * S, -1), idx.reshape(B * S, -1)

    if dist.mesh is None or dist.tp is None:
        out = _moe_body(cfg, p["experts"], xf, gf, idf,
                        n_shards=1, shard_id=0)
    else:
        tp = dist.tp
        fa = dist.fsdp_axis
        mesh = dist.mesh
        n_shards = dist.tp_size()
        espec = {"wg": P(tp, fa, None), "wu": P(tp, fa, None),
                 "wd": P(tp, None, fa)}
        dp_size = 1
        for a in dist.dp_axes:
            dp_size *= mesh.shape[a]
        # tokens split over dp when divisible (train/prefill); tiny decode
        # batches are routed redundantly on every dp rank instead
        dp = P(dist.dp_axes) if (B * S) % dp_size == 0 else P(None)

        def body(experts, xl, gl, il):
            sid = jax.lax.axis_index(tp)
            return _moe_body(cfg, experts, xl, gl, il, n_shards=n_shards,
                             shard_id=sid, dgather_axis=fa, psum_axis=tp)

        import inspect
        kw = ({"check_vma": False}
              if "check_vma" in inspect.signature(shard_map).parameters
              else {"check_rep": False})
        out = shard_map(
            body, mesh=mesh,
            in_specs=(espec, dp, dp, dp),
            out_specs=dp,
            **kw,
        )(p["experts"], xf, gf, idf)

    out = out.reshape(B, S, d)
    if cfg.moe.n_shared:
        out = out + layers.mlp_apply(cfg, p["shared"], x)
    return out


def moe_dense_ref(cfg, p, x, gates, idx):
    """No-drop oracle: evaluates every selected expert densely."""
    mo = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    out = jnp.zeros((B * S, d), jnp.float32)
    for j in range(mo.top_k):
        e = idx.reshape(B * S, -1)[:, j]
        g = gates.reshape(B * S, -1)[:, j]
        # per-token expert weights (gather) — O(T*d*f) memory, tests only
        wg = p["experts"]["wg"][e]
        wu = p["experts"]["wu"][e]
        wd = p["experts"]["wd"][e]
        a = jnp.einsum("td,tdf->tf", xf.astype(jnp.float32),
                       wg.astype(jnp.float32))
        b = jnp.einsum("td,tdf->tf", xf.astype(jnp.float32),
                       wu.astype(jnp.float32))
        h = jax.nn.silu(a) * b
        y = jnp.einsum("tf,tfd->td", h, wd.astype(jnp.float32))
        out = out + y * g[:, None].astype(jnp.float32)
    out = out.astype(x.dtype).reshape(B, S, d)
    if mo.n_shared:
        out = out + layers.mlp_apply(cfg, p["shared"], x)
    return out
