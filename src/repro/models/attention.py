"""Attention: GQA flash-style chunked softmax attention in pure jnp.

Three execution modes (cfg-controlled via ``loops``):

* ``scan``     — lax.scan over kv chunks with running (m, l, acc); O(S*chunk)
                 memory.  The production runtime path: a 32k-token prefill
                 never materializes the S x S score matrix.
* ``unroll``   — identical math with python loops (static HLO).  Used when
                 lowering layer bodies for roofline cost measurement, because
                 XLA's cost analysis counts a while-loop body exactly once
                 (verified; see DESIGN.md) and would undercount scanned FLOPs.
* ``dense``    — single full-score einsum; same FLOPs as masked ``scan``,
                 smallest HLO.  Cost-measurement default for non-causal /
                 baseline-causal cells (never executed at large S).

``triangle=True`` (causal only) skips fully-masked kv blocks: q-chunk i only
visits kv chunks 0..i.  This halves attention FLOPs exactly — a beyond-paper
performance lever recorded in EXPERIMENTS.md §Perf.  It implies ``unroll``.

The Pallas flash-attention kernel (repro/kernels/flash_attention) is the TPU
drop-in for the ``scan`` path; it is validated against `reference` here.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def reference(q, k, v, *, causal, q_offset=0, kv_len=None):
    """Pure O(S^2)-memory oracle (also ref.py for the Pallas kernel)."""
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s *= dh ** -0.5
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, dh).astype(q.dtype)


def _chunk_step(qc, kc, vc, m, l, acc, qpos, kpos, causal, kv_len, scale):
    """One (q-chunk x kv-chunk) flash update in f32."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l = l * corr + jnp.sum(p, axis=-1)
    acc = acc * corr[..., None] + jnp.einsum(
        "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
    return m_new, l, acc


def attention(q, k, v, *, causal=True, q_offset=0, kv_len=None,
              q_chunk=1024, kv_chunk=1024, loops="scan", triangle=False):
    """GQA attention.  q: (B,Sq,Hq,dh); k,v: (B,Skv,Hkv,dh) -> (B,Sq,Hq,dh).

    ``kv_len``: scalar (traced ok) valid-length mask for decode caches.
    """
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = dh ** -0.5

    if triangle:
        assert causal, "triangle blocking is causal-only"
        loops = "unroll"

    if loops == "dense" or (Sq * Skv <= q_chunk * kv_chunk):
        return reference(q, k, v, causal=causal, q_offset=q_offset,
                         kv_len=kv_len)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    if Sq % q_chunk or Skv % kv_chunk:
        # production shapes are chunk-divisible; odd sizes (tests, tails)
        # fall back to the dense oracle
        return reference(q, k, v, causal=causal, q_offset=q_offset,
                         kv_len=kv_len)
    nq, nk = Sq // q_chunk, Skv // kv_chunk

    qr = q.reshape(B, nq, q_chunk, Hkv, G, dh)
    kr = k.reshape(B, nk, kv_chunk, Hkv, dh)
    vr = v.reshape(B, nk, kv_chunk, Hkv, dh)

    def one_q_chunk(qi, qc, nk_visit):
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, dh), jnp.float32)

        def body(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            return _chunk_step(qc, kc, vc, m, l, acc, qpos, kpos,
                               causal, kv_len, scale), None

        if loops == "scan":
            # flash-style bwd: recompute the block softmax instead of saving
            # per-step probability matrices as scan residuals
            (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                          jnp.arange(nk_visit))
        else:  # unroll
            m, l, acc = m0, l0, a0
            for ki in range(nk_visit):
                (m, l, acc), _ = body((m, l, acc), ki)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, Hkv, G, q_chunk, dh)

    if triangle:
        outs = [one_q_chunk(qi, qr[:, qi], min(nk, qi * q_chunk // kv_chunk + 1))
                for qi in range(nq)]
        out = jnp.stack(outs, axis=3)          # (B,Hkv,G,nq,q_chunk,dh)
    elif loops == "unroll":
        outs = [one_q_chunk(qi, qr[:, qi], nk) for qi in range(nq)]
        out = jnp.stack(outs, axis=3)
    else:
        qr_t = jnp.moveaxis(qr, 1, 0)          # (nq,B,q_chunk,Hkv,G,dh)

        def scan_q(_, qi_qc):
            qi, qc = qi_qc
            return None, one_q_chunk(qi, qc, nk)

        _, out = jax.lax.scan(scan_q, None, (jnp.arange(nq), qr_t))
        out = jnp.moveaxis(out, 0, 3)          # (B,Hkv,G,nq,q_chunk,dh)

    out = jnp.moveaxis(out, (1, 2), (3, 4))    # (B,nq,q_chunk,Hkv,G,dh)
    return out.reshape(B, Sq, Hq, dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_len, dist=None,
                     seq_sharded=False):
    """Single-token decode: q (B,1,Hq,dh) vs cache (B,Smax,Hkv,dh).

    Dense over the cache (scores are (B,H,Smax): small), masked at kv_len.
    With ``seq_sharded`` (cache sharded on S over the TP axis), sharding
    constraints pin the distributed-flash schedule: scores/softmax stay
    S-sharded (local cache reads; only tiny max/sum/output all-reduces) —
    without them GSPMD all-gathers the V cache (measured 55 MB/layer on
    qwen2-vl decode_32k; see EXPERIMENTS.md §Perf).
    """
    if not seq_sharded or dist is None or dist.tp is None:
        return reference(q, k_cache, v_cache, causal=False, kv_len=kv_len)
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                   k_cache.astype(jnp.float32)) * dh ** -0.5
    s = dist.constrain(s, dist.dp_axes, None, None, None, dist.tp)
    kpos = jnp.arange(Skv)
    s = jnp.where((kpos < kv_len)[None, None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)          # all-reduce max (tiny)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)          # all-reduce sum (tiny)
    p = dist.constrain(p / l, dist.dp_axes, None, None, None, dist.tp)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    o = dist.constrain(o.reshape(B, Sq, Hq, dh),
                       dist.dp_axes, None, None, None)
    return o.astype(q.dtype)
