"""Distribution context + sharding rules.

``Distribution`` carries the mesh and axis names through the model code; with
``mesh=None`` everything degrades to single-device semantics (used by CPU
smoke tests).  Parameter PartitionSpecs follow Megatron-style tensor
parallelism on the ``model`` axis, with optional FSDP sharding of the
d_model/d_ff dimension over the ``data`` axis for large architectures
(DESIGN.md Sec. 5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Distribution:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)   # ("pod","data") on multi-pod
    tp_axis: Optional[str] = "model"
    fsdp: bool = False

    @property
    def dp(self):
        return self.dp_axes if self.mesh is not None else None

    @property
    def tp(self):
        return self.tp_axis if self.mesh is not None else None

    @property
    def fsdp_axis(self):
        # FSDP shards the hidden param dim over the innermost dp axis ("data")
        return self.dp_axes[-1] if (self.fsdp and self.mesh is not None) else None

    def constrain(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]


LOCAL = Distribution(mesh=None)


def _head_axis(dist: Distribution, n: int):
    """Shard a head-count dim on tp when it divides evenly; else let GSPMD
    pad (documented per-cell in the roofline table)."""
    return dist.tp


def param_specs(cfg, params, dist: Distribution):
    """PartitionSpec pytree matching ``params`` (path-based rules)."""
    fa = dist.fsdp_axis
    tp = dist.tp

    def spec_for(path: str, x):
        nd = x.ndim
        stacked = path.startswith("blocks/") or path.startswith("enc_blocks/") \
            or path.startswith("dec_blocks/")
        lead = (None,) if stacked else ()
        core = nd - len(lead)

        def S(*s):
            return P(*(lead + s))

        leaf = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""
        if leaf in ("embed", "unembed_w"):
            return P(tp, fa) if leaf == "embed" else P(fa, tp)
        if leaf == "pos_embed":
            return P(None, fa)
        if parent == "experts" or parent.endswith("experts"):
            # (E, d, f) / (E, f, d): experts on tp, hidden dim on fsdp
            return S(tp, fa, None) if core == 3 else S(tp, None)
        if leaf in ("wq", "wk", "wv", "wg", "wu"):        # column parallel
            return S(fa, tp) if core == 2 else S(None)
        if leaf in ("wo", "wd"):                          # row parallel
            return S(tp, fa) if core == 2 else S(None)
        if leaf == "wr_router":
            return S(None, None)
        if leaf in ("in_proj",):                          # mamba (d, 2*d_in)
            return S(fa, tp)
        if leaf in ("out_proj",):                         # mamba (d_in, d)
            return S(tp, fa)
        if leaf in ("A_log", "x_proj"):                   # (d_in, *)
            return S(tp, None)
        if leaf in ("D", "dt_bias", "conv_b"):            # (d_in,)
            return S(tp)
        if leaf in ("conv_w",):                           # (d_conv, d_in)
            return S(None, tp)
        if leaf in ("dt_w",):                             # (dt_rank, d_in)
            return S(None, tp)
        if leaf == "rwkv_wo":                             # (d, d) row parallel
            return S(tp, fa)
        if leaf.startswith("rwkv_w"):
            # rwkv projections (d, d): column-parallel on the head dim
            return S(fa, tp) if core == 2 else S(*([None] * core))
        return S(*([None] * core))

    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)

    specs = {path_str(kp): spec_for(path_str(kp), x) for kp, x in flat}
    treedef = jax.tree_util.tree_structure(params)
    leaves = [specs[path_str(kp)] for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def named_shardings(cfg, params, dist: Distribution):
    specs = param_specs(cfg, params, dist)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(dist.mesh, s), specs)
