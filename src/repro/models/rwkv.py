"""RWKV-6 "Finch" blocks (arXiv:2404.05892): data-dependent-decay linear
attention (time-mix) + squared-ReLU channel-mix.

Three evaluation paths:
* ``wkv_recurrent``  — exact per-step recurrence (lax.scan over time); the
  oracle for tests and the Pallas kernel's ref.
* ``wkv_chunked``    — chunk-parallel form: intra-chunk attention-like matmuls
  with cumulative-decay factors (log-space, exponent-clamped at +-30 for
  stability; error <= e^-30 relative, see DESIGN.md), inter-chunk state carry.
  This is the production path: O(T/L) sequential steps, MXU-friendly matmuls.
* single-step ``wkv_step`` — decode.

State per layer: S (B,H,K,V) + token-shift tails for time/channel mix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

TM_LORA = 32
DECAY_LORA = 64
CLAMP = 30.0


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def time_mix_init(cfg, key):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    pd = cfg.pdtype

    def vec(k, scale=0.5):
        return (jax.random.uniform(k, (d,), jnp.float32) * scale).astype(pd)

    return {
        "mu_x": vec(ks[0]), "mu_w": vec(ks[1]), "mu_k": vec(ks[2]),
        "mu_v": vec(ks[3]), "mu_r": vec(ks[4]), "mu_g": vec(ks[5]),
        "tm_lora_a": layers.dense_init(ks[6], d, 5 * TM_LORA, pd, scale=0.01),
        "tm_lora_b": (jax.random.normal(ks[7], (5, TM_LORA, d), jnp.float32)
                      * 0.01).astype(pd),
        "w0": (jax.random.normal(ks[8], (d,), jnp.float32) * 0.3 - 0.6
               ).astype(jnp.float32),
        "wA": layers.dense_init(ks[9], d, DECAY_LORA, pd, scale=0.01),
        "wB": layers.dense_init(ks[10], DECAY_LORA, d, pd, scale=0.01),
        "u": (jax.random.normal(ks[11], (H, hd), jnp.float32) * 0.3
              ).astype(jnp.float32),
        "rwkv_wr": layers.dense_init(ks[0], d, d, pd),
        "rwkv_wk": layers.dense_init(ks[1], d, d, pd),
        "rwkv_wv": layers.dense_init(ks[2], d, d, pd),
        "rwkv_wg": layers.dense_init(ks[3], d, d, pd),
        "rwkv_wo": layers.dense_init(ks[4], d, d, pd),
        "gn_gamma": jnp.ones((d,), pd),
        "gn_beta": jnp.zeros((d,), pd),
    }


def channel_mix_init(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    pd = cfg.pdtype
    return {
        "mu_ck": (jax.random.uniform(ks[0], (d,), jnp.float32) * 0.5).astype(pd),
        "mu_cr": (jax.random.uniform(ks[1], (d,), jnp.float32) * 0.5).astype(pd),
        "wu": layers.dense_init(ks[2], d, f, pd),
        "wd": layers.dense_init(ks[3], f, d, pd),
        "rwkv_wr_c": layers.dense_init(ks[4], d, d, pd),
    }


# --------------------------------------------------------------------------
# WKV core
# --------------------------------------------------------------------------

def wkv_recurrent(r, k, v, w_log, u, S0):
    """Oracle recurrence.  r/k/v/w_log: (B,T,H,K); u: (H,K); S0: (B,H,K,V)."""

    def step(S, inp):
        rt, kt, vt, wt = inp                                  # (B,H,K)
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       S + u[None, :, :, None] * kt[..., None] * vt[..., None, :])
        S = jnp.exp(wt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = jax.tree_util.tree_map(lambda x: jnp.moveaxis(x, 1, 0), (r, k, v, w_log))
    S, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S                          # (B,T,H,V), state


def wkv_step(r, k, v, w_log, u, S):
    """Single decode step. r/k/v/w_log: (B,H,K)."""
    y = jnp.einsum("bhk,bhkv->bhv", r,
                   S + u[None, :, :, None] * k[..., None] * v[..., None, :])
    S = jnp.exp(w_log)[..., None] * S + k[..., None] * v[..., None, :]
    return y, S


def wkv_chunked(r, k, v, w_log, u, S0, *, chunk=64, loops="scan"):
    """Chunk-parallel WKV.  Shapes as in wkv_recurrent."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    if T % chunk:
        raise ValueError(f"T={T} must be divisible by chunk={chunk}")
    n = T // chunk

    def resh(x):
        return jnp.moveaxis(x.reshape(B, n, chunk, H, K), 3, 2)  # (B,n,H,L,K)

    r_, k_, v_, w_ = map(resh, (r, k, v, w_log))

    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def one_chunk(S, ci):
        rc, kc, vc, wc = (x[:, ci].astype(jnp.float32)
                          for x in (r_, k_, v_, w_))      # (B,H,L,K)
        LW = jnp.cumsum(wc, axis=2)                       # LW_t = sum_{1..t}
        LWp = LW - wc                                     # LW_{t-1}
        Z = LW[:, :, chunk // 2][:, :, None, :]           # per-channel ref
        Q = rc * jnp.exp(jnp.clip(LWp - Z, -CLAMP, CLAMP))
        Kf = kc * jnp.exp(jnp.clip(Z - LW, -CLAMP, CLAMP))
        A = jnp.einsum("bhlk,bhmk->bhlm", Q, Kf)
        A = jnp.where(causal[None, None], A, 0.0)
        diag = jnp.einsum("bhlk,hk,bhlk->bhl", rc, u, kc)
        inter = jnp.einsum("bhlk,bhkv->bhlv", rc * jnp.exp(LWp), S)
        y = (jnp.einsum("bhlm,bhmv->bhlv", A, vc)
             + diag[..., None] * vc + inter)              # (B,H,L,V)
        LW_end = LW[:, :, -1]                             # (B,H,K)
        K2 = kc * jnp.exp(LW_end[:, :, None, :] - LW)     # exponent <= 0
        S = (jnp.exp(LW_end)[..., None] * S
             + jnp.einsum("bhlk,bhlv->bhkv", K2, vc))
        return S, y

    if loops == "scan":
        S, ys = jax.lax.scan(one_chunk, S0.astype(jnp.float32),
                             jnp.arange(n))
    else:
        S = S0.astype(jnp.float32)
        ys = []
        for ci in range(n):
            S, y = one_chunk(S, ci)
            ys.append(y)
        ys = jnp.stack(ys)
    # ys: (n,B,H,L,V) -> (B,T,H,V)
    out = jnp.moveaxis(ys, 0, 1)                          # (B,n,H,L,V)
    out = jnp.moveaxis(out, 2, 3).reshape(B, T, H, V)
    return out.astype(r.dtype), S


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _ddlerp(p, x, sx):
    """Data-dependent token-shift interpolation (the RWKV6 'ddlerp')."""
    xx = sx - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    lo = jnp.tanh(layers.dot(xxx, p["tm_lora_a"]))        # (B,T,5*32) f32
    lo = lo.reshape(*lo.shape[:-1], 5, TM_LORA)
    mods = jnp.einsum("btsk,skd->sbtd", lo,
                      p["tm_lora_b"].astype(jnp.float32))  # (5,B,T,d)
    outs = []
    for i, mu in enumerate(("mu_w", "mu_k", "mu_v", "mu_r", "mu_g")):
        mix = p[mu].astype(jnp.float32) + mods[i]
        outs.append((x.astype(jnp.float32)
                     + xx.astype(jnp.float32) * mix).astype(x.dtype))
    return outs                                           # xw, xk, xv, xr, xg


def _group_norm(x, gamma, beta, H, eps=64e-5):
    """Per-head layer norm over the head channel (RWKV GroupNorm(H, d))."""
    B, T, d = x.shape
    xr = x.reshape(B, T, H, d // H).astype(jnp.float32)
    mu = xr.mean(-1, keepdims=True)
    var = xr.var(-1, keepdims=True)
    xr = (xr - mu) * jax.lax.rsqrt(var + eps)
    out = xr.reshape(B, T, d) * gamma.astype(jnp.float32) \
        + beta.astype(jnp.float32)
    return out.astype(x.dtype)


def time_mix(cfg, p, x, state, *, loops="scan", chunk=64):
    """x: (B,T,d); state: {"S": (B,H,K,V), "shift": (B,d)} or None."""
    B, T, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    if state is None:
        state = {"S": jnp.zeros((B, H, hd, hd), jnp.float32),
                 "shift": jnp.zeros((B, d), x.dtype)}
    sx = jnp.concatenate([state["shift"][:, None], x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)

    def heads(z, w):
        return layers.dot(z, w).astype(x.dtype).reshape(B, T, H, hd)

    r = heads(xr, p["rwkv_wr"])
    kk = heads(xk, p["rwkv_wk"])
    v = heads(xv, p["rwkv_wv"])
    g = layers.dot(xg, p["rwkv_wg"])
    w_log = -jnp.exp(p["w0"].astype(jnp.float32)
                     + layers.dot(jnp.tanh(layers.dot(xw, p["wA"])),
                                  p["wB"]))
    w_log = jnp.clip(w_log, -8.0, -1e-5).reshape(B, T, H, hd)

    u = p["u"].astype(jnp.float32)
    if T == 1:
        y, S = wkv_step(r[:, 0].astype(jnp.float32),
                        kk[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32),
                        w_log[:, 0], u, state["S"])
        y = y[:, None]
    elif T <= chunk:
        y, S = wkv_recurrent(r.astype(jnp.float32), kk.astype(jnp.float32),
                             v.astype(jnp.float32), w_log, u, state["S"])
    else:
        y, S = wkv_chunked(r.astype(jnp.float32), kk.astype(jnp.float32),
                           v.astype(jnp.float32), w_log, u, state["S"],
                           chunk=chunk, loops=loops)
    y = y.reshape(B, T, d).astype(x.dtype)
    y = _group_norm(y, p["gn_gamma"], p["gn_beta"], H)
    y = (y * jax.nn.silu(g).astype(x.dtype))
    out = layers.dot(y, p["rwkv_wo"]).astype(x.dtype)
    new_state = {"S": S, "shift": x[:, -1]}
    return out, new_state


def channel_mix(cfg, p, x, shift_state):
    """Squared-ReLU channel mix. shift_state: (B,d) or None."""
    if shift_state is None:
        shift_state = jnp.zeros((x.shape[0], x.shape[-1]), x.dtype)
    sx = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    xx = sx - x
    xk = x + xx * p["mu_ck"].astype(x.dtype)
    xr = x + xx * p["mu_cr"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(layers.dot(xk, p["wu"]))).astype(x.dtype)
    out = jax.nn.sigmoid(layers.dot(xr, p["rwkv_wr_c"])).astype(x.dtype) \
        * layers.dot(kk, p["wd"]).astype(x.dtype)
    return out, x[:, -1]
