"""Model configuration covering the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> ceil(d_model / 16)

    def rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, -(-d_model // 16))


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0       # per-expert hidden width
    n_shared: int = 0          # always-active shared experts (DeepSeekMoE)
    first_k_dense: int = 0     # leading dense layers (kept out of the scan)
    every: int = 1             # MoE layer stride (Jamba: 2)
    capacity_factor: float = 1.25
    renorm_top_k: bool = True  # DeepSeek-style renormalized gates


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    # --- attention flavor ---
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim split
    # --- block pattern ---
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: bool = False
    rwkv_head_dim: int = 64
    attn_every: int = 1        # hybrid: 1 attention per this many layers
    attn_offset: int = 0       # position of the attn layer inside a block
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    max_positions: int = 0     # learned positional embedding table (0 = RoPE)
    # --- misc ---
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "swiglu"        # swiglu | gelu
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # --- numerics / execution ---
    dtype: str = "bfloat16"        # activations
    param_dtype: str = "bfloat16"
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    attn_triangle: bool = False    # unrolled lower-triangle blocking (skips
                                   # fully-masked kv blocks; exact FLOP savings)
    seq_parallel: bool = False     # Megatron-SP: residual stream sharded on S
                                   # over 'model' (norms distributed; TP
                                   # all-reduces become RS/AG pairs)
    remat: str = "full"            # none | dots | full
    loss_chunks: int = 8           # unembed+loss token chunking (memory)
    grad_accum: int = 1            # microbatches per train step (unrolled)
    scan_layers: bool = True
    fsdp: bool = False             # shard the d_model/d_ff param dim on 'data'
    kv_cache_seq_shard: bool = False  # sequence-sharded KV cache (CP decode)
    flash_decode: bool = True      # constrained distributed-flash decode over
                                   # S-sharded caches (False = naive baseline)
    use_pallas: bool = False       # TPU: Pallas flash-attention / wkv kernels

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def block_len(self) -> int:
        """Scan super-block length (LCM of the layer-pattern periods)."""
        import math
        period = self.attn_every
        if self.moe is not None:
            period = math.lcm(period, self.moe.every)
        return period

    def layer_kinds(self) -> list:
        """Static per-layer (mixer, ffn) kinds, after first_k_dense."""
        first = self.moe.first_k_dense if self.moe else 0
        kinds = []
        for i in range(self.n_layers):
            if self.rwkv:
                mixer = "rwkv"
            elif self.mamba is not None and self.attn_every > 1:
                mixer = ("attn" if i % self.attn_every == self.attn_offset
                         else "mamba")
            elif self.mamba is not None:
                mixer = "mamba"
            else:
                mixer = "attn"
            if self.rwkv:
                ffn = "rwkv_cmix"
            elif self.moe is not None and i >= first and \
                    i % self.moe.every == (self.moe.every - 1 if self.moe.every > 1 else 0):
                ffn = "moe"
            else:
                ffn = "dense"
            kinds.append((mixer, ffn))
        return kinds

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}
