"""Composable decoder-only / encoder-decoder LM covering all 10 assigned
architectures: GQA (+qk-norm, RoPE/M-RoPE), dense & MoE FFNs, RWKV6, Mamba,
hybrid interleaves, and the whisper enc-dec (stubbed audio frontend).

Everything is a pure function of (cfg, params, batch); distribution enters
only through ``Distribution`` (sharding constraints + the MoE shard_map).

Layer stacks are scanned over "super-blocks" of ``cfg.block_len`` layers
(Jamba: 8 = 1 attn + 7 mamba); ``moe.first_k_dense`` leading layers are kept
out of the scan.  ``loops="unroll"`` switches every internal chunk loop to
static python loops for roofline cost measurement (DESIGN.md: XLA cost
analysis counts while-loop bodies once).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers, mamba as mamba_mod, moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.config import ModelConfig
from repro.models.sharding import LOCAL, Distribution


# ==========================================================================
# init
# ==========================================================================

def _attn_init(cfg, key, cross=False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, hq * hd, cfg.pdtype),
        "wk": layers.dense_init(ks[1], d, hkv * hd, cfg.pdtype),
        "wv": layers.dense_init(ks[2], d, hkv * hd, cfg.pdtype),
        "wo": layers.dense_init(ks[3], hq * hd, d, cfg.pdtype,
                                scale=(hq * hd) ** -0.5),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), cfg.pdtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.pdtype)
    return p


def _layer_init(cfg, key, mixer_kind, ffn_kind, decoder_cross=False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": layers.norm_init(cfg),
                         "norm2": layers.norm_init(cfg)}
    if mixer_kind == "attn":
        p["mixer"] = _attn_init(cfg, k1)
    elif mixer_kind == "rwkv":
        p["mixer"] = rwkv_mod.time_mix_init(cfg, k1)
    elif mixer_kind == "mamba":
        p["mixer"] = mamba_mod.mamba_init(cfg, k1)
    else:
        raise ValueError(mixer_kind)
    if decoder_cross:
        p["cross"] = _attn_init(cfg, k3, cross=True)
        p["norm_cross"] = layers.norm_init(cfg)
    if ffn_kind == "dense":
        p["ffn"] = layers.mlp_init(cfg, k2)
    elif ffn_kind == "moe":
        p["ffn"] = moe_mod.moe_init(cfg, k2)
    elif ffn_kind == "rwkv_cmix":
        p["ffn"] = rwkv_mod.channel_mix_init(cfg, k2)
    else:
        raise ValueError(ffn_kind)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": layers.embed_init(ks[0], cfg.vocab,
                                                         cfg.d_model,
                                                         cfg.pdtype)}
    if cfg.max_positions:
        params["pos_embed"] = (jax.random.normal(
            ks[1], (cfg.max_positions, cfg.d_model), jnp.float32) * 0.01
        ).astype(cfg.pdtype)

    kinds = cfg.layer_kinds()
    if cfg.is_encdec:
        enc_keys = jax.random.split(ks[2], cfg.encoder_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: {"l0": _layer_init(cfg, k, "attn", "dense")})(enc_keys)
        dec_keys = jax.random.split(ks[3], cfg.n_layers)
        params["dec_blocks"] = jax.vmap(
            lambda k: {"l0": _layer_init(cfg, k, "attn", "dense",
                                         decoder_cross=True)})(dec_keys)
        params["enc_final_norm"] = layers.norm_init(cfg)
    else:
        first = cfg.moe.first_k_dense if cfg.moe else 0
        bl = cfg.block_len
        n_blocks = (cfg.n_layers - first) // bl
        assert (cfg.n_layers - first) % bl == 0
        if first:
            hk = jax.random.split(ks[2], first)
            params["head_layers"] = [
                _layer_init(cfg, hk[i], kinds[i][0], "dense")
                for i in range(first)]
        block_kinds = kinds[first:first + bl]

        def one_block(k):
            kk = jax.random.split(k, bl)
            return {f"l{p}": _layer_init(cfg, kk[p], *block_kinds[p])
                    for p in range(bl)}

        params["blocks"] = jax.vmap(one_block)(
            jax.random.split(ks[3], n_blocks))

    params["final_norm"] = layers.norm_init(cfg)
    if not cfg.tie_embeddings:
        params["unembed_w"] = layers.dense_init(
            ks[4], cfg.d_model, cfg.vocab, cfg.pdtype)
    return params


# ==========================================================================
# mixers
# ==========================================================================

def _shard_heads(dist, x, n):
    tp_size = dist.tp_size()
    if tp_size > 1 and n % tp_size == 0:
        return dist.constrain(x, dist.dp_axes, None, dist.tp, None)
    return x


def _attn_mixer(cfg, p, x, positions, dist, *, causal=True, loops="scan",
                cache=None, cache_pos=None, collect=False, kv_source=None,
                mrope_positions=None):
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = layers.dot(x, p["wq"]).astype(x.dtype).reshape(B, S, hq, hd)
    kv_in = x if kv_source is None else kv_source
    Skv = kv_in.shape[1]
    k = layers.dot(kv_in, p["wk"]).astype(x.dtype).reshape(B, Skv, hkv, hd)
    v = layers.dot(kv_in, p["wv"]).astype(x.dtype).reshape(B, Skv, hkv, hd)
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = layers.rmsnorm(k, p["k_norm"], cfg.rms_eps)
    if kv_source is None and not cfg.max_positions:   # rotary models
        if cfg.mrope_sections and mrope_positions is not None:
            q = layers.apply_mrope(q, mrope_positions, cfg.rope_theta,
                                   cfg.mrope_sections)
            k = layers.apply_mrope(k, mrope_positions, cfg.rope_theta,
                                   cfg.mrope_sections)
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = _shard_heads(dist, q, hq)
    k = _shard_heads(dist, k, hkv)
    v = _shard_heads(dist, v, hkv)

    new_cache = None
    unrep_kv = {"k": k, "v": v}
    tp = dist.tp_size()
    if (cache is None and tp > 1 and hkv < tp and hq % tp == 0
            and tp % hkv == 0):
        # GQA with fewer kv heads than TP: GSPMD cannot shard the grouped
        # (Hkv, G) reshape, so the whole attention would replicate.  Repeat
        # kv heads up to the TP degree (Megatron GQA practice): same FLOPs,
        # 16-way-shardable heads, kv activations duplicated tp/hkv x.
        rep = tp // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        k = _shard_heads(dist, k, tp)
        v = _shard_heads(dist, v, tp)
    if cache is not None:                               # decode (S == 1)
        z = jnp.int32(0)
        pos32 = jnp.asarray(cache_pos, jnp.int32)
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (z, pos32, z, z))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (z, pos32, z, z))
        seq_sharded = cfg.flash_decode and (
            cfg.kv_cache_seq_shard or
            (dist.tp_size() > 1 and hkv % dist.tp_size() != 0))
        o = attn_mod.decode_attention(q, kc, vc, kv_len=cache_pos + 1,
                                      dist=dist, seq_sharded=seq_sharded)
        new_cache = {"k": kc, "v": vc}
    else:
        o = attn_mod.attention(
            q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk,
            kv_chunk=cfg.attn_kv_chunk, loops=loops,
            triangle=cfg.attn_triangle and causal)
        if collect:
            new_cache = unrep_kv                 # cache stays un-repeated
    o = o.reshape(B, S, hq * hd)
    out = layers.dot(o, p["wo"]).astype(x.dtype)
    return dist.constrain(out, dist.dp_axes, None, None), new_cache


def _cross_mixer(cfg, p, x, dist, cache):
    """Decoder cross-attention over precomputed encoder K/V."""
    B, S, d = x.shape
    hq, hd = cfg.n_heads, cfg.hd
    q = layers.dot(x, p["wq"]).astype(x.dtype).reshape(B, S, hq, hd)
    o = attn_mod.reference(q, cache["ck"], cache["cv"], causal=False)
    out = layers.dot(o.reshape(B, S, hq * hd), p["wo"]).astype(x.dtype)
    return dist.constrain(out, dist.dp_axes, None, None)


def _cross_kv(cfg, p, enc_out):
    B, T, _ = enc_out.shape
    k = layers.dot(enc_out, p["wk"]).astype(enc_out.dtype)
    v = layers.dot(enc_out, p["wv"]).astype(enc_out.dtype)
    return {"ck": k.reshape(B, T, cfg.n_kv, cfg.hd),
            "cv": v.reshape(B, T, cfg.n_kv, cfg.hd)}


# ==========================================================================
# one layer
# ==========================================================================

def _seq_constrain(cfg, dist, h):
    """Megatron sequence parallelism: keep the residual stream sharded on S
    over the TP axis between sublayers (GSPMD then turns the row-parallel
    all-reduces into reduce-scatters and re-gathers before the next matmul)."""
    if cfg.seq_parallel and dist.tp is not None and h.shape[1] > 1 \
            and h.shape[1] % dist.tp_size() == 0:
        return dist.constrain(h, dist.dp_axes, dist.tp, None)
    return h


def _apply_layer(cfg, p, h, kinds, ctx, cache=None):
    """Returns (h, aux, new_cache)."""
    mixer_kind, ffn_kind = kinds
    dist: Distribution = ctx["dist"]
    loops = ctx["loops"]
    new_cache: Dict[str, Any] = {}

    h = _seq_constrain(cfg, dist, h)
    hn = layers.apply_norm(cfg, p["norm1"], h)
    if mixer_kind == "attn":
        mo, c = _attn_mixer(
            cfg, p["mixer"], hn, ctx["positions"], dist, causal=ctx["causal"],
            loops=loops, cache=None if cache is None else cache.get("attn"),
            cache_pos=ctx.get("cache_pos"), collect=ctx["collect"],
            mrope_positions=ctx.get("mrope_positions"))
        if c is not None:
            new_cache["attn"] = c
    elif mixer_kind == "rwkv":
        st = None if cache is None else cache.get("rwkv")
        T = hn.shape[1]
        chunk = math.gcd(T, max(256, T // 128))   # bounded unroll count
        mo, st2 = rwkv_mod.time_mix(cfg, p["mixer"], hn, st, loops=loops,
                                    chunk=chunk)
        if ctx["collect"] or cache is not None:
            new_cache["rwkv"] = st2
    elif mixer_kind == "mamba":
        st = None if cache is None else cache.get("mamba")
        T = hn.shape[1]
        # bounded chunk size (the associative-scan working set is
        # O(chunk * d_in * N)).  The chunk loop stays lax.scan even in
        # cost-lowering mode: unrolling its vjp is pathologically slow to
        # compile, and the undercounted intra-loop FLOPs are the elementwise
        # SSM scan only (~0.3% of layer FLOPs; matmuls are outside the loop).
        chunk = math.gcd(T, min(512, max(64, T // 16)))
        mo, st2 = mamba_mod.mamba_mixer(cfg, p["mixer"], hn, st,
                                        loops="scan", chunk=chunk)
        if ctx["collect"] or cache is not None:
            new_cache["mamba"] = st2
    else:
        raise ValueError(mixer_kind)
    h = h + mo

    if "cross" in p:
        hc = layers.apply_norm(cfg, p["norm_cross"], h)
        h = h + _cross_mixer(cfg, p["cross"], hc, dist,
                             cache["cross"] if cache else ctx["cross_kv"])
        if cache is not None:
            new_cache["cross"] = cache["cross"]

    h = _seq_constrain(cfg, dist, h)
    hn = layers.apply_norm(cfg, p["norm2"], h)
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind == "dense":
        fo = layers.mlp_apply(cfg, p["ffn"], hn)
        fo = dist.constrain(fo, dist.dp_axes, None, None)
    elif ffn_kind == "moe":
        gates, idx, aux = moe_mod.route(cfg, p["ffn"], hn)
        fo = moe_mod.moe_apply(cfg, p["ffn"], hn, gates, idx, dist)
    elif ffn_kind == "rwkv_cmix":
        st = None if cache is None else cache.get("cshift")
        fo, st2 = rwkv_mod.channel_mix(cfg, p["ffn"], hn, st)
        if ctx["collect"] or cache is not None:
            new_cache["cshift"] = st2
    else:
        raise ValueError(ffn_kind)
    return h + fo, aux, new_cache


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


# ==========================================================================
# decoder-only forward / prefill / decode
# ==========================================================================

def _stack_ctx(cfg, batch, dist, loops, collect):
    if "embeds" in batch:
        S = batch["embeds"].shape[1]
    else:
        S = batch["tokens"].shape[1]
    return {
        "dist": dist, "loops": loops, "collect": collect, "causal": True,
        "positions": jnp.arange(S)[None, :],
        "mrope_positions": batch.get("mrope_positions"),
    }


def _embed_in(cfg, params, batch, dist):
    if "embeds" in batch:
        h = batch["embeds"].astype(cfg.adtype)
    else:
        h = params["embed"][batch["tokens"]].astype(cfg.adtype)
    if cfg.max_positions:
        S = h.shape[1]
        h = h + params["pos_embed"][:S][None].astype(cfg.adtype)
    return dist.constrain(h, dist.dp_axes, None, None)


def _run_stack(cfg, params, h, ctx, caches=None):
    """Shared by forward/prefill (full-sequence) paths."""
    kinds = cfg.layer_kinds()
    first = cfg.moe.first_k_dense if cfg.moe else 0
    bl = cfg.block_len
    aux_total = jnp.zeros((), jnp.float32)
    head_caches = []
    for i in range(first):
        h, aux, hc = _apply_layer(cfg, params["head_layers"][i], h,
                                  kinds[i], ctx)
        aux_total += aux
        head_caches.append(hc)

    block_kinds = kinds[first:first + bl]

    def body(carry, bp):
        h, aux = carry
        bcache = {}
        for p_ix in range(bl):
            h, a, c = _apply_layer(cfg, bp[f"l{p_ix}"], h,
                                   block_kinds[p_ix], ctx)
            aux += a
            bcache[f"l{p_ix}"] = c
        return (h, aux), bcache

    body = _remat_wrap(cfg, body)
    (h, aux_total), block_caches = jax.lax.scan(
        body, (h, aux_total), params["blocks"])
    return h, aux_total, head_caches, block_caches


def backbone(cfg: ModelConfig, params, batch, dist: Distribution = LOCAL,
             *, loops: str = "scan", collect: bool = False):
    """Runs everything up to (and incl.) the final norm.
    Returns (h, aux, caches)."""
    if cfg.is_encdec:
        return _encdec_backbone(cfg, params, batch, dist, loops=loops,
                                collect=collect)
    ctx = _stack_ctx(cfg, batch, dist, loops, collect)
    h = _embed_in(cfg, params, batch, dist)
    h, aux, head_caches, block_caches = _run_stack(cfg, params, h, ctx)
    h = layers.apply_norm(cfg, params["final_norm"], h)
    caches = {"head": head_caches, "blocks": block_caches} if collect else None
    return h, aux, caches


def forward(cfg: ModelConfig, params, batch, dist: Distribution = LOCAL,
            *, loops: str = "scan", collect: bool = False):
    """Teacher-forcing forward.  Returns (logits, aux, caches)."""
    h, aux, caches = backbone(cfg, params, batch, dist, loops=loops,
                              collect=collect)
    return _unembed(cfg, params, h, dist), aux, caches


def _unembed(cfg, params, h, dist):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed_w"])
    logits = layers.dot(h, w)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return dist.constrain(logits, dist.dp_axes, None, dist.tp)


# ---------------------------- enc-dec (whisper) ---------------------------

def _encdec_backbone(cfg, params, batch, dist, *, loops="scan",
                     collect=False):
    enc = encode(cfg, params, batch["enc_embeds"], dist, loops=loops)
    h = params["embed"][batch["tokens"]].astype(cfg.adtype)
    S = h.shape[1]
    h = h + params["pos_embed"][:S][None].astype(cfg.adtype)
    h = dist.constrain(h, dist.dp_axes, None, None)
    ctx = {"dist": dist, "loops": loops, "collect": collect, "causal": True,
           "positions": jnp.arange(S)[None, :], "mrope_positions": None}

    def body(carry, bp):
        h, _ = carry
        ctx2 = dict(ctx)
        ctx2["cross_kv"] = _cross_kv(cfg, bp["l0"]["cross"], enc)
        h, a, c = _apply_layer(cfg, bp["l0"], h, ("attn", "dense"), ctx2)
        if collect:
            c["cross"] = ctx2["cross_kv"]
        return (h, a), {"l0": c}

    body = _remat_wrap(cfg, body)
    (h, _), block_caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), params["dec_blocks"])
    h = layers.apply_norm(cfg, params["final_norm"], h)
    caches = {"head": [], "blocks": block_caches} if collect else None
    return h, jnp.zeros((), jnp.float32), caches


def encode(cfg, params, enc_embeds, dist, *, loops="scan"):
    h = enc_embeds.astype(cfg.adtype)
    h = dist.constrain(h, dist.dp_axes, None, None)
    ctx = {"dist": dist, "loops": loops, "collect": False, "causal": False,
           "positions": jnp.arange(h.shape[1])[None, :],
           "mrope_positions": None}

    def body(carry, bp):
        h, a = carry
        h, a2, _ = _apply_layer(cfg, bp["l0"], h, ("attn", "dense"), ctx)
        return (h, a + a2), None

    body = _remat_wrap(cfg, body)
    (h, _), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                             params["enc_blocks"])
    return layers.apply_norm(cfg, params["enc_final_norm"], h)


# ==========================================================================
# loss
# ==========================================================================

@jax.custom_vjp
def _grad_transparent_barrier(xs):
    """optimization_barrier with an identity gradient: jax 0.4.x has no
    differentiation rule for the primitive, so chain it in the primal only
    (the scheduling hint matters for peak memory, not for the cotangents)."""
    return jax.lax.optimization_barrier(xs)


def _gtb_fwd(xs):
    return _grad_transparent_barrier(xs), None


def _gtb_bwd(_, g):
    return (g,)


_grad_transparent_barrier.defvjp(_gtb_fwd, _gtb_bwd)


def _nll_chunk(cfg, params, h_chunk, tgt_chunk, dist):
    logits = _unembed(cfg, params, h_chunk, dist)           # (B, S_c, V)
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    # vocab-sharding-friendly target gather (mask-and-reduce, no real gather)
    viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    tgt_logit = jnp.sum(jnp.where(viota == tgt_chunk[..., None], logits, 0.0),
                        axis=-1)
    return lse - tgt_logit


def loss_fn(cfg, params, batch, dist: Distribution = LOCAL, *,
            loops: str = "scan", aux_coef: float = 0.01):
    """Token-chunked cross entropy: the (tokens, vocab) logits matrix is
    never materialized in full.  Chunks are a static python loop (roofline
    FLOPs stay correctly counted), each chunk is rematerialized in the
    backward pass (no f32 logits residuals), and an optimization barrier
    chains the chunks so at most one logits block is live at a time."""
    h, aux, _ = backbone(cfg, params, batch, dist, loops=loops)
    B, S, d = h.shape
    tg = batch["targets"]
    mask = batch.get("loss_mask")
    n_chunks = math.gcd(S, max(1, cfg.loss_chunks))   # chunk the unsharded S
    csz = S // n_chunks
    chunk_fn = jax.checkpoint(
        lambda p, hc, tc: _nll_chunk(cfg, p, hc, tc, dist))
    nll_sum = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        nll = chunk_fn(params, h[:, i * csz:(i + 1) * csz],
                       tg[:, i * csz:(i + 1) * csz])
        if mask is not None:
            mc = mask[:, i * csz:(i + 1) * csz]
            nll_sum = nll_sum + jnp.sum(nll * mc)
            den = den + jnp.sum(mc)
        else:
            nll_sum = nll_sum + jnp.sum(nll)
            den = den + nll.size
        if n_chunks > 1:
            nll_sum, h = _grad_transparent_barrier((nll_sum, h))
    loss = nll_sum / jnp.maximum(den, 1.0)
    return loss + aux_coef * aux, {"nll": loss, "aux": aux}


# ==========================================================================
# caches: init / prefill / decode
# ==========================================================================

def _layer_cache_init(cfg, kinds, B, max_len, dtype):
    mixer_kind, ffn_kind = kinds
    d = cfg.d_model
    c: Dict[str, Any] = {}
    if mixer_kind == "attn":
        c["attn"] = {"k": jnp.zeros((B, max_len, cfg.n_kv, cfg.hd), dtype),
                     "v": jnp.zeros((B, max_len, cfg.n_kv, cfg.hd), dtype)}
    elif mixer_kind == "rwkv":
        H = d // cfg.rwkv_head_dim
        c["rwkv"] = {"S": jnp.zeros((B, H, cfg.rwkv_head_dim,
                                     cfg.rwkv_head_dim), jnp.float32),
                     "shift": jnp.zeros((B, d), dtype)}
    elif mixer_kind == "mamba":
        mc = cfg.mamba
        c["mamba"] = {"h": jnp.zeros((B, mc.expand * d, mc.d_state),
                                     jnp.float32),
                      "conv": jnp.zeros((B, mc.d_conv - 1, mc.expand * d),
                                        dtype)}
    if ffn_kind == "rwkv_cmix":
        c["cshift"] = jnp.zeros((B, d), dtype)
    return c


def init_cache(cfg, B, max_len, enc_len=0):
    dtype = cfg.adtype
    kinds = cfg.layer_kinds()
    if cfg.is_encdec:
        blocks = jax.vmap(lambda _: {"l0": {
            **_layer_cache_init(cfg, ("attn", "dense"), B, max_len, dtype),
            "cross": {"ck": jnp.zeros((B, enc_len, cfg.n_kv, cfg.hd), dtype),
                      "cv": jnp.zeros((B, enc_len, cfg.n_kv, cfg.hd), dtype)},
        }})(jnp.arange(cfg.n_layers))
        return {"head": [], "blocks": blocks}
    first = cfg.moe.first_k_dense if cfg.moe else 0
    bl = cfg.block_len
    n_blocks = (cfg.n_layers - first) // bl
    head = [_layer_cache_init(cfg, kinds[i], B, max_len, dtype)
            for i in range(first)]
    block_kinds = kinds[first:first + bl]
    blocks = jax.vmap(lambda _: {
        f"l{p}": _layer_cache_init(cfg, block_kinds[p], B, max_len, dtype)
        for p in range(bl)})(jnp.arange(n_blocks))
    return {"head": head, "blocks": blocks}


def prefill(cfg, params, batch, dist: Distribution = LOCAL, *,
            loops: str = "scan"):
    """Full-sequence forward that also returns the cache (kv/state)."""
    logits, aux, caches = forward(cfg, params, batch, dist, loops=loops,
                                  collect=True)
    return logits[:, -1:], caches


def decode_step(cfg, params, cache, token, pos, dist: Distribution = LOCAL,
                enc_out=None):
    """One decode step.  token: (B,) int32; pos: scalar int32 (write slot).

    Returns (logits (B,1,V), new_cache).
    """
    B = token.shape[0]
    h = params["embed"][token][:, None].astype(cfg.adtype)   # (B,1,d)
    if cfg.max_positions:
        h = h + params["pos_embed"][pos][None, None].astype(cfg.adtype)
    h = dist.constrain(h, dist.dp_axes, None, None)
    kinds = cfg.layer_kinds()
    first = cfg.moe.first_k_dense if cfg.moe else 0
    bl = cfg.block_len
    ctx = {"dist": dist, "loops": "scan", "collect": False, "causal": True,
           "positions": jnp.full((1, 1), pos), "cache_pos": pos,
           "mrope_positions": None}
    aux0 = jnp.zeros((), jnp.float32)

    new_head = []
    for i in range(first):
        h, _, hc = _apply_layer(cfg, params["head_layers"][i], h, kinds[i],
                                ctx, cache=cache["head"][i])
        new_head.append(hc)

    block_kinds = (kinds[first:first + bl] if not cfg.is_encdec
                   else [("attn", "dense")])
    blocks_key = "dec_blocks" if cfg.is_encdec else "blocks"

    def body(h, bp_bc):
        bp, bc = bp_bc
        ncache = {}
        for p_ix in range(len(block_kinds)):
            h, _, c = _apply_layer(cfg, bp[f"l{p_ix}"], h,
                                   block_kinds[p_ix], ctx,
                                   cache=bc[f"l{p_ix}"])
            ncache[f"l{p_ix}"] = c
        return h, ncache

    h, new_blocks = jax.lax.scan(body, h,
                                 (params[blocks_key], cache["blocks"]))
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = _unembed(cfg, params, h, dist)
    return logits, {"head": new_head, "blocks": new_blocks}
