"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].  Vision frontend is a stub:
input_specs supply precomputed patch embeddings + M-RoPE position ids."""
from repro.models import ModelConfig

ID = "qwen2-vl-7b"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="vlm", n_layers=28, d_model=3584, n_heads=28, n_kv=4,
        d_ff=18944, vocab=152064, head_dim=128, rope_theta=1e6,
        mrope_sections=(16, 24, 24),       # temporal/height/width of hd/2=64
        fsdp=True, grad_accum=8,
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
        head_dim=32, mrope_sections=(4, 6, 6), dtype="float32",
        param_dtype="float32", attn_q_chunk=16, attn_kv_chunk=16, fsdp=False, grad_accum=1)
