"""RWKV6-7B "Finch" [arXiv:2404.05892; hf]: attention-free, data-dependent
decay linear attention; O(1)-state decode (runs long_500k)."""
from repro.models import ModelConfig

ID = "rwkv6-7b"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="ssm", n_layers=32, d_model=4096, n_heads=64,
        n_kv=64, d_ff=14336, vocab=65536, rwkv=True, rwkv_head_dim=64,
        fsdp=True, grad_accum=8,
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv=8, d_ff=256, vocab=512,
        rwkv_head_dim=16, dtype="float32", param_dtype="float32", fsdp=False, grad_accum=1)
