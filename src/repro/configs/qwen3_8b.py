"""Qwen3-8B [hf:Qwen/Qwen3-8B; hf]: qk_norm, GQA kv=8, head_dim 128."""
from repro.models import ModelConfig

ID = "qwen3-8b"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense", n_layers=36, d_model=4096, n_heads=32,
        n_kv=8, d_ff=12288, vocab=151936, head_dim=128, qk_norm=True,
        rope_theta=1e6, fsdp=True, grad_accum=8
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
        head_dim=32, dtype="float32", param_dtype="float32",
        attn_q_chunk=16, attn_kv_chunk=16, fsdp=False, grad_accum=1)
