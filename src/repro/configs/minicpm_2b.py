"""MiniCPM-2B [arXiv:2404.06395; hf]: llama-like, MHA (kv=36), tied
embeddings; trained with the WSD schedule (wired in repro.optim)."""
from repro.models import ModelConfig

ID = "minicpm-2b"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense", n_layers=40, d_model=2304, n_heads=36,
        n_kv=36, d_ff=5760, vocab=122753, head_dim=64, rope_theta=1e4,
        tie_embeddings=True, fsdp=False, grad_accum=8
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
        head_dim=32, dtype="float32", param_dtype="float32",
        attn_q_chunk=16, attn_kv_chunk=16, grad_accum=1)
