"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation happens here; the dry-run lowers against these specs.
``long_500k`` is live only for sub-quadratic archs (SSM / hybrid), per the
assignment; encoder-only archs would skip decode but none are assigned
(whisper is enc-dec, so its decode cells run).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import init_cache
from repro.models.config import ModelConfig, ShapeConfig

SUBQUADRATIC = ("rwkv6-7b", "jamba-v0.1-52b")


def cell_is_live(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, ("skipped: pure full-attention arch at 512k decode is "
                       "quadratic-cost (assignment: run only for SSM/hybrid)")
    return True, ""


def live_cells(archs: Dict[str, Any], shapes) -> list:
    out = []
    for aid, mod in archs.items():
        cfg = mod.get_config()
        for s in shapes:
            if cell_is_live(cfg, s)[0]:
                out.append((aid, s.name))
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Returns kwargs-specs for the step function of this cell.

    train/prefill -> {"batch": {...}}
    decode        -> {"cache": ..., "token": ..., "pos": ...}
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    adt = cfg.adtype

    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            batch["embeds"] = _sds((B, S, cfg.d_model), adt)
            batch["mrope_positions"] = _sds((3, B, S), i32)
        elif cfg.is_encdec:
            batch["enc_embeds"] = _sds((B, S, cfg.d_model), adt)
            batch["tokens"] = _sds((B, S), i32)
        else:
            batch["tokens"] = _sds((B, S), i32)
        if shape.kind == "train":
            batch["targets"] = _sds((B, S), i32)
        return {"batch": batch}

    # decode: one new token against a cache of S positions
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, enc_len=S if cfg.is_encdec else 0))
    return {"cache": cache, "token": _sds((B,), i32),
            "pos": _sds((), i32)}
