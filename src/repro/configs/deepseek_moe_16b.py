"""DeepSeekMoE-16B [arXiv:2401.06066; hf]: fine-grained 64 routed experts
(top-6, width 1408) + 2 shared experts; first layer dense (width 10944)."""
from repro.models import ModelConfig, MoEConfig

ID = "deepseek-moe-16b"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="moe", n_layers=28, d_model=2048, n_heads=16,
        n_kv=16, d_ff=10944, vocab=102400, head_dim=128, rope_theta=1e4,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                      first_k_dense=1, capacity_factor=1.25),
        fsdp=True, grad_accum=8,
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=3, d_model=128, n_heads=4, n_kv=4, d_ff=384, vocab=512,
        head_dim=32,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=2,
                      first_k_dense=1, capacity_factor=4.0),
        dtype="float32", param_dtype="float32", attn_q_chunk=16,
        attn_kv_chunk=16, fsdp=False, grad_accum=1)
