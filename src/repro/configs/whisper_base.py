"""Whisper-base [arXiv:2212.04356; unverified]: enc-dec; the conv/audio
frontend is a STUB per the assignment — input_specs provide precomputed frame
embeddings.  Learned positional embeddings sized for the 32k decode cell
(architecturally unrealistic for real whisper-base, exercised as assigned)."""
from repro.models import ModelConfig

ID = "whisper-base"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="encdec", n_layers=6, d_model=512, n_heads=8,
        n_kv=8, d_ff=2048, vocab=51865, head_dim=64, encoder_layers=6,
        max_positions=32768, norm="layernorm", act="gelu", fsdp=False, grad_accum=4
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=2, encoder_layers=2, d_model=128, n_heads=4, n_kv=4,
        d_ff=256, vocab=512, head_dim=32, max_positions=128,
        dtype="float32", param_dtype="float32", attn_q_chunk=16,
        attn_kv_chunk=16, grad_accum=1)
