"""Registry of the 10 assigned architectures + their input-shape cells."""
from __future__ import annotations

from repro.configs import (deepseek_moe_16b, jamba_v0_1_52b, kimi_k2_1t_a32b,
                           minicpm_2b, qwen2_vl_7b, qwen3_0_6b, qwen3_32b,
                           qwen3_8b, rwkv6_7b, whisper_base)
from repro.configs.specs import cell_is_live, input_specs, live_cells
from repro.models.config import ALL_SHAPES, SHAPES_BY_NAME, ShapeConfig

_MODULES = (qwen2_vl_7b, deepseek_moe_16b, kimi_k2_1t_a32b, qwen3_32b,
            qwen3_8b, minicpm_2b, qwen3_0_6b, rwkv6_7b, jamba_v0_1_52b,
            whisper_base)

ARCHS = {m.ID: m for m in _MODULES}
ARCH_IDS = tuple(ARCHS)


def get_config(arch_id: str):
    return ARCHS[arch_id].get_config()


def reduced_config(arch_id: str):
    return ARCHS[arch_id].reduced_config()


__all__ = ["ALL_SHAPES", "ARCHS", "ARCH_IDS", "SHAPES_BY_NAME", "ShapeConfig",
           "cell_is_live", "get_config", "input_specs", "live_cells",
           "reduced_config"]
