"""Jamba-v0.1 52B [arXiv:2403.19887; hf]: 4 super-blocks of 8 layers
(attention at in-block offset 4, Mamba elsewhere), MoE (16e top-2) on every
second layer.  Hybrid: runs long_500k (O(1) Mamba state + 4 attn layers with
sequence-sharded KV)."""
from repro.models import MambaConfig, ModelConfig, MoEConfig

ID = "jamba-v0.1-52b"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="hybrid", n_layers=32, d_model=4096, n_heads=32,
        n_kv=8, d_ff=14336, vocab=65536, head_dim=128, rope_theta=1e4,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every=2,
                      capacity_factor=1.25),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        attn_every=8, attn_offset=4, fsdp=True, grad_accum=8
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=8, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
        head_dim=32,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256, every=2,
                      capacity_factor=4.0),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        dtype="float32", param_dtype="float32", attn_q_chunk=16,
        attn_kv_chunk=16, fsdp=False, grad_accum=1)
