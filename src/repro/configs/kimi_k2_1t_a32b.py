"""Kimi-K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table]: 384 routed
experts top-8 (width 2048) + 1 shared, first layer dense.  Assigned as GQA
kv=8 (the real model's MLA is out of assigned scope — DESIGN.md Sec. 6);
head_dim=128 for MXU alignment."""
from repro.models import ModelConfig, MoEConfig

ID = "kimi-k2-1t-a32b"


def get_config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="moe", n_layers=61, d_model=7168, n_heads=64, n_kv=8,
        d_ff=18432, vocab=163840, head_dim=128, rope_theta=5e4,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1,
                      first_k_dense=1, capacity_factor=1.25),
        fsdp=True, grad_accum=16,
    )


def reduced_config() -> ModelConfig:
    return get_config().replace(
        n_layers=3, d_model=128, n_heads=4, n_kv=2, d_ff=384, vocab=512,
        head_dim=32,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=64, n_shared=1,
                      first_k_dense=1, capacity_factor=4.0),
        dtype="float32", param_dtype="float32", attn_q_chunk=16,
        attn_kv_chunk=16, fsdp=False, grad_accum=1)
