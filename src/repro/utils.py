"""Small shared helpers."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def fdtype() -> jnp.dtype:
    """Canonical float dtype: float64 when x64 is enabled, else float32."""
    return jnp.result_type(float)


def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))


def tree_params(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


def block_until_ready(tree: Any) -> Any:
    return jax.block_until_ready(tree)


def time_fn(fn: Callable[[], Any], *, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock seconds per call (blocks on JAX outputs)."""
    for _ in range(warmup):
        block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def to_np(tree: Any) -> Any:
    return jax.tree_util.tree_map(np.asarray, tree)
