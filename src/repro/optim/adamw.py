"""AdamW with sharding-friendly, memory-tiered state + LR schedules.

State tiers (per-arch, DESIGN.md Sec. 5 — what makes kimi-k2 trainable):
  * "f32"  — classic: f32 master copy + f32 (m, v)          (14 B/param)
  * "bf16" — bf16 (m, v), no master (params updated in f32 then cast)
  * "int8" — blockwise-quantized (m, v) a la 8-bit Adam (block 256,
             per-block absmax scales), no master               (~4 B/param)

Schedules: cosine and WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "f32"          # f32 | bf16 | int8
    schedule: str = "cosine"          # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1           # WSD: final decay fraction of steps


def make_schedule(oc: OptConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
        if oc.schedule == "const":
            return oc.lr * warm
        if oc.schedule == "cosine":
            t = jnp.clip((step - oc.warmup_steps)
                         / max(oc.total_steps - oc.warmup_steps, 1), 0, 1)
            return oc.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))
        # WSD: stable at lr, then sqrt-decay over the last decay_frac steps
        decay_start = oc.total_steps * (1 - oc.decay_frac)
        t = jnp.clip((step - decay_start)
                     / max(oc.total_steps - decay_start, 1), 0, 1)
        return oc.lr * warm * (1 - t * (1 - 0.1))
    return sched


# ---------------------------- int8 block quant -----------------------------

def _q8(x):
    """Blockwise int8 along the last axis, shape-preserving (padded last dim)
    so the quantized state inherits the parameter's PartitionSpec."""
    last = x.shape[-1]
    pad = (-last) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nblk = (last + pad) // BLOCK
    blocks = xp.reshape(*x.shape[:-1], nblk, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(*x.shape[:-1], last + pad),
            "scale": scale[..., 0].astype(jnp.float32)}


def _dq8(s, shape):
    last = shape[-1]
    q = s["q"]
    nblk = q.shape[-1] // BLOCK
    blocks = q.astype(jnp.float32).reshape(*q.shape[:-1], nblk, BLOCK)
    deq = blocks * s["scale"][..., None]
    return deq.reshape(*q.shape[:-1], q.shape[-1])[..., :last]


# ---------------------------- state init / update ---------------------------

def adamw_init(params, oc: OptConfig):
    def one(x):
        if oc.state_dtype == "f32":
            return {"m": jnp.zeros(x.shape, jnp.float32),
                    "v": jnp.zeros(x.shape, jnp.float32),
                    # explicit copy: params may already be f32 and the
                    # master must stay donation-safe (distinct buffer)
                    "master": jnp.array(x, dtype=jnp.float32)}
        if oc.state_dtype == "bf16":
            return {"m": jnp.zeros(x.shape, jnp.bfloat16),
                    "v": jnp.zeros(x.shape, jnp.bfloat16)}
        return {"m": _q8(jnp.zeros(x.shape, jnp.float32)),
                "v": _q8(jnp.zeros(x.shape, jnp.float32))}
    return {"mu": jax.tree_util.tree_map(one, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, state, oc: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    sched = make_schedule(oc)
    step = state["step"] + 1
    lr = sched(step)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gn, 1e-12))
    bc1 = 1 - oc.beta1 ** step.astype(jnp.float32)
    bc2 = 1 - oc.beta2 ** step.astype(jnp.float32)

    def one(x, g, s):
        g = g.astype(jnp.float32) * clip
        if oc.state_dtype == "int8":
            m = _dq8(s["m"], x.shape)
            v = _dq8(s["v"], x.shape)
        else:
            m = s["m"].astype(jnp.float32)
            v = s["v"].astype(jnp.float32)
        m = oc.beta1 * m + (1 - oc.beta1) * g
        v = oc.beta2 * v + (1 - oc.beta2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
        base = s["master"] if oc.state_dtype == "f32" else x.astype(jnp.float32)
        new = base - lr * (upd + oc.weight_decay * base)
        out = {"m": (_q8(m) if oc.state_dtype == "int8" else
                     m.astype(s["m"].dtype if oc.state_dtype != "f32"
                              else jnp.float32)),
               "v": (_q8(v) if oc.state_dtype == "int8" else
                     v.astype(s["v"].dtype if oc.state_dtype != "f32"
                              else jnp.float32))}
        if oc.state_dtype == "f32":
            out["master"] = new
        return new.astype(x.dtype), out

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = state["mu"]
    flat_s_list = tdef.flatten_up_to(flat_s)
    new_p, new_s = [], []
    for x, g, s in zip(flat_p, flat_g, flat_s_list):
        np_, ns_ = one(x, g, s)
        new_p.append(np_)
        new_s.append(ns_)
    return (jax.tree_util.tree_unflatten(tdef, new_p),
            {"mu": jax.tree_util.tree_unflatten(tdef, new_s), "step": step},
            {"lr": lr, "grad_norm": gn})
