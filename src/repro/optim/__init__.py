from repro.optim.adamw import (OptConfig, adamw_init, adamw_update,
                               global_norm, make_schedule)

__all__ = ["OptConfig", "adamw_init", "adamw_update", "global_norm",
           "make_schedule"]
