"""Process-environment knobs that must be set BEFORE jax initializes.

Deliberately jax-free: importing this module must not trigger backend
initialization, or the knobs it sets would be ignored.
"""
from __future__ import annotations

import os

#: Forced host-device count shared by tests/conftest.py, the --shard
#: benchmarks and scripts/ci.sh (which re-states it in shell).  The perf
#: gate (scripts/check_bench.py) hard-fails on device_count mismatches, so
#: every entry point must agree on this number.
FORCED_HOST_DEVICES = 8

_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int = FORCED_HOST_DEVICES) -> None:
    """Inject ``--xla_force_host_platform_device_count=n`` into XLA_FLAGS.

    No-op when the flag is already present (an explicit topology pin wins).
    Only affects the CPU platform; must run before jax touches a backend.

    Parameters
    ----------
    n : int, optional
        Device count to force (default :data:`FORCED_HOST_DEVICES`).
    """
    if _FLAG not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" {_FLAG}={n}").strip()
