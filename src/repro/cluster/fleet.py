"""Fleet-level integration of the paper's GNEP allocator.

Tenant classes (arch x shape cells with SLAs) bid for TPU chips through the
RM/CM game exactly as the paper's job classes bid for VMs:

  * job profiles (A_i, B_i, C_i) are FITTED FROM THE DRY-RUN ROOFLINE TERMS
    of each tenant's cell (compute seconds -> map wave, collective seconds ->
    reduce wave) via core.profiles.from_roofline;
  * every allocator epoch (the paper's hourly re-solve), the distributed
    best-reply game allocates chips; Algorithm 4.2 integerizes; chips are
    factored into (data, model) sub-meshes per tenant;
  * node failures shrink R and trigger a re-solve (the paper's Fig. 2
    decreasing-capacity experiment, run live); running jobs elastically
    re-mesh from their latest checkpoint (repro.checkpoint reshards);
  * stragglers are mitigated at the allocator level by inflating A_i with an
    over-provisioning factor (speculative-execution analog).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (CapacityChange, CapacityEngine, ClassArrival,
                        ClassDeparture, CompactionPolicy, CrossCheckPolicy,
                        FlushPolicy, Policies, RAW_CLASS_FIELDS, Scenario,
                        SLAEdit, SolverConfig, derive)
from repro.utils import fdtype


@dataclass
class TenantSpec:
    name: str
    arch_id: str
    shape: str
    deadline_s: float          # SLA: per-window completion time for one job
    H_up: int                  # max concurrent jobs (SLA)
    H_low: int                 # guaranteed minimum
    penalty_per_job: float     # m_i [cents]
    max_bid: float = 20.0      # rho_i^up
    tp_required: int = 16      # model-parallel degree the arch needs
    straggler_factor: float = 1.0


@dataclass
class Allocation:
    chips: Dict[str, int]
    h: Dict[str, int]
    meshes: Dict[str, tuple]
    total_cost: float
    method: str
    iters: int
    # epoch/epoch_batch raise InfeasibleError instead of producing an
    # infeasible Allocation, so the flag is only ever False on the streaming
    # path, where overload transients are legitimate and must be observable.
    feasible: bool = True


class FleetSimulator:
    """Chips-for-tenants market driven by the paper's game."""

    def __init__(self, total_chips: int, tenants: List[TenantSpec], *,
                 chip_cost: float = 1.0, profile_dir: Optional[str] = None):
        self.R = total_chips
        self.tenants = tenants
        self.chip_cost = chip_cost
        self.profile_dir = profile_dir
        self.history: List[Allocation] = []

    # ---------------- profiles from the dry-run roofline ------------------
    def _roofline_record(self, t: TenantSpec) -> dict:
        d = Path(self.profile_dir or "benchmarks/results/dryrun")
        fn = d / f"{t.arch_id}__{t.shape}__single.json"
        rec = json.loads(fn.read_text())
        assert rec["status"] == "ok", f"no roofline for {t.name}"
        return rec

    def tenant_class_params(self, t: TenantSpec,
                            profiles: Optional[dict] = None) -> dict:
        """Raw GNEP class parameters for ONE tenant.

        The single source of the roofline -> job-profile fitting for both
        the batch path (:meth:`scenario` stacks these dicts) and the
        streaming path (``AdmissionWindow.arrive`` takes one directly): a
        job profiled at 256 chips spends ``t_compute`` seconds in math (the
        map wave, ~1/chips) and ``t_collective`` in collectives (the reduce
        wave), exactly the paper's ``A h / s`` form with c^M = c^R = 1
        slot/chip (see ``profiles.from_roofline``).
        """
        profiles = (profiles if profiles is not None
                    else getattr(self, "_profiles", None))
        if profiles and t.name in profiles:
            c, x, o = profiles[t.name]
        else:
            rf = self._roofline_record(t)["roofline"]
            c, x, o = rf["t_compute"], rf["t_collective"], 1.0
        return {
            "A": float(c * 256.0 * t.straggler_factor),
            "B": float(max(x, 1e-6) * 256.0),
            "E": float(o - t.deadline_s),
            "cM": 1.0, "cR": 1.0,
            "H_up": float(t.H_up), "H_low": float(t.H_low),
            "m": float(t.penalty_per_job), "rho_up": float(t.max_bid),
        }

    def scenario(self, *, profiles: Optional[dict] = None) -> Scenario:
        params = [self.tenant_class_params(t, profiles=profiles)
                  for t in self.tenants]
        arrs = {k: np.asarray([p[k] for p in params], fdtype())
                for k in RAW_CLASS_FIELDS}
        return derive(**arrs, R=float(self.R), rho_bar=self.chip_cost)

    # ---------------- epoch: solve the game, plan meshes -------------------
    def epoch(self, *, method: str = "distributed",
              profiles: Optional[dict] = None) -> Allocation:
        if profiles is not None:
            self._profiles = profiles
        profiles = getattr(self, "_profiles", None)
        scn = self.scenario(profiles=profiles)
        res = CapacityEngine().solve(scn, method=method)
        return self._allocation_from_integer(res.integer,
                                             n=len(self.tenants),
                                             iters=res.iters, method=method)

    @staticmethod
    def mesh_plan(chips: int, tp: int) -> tuple:
        """Factor a chip grant into (data, model); unusable remainder chips
        are returned to the pool (reported)."""
        if chips < tp:
            return (1, max(1, chips))
        return (chips // tp, tp)

    # ---------------- fault tolerance --------------------------------------
    def fail_nodes(self, n_chips: int, *, method: str = "distributed"):
        """Capacity drop -> immediate re-solve (paper Sec. 5.2.1, live)."""
        self.R = max(0, self.R - n_chips)
        return self.epoch(method=method)

    def restore_nodes(self, n_chips: int, *, method: str = "distributed"):
        self.R += n_chips
        return self.epoch(method=method)

    def mark_straggler(self, tenant_name: str, factor: float = 1.3,
                       *, method: str = "distributed"):
        """Inflate a tenant's map-wave profile (speculative re-execution
        headroom) and re-solve."""
        for t in self.tenants:
            if t.name == tenant_name:
                t.straggler_factor = factor
        return self.epoch(method=method)

    def _allocation_from_integer(self, it, n: int, iters: int,
                                 method: str) -> Allocation:
        """Build an Allocation record from (possibly batched-lane) integer
        solution arrays trimmed to this fleet's n tenants."""
        chips, hmap, meshes = {}, {}, {}
        for i, t in enumerate(self.tenants[:n]):
            c = int(it.r[i])
            chips[t.name] = c
            hmap[t.name] = int(it.h[i])
            meshes[t.name] = self.mesh_plan(c, t.tp_required)
        alloc = Allocation(chips=chips, h=hmap, meshes=meshes,
                           total_cost=float(it.total), method=method,
                           iters=iters)
        self.history.append(alloc)
        return alloc


def epoch_batch(fleets: Sequence[FleetSimulator], *,
                profiles: Optional[Sequence[Optional[dict]]] = None,
                eps_bar: float = 0.03, lam: float = 0.05,
                max_iters: int = 200, sweep_fn=None,
                mesh=None) -> List[Allocation]:
    """One allocator epoch for MANY fleets: every fleet's RM/CM game is a lane
    of one batched GNEP solve (ragged tenant counts pad to n_max), then one
    vectorized Algorithm 4.2 rounding pass.  This is the multi-cluster analog
    of the paper's hourly re-solve: a fleet operator runs thousands of
    clusters / what-if probes per epoch without B separate XLA dispatches.

    ``profiles``: optional per-fleet profile dicts (same semantics as
    ``FleetSimulator.epoch(profiles=...)``, remembered for later epochs);
    fleets without one fall back to their stored profiles or the dry-run
    roofline files.

    ``mesh``: optional 1-D lane mesh (``repro.core.sharding.lane_mesh``) —
    the fleets' games shard across devices, one lane slice per device; a
    fleet count that does not divide the device count is padded with inert
    lanes.  Per-fleet allocations match the unsharded epoch.

    Appends the resulting Allocation to each fleet's history and returns the
    per-fleet list, in input order.
    """
    if profiles is not None:
        for f, p in zip(fleets, profiles):
            if p is not None:
                f._profiles = p
    scns = [f.scenario(profiles=getattr(f, "_profiles", None)) for f in fleets]
    engine = CapacityEngine(SolverConfig(eps_bar=eps_bar, lam=lam,
                                         max_iters=max_iters,
                                         sweep_fn=sweep_fn, mesh=mesh))
    res = engine.solve(scns)
    allocs = []
    for b, f in enumerate(fleets):
        inst = res.instance(b)
        allocs.append(f._allocation_from_integer(
            inst.integer, n=int(res.n_classes[b]), iters=inst.iters,
            method="distributed-batch"))
    return allocs


# Fleet-level stream events: ("arrive", fleet, TenantSpec[, profile]),
# ("depart", fleet, tenant_name), ("edit", fleet, tenant_name, spec_updates),
# ("capacity", fleet, new_total_chips), ("fleet-arrive", FleetSimulator),
# ("fleet-depart", fleet).
FleetEvent = Tuple


def epoch_stream(fleets: Sequence[FleetSimulator],
                 epochs: Iterable[Sequence[FleetEvent]], *,
                 n_max: Optional[int] = None, eps_bar: float = 0.03,
                 lam: float = 0.05, max_iters: int = 200, sweep_fn=None,
                 mesh=None, cross_check: bool = False,
                 compact_below: Optional[float] = None
                 ) -> Iterator[List[Allocation]]:
    """Drive MANY fleets' games through a tenant arrival/departure trace.

    The multi-fleet analog of the paper's *runtime* loop, driven through one
    :class:`~repro.core.WindowSession`: every fleet is one lane of the
    session's live window; each epoch's events (tenants arriving, leaving,
    renegotiating SLAs, capacity changes) buffer in the session and one
    ``session.flush()`` per epoch coalesces them into one window update
    (one scatter per Scenario field, however many events the epoch carries)
    plus one warm-started incremental re-solve of exactly the dirtied lanes
    — fleets with no events keep their equilibrium at zero solver cost,
    unlike :func:`epoch_batch` which re-stacks and re-solves everything.
    Whole fleets can join and leave mid-stream (the window grows/shrinks its
    lane count at the epoch boundary), and a sparse long-lived window is
    re-packed by the session's compaction policy when ``compact_below`` is
    set.

    Parameters
    ----------
    fleets : Sequence[FleetSimulator]
        One lane each; copied internally, so the caller's sequence is never
        mutated (and fleet-indexed events address the *internal* order once
        ``fleet-arrive``/``fleet-depart`` reshuffle it).  The fleet objects
        themselves are shared: tenant lists and histories are kept in sync
        as events apply, and allocations append to each fleet's
        ``history``.  The yielded allocation lists follow the current
        internal fleet order.
    epochs : Iterable[Sequence[FleetEvent]]
        Outer iterable = allocator epochs (the paper's hourly re-solves);
        each element is the event list to apply before that epoch's solve:

        * ``("arrive", fleet_idx, TenantSpec)`` or
          ``("arrive", fleet_idx, TenantSpec, (t_compute, t_coll, t_over))``
          to also register the tenant's profile;
        * ``("depart", fleet_idx, tenant_name)``;
        * ``("edit", fleet_idx, tenant_name, {TenantSpec field: value})``;
        * ``("capacity", fleet_idx, new_total_chips)``;
        * ``("fleet-arrive", FleetSimulator)`` — a new cluster joins as a
          fresh window lane (its current tenants admitted wholesale);
        * ``("fleet-depart", fleet_idx)`` — a cluster leaves; its lane is
          removed and later indices shift down by one (indices always
          refer to the *current* fleet ordering).
    n_max : int, optional
        Initial padded width headroom for the window.
    eps_bar, lam, max_iters, sweep_fn
        Solver knobs, forwarded to ``solve_streaming``.
    mesh : jax.sharding.Mesh, optional
        1-D lane mesh: every fleet's window lane lives on its shard; the
        dirty-lane warm-start split is preserved across devices
        (``SolverConfig.mesh``).  Lane-count changes re-pad to the
        device multiple per solve (inert lanes), so grow/shrink composes.
    cross_check : bool, optional
        Cross-check every epoch against the exact centralized optimum.
    compact_below : float, optional
        Occupancy threshold (-> ``CompactionPolicy.occupancy``): after an
        epoch's events apply, if the window's occupied-slot fraction drops
        below this value the session compacts the window and the
        tenant->slot maps are remapped through the report's ``slot_map``.
        None (default) never compacts.

    Yields
    ------
    list of Allocation
        Per-fleet allocations after each epoch, in current fleet order.
        Unlike :func:`epoch_batch`, no :class:`~repro.core.InfeasibleError`
        is raised: an overloaded fleet (arrival burst, capacity loss) is a
        legitimate transient here, flagged on ``Allocation.feasible`` — its
        chips/h are the over-capacity projection and must not be deployed.
    """
    fleets = list(fleets)
    scns = [f.scenario(profiles=getattr(f, "_profiles", None)) for f in fleets]
    engine = CapacityEngine(
        SolverConfig(eps_bar=eps_bar, lam=lam, max_iters=max_iters,
                     sweep_fn=sweep_fn, mesh=mesh),
        Policies(flush=FlushPolicy(max_events=None),   # one flush per epoch
                 compaction=CompactionPolicy(occupancy=compact_below),
                 cross_check=CrossCheckPolicy(cross_check)))
    session = engine.open_window(scns, n_max=n_max)
    # tenant name -> window slot, per lane (initial stack order is 0..n-1)
    slots: List[Dict[str, int]] = [
        {t.name: i for i, t in enumerate(f.tenants)} for f in fleets]
    # class events buffer in the session; arrivals' slots resolve at drain
    pending_arrivals: List[Tuple[int, str]] = []

    def flush_pending() -> None:
        if not session.pending:
            return
        granted = session.drain()
        for slot, (b, name) in zip((s for s in granted if s is not None),
                                   pending_arrivals):
            slots[b][name] = slot
        pending_arrivals.clear()

    def slot_of(b: int, name: str) -> int:
        # a tenant that arrived earlier in this same epoch has no slot yet
        if any(pb == b and pn == name for pb, pn in pending_arrivals):
            flush_pending()
        return slots[b][name]

    def apply_event(ev: FleetEvent) -> None:
        kind = ev[0]
        if kind == "fleet-arrive":
            f = ev[1]
            flush_pending()                      # lane ops at flush boundaries
            b = session.add_lane(
                f.scenario(profiles=getattr(f, "_profiles", None)))
            fleets.append(f)
            slots.append({t.name: i for i, t in enumerate(f.tenants)})
            assert b == len(fleets) - 1
            return
        if kind == "fleet-depart":
            b = int(ev[1])
            flush_pending()
            session.remove_lane(b)
            del fleets[b]
            del slots[b]
            return
        b = int(ev[1])
        f = fleets[b]
        if kind == "arrive":
            spec = ev[2]
            if (spec.name in slots[b]
                    or any(pb == b and pn == spec.name
                           for pb, pn in pending_arrivals)):
                raise ValueError(
                    f"fleet {b} already has a tenant named {spec.name!r}")
            if len(ev) > 3 and ev[3] is not None:
                profs = dict(getattr(f, "_profiles", None) or {})
                profs[spec.name] = tuple(ev[3])
                f._profiles = profs
            f.tenants.append(spec)
            session.apply(ClassArrival(lane=b,
                                       params=f.tenant_class_params(spec)))
            pending_arrivals.append((b, spec.name))
        elif kind == "depart":
            name = ev[2]
            session.apply(ClassDeparture(lane=b, slot=slot_of(b, name)))
            del slots[b][name]
            f.tenants[:] = [t for t in f.tenants if t.name != name]
        elif kind == "edit":
            name, updates = ev[2], dict(ev[3])
            (spec,) = [t for t in f.tenants if t.name == name]
            for k, v in updates.items():
                setattr(spec, k, v)
            session.apply(SLAEdit(lane=b, slot=slot_of(b, name),
                                  updates=f.tenant_class_params(spec)))
        elif kind == "capacity":
            f.R = int(ev[2])
            session.apply(CapacityChange(lane=b, R=float(f.R)))
        else:
            raise ValueError(f"unknown fleet event kind {kind!r}")

    for events in epochs:
        for ev in events:
            apply_event(ev)
        flush_pending()
        res = session.flush()                    # policy compaction + solve
        if res.slot_map is not None:             # window was re-packed
            for b in range(len(slots)):
                slots[b] = {name: int(res.slot_map[b, s])
                            for name, s in slots[b].items()}
        # one device->host transfer per array, not per tenant
        r_np, h_np = np.asarray(res.integer.r), np.asarray(res.integer.h)
        total_np, iters_np = np.asarray(res.integer.total), np.asarray(res.iters)
        feas_np = np.asarray(res.feasible)
        allocs = []
        for b, f in enumerate(fleets):
            chips = {n: int(r_np[b, s]) for n, s in slots[b].items()}
            hmap = {n: int(h_np[b, s]) for n, s in slots[b].items()}
            meshes = {t.name: f.mesh_plan(chips[t.name], t.tp_required)
                      for t in f.tenants}
            alloc = Allocation(chips=chips, h=hmap, meshes=meshes,
                               total_cost=float(total_np[b]),
                               method="streaming",
                               iters=int(iters_np[b]),
                               feasible=bool(feas_np[b]))
            f.history.append(alloc)
            allocs.append(alloc)
        yield allocs
