"""Fleet-level integration of the paper's GNEP allocator.

Tenant classes (arch x shape cells with SLAs) bid for TPU chips through the
RM/CM game exactly as the paper's job classes bid for VMs:

  * job profiles (A_i, B_i, C_i) are FITTED FROM THE DRY-RUN ROOFLINE TERMS
    of each tenant's cell (compute seconds -> map wave, collective seconds ->
    reduce wave) via core.profiles.from_roofline;
  * every allocator epoch (the paper's hourly re-solve), the distributed
    best-reply game allocates chips; Algorithm 4.2 integerizes; chips are
    factored into (data, model) sub-meshes per tenant;
  * node failures shrink R and trigger a re-solve (the paper's Fig. 2
    decreasing-capacity experiment, run live); running jobs elastically
    re-mesh from their latest checkpoint (repro.checkpoint reshards);
  * stragglers are mitigated at the allocator level by inflating A_i with an
    over-provisioning factor (speculative-execution analog).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import (Scenario, from_roofline, round_solution, solve,
                        solve_batch, stack_scenarios)
from repro.utils import fdtype


@dataclass
class TenantSpec:
    name: str
    arch_id: str
    shape: str
    deadline_s: float          # SLA: per-window completion time for one job
    H_up: int                  # max concurrent jobs (SLA)
    H_low: int                 # guaranteed minimum
    penalty_per_job: float     # m_i [cents]
    max_bid: float = 20.0      # rho_i^up
    tp_required: int = 16      # model-parallel degree the arch needs
    straggler_factor: float = 1.0


@dataclass
class Allocation:
    chips: Dict[str, int]
    h: Dict[str, int]
    meshes: Dict[str, tuple]
    total_cost: float
    method: str
    iters: int


class FleetSimulator:
    """Chips-for-tenants market driven by the paper's game."""

    def __init__(self, total_chips: int, tenants: List[TenantSpec], *,
                 chip_cost: float = 1.0, profile_dir: Optional[str] = None):
        self.R = total_chips
        self.tenants = tenants
        self.chip_cost = chip_cost
        self.profile_dir = profile_dir
        self.history: List[Allocation] = []

    # ---------------- profiles from the dry-run roofline ------------------
    def _roofline_record(self, t: TenantSpec) -> dict:
        d = Path(self.profile_dir or "benchmarks/results/dryrun")
        fn = d / f"{t.arch_id}__{t.shape}__single.json"
        rec = json.loads(fn.read_text())
        assert rec["status"] == "ok", f"no roofline for {t.name}"
        return rec

    def scenario(self, *, profiles: Optional[dict] = None) -> Scenario:
        comp, coll, over, dl, hu, hl, m, bid = [], [], [], [], [], [], [], []
        for t in self.tenants:
            if profiles and t.name in profiles:
                c, x, o = profiles[t.name]
            else:
                rec = self._roofline_record(t)
                rf = rec["roofline"]
                c, x, o = rf["t_compute"], rf["t_collective"], 1.0
            comp.append(c * 256 * t.straggler_factor)  # chip-seconds per job
            coll.append(max(x, 1e-6) * 256)
            over.append(o)
            dl.append(t.deadline_s)
            hu.append(t.H_up)
            hl.append(t.H_low)
            m.append(t.penalty_per_job)
            bid.append(t.max_bid)
        return from_roofline(
            np.asarray(comp) / 256.0, np.asarray(coll) / 256.0,
            np.asarray(over), np.asarray(dl), chips_ref=256.0,
            H_up=np.asarray(hu, float), H_low=np.asarray(hl, float),
            m=np.asarray(m, float), rho_up=np.asarray(bid, float),
            R=float(self.R), rho_bar=self.chip_cost)

    # ---------------- epoch: solve the game, plan meshes -------------------
    def epoch(self, *, method: str = "distributed",
              profiles: Optional[dict] = None) -> Allocation:
        if profiles is not None:
            self._profiles = profiles
        profiles = getattr(self, "_profiles", None)
        scn = self.scenario(profiles=profiles)
        res = solve(scn, method=method)
        return self._allocation_from_integer(res.integer,
                                             n=len(self.tenants),
                                             iters=res.iters, method=method)

    @staticmethod
    def mesh_plan(chips: int, tp: int) -> tuple:
        """Factor a chip grant into (data, model); unusable remainder chips
        are returned to the pool (reported)."""
        if chips < tp:
            return (1, max(1, chips))
        return (chips // tp, tp)

    # ---------------- fault tolerance --------------------------------------
    def fail_nodes(self, n_chips: int, *, method: str = "distributed"):
        """Capacity drop -> immediate re-solve (paper Sec. 5.2.1, live)."""
        self.R = max(0, self.R - n_chips)
        return self.epoch(method=method)

    def restore_nodes(self, n_chips: int, *, method: str = "distributed"):
        self.R += n_chips
        return self.epoch(method=method)

    def mark_straggler(self, tenant_name: str, factor: float = 1.3,
                       *, method: str = "distributed"):
        """Inflate a tenant's map-wave profile (speculative re-execution
        headroom) and re-solve."""
        for t in self.tenants:
            if t.name == tenant_name:
                t.straggler_factor = factor
        return self.epoch(method=method)

    def _allocation_from_integer(self, it, n: int, iters: int,
                                 method: str) -> Allocation:
        """Build an Allocation record from (possibly batched-lane) integer
        solution arrays trimmed to this fleet's n tenants."""
        chips, hmap, meshes = {}, {}, {}
        for i, t in enumerate(self.tenants[:n]):
            c = int(it.r[i])
            chips[t.name] = c
            hmap[t.name] = int(it.h[i])
            meshes[t.name] = self.mesh_plan(c, t.tp_required)
        alloc = Allocation(chips=chips, h=hmap, meshes=meshes,
                           total_cost=float(it.total), method=method,
                           iters=iters)
        self.history.append(alloc)
        return alloc


def epoch_batch(fleets: Sequence[FleetSimulator], *,
                profiles: Optional[Sequence[Optional[dict]]] = None,
                eps_bar: float = 0.03, lam: float = 0.05,
                max_iters: int = 200, sweep_fn=None) -> List[Allocation]:
    """One allocator epoch for MANY fleets: every fleet's RM/CM game is a lane
    of one batched GNEP solve (ragged tenant counts pad to n_max), then one
    vectorized Algorithm 4.2 rounding pass.  This is the multi-cluster analog
    of the paper's hourly re-solve: a fleet operator runs thousands of
    clusters / what-if probes per epoch without B separate XLA dispatches.

    ``profiles``: optional per-fleet profile dicts (same semantics as
    ``FleetSimulator.epoch(profiles=...)``, remembered for later epochs);
    fleets without one fall back to their stored profiles or the dry-run
    roofline files.

    Appends the resulting Allocation to each fleet's history and returns the
    per-fleet list, in input order.
    """
    if profiles is not None:
        for f, p in zip(fleets, profiles):
            if p is not None:
                f._profiles = p
    scns = [f.scenario(profiles=getattr(f, "_profiles", None)) for f in fleets]
    batch = stack_scenarios(scns)
    res = solve_batch(batch, "distributed", eps_bar=eps_bar, lam=lam,
                      max_iters=max_iters, sweep_fn=sweep_fn)
    allocs = []
    for b, f in enumerate(fleets):
        inst = res.instance(b)
        allocs.append(f._allocation_from_integer(
            inst.integer, n=int(res.n_classes[b]), iters=inst.iters,
            method="distributed-batch"))
    return allocs
