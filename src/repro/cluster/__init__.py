from repro.cluster.fleet import (Allocation, FleetSimulator, TenantSpec,
                                 epoch_batch)

__all__ = ["Allocation", "FleetSimulator", "TenantSpec", "epoch_batch"]
