from repro.cluster.fleet import FleetSimulator, TenantSpec

__all__ = ["FleetSimulator", "TenantSpec"]
