from repro.cluster.fleet import (Allocation, FleetSimulator, TenantSpec,
                                 epoch_batch, epoch_stream)

__all__ = ["Allocation", "FleetSimulator", "TenantSpec", "epoch_batch",
           "epoch_stream"]
