"""Streaming admission engine — the paper's *runtime* allocation loop.

The paper's whole point (Sec. 1, Sec. 5 "runtime" experiments) is that the
Resource Manager and Class Managers re-negotiate capacity **as job classes
arrive and leave**, not on a fixed batch.  This module turns the batched GNEP
engine (``game.solve_distributed_batch``) into that runtime system:

* :class:`AdmissionWindow` maintains a *live* padded :class:`ScenarioBatch`
  under :class:`~repro.core.types.ClassArrival` /
  :class:`~repro.core.types.ClassDeparture` /
  :class:`~repro.core.types.SLAEdit` /
  :class:`~repro.core.types.CapacityChange` events.  A departing class's slot
  is refilled with solver-inert neutral values and recycled by the next
  arrival (free-slot recycling in the mask); the window repads every leaf to
  a larger ``n_max`` only when a lane's row is actually full, so steady-state
  event application never re-stacks the batch and never changes XLA shapes
  (no recompilation).

* :meth:`AdmissionWindow.warm_start` builds the incremental re-solve init:
  lanes whose scenario is unchanged since their last equilibrium are
  *frozen* (zero solver iterations — their stored equilibrium passes through
  the vmapped while-loop untouched), and only *dirty* lanes iterate.  Dirty
  lanes restart from the paper's cold Algorithm 4.1 init so they reproduce
  the cold trajectory exactly: CM bids only escalate during the game, so
  carrying converged bids across a scenario change would steer the game to a
  different (higher-price) equilibrium.  This makes the streaming solve
  numerically equivalent to a cold re-solve of the final window while doing
  only the dirty lanes' work.

* Windows are *dynamic*: :meth:`AdmissionWindow.apply_epoch` folds any
  number of events into one atomic, coalesced update (one scatter per
  Scenario field instead of one dispatch per event — the CPU dispatch
  bottleneck PR 3 recorded); :class:`EventEpoch` + :class:`FlushPolicy`
  decide *when* to re-solve (count / dirty-fraction triggers); lanes can be
  added and removed between solves (:meth:`AdmissionWindow.add_lane` /
  :meth:`AdmissionWindow.remove_lane`); and :meth:`AdmissionWindow.compact`
  re-packs sparse long-lived windows, remapping the stored equilibrium so
  frozen lanes stay frozen across the re-layout.

The user-facing layer is :class:`repro.core.engine.CapacityEngine` /
:class:`repro.core.engine.WindowSession` (``open_window`` -> ``apply`` /
``flush`` / ``stream``: warm solve + Algorithm 4.2 rounding + optional
centralized cross-check, flush cadence and compaction as policies); the
deprecated ``repro.core.allocator.solve_streaming`` / ``solve_coalesced``
shims delegate there.  :func:`sample_event_trace` generates
random-but-replayable event traces for tests and
``benchmarks/streaming_perf.py``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game, sharding
from repro.core.profiles import sample_class_params
from repro.core.types import (RAW_CLASS_FIELDS, CapacityChange, ClassArrival,
                              ClassDeparture, Scenario, ScenarioBatch,
                              SLAEdit, StreamEvent, WindowState, derive,
                              neutral_class_values, pad_scenario,
                              stack_scenarios)

#: Per-class Scenario fields (raw + derived) scattered on every class write.
_CLASS_FIELDS = tuple(neutral_class_values(0.0).keys())


def _pad_idx(idx: list) -> list:
    """Pad a scatter-index list to the next power of two by repeating its
    last entry.  Scattering the same value to a duplicated index is
    idempotent, and the bucketed shapes bound how many signatures the
    jitted scatter helpers below ever compile — epochs of any size hit a
    warm compile cache after the first few flushes."""
    if not idx:
        return idx
    return idx + [idx[-1]] * ((1 << (len(idx) - 1).bit_length()) - len(idx))


@jax.jit
def _scatter_class_fields(scn: Scenario, li, si, vals) -> Scenario:
    """One fused scatter updating every per-class field at (li, si).

    The write path of both the per-event and the coalesced engines: doing
    all ~20 field updates inside one jitted program costs ONE dispatch per
    event epoch instead of one per (field, event) — on CPU the dispatch,
    not the math, is the streaming bottleneck (ROADMAP caveat from PR 3).
    """
    return scn.replace(**{f: getattr(scn, f).at[li, si].set(vals[f])
                          for f in _CLASS_FIELDS})


@jax.jit
def _epoch_commit(scn: Scenario, mask_dev, state_r, li, si, vals, occ,
                  R_lanes, R_vals, hat_lanes, hat_rows):
    """The WHOLE epoch commit as one jitted program: class-field scatter,
    resident-mask-mirror scatter, vacated-slot warm-state zeroing, lane
    capacity updates and the rho_hat refresh.

    Any of the sub-updates may be absent (``None`` operands prune that
    branch at trace time; each presence combination compiles once).  One
    fused dispatch instead of up to five matters twice over: on CPU the
    dispatch is the streaming bottleneck (PR 3 caveat), and on a
    device-resident window every operand is lane-sharded, so each dispatch
    costs a full multi-device execution round.  Value-identical to running
    :func:`_scatter_class_fields` / :func:`_refresh_hats` and the mask/
    state/R scatters back-to-back (same scatter order, disjoint or
    idempotent writes).
    """
    if li is not None:
        scn = scn.replace(**{f: getattr(scn, f).at[li, si].set(vals[f])
                             for f in _CLASS_FIELDS})
        if mask_dev is not None:
            mask_dev = mask_dev.at[li, si].set(occ)
        if state_r is not None:
            # vacated slots restart from 0; occupied staged slots keep their
            # stored allocation (their lane goes dirty and restarts cold
            # anyway) — bit-equal to the old vacated-only scatter
            state_r = state_r.at[li, si].set(
                jnp.where(occ, state_r[li, si], jnp.zeros((), state_r.dtype)))
    if R_lanes is not None:
        scn = scn.replace(R=scn.R.at[R_lanes].set(R_vals))
    if hat_lanes is not None:
        hats = jnp.max(jnp.where(hat_rows, scn.rho_up[hat_lanes],
                                 scn.rho_bar[hat_lanes][:, None]), axis=1)
        scn = scn.replace(rho_hat=scn.rho_hat.at[hat_lanes].set(hats))
    return scn, mask_dev, state_r


@jax.jit
def _refresh_hats(scn: Scenario, lanes, rows) -> Scenario:
    """Recompute rho_hat = max over admitted rho_up for the given lanes.

    ``rows`` carries the lanes' occupancy-mask rows; an empty lane
    degenerates to the single candidate rho_bar (paper (P5e) interval end).
    Fused + jitted for the same dispatch-amortization reason as
    :func:`_scatter_class_fields`.
    """
    hats = jnp.max(jnp.where(rows, scn.rho_up[lanes],
                             scn.rho_bar[lanes][:, None]), axis=1)
    return scn.replace(rho_hat=scn.rho_hat.at[lanes].set(hats))


def _derive_class(params: dict, dtype) -> dict:
    """Derived per-class constants (Props. 3.3, Eqs. 7/8/17/18) for ONE class.

    Parameters
    ----------
    params : dict
        Raw per-class scalars; keys exactly :data:`RAW_CLASS_FIELDS`.
    dtype : jnp.dtype
        Float dtype of the window's leaves.

    Returns
    -------
    dict
        Field name -> python float for every per-class field of
        :class:`Scenario` (the raw values plus the derived constants),
        computed by the same :func:`repro.core.types.derive` closed forms
        the batch constructor uses.
    """
    return {f: float(v[0]) for f, v in _derive_classes([params],
                                                       dtype).items()}


#: jitted :func:`repro.core.types.derive` — the streaming write paths call
#: it per event / per epoch, where eager elementwise dispatch would dominate.
_derive_jit = jax.jit(derive)


def _derive_classes(params_list: Sequence[dict], dtype) -> Dict[str, np.ndarray]:
    """Derived constants for MANY classes in one device round-trip.

    The coalesced-epoch analog of :func:`_derive_class`: :func:`derive` is
    elementwise in its per-class inputs, so stacking the raw dicts and
    deriving once yields values bit-identical to T per-class calls while
    paying one dispatch + one host transfer per *field* instead of per
    (field, class).

    Parameters
    ----------
    params_list : Sequence[dict]
        Raw per-class scalar dicts; keys exactly :data:`RAW_CLASS_FIELDS`.
    dtype : jnp.dtype
        Float dtype of the window's leaves.

    Returns
    -------
    dict
        Field name -> (T,) numpy array for every per-class field of
        :class:`Scenario`, aligned with ``params_list``.
    """
    for params in params_list:
        missing = set(RAW_CLASS_FIELDS) - set(params)
        if missing:
            raise ValueError(f"class params missing fields {sorted(missing)}")
    many = _derive_jit(**{k: jnp.asarray([p[k] for p in params_list], dtype)
                          for k in RAW_CLASS_FIELDS},
                       R=jnp.asarray(0.0, dtype),
                       rho_bar=jnp.asarray(0.0, dtype))
    host = jax.device_get([getattr(many, f) for f in _CLASS_FIELDS])
    return dict(zip(_CLASS_FIELDS, host))


class AdmissionWindow:
    """A live, padded :class:`ScenarioBatch` plus last-equilibrium state.

    Each *lane* is one running allocation game (one cluster / fleet); events
    admit, remove or renegotiate job classes inside a lane.  The window keeps

    * the stacked :class:`Scenario` leaves ((B, n_max) per class, (B,)
      scalars) with vacated / never-used slots held at solver-inert neutral
      values (:func:`~repro.core.types.neutral_class_values`);
    * a host-side occupancy mask mirroring ``ScenarioBatch.mask`` (kept on
      host so event application never synchronises with the device);
    * the previous equilibrium (:class:`~repro.core.types.WindowState`) and a
      per-lane *dirty* flag driving the warm-started incremental re-solve.

    Parameters
    ----------
    scenarios : Sequence[Scenario]
        Initial (possibly ragged) instances, one per lane.  Neither the lane
        count B nor the class counts are fixed: lanes grow/shrink between
        solves via :meth:`add_lane` / :meth:`remove_lane`, and sparse
        windows re-pack via :meth:`compact`.
    n_max : int, optional
        Initial padded width.  Defaults to the largest initial class count;
        give headroom to avoid early growth repads.
    growth_factor : float, optional
        When a lane's row is full, every leaf is repadded to
        ``max(ceil(growth_factor * n_max), n_max + 1)`` columns.  Stored
        equilibria stay valid across growth because padding is inert.

    Notes
    -----
    Feasibility is intentionally *not* enforced at admission time: a burst of
    arrivals may legitimately push ``sum(r_low) > R`` until the operator
    sheds load or adds capacity, so infeasible transients must be
    representable.  ``solve_streaming`` reports per-lane ``feasible`` flags.
    """

    def __init__(self, scenarios: Sequence[Scenario], *,
                 n_max: Optional[int] = None, growth_factor: float = 2.0):
        scns = list(scenarios)
        if not scns:
            raise ValueError("AdmissionWindow needs at least one lane")
        if growth_factor <= 1.0:
            raise ValueError("growth_factor must be > 1")
        batch = stack_scenarios(scns, n_max=n_max)
        self._scn = batch.scenarios
        self._mask = np.asarray(batch.mask).copy()
        # device-residency state (None = classic host-round-trip layout):
        # when resident, _scn/_state leaves are lane-padded to the mesh
        # multiple and placed with lane_sharding; _mask_dev mirrors _mask
        # on the mesh so flushes never upload the occupancy mask.
        self._resident_mesh = None
        self._mask_dev = None
        self._n_classes_dev = self._n_classes_host = None
        # host cache of the per-lane unit chip cost: vacated-slot neutral
        # values need rho_bar per event epoch, and reading it off a
        # (possibly mesh-sharded) device array would synchronise every
        # flush.  Only __init__/add_lane/remove_lane ever change it.
        self._rho_bar_host = np.asarray(batch.scenarios.rho_bar,
                                        float).copy()
        self.growth_factor = float(growth_factor)
        self.dirty = np.zeros(self.batch_size, bool)
        # per-lane memo of the exact centralized (P3) total, invalidated by
        # the same events that dirty a lane (solve_streaming's cross-check
        # recomputes only stale lanes instead of the whole batch per event)
        self.baseline_totals = np.full(self.batch_size, np.nan)
        self.baseline_stale = np.ones(self.batch_size, bool)
        self._state: Optional[WindowState] = None
        # raw per-class params so SLAEdit can merge partial updates
        # (one device->host transfer per field per lane, not per scalar)
        self._raw: Dict[Tuple[int, int], dict] = {}
        for b, s in enumerate(scns):
            cols = {f: np.asarray(getattr(s, f)) for f in RAW_CLASS_FIELDS}
            for i in range(s.n):
                self._raw[(b, i)] = {f: float(cols[f][i])
                                     for f in RAW_CLASS_FIELDS}

    # ------------------------------------------------------------------ views
    @property
    def batch_size(self) -> int:
        return self._mask.shape[0]

    @property
    def n_max(self) -> int:
        return self._mask.shape[1]

    @property
    def n_classes(self) -> np.ndarray:
        """(B,) host array — current number of admitted classes per lane."""
        return self._mask.sum(axis=1)

    @property
    def batch(self) -> ScenarioBatch:
        """The current window as a solver-ready :class:`ScenarioBatch`."""
        # NB: the mask must be snapshotted — jnp.asarray zero-copies an
        # aligned numpy buffer on CPU, which would hand the solver (and
        # every report holding this batch) a live view of ``_mask`` that
        # later in-place event applications silently rewrite.
        scn = self._scn
        b = self.batch_size
        if int(scn.A.shape[0]) > b:
            # resident layout carries inert mesh-padding lanes; the host
            # mirror materialized here is always the logical window
            scn = jax.tree_util.tree_map(lambda leaf: leaf[:b], scn)
        return ScenarioBatch(scenarios=scn,
                             mask=jnp.asarray(self._mask.copy()),
                             n_classes=jnp.asarray(self.n_classes))

    @property
    def state(self) -> Optional[WindowState]:
        """Last committed equilibrium, or None before the first solve."""
        return self._state

    @property
    def occupancy(self) -> float:
        """Fraction of the (B, n_max) slot grid holding an admitted class.

        The compaction signal: a long-lived window whose tenants churn
        drifts toward a sparse mask (occupancy well below 1), paying solver
        work proportional to ``n_max`` for classes that are long gone —
        :meth:`compact` re-packs it.
        """
        return float(self._mask.mean()) if self._mask.size else 0.0

    def occupied(self, lane: int) -> List[int]:
        """Slot indices currently holding an admitted class in ``lane``."""
        return [int(i) for i in np.flatnonzero(self._mask[lane])]

    # -------------------------------------------------------- device residency
    @property
    def is_resident(self) -> bool:
        """Whether the window's device leaves live lane-sharded on a mesh."""
        return self._resident_mesh is not None

    @property
    def resident_mesh(self):
        """The 1-D lane mesh the window is resident on (None when not)."""
        return self._resident_mesh

    def make_resident(self, mesh) -> None:
        """Place the window's device state lane-sharded on ``mesh``, to stay.

        After this, the scenario leaves, the occupancy-mask mirror and the
        stored equilibrium are lane-padded to the mesh's device multiple
        (inert padding, exactly :func:`repro.core.sharding.pad_batch_lanes`)
        and committed with ``lane_sharding`` — and every subsequent event
        scatter writes *into* the resident arrays (XLA sharding propagation
        keeps them lane-sharded), so flushes pay zero per-solve host->mesh
        resharding.  Geometry changes (:meth:`add_lane`,
        :meth:`remove_lane`, :meth:`compact`) drop to the logical host
        layout internally and re-establish residency before returning;
        :meth:`grow` re-places in-place.  The host occupancy mask and raw
        parameter book-keeping stay authoritative on the host throughout.

        Parameters
        ----------
        mesh : jax.sharding.Mesh
            1-D lane mesh (``repro.core.sharding.lane_mesh``).  Re-calling
            with a different mesh migrates the window.
        """
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"lane residency needs a 1-D mesh, got axes {mesh.axis_names}")
        if self._resident_mesh is not None and self._resident_mesh != mesh:
            self._exit_residency()
        self._resident_mesh = mesh
        self._place_device_leaves()

    def release_resident(self) -> None:
        """Return to the classic host-round-trip layout.

        Trims the mesh-padding lanes off every device leaf, gathers the
        leaves back to the default device and drops the device mask mirror;
        the window is afterwards indistinguishable from one that was never
        resident (``tests/test_resident.py`` round-trips through this).
        """
        if self._resident_mesh is not None:
            self._exit_residency()

    def resident_batch(self) -> ScenarioBatch:
        """The resident (lane-padded, mesh-placed) solver view of the window.

        Unlike :attr:`batch` this materializes NO host mirror: scenarios and
        mask are the live resident arrays (padded lane count), and only the
        tiny (padded B,) class-count vector is uploaded per call.

        Returns
        -------
        ScenarioBatch
            Leaves carry the PADDED lane count; padding lanes are inert.
        """
        if self._resident_mesh is None:
            raise RuntimeError(
                "window is not device-resident — call make_resident(mesh)")
        pad_b = int(self._mask_dev.shape[0])
        counts = np.zeros(pad_b, np.int64)
        counts[:self.batch_size] = self.n_classes
        # the solver is mask-driven (game.py never reads n_classes), so the
        # counts vector is report surface only — cache its device copy and
        # re-upload only when occupancy actually changed
        if (self._n_classes_dev is None
                or not np.array_equal(counts, self._n_classes_host)):
            self._n_classes_dev = jax.device_put(
                jnp.asarray(counts),
                sharding.lane_sharding(self._resident_mesh))
            self._n_classes_host = counts
        return ScenarioBatch(scenarios=self._scn, mask=self._mask_dev,
                             n_classes=self._n_classes_dev)

    def resident_warm_start(self, rbatch: ScenarioBatch):
        """On-device incremental-re-solve init for the resident solve path.

        The resident analog of :meth:`warm_start` + ``pad_warm_start``:
        frozen/dirty splitting happens in one jitted program over the padded
        resident leaves (``sharding.resident_warm_init``), and the returned
        init's buffers are fresh — ``sharding.solve_resident_batch`` donates
        them.  Only the (padded B,) dirty-flag vector is uploaded.

        Parameters
        ----------
        rbatch : ScenarioBatch
            The window's :meth:`resident_batch` (passed in so one flush
            builds it exactly once).

        Returns
        -------
        (game.BatchWarmStart, np.ndarray)
            The donation-safe padded init, and the (B,) host ``resolved``
            flags (lanes that will iterate — dirty or never-solved).
        """
        if self._resident_mesh is None:
            raise RuntimeError(
                "window is not device-resident — call make_resident(mesh)")
        if self._state is None:
            return (sharding.resident_cold_init(rbatch),
                    np.ones(self.batch_size, bool))
        pad_b = rbatch.batch_size
        dirty_full = np.zeros(pad_b, bool)
        dirty_full[:self.batch_size] = self.dirty
        dirty_dev = jax.device_put(
            jnp.asarray(dirty_full),
            sharding.lane_sharding(self._resident_mesh))
        init = sharding.resident_warm_init(rbatch, self._state, dirty_dev)
        # active == dirty here: a never-solved lane is always dirty (the
        # only path creating solved=False rows, add_lane, also dirties)
        return init, self.dirty.copy()

    def _place_device_leaves(self) -> None:
        """(Re-)establish the resident placement: pad the lane axis to the
        mesh multiple when needed, device_put every leaf with lane
        sharding, rebuild the device mask mirror from the host mask."""
        mesh = self._resident_mesh
        B, n_max = self.batch_size, self.n_max
        pad_b = sharding.padded_lane_count(B, mesh.devices.size)
        sh = sharding.lane_sharding(mesh)
        rows = int(self._scn.A.shape[0])
        if rows == B and pad_b > B:
            host = ScenarioBatch(scenarios=self._scn,
                                 mask=jnp.asarray(self._mask.copy()),
                                 n_classes=jnp.asarray(self.n_classes))
            self._scn = sharding.pad_batch_lanes(host, pad_b).scenarios
        elif rows not in (B, pad_b):
            raise AssertionError(
                f"resident lane-axis invariant broken: {rows} device rows, "
                f"B={B}, padded={pad_b}")
        self._scn = jax.tree_util.tree_map(
            lambda leaf: jax.device_put(leaf, sh), self._scn)
        full = np.zeros((pad_b, n_max), bool)
        full[:B] = self._mask
        self._mask_dev = jax.device_put(jnp.asarray(full), sh)
        self._n_classes_dev = self._n_classes_host = None
        if self._state is not None:
            self._state = jax.tree_util.tree_map(
                lambda leaf: jax.device_put(leaf, sh),
                sharding.pad_window_state(self._state, pad_b))

    def _exit_residency(self) -> None:
        """Materialize the logical host layout: trim mesh-padding lanes,
        gather leaves back to the default device, drop the mask mirror."""
        b = self.batch_size

        def trim(leaf):
            leaf = leaf[:b] if int(leaf.shape[0]) > b else leaf
            return jax.device_put(leaf)

        self._scn = jax.tree_util.tree_map(trim, self._scn)
        if self._state is not None:
            self._state = jax.tree_util.tree_map(trim, self._state)
        self._mask_dev = None
        self._n_classes_dev = self._n_classes_host = None
        self._resident_mesh = None

    @contextlib.contextmanager
    def _host_geometry(self):
        """Run a lane-geometry mutation (add/remove/compact) in the logical
        host layout, then re-establish residency — so the geometry code
        never has to reason about mesh padding."""
        mesh = self._resident_mesh
        if mesh is None:
            yield
            return
        self._exit_residency()
        try:
            yield
        finally:
            self.make_resident(mesh)

    # ------------------------------------------------------------------ events
    def apply(self, event: StreamEvent) -> Optional[int]:
        """Apply one event; returns the assigned slot for arrivals.

        Parameters
        ----------
        event : StreamEvent
            One of ClassArrival, ClassDeparture, SLAEdit, CapacityChange.

        Returns
        -------
        int or None
            The slot granted to a :class:`ClassArrival`, else None.
        """
        if isinstance(event, ClassArrival):
            return self.arrive(event.lane, **event.params)
        if isinstance(event, ClassDeparture):
            self.depart(event.lane, event.slot)
        elif isinstance(event, SLAEdit):
            self.edit(event.lane, event.slot, **event.updates)
        elif isinstance(event, CapacityChange):
            self.set_capacity(event.lane, event.R)
        else:
            raise TypeError(f"unknown event {event!r}")
        return None

    def apply_epoch(self, events: Sequence[StreamEvent]) -> List[Optional[int]]:
        """Fold MANY events into one atomic, coalesced window update.

        Numerically identical to applying ``events`` one by one with
        :meth:`apply` (same slot assignments, same growth schedule, same
        written values — the per-slot constants come from the same
        :func:`derive` closed forms), but the device work is *coalesced*:
        the whole epoch commits in ONE fused dispatch
        (:func:`_epoch_commit`: every class field, the resident mask
        mirror, vacated warm-state slots, lane capacities and the rho_hat
        refresh), so an epoch of K events costs one dispatch instead of
        ~20·K.  This is the dispatch amortization that makes coalesced
        re-solve epochs (:class:`EventEpoch`, ``allocator.solve_coalesced``)
        pay off on dispatch-bound backends — and it is what keeps
        device-resident windows cheap, where every dispatch is a full
        multi-device execution round.

        The update is atomic: events are validated against a host-side
        simulation of the whole epoch first, so an invalid event (unknown
        lane, departing an empty slot, bad SLA fields) raises before any
        state is mutated.

        Parameters
        ----------
        events : Sequence[StreamEvent]
            Events in application order (the order defines slot assignment
            for arrivals and the merge order of SLA edits).

        Returns
        -------
        list of (int or None)
            One entry per event: the slot granted to a
            :class:`ClassArrival`, None for every other kind.
        """
        events = list(events)
        if not events:
            return []
        # ---- simulate: net per-slot effect + validation, no mutation yet
        sim_mask = self._mask.copy()
        n_max, B = self.n_max, self.batch_size
        staged: Dict[Tuple[int, int], Optional[dict]] = {}  # None = vacated
        vacated: Set[Tuple[int, int]] = set()
        new_R: Dict[int, float] = {}
        granted: List[Optional[int]] = []
        for ev in events:
            if isinstance(ev, ClassArrival):
                self._check_lane(ev.lane)
                missing = set(RAW_CLASS_FIELDS) - set(ev.params)
                if missing:
                    raise ValueError(
                        f"class params missing fields {sorted(missing)}")
                free = np.flatnonzero(~sim_mask[ev.lane])
                if free.size == 0:                  # mirror self.grow
                    grown = grown_n_max(n_max, self.growth_factor)
                    sim_mask = np.concatenate(
                        [sim_mask, np.zeros((B, grown - n_max), bool)], axis=1)
                    n_max = grown
                    free = np.flatnonzero(~sim_mask[ev.lane])
                slot = int(free[0])
                sim_mask[ev.lane, slot] = True
                staged[(ev.lane, slot)] = dict(ev.params)
                granted.append(slot)
                continue
            granted.append(None)
            if isinstance(ev, ClassDeparture):
                self._check_lane(ev.lane)
                if not 0 <= ev.slot < n_max or not sim_mask[ev.lane, ev.slot]:
                    raise IndexError(
                        f"(lane={ev.lane}, slot={ev.slot}) holds no class")
                sim_mask[ev.lane, ev.slot] = False
                staged[(ev.lane, ev.slot)] = None
                vacated.add((ev.lane, ev.slot))
            elif isinstance(ev, SLAEdit):
                self._check_lane(ev.lane)
                if not 0 <= ev.slot < n_max or not sim_mask[ev.lane, ev.slot]:
                    raise IndexError(
                        f"(lane={ev.lane}, slot={ev.slot}) holds no class")
                bad = set(ev.updates) - set(RAW_CLASS_FIELDS)
                if bad:
                    raise ValueError(f"unknown raw fields {sorted(bad)}")
                base = (staged[(ev.lane, ev.slot)]
                        if (ev.lane, ev.slot) in staged
                        else self._raw[(ev.lane, ev.slot)])
                staged[(ev.lane, ev.slot)] = {**base, **ev.updates}
            elif isinstance(ev, CapacityChange):
                self._check_lane(ev.lane)
                new_R[ev.lane] = float(ev.R)
            else:
                raise TypeError(f"unknown event {ev!r}")

        # ---- commit: grow once, host bookkeeping, then ONE fused dispatch
        if n_max > self.n_max:
            self.grow(n_max)
        dt = self._scn.A.dtype
        li = si = vals_dev = occ_dev = state_r = None
        if staged:
            keys = sorted(staged)
            rho_bar_np = self._rho_bar_host
            neutral = neutral_class_values(0.0)
            vals = {f: np.full(len(keys), neutral[f], np.dtype(dt))
                    for f in _CLASS_FIELDS}
            for i, k in enumerate(keys):            # vacated slots go neutral
                if staged[k] is None:
                    vals["rho_up"][i] = rho_bar_np[k[0]]
            occ_pos = [i for i, k in enumerate(keys) if staged[k] is not None]
            if occ_pos:
                opad = _pad_idx(occ_pos)          # bucket the derive, too
                derived = _derive_classes([staged[keys[i]] for i in opad], dt)
                for f in _CLASS_FIELDS:
                    vals[f][occ_pos] = derived[f][:len(occ_pos)]
            pidx = _pad_idx(list(range(len(keys))))   # shape-bucketed scatter
            li = jnp.asarray([keys[i][0] for i in pidx])
            si = jnp.asarray([keys[i][1] for i in pidx])
            vals_dev = {f: jnp.asarray(vals[f][pidx], dt)
                        for f in _CLASS_FIELDS}
            occ_dev = jnp.asarray(
                np.asarray([staged[keys[i]] is not None for i in pidx]))
            for k in keys:
                occupied = staged[k] is not None
                self._mask[k] = occupied
                if occupied:
                    self._raw[k] = dict(staged[k])
                else:
                    self._raw.pop(k, None)
            if vacated and self._state is not None:
                state_r = self._state.r
        R_lanes = R_vals = None
        if new_R:
            lanes_R = _pad_idx(sorted(new_R))
            R_lanes = jnp.asarray(lanes_R)
            R_vals = jnp.asarray([new_R[l] for l in lanes_R], dt)
        class_lanes = sorted({k[0] for k in staged})
        hat_lanes = hat_rows = None
        if class_lanes:
            padded_lanes = _pad_idx(class_lanes)
            hat_lanes = jnp.asarray(padded_lanes)
            hat_rows = jnp.asarray(self._mask[padded_lanes])
        if staged or new_R:
            scn, mask_dev, new_state_r = _epoch_commit(
                self._scn, self._mask_dev if staged else None, state_r,
                li, si, vals_dev, occ_dev, R_lanes, R_vals,
                hat_lanes, hat_rows)
            self._scn = scn
            if staged and self._mask_dev is not None:
                self._mask_dev = mask_dev
            if new_state_r is not None:
                self._state = self._state._replace(r=new_state_r)
        for lane in {*class_lanes, *new_R}:
            self._mark_dirty(lane)
        return granted

    def arrive(self, lane: int, **params) -> int:
        """Admit a new class to ``lane``; returns its slot.

        Parameters
        ----------
        lane : int
            Target lane.
        **params
            Raw per-class scalars, exactly :data:`RAW_CLASS_FIELDS`
            (A, B, E, cM, cR, H_up, H_low, m, rho_up).

        Returns
        -------
        int
            The slot index granted — the lowest free slot; the window grows
            (repads every leaf) only when the lane's row is full.
        """
        self._check_lane(lane)
        missing = set(RAW_CLASS_FIELDS) - set(params)
        if missing:
            # validate BEFORE any mutation: an aborted admission must leave
            # both the host book-keeping and (for resident windows) the
            # device buffers exactly at the last consistent state
            raise ValueError(f"class params missing fields {sorted(missing)}")
        free = np.flatnonzero(~self._mask[lane])
        if free.size == 0:
            self.grow(grown_n_max(self.n_max, self.growth_factor))
            free = np.flatnonzero(~self._mask[lane])
        slot = int(free[0])
        self._raw[(lane, slot)] = dict(params)
        self._write_class(lane, slot, dict(params))
        self._set_mask(lane, slot, True)
        self._refresh_rho_hat(lane)
        self._mark_dirty(lane)
        return slot

    def depart(self, lane: int, slot: int) -> None:
        """Remove the class at (lane, slot); the slot becomes recyclable."""
        self._check_slot(lane, slot)
        dt = self._scn.A.dtype
        neutral = neutral_class_values(float(self._rho_bar_host[lane]))
        self._scn = _scatter_class_fields(
            self._scn, jnp.asarray([lane]), jnp.asarray([slot]),
            {f: jnp.asarray([neutral[f]], dt) for f in _CLASS_FIELDS})
        self._set_mask(lane, slot, False)
        self._raw.pop((lane, slot), None)
        self._refresh_rho_hat(lane)
        if self._state is not None:
            self._state = self._state._replace(
                r=self._state.r.at[lane, slot].set(0.0))
        self._mark_dirty(lane)

    def edit(self, lane: int, slot: int, **updates) -> None:
        """Renegotiate the SLA / profile of the class at (lane, slot).

        Parameters
        ----------
        lane, slot : int
            Addressed class (must be admitted).
        **updates
            Subset of :data:`RAW_CLASS_FIELDS` to overwrite; derived
            constants are recomputed from the merged raw parameters.
        """
        self._check_slot(lane, slot)
        bad = set(updates) - set(RAW_CLASS_FIELDS)
        if bad:
            raise ValueError(f"unknown raw fields {sorted(bad)}")
        merged = {**self._raw[(lane, slot)], **updates}
        self._raw[(lane, slot)] = merged
        self._write_class(lane, slot, merged)
        self._refresh_rho_hat(lane)
        self._mark_dirty(lane)

    def set_capacity(self, lane: int, R: float) -> None:
        """Set lane capacity R (node failures / restores, paper Fig. 2)."""
        self._check_lane(lane)
        self._scn = self._scn.replace(
            R=self._scn.R.at[lane].set(float(R)))
        self._mark_dirty(lane)

    def grow(self, new_n_max: int) -> None:
        """Repad every (B, n_max) leaf to ``new_n_max`` columns.

        Padding is solver-inert (neutral classes, mask False), so stored
        equilibria of clean lanes remain exact across growth — their padded
        tail contributes 0 to every sum the solver takes.
        """
        old = self.n_max
        if new_n_max <= old:
            raise ValueError(f"new_n_max={new_n_max} must exceed {old}")
        B, pad = self.batch_size, new_n_max - old
        # device leaves may carry mesh-padding lanes (resident layout);
        # grow their actual row count, not the logical B (padding lanes'
        # rho_bar is 1, so their rho_up fill stays the inert 1)
        rows = int(self._scn.A.shape[0])
        dt = self._scn.A.dtype
        neutral = neutral_class_values(0.0)
        kw = {}
        for f in _CLASS_FIELDS:
            leaf = getattr(self._scn, f)
            if f == "rho_up":
                fill = jnp.broadcast_to(self._scn.rho_bar[:, None],
                                        (rows, pad))
            else:
                fill = jnp.full((rows, pad), neutral[f], dt)
            kw[f] = jnp.concatenate([leaf, fill.astype(dt)], axis=1)
        self._scn = self._scn.replace(**kw)
        self._mask = np.concatenate(
            [self._mask, np.zeros((B, pad), bool)], axis=1)
        if self._state is not None:
            st = self._state
            self._state = st._replace(
                r=jnp.concatenate(
                    [st.r, jnp.zeros((int(st.r.shape[0]), pad), dt)],
                    axis=1))
        if self._resident_mesh is not None:
            # column concats may leave fresh leaves unplaced — re-commit
            # everything (device_put is a no-op for already-placed leaves)
            self._place_device_leaves()

    # ------------------------------------------------------- dynamic lanes
    def add_lane(self, scn: Optional[Scenario] = None, *,
                 R: Optional[float] = None,
                 rho_bar: Optional[float] = None) -> int:
        """Append one lane (a new cluster / fleet joining the window).

        The lane row is built by :func:`repro.core.sharding.pad_batch_lanes`
        — the same inert-lane construction the device-sharded solver pads
        ragged fleets with — then overwritten with ``scn`` when given, so a
        batch resident on a lane mesh stays shardable (the mesh path repads
        to the device multiple per solve; see ``sharding.shard_batch``).
        Stored equilibria of existing lanes are untouched; the new lane
        starts dirty/never-solved, so the next ``solve_streaming`` iterates
        exactly it (plus any other dirty lanes).

        Call between solves (flush boundaries): an :class:`EventEpoch` with
        pending events still references pre-growth lane numbering only, so
        ordering is safe, but slot simulation assumes a fixed B per epoch.

        Parameters
        ----------
        scn : Scenario, optional
            Initial classes of the new lane (ragged n is fine; the window
            grows ``n_max`` first if ``scn.n`` exceeds it).  ``None`` admits
            an *empty* lane that later arrivals fill.
        R : float, optional
            Lane capacity, required (with ``rho_bar``) when ``scn`` is None.
        rho_bar : float, optional
            Lane unit chip cost, required (with ``R``) when ``scn`` is None.

        Returns
        -------
        int
            The new lane's index (the previous ``batch_size``).
        """
        if scn is None and (R is None or rho_bar is None):
            raise ValueError("an empty lane needs explicit R= and rho_bar=")
        with self._host_geometry():
            if scn is not None and scn.n > self.n_max:
                self.grow(int(scn.n))
            b = self.batch_size
            dt = self._scn.A.dtype
            self._scn = sharding.pad_batch_lanes(self.batch, b + 1).scenarios
            self._mask = np.concatenate(
                [self._mask, np.zeros((1, self.n_max), bool)], axis=0)
            if scn is not None:
                row = pad_scenario(scn, self.n_max)
                self._scn = self._scn.replace(
                    **{f.name: getattr(self._scn, f.name).at[b].set(
                           jnp.asarray(getattr(row, f.name), dt))
                       for f in dataclasses.fields(Scenario)})
                self._mask[b, :scn.n] = True
                cols = {f: np.asarray(getattr(scn, f))
                        for f in RAW_CLASS_FIELDS}
                for i in range(scn.n):
                    self._raw[(b, i)] = {f: float(cols[f][i])
                                         for f in RAW_CLASS_FIELDS}
            else:
                self._scn = self._scn.replace(
                    R=self._scn.R.at[b].set(float(R)),
                    rho_bar=self._scn.rho_bar.at[b].set(float(rho_bar)),
                    rho_hat=self._scn.rho_hat.at[b].set(float(rho_bar)),
                    rho_up=self._scn.rho_up.at[b].set(
                        jnp.full((self.n_max,), float(rho_bar), dt)))
            if self._state is not None:
                st = self._state
                self._state = st._replace(
                    r=jnp.concatenate([st.r, jnp.zeros((1, self.n_max), dt)],
                                      axis=0),
                    rho=jnp.concatenate([st.rho, jnp.ones((1,), dt)]),
                    lane_iters=jnp.concatenate(
                        [st.lane_iters, jnp.zeros((1,), jnp.int32)]),
                    solved=jnp.concatenate([st.solved,
                                            jnp.zeros((1,), bool)]))
            self.dirty = np.append(self.dirty, True)
            self.baseline_totals = np.append(self.baseline_totals, np.nan)
            self.baseline_stale = np.append(self.baseline_stale, True)
            self._rho_bar_host = np.asarray(self._scn.rho_bar, float).copy()
        return b

    def remove_lane(self, lane: int) -> None:
        """Drop ``lane`` (a cluster / fleet leaving) and shrink B by one.

        Lanes above ``lane`` shift down by one; the caller owns any external
        lane-indexed bookkeeping (``cluster.epoch_stream`` does this for its
        fleet list).  Stored equilibria of the surviving lanes move with
        them — clean lanes stay frozen across the shrink.  Like
        :meth:`add_lane`, call at flush boundaries only.
        """
        self._check_lane(lane)
        if self.batch_size == 1:
            raise ValueError("cannot remove the last lane")
        with self._host_geometry():
            self._scn = self._scn.replace(
                **{f.name: jnp.delete(getattr(self._scn, f.name), lane,
                                      axis=0)
                   for f in dataclasses.fields(Scenario)})
            self._mask = np.delete(self._mask, lane, axis=0)
            self.dirty = np.delete(self.dirty, lane)
            self.baseline_totals = np.delete(self.baseline_totals, lane)
            self.baseline_stale = np.delete(self.baseline_stale, lane)
            if self._state is not None:
                st = self._state
                self._state = st._replace(
                    r=jnp.delete(st.r, lane, axis=0),
                    rho=jnp.delete(st.rho, lane),
                    lane_iters=jnp.delete(st.lane_iters, lane),
                    solved=jnp.delete(st.solved, lane))
            self._raw = {(b - (b > lane), s): raw
                         for (b, s), raw in self._raw.items() if b != lane}
            self._rho_bar_host = np.delete(self._rho_bar_host, lane)

    def compact(self, *, n_max: Optional[int] = None) -> np.ndarray:
        """Re-pack every lane's admitted classes into a slot prefix.

        Long-lived windows go sparse: churn leaves holes in the mask and
        growth ratchets ``n_max`` up, so every solve pays O(n_max) for
        classes that are long gone.  Compaction gathers each lane's
        admitted classes down to slots ``0..k-1`` (relative order
        preserved), shrinks ``n_max`` to the widest lane (or the requested
        ``n_max``), and remaps the stored equilibrium and raw-parameter
        book-keeping the same way — so clean lanes stay *frozen* through
        the next solve and every post-compaction solve is numerically
        equivalent (<= 1e-6; bit-equal on backends with order-stable
        reductions) to solving the uncompacted window, just on a smaller
        program.  Dirty flags and memoized centralized baselines are
        untouched (the per-lane scenarios are semantically unchanged).

        Call at flush boundaries only: pending events and previously
        sampled traces address classes by their *old* slots.  The new
        ``n_max`` changes XLA shapes, so the next solve recompiles — that
        one-off cost is why compaction is a policy decision
        (``docs/OPERATIONS.md``), not automatic.

        Parameters
        ----------
        n_max : int, optional
            Target padded width; defaults to the minimal width (the
            largest per-lane class count, floor 1).  Must be >= it.

        Returns
        -------
        np.ndarray
            (B, old_n_max) int map: old slot -> new slot, -1 where the old
            slot held no class.  Callers with slot-addressed bookkeeping
            (e.g. ``cluster.epoch_stream``'s tenant->slot maps) remap
            through it.
        """
        counts = self._mask.sum(axis=1)
        min_width = max(int(counts.max()), 1)
        target = min_width if n_max is None else int(n_max)
        if target < min_width:
            raise ValueError(
                f"n_max={target} below the widest lane ({min_width})")
        B, old = self.batch_size, self.n_max
        slot_map = np.full((B, old), -1, np.int64)
        src = np.zeros((B, target), np.int64)
        for b in range(B):
            occ = np.flatnonzero(self._mask[b])
            slot_map[b, occ] = np.arange(occ.size)
            src[b, :occ.size] = occ
        new_mask = np.arange(target)[None, :] < counts[:, None]
        if target == old and np.array_equal(new_mask, self._mask):
            return slot_map                      # already packed at this width
        with self._host_geometry():
            dt = self._scn.A.dtype
            srcj, nm = jnp.asarray(src), jnp.asarray(new_mask)
            neutral = neutral_class_values(0.0)
            kw = {}
            for f in _CLASS_FIELDS:
                gathered = jnp.take_along_axis(getattr(self._scn, f), srcj,
                                               axis=1)
                if f == "rho_up":
                    fill = jnp.broadcast_to(self._scn.rho_bar[:, None],
                                            (B, target))
                else:
                    fill = jnp.full((B, target), neutral[f], dt)
                kw[f] = jnp.where(nm, gathered, fill).astype(dt)
            self._scn = self._scn.replace(**kw)
            self._mask = new_mask
            self._raw = {(b, int(slot_map[b, s])): raw
                         for (b, s), raw in self._raw.items()}
            if self._state is not None:
                st = self._state
                self._state = st._replace(
                    r=jnp.where(nm, jnp.take_along_axis(st.r, srcj, axis=1),
                                0.0).astype(dt))
        return slot_map

    # ------------------------------------------------------------ solver state
    def warm_start(self) -> game.BatchWarmStart:
        """Incremental-re-solve init for ``solve_distributed_batch``.

        Returns
        -------
        game.BatchWarmStart
            Clean, previously solved lanes are frozen at their stored
            equilibrium (``active`` False — zero iterations); dirty or
            never-solved lanes get the cold Algorithm 4.1 init so they
            reproduce the cold trajectory exactly (see module docstring for
            why bids are never carried over).
        """
        if self._resident_mesh is not None:
            raise RuntimeError(
                "resident windows build their init on-device — use "
                "resident_warm_start (or release_resident first)")
        cold = game.cold_start(self.batch)
        if self._state is None:
            return cold
        st = self._state
        frozen_np = np.asarray(st.solved) & ~self.dirty
        frozen = jnp.asarray(frozen_np)
        keep = frozen[:, None]
        return game.BatchWarmStart(
            r=jnp.where(keep, st.r, cold.r),
            bids=cold.bids,
            rho=jnp.where(frozen, st.rho, cold.rho),
            lane_iters=jnp.where(frozen, st.lane_iters,
                                 jnp.zeros_like(st.lane_iters)),
            active=~frozen)

    def commit(self, r, rho, lane_iters) -> None:
        """Store a fresh equilibrium and mark every lane clean.

        Parameters
        ----------
        r : jnp.ndarray
            (B, n_max) equilibrium allocation of the just-finished solve
            (a resident solve commits the PADDED lane count — the mesh
            padding rows stay part of the stored state).
        rho : jnp.ndarray
            (B,) final RM prices (``Solution.aux``).
        lane_iters : jnp.ndarray
            (B,) per-lane iteration counts (``Solution.iters``).
        """
        dt = self._scn.A.dtype
        self._state = WindowState(
            r=jnp.asarray(r, dt),
            rho=jnp.asarray(rho, dt),
            lane_iters=jnp.asarray(lane_iters, jnp.int32),
            solved=jnp.ones((int(np.shape(r)[0]),), bool))
        self.dirty[:] = False

    # -------------------------------------------------------------- internals
    def _set_mask(self, lane: int, slot: int, occupied: bool) -> None:
        """One slot's occupancy, kept in sync on the host mask and (when
        resident) the device mirror — the single-event write path."""
        self._mask[lane, slot] = occupied
        if self._mask_dev is not None:
            self._mask_dev = self._mask_dev.at[lane, slot].set(occupied)

    def _mark_dirty(self, lane: int) -> None:
        self.dirty[lane] = True
        self.baseline_stale[lane] = True

    def _check_lane(self, lane: int) -> None:
        if not 0 <= lane < self.batch_size:
            raise IndexError(f"lane {lane} out of range [0, {self.batch_size})")

    def _check_slot(self, lane: int, slot: int) -> None:
        self._check_lane(lane)
        if not 0 <= slot < self.n_max or not self._mask[lane, slot]:
            raise IndexError(f"(lane={lane}, slot={slot}) holds no class")

    def _write_class(self, lane: int, slot: int, raw: dict) -> None:
        dt = self._scn.A.dtype
        vals = _derive_class(raw, dt)
        self._scn = _scatter_class_fields(
            self._scn, jnp.asarray([lane]), jnp.asarray([slot]),
            {f: jnp.asarray([vals[f]], dt) for f in _CLASS_FIELDS})

    def _refresh_rho_hat(self, lane: int) -> None:
        # rho_hat = max_i rho_up over ADMITTED classes (paper (P5e) interval
        # end); an empty lane degenerates to the single candidate rho_bar.
        # copy: ``_mask[lane][None]`` is a numpy view and jnp.asarray may
        # zero-copy it — the jitted refresh must read a snapshot
        self._scn = _refresh_hats(self._scn, jnp.asarray([lane]),
                                  jnp.asarray(self._mask[lane][None].copy()))


def grown_n_max(n_max: int, growth_factor: float) -> int:
    """Deterministic growth schedule shared by the window and trace tools.

    Parameters
    ----------
    n_max : int
        Current padded width.
    growth_factor : float
        Multiplicative headroom (> 1).

    Returns
    -------
    int
        ``max(ceil(growth_factor * n_max), n_max + 1)``.
    """
    return max(int(math.ceil(n_max * growth_factor)), n_max + 1)


# --------------------------------------------------------------------------
# Event coalescing: fold many events into one re-solve epoch
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class FlushPolicy:
    """When should a buffered event epoch stop accumulating and re-solve?

    The re-solve cadence is the operator's real control knob (see
    ``docs/OPERATIONS.md``): coalescing K events per solve amortizes the
    per-solve dispatch cost ~K-fold at the price of K events of equilibrium
    staleness.  Count/fraction triggers compose with OR; a policy with both
    None never auto-flushes (purely manual ``flush`` calls).  On top of
    the bulk triggers, the *deadline-aware* triggers (see
    :meth:`deadline`) force an immediate flush for SLA-critical events —
    an :class:`~repro.core.types.SLAEdit` tightening a deadline, or an
    arrival whose deadline is already nearly exhausted — so the game
    re-equilibrates before a critical class waits out a whole epoch, while
    bulk events keep coalescing.

    Attributes
    ----------
    max_events : int, optional
        Flush once this many events are buffered (the latency bound: no
        admitted class waits more than ``max_events`` events for capacity).
    max_dirty_fraction : float, optional
        Flush once the prospective dirty-lane fraction (window-dirty plus
        buffered lanes, over B) reaches this value.  Past ~0.5 the
        frozen-lane saving of the warm start is mostly gone, so waiting
        longer buys staleness without saving work.
    deadline_slack_s : float, optional
        SLA-criticality threshold on ``E = C - D`` (< 0 when the deadline
        is attainable): an arrival or deadline edit landing at
        ``E >= -deadline_slack_s`` — within ``deadline_slack_s`` seconds
        of an unattainable deadline — flushes immediately.  ``None``
        (default) disables the trigger.
    flush_on_sla_tightening : bool
        Flush immediately on any :class:`~repro.core.types.SLAEdit` that
        *tightens* a class's deadline (raises its ``E`` toward 0), however
        much slack remains — the renegotiation the paper's runtime loop
        reacts to fastest.
    """
    max_events: Optional[int] = 8
    max_dirty_fraction: Optional[float] = None
    deadline_slack_s: Optional[float] = None
    flush_on_sla_tightening: bool = False

    @classmethod
    def deadline(cls, slack_s: float, *, max_events: Optional[int] = 64,
                 max_dirty_fraction: Optional[float] = None,
                 tightening: bool = True) -> "FlushPolicy":
        """Deadline-aware policy: SLA-critical events flush immediately.

        ``Policies(flush=FlushPolicy.deadline(30.0))`` gives the paper's
        runtime loop a two-speed cadence: bulk churn (arrivals with ample
        slack, departures, capacity steps) coalesces up to ``max_events``
        per re-solve, while a deadline-critical event — a class arriving
        within ``slack_s`` seconds of infeasibility, or an SLA edit
        tightening a deadline — re-equilibrates the game at once.

        Parameters
        ----------
        slack_s : float
            Criticality threshold [s] on ``E = C - D``: events with
            ``E >= -slack_s`` are critical.
        max_events : int, optional
            Bulk coalescing bound (default 64 — deliberately loose; the
            deadline triggers carry the latency guarantee).
        max_dirty_fraction : float, optional
            Optional bulk dirty-fraction trigger, as on the default policy.
        tightening : bool, optional
            Also flush on every deadline-tightening SLA edit (default
            True).

        Returns
        -------
        FlushPolicy
            The configured policy.
        """
        return cls(max_events=max_events,
                   max_dirty_fraction=max_dirty_fraction,
                   deadline_slack_s=float(slack_s),
                   flush_on_sla_tightening=tightening)

    def is_critical(self, event: StreamEvent,
                    window: "AdmissionWindow") -> bool:
        """Does ``event`` demand an immediate flush (deadline triggers)?

        Parameters
        ----------
        event : StreamEvent
            The event being buffered.
        window : AdmissionWindow
            The live window — consulted for the edited class's current
            ``E`` so *tightening* is judged against the last applied state
            (an edit to a class that itself arrived earlier in the same
            epoch is judged by the slack threshold only).

        Returns
        -------
        bool
            True when a deadline trigger fires; always False for policies
            without deadline triggers configured.
        """
        slack = self.deadline_slack_s
        if isinstance(event, ClassArrival):
            return (slack is not None
                    and float(event.params.get("E", -np.inf)) >= -slack)
        if isinstance(event, SLAEdit) and "E" in event.updates:
            new_E = float(event.updates["E"])
            if slack is not None and new_E >= -slack:
                return True
            if self.flush_on_sla_tightening:
                old = window._raw.get((event.lane, event.slot))
                return old is not None and new_E > float(old["E"])
        return False

    def should_flush(self, *, n_events: int, n_dirty: int,
                     batch_size: int) -> bool:
        """Evaluate the triggers against an epoch's current accumulation.

        Parameters
        ----------
        n_events : int
            Events buffered so far.
        n_dirty : int
            Prospective dirty lanes of the flush (window dirty | buffered).
        batch_size : int
            Window lane count B.

        Returns
        -------
        bool
            True when any configured trigger fires.
        """
        if self.max_events is not None and n_events >= self.max_events:
            return True
        if (self.max_dirty_fraction is not None and batch_size > 0
                and n_dirty / batch_size >= self.max_dirty_fraction):
            return True
        return False


class EventEpoch:
    """Accumulate events against a window; one coalesced solve per flush.

    The coalescing layer between per-event streaming (PR 2) and the
    operator's cadence policy: events buffer on the host (zero device
    work), and :meth:`flush` folds them into the window with ONE scatter
    per Scenario field (:meth:`AdmissionWindow.apply_epoch`) followed by
    ONE warm-started ``solve_streaming`` over the union of dirtied lanes.
    Replaying a trace through epochs lands on exactly the per-event
    equilibria at every flush boundary: a lane dirtied anywhere in the
    epoch restarts from the cold Algorithm 4.1 init on its *final*
    scenario, which is precisely what the last per-event solve would have
    computed (``tests/test_coalescing.py``).

    Parameters
    ----------
    window : AdmissionWindow
        The live window; mutated only at flush.
    policy : FlushPolicy, optional
        Auto-flush triggers consulted by :meth:`add` (default: flush every
        8 events).

    Attributes
    ----------
    flushes : int
        Completed flushes.
    events_folded : int
        Total events applied across all flushes.
    last_slots : list
        Per-event slot grants of the most recent flush (see
        :meth:`AdmissionWindow.apply_epoch`).
    """

    def __init__(self, window: AdmissionWindow,
                 policy: Optional[FlushPolicy] = None):
        self.window = window
        self.policy = policy or FlushPolicy()
        self._events: List[StreamEvent] = []
        self.flushes = 0
        self.events_folded = 0
        self.last_slots: List[Optional[int]] = []

    def __len__(self) -> int:
        return len(self._events)

    @property
    def pending(self) -> Tuple[StreamEvent, ...]:
        """Buffered, not-yet-applied events (application order)."""
        return tuple(self._events)

    @property
    def dirty_lanes(self) -> Set[int]:
        """Lanes the next flush will re-solve: window-dirty | buffered."""
        return (set(np.flatnonzero(self.window.dirty))
                | {ev.lane for ev in self._events})

    def add(self, event: StreamEvent) -> bool:
        """Buffer one event; report whether the policy wants a flush.

        Parameters
        ----------
        event : StreamEvent
            Any of the four event kinds; validated at flush (atomically,
            see :meth:`AdmissionWindow.apply_epoch`).

        Returns
        -------
        bool
            True when the flush policy's triggers fire — including an
            SLA-critical event under a deadline-aware policy — and the
            caller should :meth:`flush` (``WindowSession.stream`` does).
        """
        self._events.append(event)
        return (self.policy.is_critical(event, self.window)
                or self.policy.should_flush(
                    n_events=len(self._events),
                    n_dirty=len(self.dirty_lanes),
                    batch_size=self.window.batch_size))

    def flush(self, **solve_kwargs):
        """Apply the buffered events and re-solve the window once.

        Parameters
        ----------
        **solve_kwargs
            Legacy solver kwargs (``mesh=``, ``integer=``, solver knobs,
            ...) mapped onto a config/policy pair by
            ``engine._legacy_solve_window``.

        Returns
        -------
        repro.core.engine.WindowSolveReport
            The coalesced re-solve (an empty flush with a clean window is
            legal and nearly free: every lane freezes).
        """
        from repro.core.engine import _legacy_solve_window
        self.last_slots = self.window.apply_epoch(self._events)
        self.events_folded += len(self._events)
        self._events = []
        res = _legacy_solve_window(self.window, **solve_kwargs)
        self.flushes += 1
        return res


# --------------------------------------------------------------------------
# Event-trace generation (tests + benchmarks/streaming_perf.py)
# --------------------------------------------------------------------------


def sample_event_trace(seed: int, window: AdmissionWindow, n_events: int, *,
                       p_arrive: float = 0.45, p_depart: float = 0.30,
                       p_edit: float = 0.15, p_capacity: float = 0.10,
                       params_fn=None) -> List[StreamEvent]:
    """Random, replayable event trace applicable to ``window`` (unmutated).

    The generator simulates the window's slot-assignment and growth rules on
    a host-side copy of the occupancy mask, so departure / edit events always
    address slots that will actually be occupied when the trace is applied in
    order — the same trace can therefore be replayed against an identically
    initialised second window (the cold baseline of the benchmark).

    Parameters
    ----------
    seed : int
        Seeds both the structural RNG and the per-arrival parameter draws.
    window : AdmissionWindow
        Snapshot defining initial occupancy, ``n_max`` and growth factor.
    n_events : int
        Trace length.
    p_arrive, p_depart, p_edit, p_capacity : float, optional
        Event-kind mixture (renormalised).  Kinds that are momentarily
        impossible (departing from an all-empty window) fall back to arrival.
    params_fn : callable, optional
        ``params_fn(jax_key) -> dict`` drawing one class's raw parameters;
        defaults to :func:`repro.core.profiles.sample_class_params`
        (the paper's Table 5 design of experiments).

    Returns
    -------
    list of StreamEvent
        Events in application order.
    """
    params_fn = params_fn or sample_class_params
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    probs = np.asarray([p_arrive, p_depart, p_edit, p_capacity], float)
    probs = probs / probs.sum()

    mask = window._mask.copy()
    n_max = window.n_max
    R = np.asarray(window._scn.R, float).copy()
    B = mask.shape[0]

    events: List[StreamEvent] = []
    for _ in range(n_events):
        kind = rng.choice(4, p=probs)
        occupied = np.argwhere(mask)
        if kind in (1, 2) and occupied.size == 0:
            kind = 0
        if kind == 0:                                   # arrival
            lane = int(rng.integers(B))
            key, sub = jax.random.split(key)
            events.append(ClassArrival(lane=lane, params=params_fn(sub)))
            free = np.flatnonzero(~mask[lane])
            if free.size == 0:                          # mirror window.grow
                new = grown_n_max(n_max, window.growth_factor)
                mask = np.concatenate(
                    [mask, np.zeros((B, new - n_max), bool)], axis=1)
                n_max = new
                free = np.flatnonzero(~mask[lane])
            mask[lane, int(free[0])] = True
        elif kind == 1:                                 # departure
            lane, slot = occupied[rng.integers(len(occupied))]
            events.append(ClassDeparture(lane=int(lane), slot=int(slot)))
            mask[lane, slot] = False
        elif kind == 2:                                 # SLA edit
            lane, slot = occupied[rng.integers(len(occupied))]
            key, sub = jax.random.split(key)
            fresh = params_fn(sub)
            events.append(SLAEdit(
                lane=int(lane), slot=int(slot),
                updates={k: fresh[k]
                         for k in ("E", "m", "rho_up", "H_up", "H_low")}))
        else:                                           # capacity change
            lane = int(rng.integers(B))
            R[lane] *= float(rng.uniform(0.9, 1.1))
            events.append(CapacityChange(lane=lane, R=float(R[lane])))
    return events


def replay(window: AdmissionWindow, events: Sequence[StreamEvent]) -> None:
    """Apply ``events`` to ``window`` in order (no solving).

    Parameters
    ----------
    window : AdmissionWindow
        Mutated in place.
    events : Sequence[StreamEvent]
        A trace, e.g. from :func:`sample_event_trace`.
    """
    for ev in events:
        window.apply(ev)
