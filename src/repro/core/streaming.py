"""Streaming admission engine — the paper's *runtime* allocation loop.

The paper's whole point (Sec. 1, Sec. 5 "runtime" experiments) is that the
Resource Manager and Class Managers re-negotiate capacity **as job classes
arrive and leave**, not on a fixed batch.  This module turns the batched GNEP
engine (``game.solve_distributed_batch``) into that runtime system:

* :class:`AdmissionWindow` maintains a *live* padded :class:`ScenarioBatch`
  under :class:`~repro.core.types.ClassArrival` /
  :class:`~repro.core.types.ClassDeparture` /
  :class:`~repro.core.types.SLAEdit` /
  :class:`~repro.core.types.CapacityChange` events.  A departing class's slot
  is refilled with solver-inert neutral values and recycled by the next
  arrival (free-slot recycling in the mask); the window repads every leaf to
  a larger ``n_max`` only when a lane's row is actually full, so steady-state
  event application never re-stacks the batch and never changes XLA shapes
  (no recompilation).

* :meth:`AdmissionWindow.warm_start` builds the incremental re-solve init:
  lanes whose scenario is unchanged since their last equilibrium are
  *frozen* (zero solver iterations — their stored equilibrium passes through
  the vmapped while-loop untouched), and only *dirty* lanes iterate.  Dirty
  lanes restart from the paper's cold Algorithm 4.1 init so they reproduce
  the cold trajectory exactly: CM bids only escalate during the game, so
  carrying converged bids across a scenario change would steer the game to a
  different (higher-price) equilibrium.  This makes the streaming solve
  numerically equivalent to a cold re-solve of the final window while doing
  only the dirty lanes' work.

The user-facing facade is :func:`repro.core.allocator.solve_streaming`
(warm solve + Algorithm 4.2 rounding + optional centralized cross-check);
:func:`sample_event_trace` generates random-but-replayable event traces for
tests and ``benchmarks/streaming_perf.py``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game
from repro.core.profiles import sample_class_params
from repro.core.types import (RAW_CLASS_FIELDS, CapacityChange, ClassArrival,
                              ClassDeparture, Scenario, ScenarioBatch,
                              SLAEdit, StreamEvent, WindowState, derive,
                              neutral_class_values, stack_scenarios)

#: Per-class Scenario fields (raw + derived) scattered on every class write.
_CLASS_FIELDS = tuple(neutral_class_values(0.0).keys())


def _derive_class(params: dict, dtype) -> dict:
    """Derived per-class constants (Props. 3.3, Eqs. 7/8/17/18) for ONE class.

    Parameters
    ----------
    params : dict
        Raw per-class scalars; keys exactly :data:`RAW_CLASS_FIELDS`.
    dtype : jnp.dtype
        Float dtype of the window's leaves.

    Returns
    -------
    dict
        Field name -> python float for every per-class field of
        :class:`Scenario` (the raw values plus the derived constants),
        computed by the same :func:`repro.core.types.derive` closed forms
        the batch constructor uses.
    """
    missing = set(RAW_CLASS_FIELDS) - set(params)
    if missing:
        raise ValueError(f"class params missing fields {sorted(missing)}")
    one = derive(**{k: jnp.asarray([params[k]], dtype)
                    for k in RAW_CLASS_FIELDS},
                 R=jnp.asarray(0.0, dtype), rho_bar=jnp.asarray(0.0, dtype))
    return {f: float(getattr(one, f)[0]) for f in _CLASS_FIELDS}


class AdmissionWindow:
    """A live, padded :class:`ScenarioBatch` plus last-equilibrium state.

    Each *lane* is one running allocation game (one cluster / fleet); events
    admit, remove or renegotiate job classes inside a lane.  The window keeps

    * the stacked :class:`Scenario` leaves ((B, n_max) per class, (B,)
      scalars) with vacated / never-used slots held at solver-inert neutral
      values (:func:`~repro.core.types.neutral_class_values`);
    * a host-side occupancy mask mirroring ``ScenarioBatch.mask`` (kept on
      host so event application never synchronises with the device);
    * the previous equilibrium (:class:`~repro.core.types.WindowState`) and a
      per-lane *dirty* flag driving the warm-started incremental re-solve.

    Parameters
    ----------
    scenarios : Sequence[Scenario]
        Initial (possibly ragged) instances, one per lane.  The lane count B
        is fixed for the window's lifetime; class counts are not.
    n_max : int, optional
        Initial padded width.  Defaults to the largest initial class count;
        give headroom to avoid early growth repads.
    growth_factor : float, optional
        When a lane's row is full, every leaf is repadded to
        ``max(ceil(growth_factor * n_max), n_max + 1)`` columns.  Stored
        equilibria stay valid across growth because padding is inert.

    Notes
    -----
    Feasibility is intentionally *not* enforced at admission time: a burst of
    arrivals may legitimately push ``sum(r_low) > R`` until the operator
    sheds load or adds capacity, so infeasible transients must be
    representable.  ``solve_streaming`` reports per-lane ``feasible`` flags.
    """

    def __init__(self, scenarios: Sequence[Scenario], *,
                 n_max: Optional[int] = None, growth_factor: float = 2.0):
        scns = list(scenarios)
        if not scns:
            raise ValueError("AdmissionWindow needs at least one lane")
        if growth_factor <= 1.0:
            raise ValueError("growth_factor must be > 1")
        batch = stack_scenarios(scns, n_max=n_max)
        self._scn = batch.scenarios
        self._mask = np.asarray(batch.mask).copy()
        self.growth_factor = float(growth_factor)
        self.dirty = np.zeros(self.batch_size, bool)
        # per-lane memo of the exact centralized (P3) total, invalidated by
        # the same events that dirty a lane (solve_streaming's cross-check
        # recomputes only stale lanes instead of the whole batch per event)
        self.baseline_totals = np.full(self.batch_size, np.nan)
        self.baseline_stale = np.ones(self.batch_size, bool)
        self._state: Optional[WindowState] = None
        # raw per-class params so SLAEdit can merge partial updates
        # (one device->host transfer per field per lane, not per scalar)
        self._raw: Dict[Tuple[int, int], dict] = {}
        for b, s in enumerate(scns):
            cols = {f: np.asarray(getattr(s, f)) for f in RAW_CLASS_FIELDS}
            for i in range(s.n):
                self._raw[(b, i)] = {f: float(cols[f][i])
                                     for f in RAW_CLASS_FIELDS}

    # ------------------------------------------------------------------ views
    @property
    def batch_size(self) -> int:
        return self._mask.shape[0]

    @property
    def n_max(self) -> int:
        return self._mask.shape[1]

    @property
    def n_classes(self) -> np.ndarray:
        """(B,) host array — current number of admitted classes per lane."""
        return self._mask.sum(axis=1)

    @property
    def batch(self) -> ScenarioBatch:
        """The current window as a solver-ready :class:`ScenarioBatch`."""
        return ScenarioBatch(scenarios=self._scn,
                             mask=jnp.asarray(self._mask),
                             n_classes=jnp.asarray(self.n_classes))

    @property
    def state(self) -> Optional[WindowState]:
        """Last committed equilibrium, or None before the first solve."""
        return self._state

    def occupied(self, lane: int) -> List[int]:
        """Slot indices currently holding an admitted class in ``lane``."""
        return [int(i) for i in np.flatnonzero(self._mask[lane])]

    # ------------------------------------------------------------------ events
    def apply(self, event: StreamEvent) -> Optional[int]:
        """Apply one event; returns the assigned slot for arrivals.

        Parameters
        ----------
        event : StreamEvent
            One of ClassArrival, ClassDeparture, SLAEdit, CapacityChange.

        Returns
        -------
        int or None
            The slot granted to a :class:`ClassArrival`, else None.
        """
        if isinstance(event, ClassArrival):
            return self.arrive(event.lane, **event.params)
        if isinstance(event, ClassDeparture):
            self.depart(event.lane, event.slot)
        elif isinstance(event, SLAEdit):
            self.edit(event.lane, event.slot, **event.updates)
        elif isinstance(event, CapacityChange):
            self.set_capacity(event.lane, event.R)
        else:
            raise TypeError(f"unknown event {event!r}")
        return None

    def arrive(self, lane: int, **params) -> int:
        """Admit a new class to ``lane``; returns its slot.

        Parameters
        ----------
        lane : int
            Target lane.
        **params
            Raw per-class scalars, exactly :data:`RAW_CLASS_FIELDS`
            (A, B, E, cM, cR, H_up, H_low, m, rho_up).

        Returns
        -------
        int
            The slot index granted — the lowest free slot; the window grows
            (repads every leaf) only when the lane's row is full.
        """
        self._check_lane(lane)
        free = np.flatnonzero(~self._mask[lane])
        if free.size == 0:
            self.grow(grown_n_max(self.n_max, self.growth_factor))
            free = np.flatnonzero(~self._mask[lane])
        slot = int(free[0])
        self._raw[(lane, slot)] = dict(params)
        self._write_class(lane, slot, dict(params))
        self._mask[lane, slot] = True
        self._refresh_rho_hat(lane)
        self._mark_dirty(lane)
        return slot

    def depart(self, lane: int, slot: int) -> None:
        """Remove the class at (lane, slot); the slot becomes recyclable."""
        self._check_slot(lane, slot)
        neutral = neutral_class_values(float(self._scn.rho_bar[lane]))
        kw = {}
        for f in _CLASS_FIELDS:
            kw[f] = getattr(self._scn, f).at[lane, slot].set(neutral[f])
        self._scn = self._scn.replace(**kw)
        self._mask[lane, slot] = False
        self._raw.pop((lane, slot), None)
        self._refresh_rho_hat(lane)
        if self._state is not None:
            self._state = self._state._replace(
                r=self._state.r.at[lane, slot].set(0.0))
        self._mark_dirty(lane)

    def edit(self, lane: int, slot: int, **updates) -> None:
        """Renegotiate the SLA / profile of the class at (lane, slot).

        Parameters
        ----------
        lane, slot : int
            Addressed class (must be admitted).
        **updates
            Subset of :data:`RAW_CLASS_FIELDS` to overwrite; derived
            constants are recomputed from the merged raw parameters.
        """
        self._check_slot(lane, slot)
        bad = set(updates) - set(RAW_CLASS_FIELDS)
        if bad:
            raise ValueError(f"unknown raw fields {sorted(bad)}")
        merged = {**self._raw[(lane, slot)], **updates}
        self._raw[(lane, slot)] = merged
        self._write_class(lane, slot, merged)
        self._refresh_rho_hat(lane)
        self._mark_dirty(lane)

    def set_capacity(self, lane: int, R: float) -> None:
        """Set lane capacity R (node failures / restores, paper Fig. 2)."""
        self._check_lane(lane)
        self._scn = self._scn.replace(
            R=self._scn.R.at[lane].set(float(R)))
        self._mark_dirty(lane)

    def grow(self, new_n_max: int) -> None:
        """Repad every (B, n_max) leaf to ``new_n_max`` columns.

        Padding is solver-inert (neutral classes, mask False), so stored
        equilibria of clean lanes remain exact across growth — their padded
        tail contributes 0 to every sum the solver takes.
        """
        old = self.n_max
        if new_n_max <= old:
            raise ValueError(f"new_n_max={new_n_max} must exceed {old}")
        B, pad = self.batch_size, new_n_max - old
        dt = self._scn.A.dtype
        neutral = neutral_class_values(0.0)
        kw = {}
        for f in _CLASS_FIELDS:
            leaf = getattr(self._scn, f)
            if f == "rho_up":
                fill = jnp.broadcast_to(self._scn.rho_bar[:, None], (B, pad))
            else:
                fill = jnp.full((B, pad), neutral[f], dt)
            kw[f] = jnp.concatenate([leaf, fill.astype(dt)], axis=1)
        self._scn = self._scn.replace(**kw)
        self._mask = np.concatenate(
            [self._mask, np.zeros((B, pad), bool)], axis=1)
        if self._state is not None:
            st = self._state
            self._state = st._replace(
                r=jnp.concatenate([st.r, jnp.zeros((B, pad), dt)], axis=1))

    # ------------------------------------------------------------ solver state
    def warm_start(self) -> game.BatchWarmStart:
        """Incremental-re-solve init for ``solve_distributed_batch``.

        Returns
        -------
        game.BatchWarmStart
            Clean, previously solved lanes are frozen at their stored
            equilibrium (``active`` False — zero iterations); dirty or
            never-solved lanes get the cold Algorithm 4.1 init so they
            reproduce the cold trajectory exactly (see module docstring for
            why bids are never carried over).
        """
        cold = game.cold_start(self.batch)
        if self._state is None:
            return cold
        st = self._state
        frozen_np = np.asarray(st.solved) & ~self.dirty
        frozen = jnp.asarray(frozen_np)
        keep = frozen[:, None]
        return game.BatchWarmStart(
            r=jnp.where(keep, st.r, cold.r),
            bids=cold.bids,
            rho=jnp.where(frozen, st.rho, cold.rho),
            lane_iters=jnp.where(frozen, st.lane_iters,
                                 jnp.zeros_like(st.lane_iters)),
            active=~frozen)

    def commit(self, r, rho, lane_iters) -> None:
        """Store a fresh equilibrium and mark every lane clean.

        Parameters
        ----------
        r : jnp.ndarray
            (B, n_max) equilibrium allocation of the just-finished solve.
        rho : jnp.ndarray
            (B,) final RM prices (``Solution.aux``).
        lane_iters : jnp.ndarray
            (B,) per-lane iteration counts (``Solution.iters``).
        """
        dt = self._scn.A.dtype
        self._state = WindowState(
            r=jnp.asarray(r, dt),
            rho=jnp.asarray(rho, dt),
            lane_iters=jnp.asarray(lane_iters, jnp.int32),
            solved=jnp.ones((self.batch_size,), bool))
        self.dirty[:] = False

    # -------------------------------------------------------------- internals
    def _mark_dirty(self, lane: int) -> None:
        self.dirty[lane] = True
        self.baseline_stale[lane] = True

    def _check_lane(self, lane: int) -> None:
        if not 0 <= lane < self.batch_size:
            raise IndexError(f"lane {lane} out of range [0, {self.batch_size})")

    def _check_slot(self, lane: int, slot: int) -> None:
        self._check_lane(lane)
        if not 0 <= slot < self.n_max or not self._mask[lane, slot]:
            raise IndexError(f"(lane={lane}, slot={slot}) holds no class")

    def _write_class(self, lane: int, slot: int, raw: dict) -> None:
        vals = _derive_class(raw, self._scn.A.dtype)
        kw = {}
        for f in _CLASS_FIELDS:
            kw[f] = getattr(self._scn, f).at[lane, slot].set(vals[f])
        self._scn = self._scn.replace(**kw)

    def _refresh_rho_hat(self, lane: int) -> None:
        # rho_hat = max_i rho_up over ADMITTED classes (paper (P5e) interval
        # end); an empty lane degenerates to the single candidate rho_bar.
        row = self._mask[lane]
        rho_up_row = jnp.where(jnp.asarray(row), self._scn.rho_up[lane],
                               self._scn.rho_bar[lane])
        self._scn = self._scn.replace(
            rho_hat=self._scn.rho_hat.at[lane].set(jnp.max(rho_up_row)))


def grown_n_max(n_max: int, growth_factor: float) -> int:
    """Deterministic growth schedule shared by the window and trace tools.

    Parameters
    ----------
    n_max : int
        Current padded width.
    growth_factor : float
        Multiplicative headroom (> 1).

    Returns
    -------
    int
        ``max(ceil(growth_factor * n_max), n_max + 1)``.
    """
    return max(int(math.ceil(n_max * growth_factor)), n_max + 1)


# --------------------------------------------------------------------------
# Event-trace generation (tests + benchmarks/streaming_perf.py)
# --------------------------------------------------------------------------


def sample_event_trace(seed: int, window: AdmissionWindow, n_events: int, *,
                       p_arrive: float = 0.45, p_depart: float = 0.30,
                       p_edit: float = 0.15, p_capacity: float = 0.10,
                       params_fn=None) -> List[StreamEvent]:
    """Random, replayable event trace applicable to ``window`` (unmutated).

    The generator simulates the window's slot-assignment and growth rules on
    a host-side copy of the occupancy mask, so departure / edit events always
    address slots that will actually be occupied when the trace is applied in
    order — the same trace can therefore be replayed against an identically
    initialised second window (the cold baseline of the benchmark).

    Parameters
    ----------
    seed : int
        Seeds both the structural RNG and the per-arrival parameter draws.
    window : AdmissionWindow
        Snapshot defining initial occupancy, ``n_max`` and growth factor.
    n_events : int
        Trace length.
    p_arrive, p_depart, p_edit, p_capacity : float, optional
        Event-kind mixture (renormalised).  Kinds that are momentarily
        impossible (departing from an all-empty window) fall back to arrival.
    params_fn : callable, optional
        ``params_fn(jax_key) -> dict`` drawing one class's raw parameters;
        defaults to :func:`repro.core.profiles.sample_class_params`
        (the paper's Table 5 design of experiments).

    Returns
    -------
    list of StreamEvent
        Events in application order.
    """
    params_fn = params_fn or sample_class_params
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    probs = np.asarray([p_arrive, p_depart, p_edit, p_capacity], float)
    probs = probs / probs.sum()

    mask = window._mask.copy()
    n_max = window.n_max
    R = np.asarray(window._scn.R, float).copy()
    B = mask.shape[0]

    events: List[StreamEvent] = []
    for _ in range(n_events):
        kind = rng.choice(4, p=probs)
        occupied = np.argwhere(mask)
        if kind in (1, 2) and occupied.size == 0:
            kind = 0
        if kind == 0:                                   # arrival
            lane = int(rng.integers(B))
            key, sub = jax.random.split(key)
            events.append(ClassArrival(lane=lane, params=params_fn(sub)))
            free = np.flatnonzero(~mask[lane])
            if free.size == 0:                          # mirror window.grow
                new = grown_n_max(n_max, window.growth_factor)
                mask = np.concatenate(
                    [mask, np.zeros((B, new - n_max), bool)], axis=1)
                n_max = new
                free = np.flatnonzero(~mask[lane])
            mask[lane, int(free[0])] = True
        elif kind == 1:                                 # departure
            lane, slot = occupied[rng.integers(len(occupied))]
            events.append(ClassDeparture(lane=int(lane), slot=int(slot)))
            mask[lane, slot] = False
        elif kind == 2:                                 # SLA edit
            lane, slot = occupied[rng.integers(len(occupied))]
            key, sub = jax.random.split(key)
            fresh = params_fn(sub)
            events.append(SLAEdit(
                lane=int(lane), slot=int(slot),
                updates={k: fresh[k]
                         for k in ("E", "m", "rho_up", "H_up", "H_low")}))
        else:                                           # capacity change
            lane = int(rng.integers(B))
            R[lane] *= float(rng.uniform(0.9, 1.1))
            events.append(CapacityChange(lane=lane, R=float(R[lane])))
    return events


def replay(window: AdmissionWindow, events: Sequence[StreamEvent]) -> None:
    """Apply ``events`` to ``window`` in order (no solving).

    Parameters
    ----------
    window : AdmissionWindow
        Mutated in place.
    events : Sequence[StreamEvent]
        A trace, e.g. from :func:`sample_event_trace`.
    """
    for ev in events:
        window.apply(ev)
