"""Shared workload-trace library: open-loop arrival schedules by regime.

One home for every synthetic arrival process in the repo, so the what-if
capacity planner (:mod:`repro.core.planning`), the admission daemon
(:mod:`repro.serving.allocd`) and the benchmarks
(``benchmarks/allocd_perf.py`` / ``benchmarks/plan_perf.py``) are driven by
the *same* workloads instead of each growing ad-hoc generators.  The
regimes follow the managed-Hadoop utilization literature (PAPERS.md):

* :func:`poisson_times` — the steady baseline (memoryless arrivals);
* :func:`flash_crowd_times` — one hard mid-run rate step (the spike);
* :func:`diurnal_times` — smooth sinusoidal day/night modulation;
* :func:`bursty_times` — a two-state Markov-modulated Poisson process
  (quiet/burst phases with geometric dwell times), the "bursty" regime
  where load arrives in trains rather than one spike;
* :func:`straggler_times` — exponential arrivals with a heavy-tailed
  (Pareto-inflated) fraction of inter-arrival gaps: long quiet stretches
  punctuating normal traffic, the straggler-tail regime.

Every generator takes ``(seed, n, rate)`` and returns a monotone
``(n,)`` array of arrival offsets in seconds whose *mean* rate is the
requested ``rate`` in expectation (the modulated profiles normalize their
rate process so regime shape changes the arrival *pattern*, not the total
load — two profiles at the same ``rate`` are comparable experiments).
:data:`ARRIVAL_PROFILES` maps profile names to generators (the
``--arrival`` / ``PlanSpec.profile`` vocabulary).

The first three generators moved here verbatim from
``repro.serving.allocd`` (which re-exports them bit-compatibly: same RNG
streams, same outputs — committed ``BENCH_allocd.json`` sections and the
trace-conformance tests are unchanged).
"""
from __future__ import annotations

import numpy as np


def poisson_times(seed: int, n: int, rate: float) -> np.ndarray:
    """Open-loop Poisson arrival schedule: `n` times at `rate` events/s.

    Parameters
    ----------
    seed : int
        RNG seed (numpy Generator).
    n : int
        Number of arrivals.
    rate : float
        Mean arrival rate in events per second.

    Returns
    -------
    numpy.ndarray
        Monotone arrival offsets [s] from the run start, shape ``(n,)``.
    """
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def flash_crowd_times(seed: int, n: int, rate: float, *,
                      burst_factor: float = 8.0,
                      burst_frac: float = 0.4) -> np.ndarray:
    """Flash-crowd schedule: Poisson baseline with a mid-run burst.

    The middle ``burst_frac`` of events arrive ``burst_factor`` times
    faster than `rate` — the diurnal-spike regime the Hadoop utilization
    literature reports, compressed into one run.

    Parameters
    ----------
    seed : int
        RNG seed.
    n : int
        Number of arrivals.
    rate : float
        Baseline arrival rate in events per second.
    burst_factor : float, optional
        Rate multiplier inside the burst.
    burst_frac : float, optional
        Fraction of events (centered) arriving at the burst rate.

    Returns
    -------
    numpy.ndarray
        Monotone arrival offsets [s] from the run start, shape ``(n,)``.
    """
    rng = np.random.default_rng(seed)
    lo = int(n * (0.5 - burst_frac / 2.0))
    hi = int(n * (0.5 + burst_frac / 2.0))
    rates = np.full(n, rate, dtype=np.float64)
    rates[lo:hi] *= burst_factor
    return np.cumsum(rng.exponential(1.0, size=n) / rates)


def diurnal_times(seed: int, n: int, rate: float, *,
                  peak_factor: float = 4.0,
                  cycles: float = 2.0) -> np.ndarray:
    """Diurnal arrival schedule: sinusoidally modulated Poisson process.

    The day/night utilization cycle of the Hadoop trace studies, compressed
    into one run: the instantaneous rate swings between ``rate`` (the
    trough) and ``peak_factor * rate`` (the peak) along ``cycles`` full
    sine periods over the trace.  Unlike :func:`flash_crowd_times`'s one
    hard step, the load ramps smoothly — the regime where a deadline-aware
    flush scheduler has time to adapt its cadence.

    Parameters
    ----------
    seed : int
        RNG seed.
    n : int
        Number of arrivals.
    rate : float
        Trough arrival rate in events per second.
    peak_factor : float, optional
        Peak-to-trough rate ratio (>= 1).
    cycles : float, optional
        Number of full diurnal periods spanned by the trace.

    Returns
    -------
    numpy.ndarray
        Monotone arrival offsets [s] from the run start, shape ``(n,)``.
    """
    rng = np.random.default_rng(seed)
    phase = np.linspace(0.0, 2.0 * np.pi * cycles, n, endpoint=False)
    # rate(k) in [rate, peak_factor * rate], sinusoidal; thinning-free
    # construction: scale each exponential gap by its local rate
    rates = rate * (1.0 + (peak_factor - 1.0) * 0.5 * (1.0 - np.cos(phase)))
    return np.cumsum(rng.exponential(1.0, size=n) / rates)


def bursty_times(seed: int, n: int, rate: float, *,
                 burst_factor: float = 10.0,
                 p_enter: float = 0.05,
                 p_exit: float = 0.25) -> np.ndarray:
    """Bursty schedule: two-state Markov-modulated Poisson process (MMPP).

    A hidden quiet/burst state evolves as a Markov chain over events
    (geometric dwell times: a quiet phase lasts ``1/p_enter`` events on
    average, a burst ``1/p_exit``); inside a burst the instantaneous rate
    is ``burst_factor`` times the quiet rate.  Unlike
    :func:`flash_crowd_times`'s single deterministic spike, bursts recur
    at random throughout the trace — the "trains of arrivals" regime of
    the managed-Hadoop utilization study (PAPERS.md).

    The per-event rate sequence is normalized (conditionally on the
    sampled state path) so the expected trace duration is ``n / rate``:
    the *mean* load matches `rate` exactly, only its burst structure
    varies with the dwell parameters.

    Parameters
    ----------
    seed : int
        RNG seed.
    n : int
        Number of arrivals.
    rate : float
        Target mean arrival rate in events per second.
    burst_factor : float, optional
        Burst-to-quiet instantaneous rate ratio (>= 1).
    p_enter : float, optional
        Per-event probability of a quiet->burst transition.
    p_exit : float, optional
        Per-event probability of a burst->quiet transition.

    Returns
    -------
    numpy.ndarray
        Monotone arrival offsets [s] from the run start, shape ``(n,)``.
    """
    rng = np.random.default_rng(seed)
    flips = rng.random(n)
    state = np.empty(n, dtype=bool)        # True = burst phase
    s = False
    for k in range(n):
        s = (flips[k] < p_enter) if not s else (flips[k] >= p_exit)
        state[k] = s
    mult = np.where(state, burst_factor, 1.0)
    gaps = rng.exponential(1.0, size=n) / mult
    # conditional normalization: E[sum gaps | state path] == n / rate
    gaps *= (n / rate) / np.sum(1.0 / mult)
    return np.cumsum(gaps)


def straggler_times(seed: int, n: int, rate: float, *,
                    tail_frac: float = 0.1,
                    tail_index: float = 2.5) -> np.ndarray:
    """Straggler-tail schedule: Poisson arrivals with heavy-tailed gaps.

    A ``tail_frac`` fraction of inter-arrival gaps is inflated by a
    Pareto(``tail_index``) factor — occasional long quiet stretches
    (upstream stragglers holding back a wave of submissions) punctuating
    otherwise memoryless traffic.  ``tail_index > 2`` keeps the gap
    variance finite so the empirical mean rate of a finite trace still
    concentrates around `rate`; smaller values fatten the tail.

    Gaps are normalized by the mixture's closed-form mean
    ``1 - tail_frac + tail_frac * tail_index / (tail_index - 1)`` so the
    expected trace duration is ``n / rate`` — the target mean rate holds
    in expectation regardless of the tail parameters.

    Parameters
    ----------
    seed : int
        RNG seed.
    n : int
        Number of arrivals.
    rate : float
        Target mean arrival rate in events per second.
    tail_frac : float, optional
        Fraction of gaps drawn from the heavy tail (in (0, 1)).
    tail_index : float, optional
        Pareto shape of the tail factor (> 1; > 2 for finite variance).

    Returns
    -------
    numpy.ndarray
        Monotone arrival offsets [s] from the run start, shape ``(n,)``.
    """
    if not tail_index > 1.0:
        raise ValueError(f"tail_index={tail_index} must be > 1 "
                         "(the Pareto tail factor needs a finite mean)")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0, size=n)
    heavy = rng.random(n) < tail_frac
    pareto = (1.0 - rng.random(n)) ** (-1.0 / tail_index)   # Pareto(a), >= 1
    gaps = np.where(heavy, gaps * pareto, gaps)
    mix_mean = 1.0 - tail_frac + tail_frac * tail_index / (tail_index - 1.0)
    return np.cumsum(gaps / (rate * mix_mean))


ARRIVAL_PROFILES = {
    "poisson": poisson_times,
    "flash": flash_crowd_times,
    "diurnal": diurnal_times,
    "bursty": bursty_times,
    "straggler": straggler_times,
}
"""Open-loop arrival schedule generators by profile name — the shared
``--arrival`` / ``PlanSpec.profile`` vocabulary of the admission daemon,
the capacity planner and the benchmarks (steady baseline, flash-crowd
step, diurnal sine, Markov-modulated bursts, heavy straggler tail)."""
