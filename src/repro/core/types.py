"""Scenario / solution containers for the GNEP capacity-allocation problem.

All per-class quantities are (N,) arrays; scalars are 0-d arrays so every
container is a jittable pytree.  Notation follows the paper (Tables 1-4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def _register(cls):
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_node(
        cls,
        lambda s: (tuple(getattr(s, f) for f in fields), None),
        lambda _, xs: cls(*xs),
    )
    return cls


@_register
@dataclass
class Scenario:
    """One allocation problem instance over N job classes (paper Tables 1, 5, 6).

    Raw SLA / profile parameters plus the derived constants of Props. 3.3/4.1.
    """
    # -- raw, per class (N,) -------------------------------------------------
    A: jnp.ndarray          # map-phase profile coefficient           [s]
    B: jnp.ndarray          # reduce/shuffle-phase profile coefficient[s]
    E: jnp.ndarray          # C_i - D_i  (< 0 for feasibility)        [s]
    cM: jnp.ndarray         # map slots per VM/chip
    cR: jnp.ndarray         # reduce slots per VM/chip
    H_up: jnp.ndarray       # max SLA concurrency
    H_low: jnp.ndarray      # min SLA concurrency
    m: jnp.ndarray          # penalty per rejected job                [cents]
    rho_up: jnp.ndarray     # max bid CM i can place                  [cents]
    # -- raw, scalars --------------------------------------------------------
    R: jnp.ndarray          # cluster capacity (number of VMs/chips)
    rho_bar: jnp.ndarray    # unit-time cost of one VM/chip           [cents]
    # -- derived, per class (N,) ---------------------------------------------
    psi_low: jnp.ndarray    # 1 / H_up
    psi_up: jnp.ndarray     # 1 / H_low
    alpha: jnp.ndarray      # penalty slope   (Eq. 17a)
    beta: jnp.ndarray       # penalty offset  (Eq. 17b)
    xiM: jnp.ndarray        # Eq. 7a
    xiR: jnp.ndarray        # Eq. 7b
    K: jnp.ndarray          # Eq. 7c: chips per job to meet deadline
    r_up: jnp.ndarray       # Eq. 8a: K * H_up
    r_low: jnp.ndarray      # Eq. 8b: K * H_low
    p: jnp.ndarray          # Eq. 18: m / K
    rho_hat: jnp.ndarray    # max_i rho_up  (scalar)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


def derive(A, B, E, cM, cR, H_up, H_low, m, rho_up, R, rho_bar) -> Scenario:
    """Compute the closed-form constants (Props. 3.3, Eqs. 7/8/17/18)."""
    A, B, E = jnp.asarray(A), jnp.asarray(B), jnp.asarray(E)
    cM, cR = jnp.asarray(cM, A.dtype), jnp.asarray(cR, A.dtype)
    H_up, H_low = jnp.asarray(H_up, A.dtype), jnp.asarray(H_low, A.dtype)
    m, rho_up = jnp.asarray(m, A.dtype), jnp.asarray(rho_up, A.dtype)
    psi_low = 1.0 / H_up
    psi_up = 1.0 / H_low
    alpha = m * H_up * H_low
    beta = m * H_low
    xiM = cM / (1.0 + jnp.sqrt(B * cM / (A * cR)))
    xiR = cR / (1.0 + jnp.sqrt(A * cR / (B * cM)))
    K = (jnp.sqrt(A / cM) + jnp.sqrt(B / cR)) ** 2 / (-E)
    r_up = K * H_up
    r_low = K * H_low
    p = m / K
    return Scenario(
        A=A, B=B, E=E, cM=cM, cR=cR, H_up=H_up, H_low=H_low, m=m,
        rho_up=rho_up, R=jnp.asarray(R, A.dtype),
        rho_bar=jnp.asarray(rho_bar, A.dtype),
        psi_low=psi_low, psi_up=psi_up, alpha=alpha, beta=beta,
        xiM=xiM, xiR=xiR, K=K, r_up=r_up, r_low=r_low, p=p,
        rho_hat=jnp.max(rho_up),
    )


@_register
@dataclass
class ScenarioBatch:
    """B independent allocation instances stacked for one vmapped solve.

    ``scenarios`` is a :class:`Scenario` whose per-class leaves are (B, n_max)
    and whose scalars are (B,).  Instances with fewer than ``n_max`` classes
    are padded with *neutral* classes (``r_low = r_up = p = alpha = beta = 0``)
    and flagged invalid in ``mask`` so every mask-aware solver step is an
    exact no-op on them: a padded class never receives capacity, never bids,
    and contributes nothing to cost, penalty or the convergence metric.
    """
    scenarios: Scenario     # stacked leaves: (B, n_max) per class, (B,) scalars
    mask: jnp.ndarray       # (B, n_max) bool — True where the class is real
    n_classes: jnp.ndarray  # (B,) int — number of valid classes per instance

    @property
    def batch_size(self) -> int:
        return self.mask.shape[0]

    @property
    def n_max(self) -> int:
        return self.mask.shape[1]

    def instance(self, b: int) -> Scenario:
        """Recover the b-th (unpadded) single-instance Scenario."""
        n = int(self.n_classes[b])

        def pick(leaf):
            leaf = leaf[b]
            return leaf[:n] if leaf.ndim else leaf

        return jax.tree_util.tree_map(pick, self.scenarios)


def pad_scenario(scn: Scenario, n_max: int) -> Scenario:
    """Pad per-class arrays of ``scn`` to ``n_max`` with neutral classes.

    Neutral values keep every solver formula finite and inert for padded
    slots: zero allocation bounds / prices / penalties, unit work profile.
    """
    n = scn.n
    if n > n_max:
        raise ValueError(f"scenario has {n} classes > n_max={n_max}")
    pad = n_max - n
    dt = scn.A.dtype
    neutral = {
        "A": 1.0, "B": 1.0, "E": -1.0, "cM": 1.0, "cR": 1.0,
        "H_up": 1.0, "H_low": 1.0, "m": 0.0, "rho_up": float(scn.rho_bar),
        "psi_low": 1.0, "psi_up": 1.0, "alpha": 0.0, "beta": 0.0,
        "xiM": 1.0, "xiR": 1.0, "K": 1.0, "r_up": 0.0, "r_low": 0.0,
        "p": 0.0,
    }
    kw = {}
    for f in dataclasses.fields(Scenario):
        leaf = getattr(scn, f.name)
        if f.name in neutral and leaf.ndim == 1:
            kw[f.name] = jnp.pad(leaf, (0, pad),
                                 constant_values=neutral[f.name]).astype(dt)
        else:
            kw[f.name] = leaf
    return Scenario(**kw)


def stack_scenarios(scns, n_max: int | None = None) -> ScenarioBatch:
    """Stack a list of (possibly ragged) Scenarios into a ScenarioBatch."""
    scns = list(scns)
    if not scns:
        raise ValueError("stack_scenarios needs at least one scenario")
    ns = [s.n for s in scns]
    n_max = max(ns) if n_max is None else n_max
    padded = [pad_scenario(s, n_max) for s in scns]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    mask = jnp.arange(n_max)[None, :] < jnp.asarray(ns)[:, None]
    return ScenarioBatch(scenarios=stacked, mask=mask,
                         n_classes=jnp.asarray(ns))


@_register
@dataclass
class Solution:
    """A (possibly fractional) solution of the allocation problem."""
    r: jnp.ndarray       # chips per class
    psi: jnp.ndarray     # 1 / concurrency
    sM: jnp.ndarray      # map slots
    sR: jnp.ndarray      # reduce slots
    cost: jnp.ndarray    # rho_bar * sum(r)
    penalty: jnp.ndarray # sum(alpha * psi - beta)
    total: jnp.ndarray   # cost + penalty   (objective P2a)
    feasible: jnp.ndarray
    iters: jnp.ndarray   # solver iterations (0 for closed-form)
    aux: jnp.ndarray     # method-specific: KKT multiplier a / final price rho

    @property
    def h(self) -> jnp.ndarray:
        return 1.0 / self.psi


def objective(scn: Scenario, r, psi) -> jnp.ndarray:
    """Paper objective (P2a) = running cost + rejection penalties."""
    return scn.rho_bar * jnp.sum(r) + jnp.sum(scn.alpha * psi - scn.beta)


def deadline_lhs(scn: Scenario, psi, sM, sR) -> jnp.ndarray:
    """LHS of (P2d): A/(sM psi) + B/(sR psi) + E  (<= 0 when deadline met)."""
    return scn.A / (sM * psi) + scn.B / (sR * psi) + scn.E
