"""Scenario / solution containers for the GNEP capacity-allocation problem.

All per-class quantities are (N,) arrays; scalars are 0-d arrays so every
container is a jittable pytree.  Notation follows the paper (Tables 1-4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def _register(cls):
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_node(
        cls,
        lambda s: (tuple(getattr(s, f) for f in fields), None),
        lambda _, xs: cls(*xs),
    )
    return cls


@_register
@dataclass
class Scenario:
    """One allocation problem instance over N job classes (paper Tables 1, 5, 6).

    Raw SLA / profile parameters plus the derived constants of Props. 3.3/4.1.
    """
    # -- raw, per class (N,) -------------------------------------------------
    A: jnp.ndarray          # map-phase profile coefficient           [s]
    B: jnp.ndarray          # reduce/shuffle-phase profile coefficient[s]
    E: jnp.ndarray          # C_i - D_i  (< 0 for feasibility)        [s]
    cM: jnp.ndarray         # map slots per VM/chip
    cR: jnp.ndarray         # reduce slots per VM/chip
    H_up: jnp.ndarray       # max SLA concurrency
    H_low: jnp.ndarray      # min SLA concurrency
    m: jnp.ndarray          # penalty per rejected job                [cents]
    rho_up: jnp.ndarray     # max bid CM i can place                  [cents]
    # -- raw, scalars --------------------------------------------------------
    R: jnp.ndarray          # cluster capacity (number of VMs/chips)
    rho_bar: jnp.ndarray    # unit-time cost of one VM/chip           [cents]
    # -- derived, per class (N,) ---------------------------------------------
    psi_low: jnp.ndarray    # 1 / H_up
    psi_up: jnp.ndarray     # 1 / H_low
    alpha: jnp.ndarray      # penalty slope   (Eq. 17a)
    beta: jnp.ndarray       # penalty offset  (Eq. 17b)
    xiM: jnp.ndarray        # Eq. 7a
    xiR: jnp.ndarray        # Eq. 7b
    K: jnp.ndarray          # Eq. 7c: chips per job to meet deadline
    r_up: jnp.ndarray       # Eq. 8a: K * H_up
    r_low: jnp.ndarray      # Eq. 8b: K * H_low
    p: jnp.ndarray          # Eq. 18: m / K
    rho_hat: jnp.ndarray    # max_i rho_up  (scalar)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


def derive(A, B, E, cM, cR, H_up, H_low, m, rho_up, R, rho_bar) -> Scenario:
    """Compute the closed-form constants (Props. 3.3, Eqs. 7/8/17/18)."""
    A, B, E = jnp.asarray(A), jnp.asarray(B), jnp.asarray(E)
    cM, cR = jnp.asarray(cM, A.dtype), jnp.asarray(cR, A.dtype)
    H_up, H_low = jnp.asarray(H_up, A.dtype), jnp.asarray(H_low, A.dtype)
    m, rho_up = jnp.asarray(m, A.dtype), jnp.asarray(rho_up, A.dtype)
    psi_low = 1.0 / H_up
    psi_up = 1.0 / H_low
    alpha = m * H_up * H_low
    beta = m * H_low
    xiM = cM / (1.0 + jnp.sqrt(B * cM / (A * cR)))
    xiR = cR / (1.0 + jnp.sqrt(A * cR / (B * cM)))
    K = (jnp.sqrt(A / cM) + jnp.sqrt(B / cR)) ** 2 / (-E)
    r_up = K * H_up
    r_low = K * H_low
    p = m / K
    return Scenario(
        A=A, B=B, E=E, cM=cM, cR=cR, H_up=H_up, H_low=H_low, m=m,
        rho_up=rho_up, R=jnp.asarray(R, A.dtype),
        rho_bar=jnp.asarray(rho_bar, A.dtype),
        psi_low=psi_low, psi_up=psi_up, alpha=alpha, beta=beta,
        xiM=xiM, xiR=xiR, K=K, r_up=r_up, r_low=r_low, p=p,
        rho_hat=jnp.max(rho_up),
    )


@_register
@dataclass
class Solution:
    """A (possibly fractional) solution of the allocation problem."""
    r: jnp.ndarray       # chips per class
    psi: jnp.ndarray     # 1 / concurrency
    sM: jnp.ndarray      # map slots
    sR: jnp.ndarray      # reduce slots
    cost: jnp.ndarray    # rho_bar * sum(r)
    penalty: jnp.ndarray # sum(alpha * psi - beta)
    total: jnp.ndarray   # cost + penalty   (objective P2a)
    feasible: jnp.ndarray
    iters: jnp.ndarray   # solver iterations (0 for closed-form)
    aux: jnp.ndarray     # method-specific: KKT multiplier a / final price rho

    @property
    def h(self) -> jnp.ndarray:
        return 1.0 / self.psi


def objective(scn: Scenario, r, psi) -> jnp.ndarray:
    """Paper objective (P2a) = running cost + rejection penalties."""
    return scn.rho_bar * jnp.sum(r) + jnp.sum(scn.alpha * psi - scn.beta)


def deadline_lhs(scn: Scenario, psi, sM, sR) -> jnp.ndarray:
    """LHS of (P2d): A/(sM psi) + B/(sR psi) + E  (<= 0 when deadline met)."""
    return scn.A / (sM * psi) + scn.B / (sR * psi) + scn.E
