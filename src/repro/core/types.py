"""Scenario / solution containers for the GNEP capacity-allocation problem.

All per-class quantities are (N,) arrays; scalars are 0-d arrays so every
container is a jittable pytree.  Notation follows the paper (Tables 1-4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls):
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_node(
        cls,
        lambda s: (tuple(getattr(s, f) for f in fields), None),
        lambda _, xs: cls(*xs),
    )
    return cls


@_register
@dataclass
class Scenario:
    """One allocation problem instance over N job classes (paper Tables 1, 5, 6).

    Raw SLA / profile parameters plus the derived constants of Props. 3.3/4.1.
    """
    # -- raw, per class (N,) -------------------------------------------------
    A: jnp.ndarray          # map-phase profile coefficient           [s]
    B: jnp.ndarray          # reduce/shuffle-phase profile coefficient[s]
    E: jnp.ndarray          # C_i - D_i  (< 0 for feasibility)        [s]
    cM: jnp.ndarray         # map slots per VM/chip
    cR: jnp.ndarray         # reduce slots per VM/chip
    H_up: jnp.ndarray       # max SLA concurrency
    H_low: jnp.ndarray      # min SLA concurrency
    m: jnp.ndarray          # penalty per rejected job                [cents]
    rho_up: jnp.ndarray     # max bid CM i can place                  [cents]
    # -- raw, scalars --------------------------------------------------------
    R: jnp.ndarray          # cluster capacity (number of VMs/chips)
    rho_bar: jnp.ndarray    # unit-time cost of one VM/chip           [cents]
    # -- derived, per class (N,) ---------------------------------------------
    psi_low: jnp.ndarray    # 1 / H_up
    psi_up: jnp.ndarray     # 1 / H_low
    alpha: jnp.ndarray      # penalty slope   (Eq. 17a)
    beta: jnp.ndarray       # penalty offset  (Eq. 17b)
    xiM: jnp.ndarray        # Eq. 7a
    xiR: jnp.ndarray        # Eq. 7b
    K: jnp.ndarray          # Eq. 7c: chips per job to meet deadline
    r_up: jnp.ndarray       # Eq. 8a: K * H_up
    r_low: jnp.ndarray      # Eq. 8b: K * H_low
    p: jnp.ndarray          # Eq. 18: m / K
    rho_hat: jnp.ndarray    # max_i rho_up  (scalar)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


def derive(A, B, E, cM, cR, H_up, H_low, m, rho_up, R, rho_bar) -> Scenario:
    """Compute the closed-form constants (Props. 3.3, Eqs. 7/8/17/18)."""
    A, B, E = jnp.asarray(A), jnp.asarray(B), jnp.asarray(E)
    cM, cR = jnp.asarray(cM, A.dtype), jnp.asarray(cR, A.dtype)
    H_up, H_low = jnp.asarray(H_up, A.dtype), jnp.asarray(H_low, A.dtype)
    m, rho_up = jnp.asarray(m, A.dtype), jnp.asarray(rho_up, A.dtype)
    psi_low = 1.0 / H_up
    psi_up = 1.0 / H_low
    alpha = m * H_up * H_low
    beta = m * H_low
    xiM = cM / (1.0 + jnp.sqrt(B * cM / (A * cR)))
    xiR = cR / (1.0 + jnp.sqrt(A * cR / (B * cM)))
    K = (jnp.sqrt(A / cM) + jnp.sqrt(B / cR)) ** 2 / (-E)
    r_up = K * H_up
    r_low = K * H_low
    p = m / K
    return Scenario(
        A=A, B=B, E=E, cM=cM, cR=cR, H_up=H_up, H_low=H_low, m=m,
        rho_up=rho_up, R=jnp.asarray(R, A.dtype),
        rho_bar=jnp.asarray(rho_bar, A.dtype),
        psi_low=psi_low, psi_up=psi_up, alpha=alpha, beta=beta,
        xiM=xiM, xiR=xiR, K=K, r_up=r_up, r_low=r_low, p=p,
        rho_hat=jnp.max(rho_up),
    )


@_register
@dataclass
class ScenarioBatch:
    """B independent allocation instances stacked for one vmapped solve.

    ``scenarios`` is a :class:`Scenario` whose per-class leaves are (B, n_max)
    and whose scalars are (B,).  Instances with fewer than ``n_max`` classes
    are padded with *neutral* classes (``r_low = r_up = p = alpha = beta = 0``)
    and flagged invalid in ``mask`` so every mask-aware solver step is an
    exact no-op on them: a padded class never receives capacity, never bids,
    and contributes nothing to cost, penalty or the convergence metric.
    """
    scenarios: Scenario     # stacked leaves: (B, n_max) per class, (B,) scalars
    mask: jnp.ndarray       # (B, n_max) bool — True where the class is real
    n_classes: jnp.ndarray  # (B,) int — number of valid classes per instance

    @property
    def batch_size(self) -> int:
        return self.mask.shape[0]

    @property
    def n_max(self) -> int:
        return self.mask.shape[1]

    def take(self, lanes) -> "ScenarioBatch":
        """Gather a sub-batch of the given lane indices (order preserved).

        Utility for partial work over a batch (what-if subsets, sharding
        lanes across devices).  Note the gathered shape follows
        ``len(lanes)``, so jitted consumers retrace per distinct count —
        for shape-stable per-lane work, index lanes individually instead.
        """
        lanes = jnp.asarray(lanes)
        return ScenarioBatch(
            scenarios=jax.tree_util.tree_map(lambda l: l[lanes],
                                             self.scenarios),
            mask=self.mask[lanes], n_classes=self.n_classes[lanes])

    def instance(self, b: int) -> Scenario:
        """Recover the b-th (unpadded) single-instance Scenario.

        Valid classes are gathered through the mask (slot order preserved),
        so this also works for streaming windows whose recycled free slots
        leave holes rather than a padded suffix.
        """
        sel = np.asarray(self.mask[b])

        def pick(leaf):
            leaf = leaf[b]
            return leaf[sel] if leaf.ndim else leaf

        return jax.tree_util.tree_map(pick, self.scenarios)


#: Raw-parameter field names of :class:`Scenario` (per-class, user-settable).
#: Everything else in the container is derived from these via :func:`derive`.
RAW_CLASS_FIELDS = ("A", "B", "E", "cM", "cR", "H_up", "H_low", "m", "rho_up")


def neutral_class_values(rho_bar: float) -> dict:
    """Per-class values that make a padded / vacated slot solver-inert.

    Neutral values keep every solver formula finite and an exact no-op for
    the slot: zero allocation bounds (``r_low = r_up = 0``) so it never
    receives capacity, zero penalty slope (``alpha = beta = p = m = 0``) so
    it never contributes to cost or penalty, a unit work profile so divisions
    stay finite, and a ``rho_up`` equal to ``rho_bar`` so the slot's bid is a
    price candidate that is always present anyway.

    Parameters
    ----------
    rho_bar : float
        The instance's unit-time chip cost (the neutral bid value).

    Returns
    -------
    dict
        Field name -> neutral scalar for every per-class field of
        :class:`Scenario` (raw and derived).
    """
    return {
        "A": 1.0, "B": 1.0, "E": -1.0, "cM": 1.0, "cR": 1.0,
        "H_up": 1.0, "H_low": 1.0, "m": 0.0, "rho_up": float(rho_bar),
        "psi_low": 1.0, "psi_up": 1.0, "alpha": 0.0, "beta": 0.0,
        "xiM": 1.0, "xiR": 1.0, "K": 1.0, "r_up": 0.0, "r_low": 0.0,
        "p": 0.0,
    }


def pad_scenario(scn: Scenario, n_max: int) -> Scenario:
    """Pad per-class arrays of ``scn`` to ``n_max`` with neutral classes.

    See :func:`neutral_class_values` for why the padding is solver-inert.
    """
    n = scn.n
    if n > n_max:
        raise ValueError(f"scenario has {n} classes > n_max={n_max}")
    pad = n_max - n
    dt = scn.A.dtype
    neutral = neutral_class_values(float(scn.rho_bar))
    kw = {}
    for f in dataclasses.fields(Scenario):
        leaf = getattr(scn, f.name)
        if f.name in neutral and leaf.ndim == 1:
            kw[f.name] = jnp.pad(leaf, (0, pad),
                                 constant_values=neutral[f.name]).astype(dt)
        else:
            kw[f.name] = leaf
    return Scenario(**kw)


def stack_scenarios(scns, n_max: int | None = None) -> ScenarioBatch:
    """Stack a list of (possibly ragged) Scenarios into a ScenarioBatch."""
    scns = list(scns)
    if not scns:
        raise ValueError("stack_scenarios needs at least one scenario")
    ns = [s.n for s in scns]
    n_max = max(ns) if n_max is None else n_max
    padded = [pad_scenario(s, n_max) for s in scns]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    mask = jnp.arange(n_max)[None, :] < jnp.asarray(ns)[:, None]
    return ScenarioBatch(scenarios=stacked, mask=mask,
                         n_classes=jnp.asarray(ns))


@_register
@dataclass
class Solution:
    """A (possibly fractional) solution of the allocation problem."""
    r: jnp.ndarray       # chips per class
    psi: jnp.ndarray     # 1 / concurrency
    sM: jnp.ndarray      # map slots
    sR: jnp.ndarray      # reduce slots
    cost: jnp.ndarray    # rho_bar * sum(r)
    penalty: jnp.ndarray # sum(alpha * psi - beta)
    total: jnp.ndarray   # cost + penalty   (objective P2a)
    feasible: jnp.ndarray
    iters: jnp.ndarray   # solver iterations (0 for closed-form)
    aux: jnp.ndarray     # method-specific: KKT multiplier a / final price rho

    @property
    def h(self) -> jnp.ndarray:
        return 1.0 / self.psi


def objective(scn: Scenario, r, psi) -> jnp.ndarray:
    """Paper objective (P2a) = running cost + rejection penalties."""
    return scn.rho_bar * jnp.sum(r) + jnp.sum(scn.alpha * psi - scn.beta)


def deadline_lhs(scn: Scenario, psi, sM, sR) -> jnp.ndarray:
    """LHS of (P2d): A/(sM psi) + B/(sR psi) + E  (<= 0 when deadline met)."""
    return scn.A / (sM * psi) + scn.B / (sR * psi) + scn.E


# --------------------------------------------------------------------------
# Streaming admission: events + per-window solver state
# --------------------------------------------------------------------------
#
# Events are plain host-side records (NOT pytrees): they mutate the
# AdmissionWindow (core.streaming) between solves; only the resulting padded
# ScenarioBatch ever crosses into jitted code.


@dataclass(frozen=True)
class ClassArrival:
    """A new job class entering ``lane``'s allocation game.

    ``params`` holds the raw per-class scalars (the :data:`RAW_CLASS_FIELDS`:
    A, B, E, cM, cR, H_up, H_low, m, rho_up); derived constants are computed
    by the window on admission.  The slot is chosen by the window (lowest
    free slot, growing ``n_max`` only when the lane's row is full).
    """
    lane: int
    params: dict


@dataclass(frozen=True)
class ClassDeparture:
    """Job class in (``lane``, ``slot``) leaves; its slot is recycled."""
    lane: int
    slot: int


@dataclass(frozen=True)
class SLAEdit:
    """In-place SLA / profile renegotiation for the class in (lane, slot).

    ``updates`` maps raw field names (subset of :data:`RAW_CLASS_FIELDS`) to
    new values; the window merges them and re-derives the class constants.
    """
    lane: int
    slot: int
    updates: dict


@dataclass(frozen=True)
class CapacityChange:
    """Lane capacity R changes (node failures / restores, paper Fig. 2)."""
    lane: int
    R: float


StreamEvent = Union[ClassArrival, ClassDeparture, SLAEdit, CapacityChange]


class WindowState(NamedTuple):
    """Last-equilibrium solver state an :class:`AdmissionWindow` carries.

    Shapes: ``r`` is (B, n_max); ``rho``/``lane_iters``/``solved`` are (B,).
    ``solved`` marks lanes whose stored equilibrium is valid (a lane that
    has been solved at least once since construction); the window's separate
    host-side *dirty* mask marks lanes whose scenario changed after the
    state was stored.  Together they drive the warm-start: clean solved
    lanes are frozen at their stored equilibrium, all others re-iterate.
    Equilibrium bids are intentionally NOT stored: a frozen lane never uses
    them and a dirty lane must restart from the cold ``rho_bar`` bids to
    reproduce the cold Alg. 4.1 trajectory (bids are monotone in the game).
    """
    r: jnp.ndarray
    rho: jnp.ndarray
    lane_iters: jnp.ndarray
    solved: jnp.ndarray
