"""Unified session API over the GNEP solver stack: one engine, one config.

PRs 1-4 grew the paper's runtime capacity-allocation dynamic into seven
divergent entry points (``solve``, ``solve_batch``, ``solve_streaming``,
``solve_coalesced``, ``solve_centralized[_batch]``, ``solve_sharded_batch``),
each re-threading the same ``eps_bar`` / ``lam`` / ``mesh`` / ``sweep_fn``
kwargs.  This module replaces that zoo with a single configured-session
abstraction (the shape design tools like D-SPACE4Cloud converge on):

* :class:`SolverConfig` — every Algorithm 4.1 knob plus kernel and device
  placement in one frozen, hashable object (``eps_bar``, ``lam``,
  ``max_iters``, ``dtype``, ``sweep_fn``, ``mesh``) with a stable
  :meth:`~SolverConfig.fingerprint` the benchmark regression gate records;
* :class:`Policies` — the *operational* choices as explicit policy objects:
  flush cadence (:class:`~repro.core.streaming.FlushPolicy`, including the
  deadline-aware constructor), compaction occupancy
  (:class:`CompactionPolicy`), Algorithm 4.2 rounding
  (:class:`RoundingPolicy`) and the exact centralized (P3) cross-check
  baseline (:class:`CrossCheckPolicy`);
* :class:`CapacityEngine` — a small verb set: :meth:`~CapacityEngine.solve`
  for one-shot instances/batches and :meth:`~CapacityEngine.open_window`
  for the paper's runtime loop;
* :class:`WindowSession` — the live loop: ``apply`` events, ``flush``
  coalesced re-solves, ``stream`` whole traces; warm-start state, the
  coalescing FlushPolicy loop and mesh placement all live inside;
* the :class:`SolveReport` hierarchy — one result shape (equilibrium,
  per-lane iterations/convergence, rounding, centralized gap, timing)
  subsuming the legacy ``AllocationResult`` / ``BatchAllocationResult`` /
  ``StreamingResult``.

The legacy ``repro.core.allocator.solve_*`` facades are thin deprecated
shims over this module, proven bit-equal in ``tests/test_engine.py``; the
old-call -> engine-call migration table is ``docs/API.md``.  ``game.py`` /
``streaming.py`` / ``sharding.py`` / ``centralized.py`` stay pure mechanism:
adding a new backend kernel or event kind is a config/policy field here, not
another ``solve_*`` variant.
"""
from __future__ import annotations

import re
import time
from dataclasses import dataclass, replace
from typing import (Any, Callable, Iterable, Iterator, List, Optional,
                    Sequence, Set, Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game, sharding
from repro.core.centralized import solve_centralized
from repro.core.rounding import (IntegerSolution, round_solution,
                                 round_solution_batch)
from repro.core.streaming import AdmissionWindow, FlushPolicy
from repro.core.types import (ClassArrival, Scenario, ScenarioBatch, SLAEdit,
                              Solution, StreamEvent, stack_scenarios)


class InfeasibleError(RuntimeError):
    """Deadlines/SLAs cannot be met with the available capacity."""


class QuotaExceededError(RuntimeError):
    """A session operation would exceed its :class:`TenantQuota`.

    Raised by :meth:`WindowSession.offer` (event budget) and
    :meth:`WindowSession.add_lane` (lane budget).  External schedulers like
    ``repro.serving.allocd`` check the quota *before* handing an event to
    the session (the rejection then carries the paper's rejection cost
    instead of an exception), so in a correctly plumbed daemon this error
    is the backstop, not the control path.
    """


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission budget enforced by a :class:`WindowSession`.

    The multi-tenant generalization of the daemon-wide queue bound: each
    tenant gets its own event and lane budget so one tenant's burst can
    never exhaust the shared daemon's headroom (the daemon-wide bound
    remains as a backstop).  ``None`` fields are unlimited.

    Attributes
    ----------
    max_queued : int, optional
        Upper bound on this tenant's not-yet-flushed events — in daemon
        terms the sum of its queued and already-buffered (in-epoch) events;
        for a bare session, the buffered-event count :meth:`WindowSession.offer`
        enforces.  Submissions beyond it are rejected and charged the
        paper's rejection penalty (``m * H_up`` for a class arrival).
    max_lanes : int, optional
        Upper bound on the tenant's open window lanes:
        :meth:`WindowSession.add_lane` refuses to grow past it, and a
        daemon refuses to register a tenant whose initial window is
        already wider.
    """
    max_queued: Optional[int] = None
    max_lanes: Optional[int] = None

    def admits_event(self, n_queued: int) -> bool:
        """Whether one more event fits under ``max_queued``.

        Parameters
        ----------
        n_queued : int
            Events currently queued/buffered against this quota.

        Returns
        -------
        bool
            True when unlimited or ``n_queued < max_queued``.
        """
        return self.max_queued is None or n_queued < self.max_queued

    def admits_lane(self, n_lanes: int) -> bool:
        """Whether one more lane fits under ``max_lanes``.

        Parameters
        ----------
        n_lanes : int
            Lanes currently open against this quota.

        Returns
        -------
        bool
            True when unlimited or ``n_lanes < max_lanes``.
        """
        return self.max_lanes is None or n_lanes < self.max_lanes


# --------------------------------------------------------------------------
# Configuration: every solver knob in one frozen object
# --------------------------------------------------------------------------


_F32_CHECKED_RE = re.compile(r"f32_checked(?:\[:([1-9]\d*)\])?$")


def _parse_dtype_policy(policy: str):
    """Parse a ``SolverConfig.dtype_policy`` string.

    Parameters
    ----------
    policy : str
        ``"f64"``, ``"f32_checked"`` or ``"f32_checked[:k]"``.

    Returns
    -------
    tuple or None
        ``("f64", None)`` or ``("f32_checked", k)`` (k defaults to 4);
        None when the string is not a valid policy.
    """
    if policy == "f64":
        return ("f64", None)
    m = _F32_CHECKED_RE.fullmatch(policy)
    if m:
        return ("f32_checked", int(m.group(1)) if m.group(1) else 4)
    return None


@dataclass(frozen=True)
class SolverConfig:
    """Every Algorithm 4.1 knob, kernel choice and placement in one object.

    Frozen and hashable (safe as a static jit argument and as a dict key),
    with float/int leaves only — pytree-friendly by construction.  One
    config replaces the six kwargs the legacy facades threaded separately;
    the engine passes it to every mechanism call so no path can silently
    drop a knob (the kwargs-drift class of bug the redesign retires).

    Attributes
    ----------
    eps_bar : float
        Algorithm 4.1 stopping tolerance on the relative allocation change
        ``sum_i |r_i' - r_i| / r_i`` (paper uses 0.03).
    lam : float
        Bid-escalation (pseudo-gradient) step of ``game.cm_bid_update``: a
        rejecting CM raises its bid by ``lam * rho_up`` per iteration.
    max_iters : int
        Best-reply iteration cap (a static jit argument: changing it
        recompiles).
    dtype : jnp.dtype or str, optional
        Float dtype scenario leaves are coerced to by :func:`_coerce`.
        ``None`` (default) keeps each input's native dtype.  Mutually
        exclusive with ``dtype_policy`` (which subsumes it).
    dtype_policy : str, optional
        Checked precision policy, the supported alternative to raw
        ``dtype``: ``"f64"`` coerces every solve to float64 (the bit
        authority); ``"f32_checked"`` (optionally ``"f32_checked[:k]"``,
        default k=4) runs the fast float32 path and then re-solves ``k``
        evenly-spaced sample lanes of every batched/streaming solve in
        float64 on the unfused reference path, raising ``RuntimeError``
        (naming the lanes) if any sampled lane's allocation deviates
        beyond the documented bound ``2 * eps_bar`` relative — both
        precisions are ``eps_bar``-converged equilibria of the same
        game, so they can legitimately sit anywhere inside one stopping
        tolerance of each other, and the check flags anything worse.
        Reports carry the measurement in ``dtype_check``.  ``None``
        (default) applies no policy.  See docs/OPERATIONS.md for how to
        choose (and the CPU-runner caveats).
    sweep_fn : callable, optional
        Batched RM price-sweep override, e.g. the Pallas kernel from
        ``repro.kernels.gnep_sweep.ops.make_batched_sweep_fn`` — applied on
        every batched/streaming solve.  Pass a memoized function object
        (it keys the compiled-program caches by identity).
    iter_fn : object, optional
        Fused-iteration override, e.g.
        ``repro.kernels.gnep_iter.ops.make_fused_iter_fn()``: the whole
        Alg. 4.1 inner iteration (sweep + best responses + bid update +
        eps) runs as one fused step per while-loop body, with the
        iteration-invariant prep hoisted out of the loop.  Takes
        precedence over ``sweep_fn`` on every batched/streaming solve.
        Pass a memoized object (identity keys the compiled-program
        caches); its ``__name__`` is recorded in the fingerprint.
    mesh : jax.sharding.Mesh, optional
        1-D lane mesh (``repro.core.sharding.lane_mesh``): batched and
        streaming solves shard their lanes across the mesh's devices,
        inert-lane padding handling ragged lane counts.  ``None`` keeps
        everything on one device.
    residency : str
        Where a :class:`WindowSession`'s state lives between flushes.
        ``"round-trip"`` (default) re-places the window on the mesh every
        solve — simple, but the per-flush host<->device resharding is why
        sharded streaming historically scaled *worse* than unsharded.
        ``"resident"`` (requires ``mesh``) keeps the window's scenario
        leaves, occupancy-mask mirror and warm-start state lane-sharded on
        the mesh across flushes (``AdmissionWindow.make_resident``), with
        the warm-start buffers donated between consecutive solves —
        bit-equal results (``tests/test_resident.py``), no per-flush
        resharding.  One-shot ``solve`` calls are unaffected.
    """
    eps_bar: float = 0.03
    lam: float = 0.05
    max_iters: int = 200
    dtype: Optional[Any] = None
    sweep_fn: Optional[Callable] = None
    mesh: Optional[Any] = None
    residency: str = "round-trip"
    iter_fn: Optional[Any] = None
    dtype_policy: Optional[str] = None

    def __post_init__(self):
        if self.dtype_policy is None:
            return
        if self.dtype is not None:
            raise ValueError(
                "dtype= and dtype_policy= are mutually exclusive — "
                "dtype_policy subsumes the cast (use dtype_policy alone)")
        if _parse_dtype_policy(self.dtype_policy) is None:
            raise ValueError(
                f"unknown dtype_policy {self.dtype_policy!r} — expected "
                "'f64', 'f32_checked' or 'f32_checked[:k]' with k >= 1")

    def effective_dtype(self):
        """The dtype scenario leaves are coerced to under this config.

        Returns
        -------
        jnp.dtype or None
            ``dtype_policy``'s cast when a policy is set (f64 / f32),
            otherwise the raw ``dtype`` knob (``None`` = keep native).
        """
        if self.dtype_policy is None:
            return self.dtype
        mode, _ = _parse_dtype_policy(self.dtype_policy)
        return jnp.float64 if mode == "f64" else jnp.float32

    def check_sample(self) -> int:
        """Sample-lane count of the ``f32_checked`` cross-check (0 if the
        policy does not check)."""
        if self.dtype_policy is None:
            return 0
        mode, k = _parse_dtype_policy(self.dtype_policy)
        return k if mode == "f32_checked" else 0

    def fingerprint(self) -> str:
        """Stable identity string for benchmark / baseline provenance.

        ``benchmarks/*_perf.py --json`` records it and
        ``scripts/check_bench.py`` treats it as configuration: numbers
        measured under different solver configs (or on the pre-redesign
        facades, which recorded none) are never compared.

        Returns
        -------
        str
            ``eps_bar=..|lam=..|max_iters=..|dtype=..|sweep=..|mesh=..``;
            the sweep kernel contributes its ``__name__``, the mesh its
            shape and axis names.  Non-default ``residency`` / ``iter_fn``
            / ``dtype_policy`` append ``|residency=..`` / ``|iter=..`` /
            ``|dtype_policy=..`` in that order (defaults append nothing,
            so fingerprints recorded before each knob existed stay
            comparable).
        """
        dtype = ("native" if self.dtype is None
                 else jnp.dtype(self.dtype).name)
        sweep = ("reference" if self.sweep_fn is None
                 else getattr(self.sweep_fn, "__name__",
                              type(self.sweep_fn).__name__))
        mesh = ("none" if self.mesh is None
                else "x".join(map(str, self.mesh.devices.shape))
                + ":" + ",".join(self.mesh.axis_names))
        tail = ("" if self.residency == "round-trip"
                else f"|residency={self.residency}")
        if self.iter_fn is not None:
            tail += "|iter=" + getattr(self.iter_fn, "__name__",
                                       type(self.iter_fn).__name__)
        if self.dtype_policy is not None:
            tail += f"|dtype_policy={self.dtype_policy}"
        return (f"eps_bar={self.eps_bar}|lam={self.lam}"
                f"|max_iters={self.max_iters}|dtype={dtype}"
                f"|sweep={sweep}|mesh={mesh}{tail}")


# --------------------------------------------------------------------------
# Policies: the operational choices, as explicit objects
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RoundingPolicy:
    """Whether (and that) Algorithm 4.2 integerization runs after the solve.

    Attributes
    ----------
    enabled : bool
        Apply the (vectorized) Algorithm 4.2 rounding pass; reports carry
        ``integer=None`` when disabled (what-if sweeps and benchmarks that
        time the fractional solve alone turn it off).
    """
    enabled: bool = True


@dataclass(frozen=True)
class CrossCheckPolicy:
    """Compare every lane against its exact centralized (P3) optimum.

    When enabled, window solves attach the per-lane relative gap of the
    GNEP total over the exact optimum (``SolveReport.centralized_gap``).
    Baseline totals are memoized per lane in the window and recomputed only
    for lanes whose scenario changed, mirroring the incremental solve.

    Attributes
    ----------
    enabled : bool
        Run the baseline (default off — it costs one water-filling solve
        per stale lane).
    atol : float
        Absolute slack allowed in the sanity direction: a feasible lane's
        GNEP total undercutting the exact optimum by more than this raises
        ``RuntimeError`` (impossible for a correct solver).
    """
    enabled: bool = False
    atol: float = 1e-6


@dataclass(frozen=True)
class CompactionPolicy:
    """When a :class:`WindowSession` re-packs its sparse window.

    Churn leaves holes in the occupancy mask and growth ratchets ``n_max``
    up; solver work scales with ``B x n_max``, so long-lived windows slowly
    pay for ghosts.  At every flush boundary the session compares
    ``window.occupancy`` against ``occupancy`` and compacts
    (``AdmissionWindow.compact``) when it drops below — the report carries
    the old->new ``slot_map`` so slot-addressed bookkeeping can follow.

    Attributes
    ----------
    occupancy : float, optional
        Occupied-slot fraction below which the session compacts at the
        next flush boundary.  ``None`` (default) never auto-compacts
        (compaction changes XLA shapes — one recompile — so it stays an
        explicit operator decision; see ``docs/OPERATIONS.md``).
    headroom : float
        Width multiplier for the compacted window: the target ``n_max`` is
        ``ceil(headroom * widest lane)`` (floor: the widest lane), so a
        value > 1 leaves slack before the next arrival forces a re-grow.
    """
    occupancy: Optional[float] = None
    headroom: float = 1.0


@dataclass(frozen=True)
class Policies:
    """The engine's operational policy bundle (all fields are policies).

    Attributes
    ----------
    flush : repro.core.streaming.FlushPolicy
        When buffered events force a coalesced re-solve — including the
        deadline-aware triggers of ``FlushPolicy.deadline`` (SLA-critical
        events flush immediately, bulk events keep coalescing).
    compaction : CompactionPolicy
        When a sparse long-lived window is re-packed.
    rounding : RoundingPolicy
        Whether Algorithm 4.2 integerization runs.
    cross_check : CrossCheckPolicy
        Whether window solves attach the exact (P3) baseline gap.
    """
    flush: FlushPolicy = FlushPolicy()
    compaction: CompactionPolicy = CompactionPolicy()
    rounding: RoundingPolicy = RoundingPolicy()
    cross_check: CrossCheckPolicy = CrossCheckPolicy()


# --------------------------------------------------------------------------
# The unified report hierarchy
# --------------------------------------------------------------------------


@dataclass
class SolveReport:
    """One solved instance: the unified result shape of the engine.

    Subsumes the legacy ``AllocationResult`` (its alias since the engine
    redesign): same core fields, plus the config fingerprint and host-side
    timing every engine call attaches.

    Attributes
    ----------
    method : str
        ``"centralized"``, ``"distributed"``, ``"distributed-python"``,
        ``"distributed-batch"`` or ``"streaming"``.
    fractional : Solution
        The fractional equilibrium / optimum.
    integer : IntegerSolution or None
        Algorithm 4.2 integerization (None when rounding is disabled).
    iters : int or jnp.ndarray
        Best-reply iterations (per lane for batched reports).
    config : SolverConfig or None
        The solver config that produced this report.
    elapsed_s : float
        Host-side wall-clock of the engine call (dispatch + rounding; on
        async backends the device work may still be in flight).
    """
    method: str
    fractional: Solution
    integer: Optional[IntegerSolution]
    iters: Any
    config: Optional[SolverConfig] = None
    elapsed_s: float = 0.0

    @property
    def r(self):
        """Allocation of the preferred (integer when present) solution."""
        return self.integer.r if self.integer is not None else self.fractional.r

    @property
    def total(self):
        """Objective total of the preferred solution."""
        return (self.integer.total if self.integer is not None
                else self.fractional.total)

    @property
    def converged(self):
        """Whether Algorithm 4.1 stopped on tolerance, not the iteration cap
        (per lane for batched reports; trivially True for closed forms)."""
        limit = self.config.max_iters if self.config is not None else np.inf
        return self.iters < limit


@dataclass
class BatchSolveReport(SolveReport):
    """One batched solve: every leaf carries a leading B dim.

    Subsumes the legacy ``BatchAllocationResult`` (its alias).  Per-class
    arrays are (B, n_max) with padded classes identically zero;
    :meth:`instance` trims one lane back to a single-instance
    :class:`SolveReport`.

    Attributes (beyond :class:`SolveReport`)
    ----------------------------------------
    mask : jnp.ndarray
        (B, n_max) class-validity mask of the solved batch.
    n_classes : jnp.ndarray
        (B,) valid-class counts.
    feasible : jnp.ndarray
        (B,) per-lane feasibility flags (``sum(r_low) <= R`` and all
        ``E_i < 0``).
    dtype_check : dict or None
        The ``dtype_policy="f32_checked"`` measurement: sampled ``lanes``,
        worst per-lane relative allocation deviation ``max_rel`` vs the
        f64 reference re-solve, and the ``bound`` it was held to.  None
        when no checking policy is active.
    """
    mask: Optional[jnp.ndarray] = None
    n_classes: Optional[jnp.ndarray] = None
    feasible: Optional[jnp.ndarray] = None
    dtype_check: Optional[dict] = None

    @property
    def batch_size(self) -> int:
        """Number of lanes B in this report."""
        return self.mask.shape[0]

    def instance(self, b: int) -> SolveReport:
        """Trim lane ``b`` to a single-instance view.

        Mask-aware: works for streaming windows whose free slots leave
        holes in the mask (gathers valid slots, never slices a prefix).

        Parameters
        ----------
        b : int
            Lane index.

        Returns
        -------
        SolveReport
            The lane's solution with per-class leaves trimmed to its valid
            classes.
        """
        sel = np.asarray(self.mask[b])

        def pick(leaf):
            leaf = leaf[b]
            return leaf[sel] if getattr(leaf, "ndim", 0) else leaf

        frac = jax.tree_util.tree_map(pick, self.fractional)
        integ = (jax.tree_util.tree_map(pick, self.integer)
                 if self.integer is not None else None)
        return SolveReport(method=self.method, fractional=frac, integer=integ,
                           iters=int(self.iters[b]), config=self.config,
                           elapsed_s=self.elapsed_s)


@dataclass
class WindowSolveReport(BatchSolveReport):
    """One streaming re-solve: a batch report plus incremental bookkeeping.

    Subsumes the legacy ``StreamingResult`` (its alias).

    Attributes (beyond :class:`BatchSolveReport`)
    ---------------------------------------------
    resolved : np.ndarray
        (B,) bool — lanes that actually iterated this solve (dirty or
        never-solved); the complement was frozen at its stored equilibrium.
    centralized_gap : jnp.ndarray or None
        (B,) relative gap of the fractional GNEP total over the exact
        centralized (P3) optimum, when the cross-check policy is enabled.
    slot_map : np.ndarray or None
        (B, old_n_max) old-slot -> new-slot map when this flush compacted
        the window under a :class:`CompactionPolicy` (None otherwise);
        callers with slot-addressed bookkeeping remap through it.
    """
    resolved: Optional[np.ndarray] = None
    centralized_gap: Optional[jnp.ndarray] = None
    slot_map: Optional[np.ndarray] = None


# --------------------------------------------------------------------------
# Input coercion: one helper, every entry point
# --------------------------------------------------------------------------


def _coerce(problem, *, dtype=None, n_max: Optional[int] = None
            ) -> ScenarioBatch:
    """Normalize any accepted problem form into a :class:`ScenarioBatch`.

    The single input-coercion point of the engine (every verb routes
    through it), retiring the legacy drift where ``solve_batch`` accepted a
    ``Sequence[Scenario]`` but the streaming facades did not.

    Parameters
    ----------
    problem : ScenarioBatch, Scenario, Sequence[Scenario] or AdmissionWindow
        A prepared batch (returned as-is, modulo dtype), a single instance
        (stacked as one lane), a plain — possibly ragged — scenario list
        (stacked/padded here), or a live window (its current batch).
    dtype : jnp.dtype or str, optional
        Cast every float leaf to this dtype (``SolverConfig.dtype``);
        ``None`` keeps the input's native dtypes.
    n_max : int, optional
        Padded width passed to ``stack_scenarios`` when stacking loose
        scenarios (ignored for already-stacked inputs).

    Returns
    -------
    ScenarioBatch
        The canonical stacked + masked form every solver consumes.

    Raises
    ------
    TypeError
        For anything else (with the accepted forms named).
    """
    if isinstance(problem, AdmissionWindow):
        batch = problem.batch
    elif isinstance(problem, ScenarioBatch):
        batch = problem
    elif isinstance(problem, Scenario):
        batch = stack_scenarios([problem], n_max=n_max)
    elif isinstance(problem, Sequence) and not isinstance(problem, (str, bytes)):
        items = list(problem)
        if not all(isinstance(s, Scenario) for s in items):
            raise TypeError(
                "sequence inputs must contain Scenario instances only")
        batch = stack_scenarios(items, n_max=n_max)
    else:
        raise TypeError(
            f"cannot coerce {type(problem).__name__!r} — pass a Scenario, a "
            "Sequence[Scenario], a ScenarioBatch or an AdmissionWindow")
    if dtype is not None:
        batch = ScenarioBatch(scenarios=_cast_floats(batch.scenarios, dtype),
                              mask=batch.mask, n_classes=batch.n_classes)
    return batch


def _cast_floats(tree, dtype):
    """Cast every floating leaf of a pytree to ``dtype`` (the one
    dtype-coercion rule of the engine; integer/bool leaves pass through)."""
    dt = jnp.dtype(dtype)
    return jax.tree_util.tree_map(
        lambda leaf: (leaf.astype(dt)
                      if jnp.issubdtype(leaf.dtype, jnp.floating) else leaf),
        tree)


def _dtype_check(cfg: "SolverConfig", batch: ScenarioBatch, sol: Solution,
                 masks=None) -> Optional[dict]:
    """The ``dtype_policy="f32_checked"`` cross-check of a batched solve.

    Re-solves ``cfg.check_sample()`` evenly-spaced sample lanes in float64
    on the unfused reference path (cold start, no kernels, no mesh — the
    most conservative configuration available) and compares allocations.
    Both solves are ``eps_bar``-converged equilibria of the same game, so
    their allocations can legitimately differ by up to one stopping
    tolerance each; the check holds the per-lane relative L1 deviation to
    ``2 * cfg.eps_bar`` (plus a small absolute slack for near-zero
    allocations) and raising past it means the f32 path left the f64
    equilibrium's basin — a real precision failure, not rounding noise.

    Parameters
    ----------
    cfg : SolverConfig
        The active config (supplies ``eps_bar`` and the sample count).
    batch : ScenarioBatch
        The batch that was solved (f32 leaves under the policy).
    sol : Solution
        The f32 solution to audit.
    masks : jnp.ndarray, optional
        Lane-validity mask ((B,) bool) restricting which lanes may be
        sampled — streaming windows pass their occupancy so free slots
        are never audited.  None samples over all lanes.

    Returns
    -------
    dict or None
        ``{"lanes": [...], "max_rel": float, "bound": float}``; None when
        the config's policy does not check or no lane is eligible.

    Raises
    ------
    RuntimeError
        Naming the offending lanes when any sampled lane deviates beyond
        the bound.
    """
    k = cfg.check_sample()
    if k == 0:
        return None
    if not jax.config.jax_enable_x64:
        # Without x64 the float64 re-solve silently truncates back to f32
        # and the "check" compares the fast path against itself.
        raise RuntimeError(
            f"dtype_policy={cfg.dtype_policy!r} needs jax_enable_x64: with "
            "x64 disabled the f64 reference re-solve truncates to float32 "
            "and the cross-check can never fail")
    eligible = (np.arange(batch.batch_size) if masks is None
                else np.flatnonzero(np.asarray(masks)))
    if eligible.size == 0:
        return None
    k = min(k, eligible.size)
    pick = np.unique(np.linspace(0, eligible.size - 1, k).round().astype(int))
    lanes = [int(b) for b in eligible[pick]]

    sub = batch.take(np.asarray(lanes))
    sub64 = ScenarioBatch(
        scenarios=_cast_floats(sub.scenarios, jnp.float64),
        mask=sub.mask, n_classes=sub.n_classes)
    ref = game.solve_distributed_batch(sub64, eps_bar=cfg.eps_bar,
                                       lam=cfg.lam, max_iters=cfg.max_iters)
    r32 = jnp.asarray(sol.r)[np.asarray(lanes)].astype(jnp.float64)
    r64 = ref.r
    dev = jnp.sum(jnp.abs(r32 - r64), axis=1)
    scale = jnp.maximum(jnp.sum(jnp.abs(r64), axis=1), 1.0)
    rel = np.asarray(dev / scale)
    bound = 2.0 * cfg.eps_bar + 1e-6
    if np.any(rel > bound):
        bad = [lanes[i] for i in np.flatnonzero(rel > bound)]
        raise RuntimeError(
            f"dtype_policy={cfg.dtype_policy!r}: lanes {bad} deviate from "
            f"the f64 reference beyond {bound:.3g} relative "
            f"(worst {float(rel.max()):.3g}) — the f32 fast path is not "
            "trustworthy for this workload; use dtype_policy='f64'")
    return {"lanes": lanes, "max_rel": float(rel.max()), "bound": bound}


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------


class CapacityEngine:
    """The single entry point to the GNEP capacity-allocation stack.

    One engine = one :class:`SolverConfig` (solver knobs, kernel, mesh) +
    one :class:`Policies` bundle (flush cadence, compaction, rounding,
    cross-check).  Engines are cheap, stateless handles — all compiled
    programs live in module-level caches keyed by the config values, so
    constructing many engines costs nothing; all *mutable* state (warm
    starts, pending events) lives in the :class:`WindowSession` /
    ``AdmissionWindow`` a session wraps.

    Parameters
    ----------
    config : SolverConfig, optional
        Solver knobs + kernel + placement (defaults: the paper's).
    policies : Policies, optional
        Operational policies (defaults: round, no cross-check, flush every
        8 events, never auto-compact).
    """

    def __init__(self, config: Optional[SolverConfig] = None,
                 policies: Optional[Policies] = None):
        self.config = config if config is not None else SolverConfig()
        self.policies = policies if policies is not None else Policies()
        if self.config.residency not in ("round-trip", "resident"):
            raise ValueError(
                f"unknown residency {self.config.residency!r} — "
                "expected 'round-trip' or 'resident'")
        if self.config.residency == "resident" and self.config.mesh is None:
            raise ValueError(
                "residency='resident' needs a mesh= in the SolverConfig "
                "(repro.core.sharding.lane_mesh)")
        if (self.config.check_sample() > 0
                and self.config.residency == "resident"):
            # the resident flush donates its warm-start buffers to the
            # solve, so the f64 shadow re-solve the check needs cannot see
            # the same init — refusing keeps the check's semantics exact
            # instead of silently weakening them
            raise ValueError(
                "dtype_policy='f32_checked' is not supported with "
                "residency='resident' — use residency='round-trip' for "
                "checked f32, or dtype_policy='f64' for resident sessions")

    # ------------------------------------------------------------- one-shot
    def solve(self, problem, *, method: str = "distributed",
              check_feasible: bool = True
              ) -> Union[SolveReport, BatchSolveReport]:
        """Solve one instance or one batch of independent instances.

        Parameters
        ----------
        problem : Scenario, Sequence[Scenario], ScenarioBatch or AdmissionWindow
            A single :class:`Scenario` runs the single-instance pipeline
            (any ``method``); everything else is coerced by
            :func:`_coerce` and runs the batched engine (B lanes as one
            XLA program, sharded over ``config.mesh`` when set).
        method : str, optional
            ``"distributed"`` (Algorithm 4.1, default), ``"centralized"``
            (exact P3 water-filling) or ``"distributed-python"`` (the
            paper-faithful serial loop) — the latter two for single
            instances only.
        check_feasible : bool, optional
            Batched path: with True (default) an :class:`InfeasibleError`
            names every infeasible lane; False returns per-lane
            ``feasible`` flags instead (what-if sweeps legitimately probe
            infeasible capacity points).  The single-instance path always
            raises, as the legacy facade did.

        Returns
        -------
        SolveReport or BatchSolveReport
            Fractional (and, per the rounding policy, integer) solutions
            plus iteration counts; batched reports carry a leading B dim
            on every leaf and ``instance(b)`` trims one lane.

        Raises
        ------
        InfeasibleError
            If ``sum(r_low) > R`` or some deadline is unattainable
            (E_i >= 0) — per ``check_feasible`` on the batched path.
        ValueError
            For an unknown or unsupported ``method``.
        """
        if isinstance(problem, Scenario):
            return self._solve_single(problem, method)
        if method != "distributed":
            raise ValueError("batched solves support method='distributed' "
                             f"only, got {method!r}")
        return self._solve_batch(
            _coerce(problem, dtype=self.config.effective_dtype()),
            check_feasible)

    def _solve_single(self, scn: Scenario, method: str) -> SolveReport:
        cfg = self.config
        if cfg.effective_dtype() is not None:
            scn = _cast_floats(scn, cfg.effective_dtype())
        t0 = time.perf_counter()
        if method == "centralized":
            sol = solve_centralized(scn)
        elif method == "distributed":
            sol = game.solve_distributed(scn, eps_bar=cfg.eps_bar,
                                         lam=cfg.lam,
                                         max_iters=cfg.max_iters)
        elif method == "distributed-python":
            sol, _, _ = game.solve_distributed_python(
                scn, eps_bar=cfg.eps_bar, lam=cfg.lam,
                max_iters=cfg.max_iters)
        else:
            raise ValueError(f"unknown method {method!r}")

        if not bool(sol.feasible):
            raise InfeasibleError(
                "instance infeasible: "
                f"sum(r_low)={float(jnp.sum(scn.r_low)):.1f} "
                f"> R={float(scn.R):.1f} or some E_i >= 0")

        if cfg.check_sample() > 0 and method == "distributed":
            # single-instance flavor of _dtype_check: one f64 re-solve
            sol64 = game.solve_distributed(
                _cast_floats(scn, jnp.float64), eps_bar=cfg.eps_bar,
                lam=cfg.lam, max_iters=cfg.max_iters)
            dev = float(jnp.sum(jnp.abs(sol.r.astype(jnp.float64) - sol64.r)))
            scale = max(float(jnp.sum(jnp.abs(sol64.r))), 1.0)
            bound = 2.0 * cfg.eps_bar + 1e-6
            if dev / scale > bound:
                raise RuntimeError(
                    f"dtype_policy={cfg.dtype_policy!r}: instance deviates "
                    f"from the f64 reference beyond {bound:.3g} relative "
                    f"({dev / scale:.3g}) — use dtype_policy='f64'")

        integer_sol = (round_solution(scn, sol.r, sol.sM, sol.sR, sol.psi)
                       if self.policies.rounding.enabled else None)
        return SolveReport(method=method, fractional=sol, integer=integer_sol,
                           iters=int(sol.iters), config=cfg,
                           elapsed_s=time.perf_counter() - t0)

    def _solve_batch(self, batch: ScenarioBatch,
                     check_feasible: bool) -> BatchSolveReport:
        cfg = self.config
        t0 = time.perf_counter()
        sol = game.solve_distributed_batch(batch, eps_bar=cfg.eps_bar,
                                           lam=cfg.lam,
                                           max_iters=cfg.max_iters,
                                           sweep_fn=cfg.sweep_fn,
                                           mesh=cfg.mesh,
                                           iter_fn=cfg.iter_fn)
        if check_feasible and not bool(jnp.all(sol.feasible)):
            bad = [int(b) for b in jnp.nonzero(~sol.feasible)[0]]
            raise InfeasibleError(f"instances {bad} infeasible: "
                                  "sum(r_low) > R or some E_i >= 0")
        dtype_check = _dtype_check(cfg, batch, sol)

        integer_sol = (round_solution_batch(batch, sol.r, sol.sM, sol.sR,
                                            sol.psi)
                       if self.policies.rounding.enabled else None)
        return BatchSolveReport(method="distributed", fractional=sol,
                                integer=integer_sol, iters=sol.iters,
                                config=cfg,
                                elapsed_s=time.perf_counter() - t0,
                                mask=batch.mask, n_classes=batch.n_classes,
                                feasible=sol.feasible,
                                dtype_check=dtype_check)

    # ------------------------------------------------------------ sessions
    def open_window(self, lanes, *, n_max: Optional[int] = None,
                    growth_factor: float = 2.0,
                    quota: Optional[TenantQuota] = None) -> "WindowSession":
        """Open the runtime loop: a live window driven by this engine.

        Parameters
        ----------
        lanes : AdmissionWindow, Scenario, Sequence[Scenario] or ScenarioBatch
            An existing live window is adopted as-is (its warm-start state,
            occupancy and dirty flags are preserved — this is how the
            legacy streaming facades delegate); anything else is coerced by
            :func:`_coerce` into the initial lane set of a fresh
            :class:`~repro.core.streaming.AdmissionWindow`.
        n_max : int, optional
            Initial padded width of a fresh window (headroom avoids early
            growth repads); ignored when adopting an existing window.
        growth_factor : float, optional
            Fresh-window growth multiplier when a lane's row fills
            (ignored when adopting an existing window).
        quota : TenantQuota, optional
            Per-tenant budget the session enforces: ``offer`` refuses
            events past ``max_queued`` and ``add_lane`` refuses lanes past
            ``max_lanes`` (both with :class:`QuotaExceededError`).  The
            initial lane count must already fit the lane budget.

        Returns
        -------
        WindowSession
            The session; all solver/policy behavior comes from this
            engine's ``config`` and ``policies``.
        """
        if isinstance(lanes, AdmissionWindow):
            return WindowSession(self, lanes, quota=quota)
        batch = _coerce(lanes, dtype=self.config.effective_dtype())
        scns = [batch.instance(b) for b in range(batch.batch_size)]
        window = AdmissionWindow(scns, n_max=n_max or batch.n_max,
                                 growth_factor=growth_factor)
        return WindowSession(self, window, quota=quota)

    # ----------------------------------------------------------- internals
    def _solve_window(self, window: AdmissionWindow) -> WindowSolveReport:
        """Warm-started incremental re-solve of a live window (the streaming
        mechanism: only dirty lanes iterate, clean lanes freeze at their
        stored equilibrium; numerically equivalent to a cold re-solve).
        Dispatches on residency: a device-resident window (or a
        ``residency='resident'`` config, which makes the window resident on
        first use) takes the zero-resharding resident path."""
        cfg = self.config
        if not window.is_resident and cfg.residency == "resident":
            window.make_resident(cfg.mesh)
        if window.is_resident:
            if cfg.mesh is not None and cfg.mesh != window.resident_mesh:
                raise ValueError(
                    "window is resident on a different mesh than the "
                    "engine's config.mesh — release_resident or match them")
            return self._solve_window_resident(window)
        return self._solve_window_roundtrip(window)

    def _solve_window_roundtrip(self,
                                window: AdmissionWindow) -> WindowSolveReport:
        """The classic flush: host-side warm start, per-solve mesh placement
        (when ``config.mesh`` is set), host-trimmed result."""
        cfg = self.config
        t0 = time.perf_counter()
        batch = window.batch
        init = window.warm_start()
        resolved = np.asarray(init.active).copy()

        sol = game.solve_distributed_batch(batch, eps_bar=cfg.eps_bar,
                                           lam=cfg.lam,
                                           max_iters=cfg.max_iters,
                                           sweep_fn=cfg.sweep_fn, init=init,
                                           mesh=cfg.mesh, iter_fn=cfg.iter_fn)
        window.commit(sol.r, sol.aux, sol.iters)
        dtype_check = _dtype_check(cfg, batch, sol,
                                   masks=np.asarray(batch.mask).any(axis=1))
        return self._window_report(window, batch, sol, resolved, t0,
                                   dtype_check=dtype_check)

    def _solve_window_resident(self,
                               window: AdmissionWindow) -> WindowSolveReport:
        """The resident flush: scenario leaves, mask mirror and warm-start
        state already live lane-sharded on the window's mesh, the init is
        built on-device and its buffers donated to the solve — zero
        per-flush host->mesh resharding (the tentpole of the
        device-resident session design; see docs/ARCHITECTURE.md)."""
        cfg = self.config
        t0 = time.perf_counter()
        rbatch = window.resident_batch()
        init, resolved = window.resident_warm_start(rbatch)
        sol_p = sharding.solve_resident_batch(
            rbatch, window.resident_mesh, eps_bar=cfg.eps_bar, lam=cfg.lam,
            max_iters=cfg.max_iters, sweep_fn=cfg.sweep_fn, init=init,
            iter_fn=cfg.iter_fn)
        del init                  # donated: unusable after the solve
        window.commit(sol_p.r, sol_p.aux, sol_p.iters)
        b = window.batch_size
        sol = (sol_p if rbatch.batch_size == b
               else jax.tree_util.tree_map(lambda leaf: leaf[:b], sol_p))
        # the report's batch view is the logical host mirror — same mask
        # snapshot recipe as the round-trip path, so reports from the two
        # paths are structurally identical (tests/test_resident.py asserts
        # bit-equality)
        return self._window_report(window, window.batch, sol, resolved, t0)

    def _window_report(self, window: AdmissionWindow, batch: ScenarioBatch,
                       sol, resolved: np.ndarray, t0: float,
                       dtype_check: Optional[dict] = None
                       ) -> WindowSolveReport:
        """Shared tail of both flush paths: centralized cross-check,
        Algorithm 4.2 rounding, report assembly — all over the LOGICAL
        lane count."""
        cfg, pol = self.config, self.policies
        gap = None
        if pol.cross_check.enabled:
            # The exact (P3) optimum of a lane only changes when its
            # scenario does, so recompute just the stale lanes and serve
            # the rest from the window's memo.  Per-lane single-instance
            # solves keep the shapes (n_max,) regardless of how many lanes
            # are stale — one compiled program per window width, never a
            # retrace per stale count the way a ragged sub-batch gather
            # would.
            stale = np.flatnonzero(window.baseline_stale)
            for b in stale:
                lane = jax.tree_util.tree_map(lambda l: l[b], batch.scenarios)
                window.baseline_totals[b] = float(
                    solve_centralized(lane, mask=batch.mask[b]).total)
            window.baseline_stale[stale] = False
            cent_total = jnp.asarray(window.baseline_totals, sol.total.dtype)
            scale = jnp.maximum(jnp.abs(cent_total), 1.0)
            gap = (sol.total - cent_total) / scale
            undercut = ((sol.total < cent_total - pol.cross_check.atol)
                        & sol.feasible)
            if bool(jnp.any(undercut)):
                bad = [int(b) for b in jnp.nonzero(undercut)[0]]
                raise RuntimeError(
                    f"lanes {bad}: GNEP total beats the exact (P3) optimum "
                    "— solver inconsistency (check mask/padding invariants)")

        integer_sol = (round_solution_batch(batch, sol.r, sol.sM, sol.sR,
                                            sol.psi)
                       if pol.rounding.enabled else None)
        return WindowSolveReport(method="streaming", fractional=sol,
                                 integer=integer_sol, iters=sol.iters,
                                 config=cfg,
                                 elapsed_s=time.perf_counter() - t0,
                                 mask=batch.mask, n_classes=batch.n_classes,
                                 feasible=sol.feasible, resolved=resolved,
                                 centralized_gap=gap,
                                 dtype_check=dtype_check)


class WindowSession:
    """The paper's runtime loop as a session: events in, equilibria out.

    Wraps a live :class:`~repro.core.streaming.AdmissionWindow` and owns
    everything the legacy facades made the caller thread by hand: the
    event buffer and its :class:`~repro.core.streaming.FlushPolicy` (incl.
    deadline-aware immediate flushes), the warm-start state carried between
    re-solves, mesh placement, compaction policy, rounding and the
    centralized cross-check.  Per-lane ``feasible`` flags report infeasible
    transients without raising (arrival bursts legitimately overload a
    window until load is shed).

    Obtain sessions from :meth:`CapacityEngine.open_window`.

    Parameters
    ----------
    engine : CapacityEngine
        Supplies ``config`` (solver knobs, kernel, mesh) and ``policies``.
    window : AdmissionWindow
        The live window; mutated by ``apply``/``flush``/lane operations.
    quota : TenantQuota, optional
        Per-tenant budget; ``offer`` and ``add_lane`` enforce it with
        :class:`QuotaExceededError`.  ``None`` is unlimited.
    """

    def __init__(self, engine: CapacityEngine, window: AdmissionWindow,
                 quota: Optional[TenantQuota] = None):
        if (quota is not None
                and not quota.admits_lane(window.batch_size - 1)):
            raise QuotaExceededError(
                f"window opens with {window.batch_size} lanes, quota "
                f"allows {quota.max_lanes}")
        self.engine = engine
        self.window = window
        self.quota = quota
        self._pending: List[StreamEvent] = []
        self.flushes = 0
        self.events_folded = 0
        self.last_slots: List[Optional[int]] = []
        self._last_report: Optional[WindowSolveReport] = None

    # ------------------------------------------------------------- queries
    @property
    def pending(self):
        """Buffered, not-yet-applied events (application order)."""
        return tuple(self._pending)

    @property
    def dirty_lanes(self) -> Set[int]:
        """Lanes the next flush will re-solve: window-dirty | buffered."""
        return (set(int(b) for b in np.flatnonzero(self.window.dirty))
                | {ev.lane for ev in self._pending})

    # --------------------------------------------------------------- verbs
    def solve(self) -> WindowSolveReport:
        """Warm-started incremental re-solve of the window's current state.

        Only lanes dirtied since the last equilibrium iterate Algorithm 4.1
        (restarting from the paper's cold init so they reproduce the cold
        trajectory exactly); clean lanes are frozen at zero solver cost.
        Buffered events are NOT applied — use :meth:`flush` for that.

        Returns
        -------
        WindowSolveReport
            Batch result over all lanes plus ``resolved`` /
            ``centralized_gap`` bookkeeping.
        """
        return self.engine._solve_window(self.window)

    def apply(self, *events: StreamEvent) -> Optional[WindowSolveReport]:
        """Buffer events; flush automatically when the policy demands it.

        Each event is checked against the engine's flush policy: an
        SLA-critical event (per ``FlushPolicy.deadline``) or a fired
        count / dirty-fraction trigger causes an immediate :meth:`flush`
        — bulk events keep coalescing until then.

        Parameters
        ----------
        *events : StreamEvent
            ClassArrival / ClassDeparture / SLAEdit / CapacityChange, in
            application order (validated atomically at flush).

        Returns
        -------
        WindowSolveReport or None
            The report of the LAST policy-triggered flush, or None when
            everything is still buffered.
        """
        policy = self.engine.policies.flush
        report = None
        for ev in events:
            self._pending.append(ev)
            if self._policy_fires(policy, ev):
                report = self.flush()
        return report

    def _policy_fires(self, policy: FlushPolicy, ev: StreamEvent) -> bool:
        """One buffered event's flush decision (dirty-lane accounting is
        skipped unless the policy actually has a dirty-fraction trigger —
        it costs a host scan per event on the dispatch-bound path)."""
        if policy.is_critical(ev, self.window):
            return True
        n_dirty = (len(self.dirty_lanes)
                   if policy.max_dirty_fraction is not None else 0)
        return policy.should_flush(n_events=len(self._pending),
                                   n_dirty=n_dirty,
                                   batch_size=self.window.batch_size)

    def offer(self, event: StreamEvent) -> bool:
        """Buffer one event WITHOUT flushing; report whether a flush is due.

        The external-scheduler hook: :meth:`apply` decides *and executes*
        flushes inline, which is right for a single session but wrong for a
        daemon multiplexing many sessions — there the flush *order* across
        sessions is a scheduling decision (``repro.serving.allocd`` flushes
        the session with the tightest SLA slack first).  ``offer`` runs
        exactly the flush-policy check :meth:`apply` runs (so flush
        *boundaries* stay bit-identical to an inline replay) but leaves the
        flush to the caller.  Once ``offer`` returns True, do not offer the
        session further events until :meth:`flush` has run — interleaving
        would move the boundary and break replay conformance.

        Parameters
        ----------
        event : StreamEvent
            The event to buffer (validated atomically at flush).

        Returns
        -------
        bool
            True when the engine's flush policy demands a flush now —
            including SLA-critical events under a deadline-aware policy.

        Raises
        ------
        QuotaExceededError
            When the session carries a :class:`TenantQuota` and the buffer
            already holds ``max_queued`` events.  Schedulers that meter
            their own queues against the quota (the admission daemon does)
            never trip this; it is the backstop against unbounded buffer
            growth under a flush policy that never fires.
        """
        if (self.quota is not None
                and not self.quota.admits_event(len(self._pending))):
            raise QuotaExceededError(
                f"session buffer holds {len(self._pending)} events, quota "
                f"allows {self.quota.max_queued}")
        self._pending.append(event)
        return self._policy_fires(self.engine.policies.flush, event)

    def pending_slack(self) -> float:
        """Tightest SLA slack [s] carried by the buffered events.

        The cross-session scheduling key of ``repro.serving.allocd``: among
        sessions due to flush, the one whose tightest deadline expires
        soonest flushes first.  Slack of one event is ``-E`` (``E = C - D``
        is negative while the deadline is attainable) taken from a
        :class:`~repro.core.types.ClassArrival`'s params or an
        :class:`~repro.core.types.SLAEdit`'s updates; events that carry no
        deadline (departures, capacity changes, E-less edits) contribute
        nothing.

        Returns
        -------
        float
            ``min(-E)`` over deadline-carrying buffered events, ``inf``
            when there are none (flush-order ties break by fairness, not
            urgency).
        """
        slack = np.inf
        for ev in self._pending:
            E = None
            if isinstance(ev, ClassArrival):
                E = ev.params.get("E")
            elif isinstance(ev, SLAEdit):
                E = ev.updates.get("E")
            if E is not None:
                slack = min(slack, -float(E))
        return slack

    def drain(self) -> List[Optional[int]]:
        """Fold every buffered event into the window WITHOUT re-solving.

        One coalesced ``AdmissionWindow.apply_epoch`` (one scatter per
        Scenario field however many events are pending); the window is
        left dirty for the next :meth:`solve` / :meth:`flush`.  Drivers
        that need arrival slot grants before deciding further events (the
        fleet layer does) call this directly.

        Returns
        -------
        list of (int or None)
            Per-event slot grants (arrivals) in buffer order — also kept
            on ``last_slots``; empty when nothing was pending.
        """
        if not self._pending:
            return []
        if len(self._pending) == 1:
            # single-event fast path: skip the epoch simulation entirely
            # (apply_epoch is proven bit-equal to sequential apply, so this
            # changes dispatch cost only — per-event streaming is
            # dispatch-bound on CPU)
            slots = [self.window.apply(self._pending[0])]
        else:
            slots = self.window.apply_epoch(self._pending)
        self.events_folded += len(self._pending)
        self._pending = []
        self.last_slots = slots
        return slots

    def discard_pending(self) -> Tuple[StreamEvent, ...]:
        """Drop every buffered event without folding it into the window.

        The abort hook for external schedulers: an aborting daemon (or a
        driver whose epoch failed ``apply_epoch`` validation) must leave
        the session at its last *flushed* state — partially-buffered
        epochs are discarded rather than half-applied, so the session's
        flush-boundary history stays a prefix of the full-trace replay.
        The window itself is untouched (state, dirty flags, counters).

        Returns
        -------
        tuple of StreamEvent
            The dropped events, in the order they were buffered (callers
            may re-queue, log or fail them).
        """
        dropped = tuple(self._pending)
        self._pending = []
        return dropped

    def flush(self) -> WindowSolveReport:
        """Apply buffered events, run policy compaction, re-solve once.

        The coalesced cadence step: ONE window update folds the whole
        buffer, the compaction policy may re-pack a sparse window (the
        report's ``slot_map`` records the re-layout), and ONE warm-started
        re-solve re-equilibrates the union of dirtied lanes.  An empty
        flush on a clean, already-solved, geometry-unchanged window is a
        true no-op: it echoes the previous flush's report (``slot_map``
        cleared — no compaction happened NOW) without any solve dispatch;
        the daemon's drain path hits this on every idle session, and
        ``flushes`` / ``events_folded`` do not advance.

        Returns
        -------
        WindowSolveReport
            Numerically equivalent to having re-solved after every single
            event (the last per-event solve of the epoch; proven in
            ``tests/test_coalescing.py``).
        """
        if (not self._pending and self._last_report is not None
                and self.window.state is not None
                and not self.window.dirty.any()
                and np.array_equal(np.asarray(self._last_report.mask),
                                   self.window._mask)):
            # slot_map describes the PREVIOUS flush's compaction — this
            # no-op flush performed none, so the echo must not carry it
            return replace(self._last_report, slot_map=None)
        self.drain()
        report_map = None
        comp = self.engine.policies.compaction
        if (comp.occupancy is not None
                and self.window.occupancy < comp.occupancy):
            counts = self.window.n_classes
            widest = max(int(counts.max()), 1)
            target = max(int(np.ceil(comp.headroom * widest)), widest)
            report_map = self.window.compact(n_max=target)
        report = self.engine._solve_window(self.window)
        report.slot_map = report_map
        self.flushes += 1
        self._last_report = report
        return report

    def stream(self, events: Iterable[StreamEvent]
               ) -> Iterator[WindowSolveReport]:
        """Replay an event stream in policy-coalesced re-solve epochs.

        The generator form of :meth:`apply`: events accumulate until the
        flush policy triggers (count, dirty fraction, or an SLA-critical
        event), then one coalesced flush yields its report.  A trailing
        partial epoch is flushed after the stream ends, so consuming the
        generator always leaves the window clean and solved.

        Parameters
        ----------
        events : iterable of StreamEvent
            The event stream, in application order.  May be a lazy
            iterator — epochs form as events arrive.

        Yields
        ------
        WindowSolveReport
            One per flush, in stream order.
        """
        for ev in events:
            report = self.apply(ev)
            if report is not None:
                yield report
        if self._pending:
            yield self.flush()

    # ----------------------------------------------------- window geometry
    def add_lane(self, scn: Optional[Scenario] = None, *,
                 R: Optional[float] = None,
                 rho_bar: Optional[float] = None) -> int:
        """Append one lane (a new cluster / fleet joining the window).

        Buffered events are drained first (lane geometry changes only at
        flush boundaries); the new lane starts dirty/never-solved, so the
        next solve iterates exactly it.

        Parameters
        ----------
        scn : Scenario, optional
            Initial classes of the new lane; ``None`` admits an empty lane.
        R : float, optional
            Lane capacity, required (with ``rho_bar``) when ``scn`` is None.
        rho_bar : float, optional
            Lane unit chip cost, required (with ``R``) when ``scn`` is None.

        Returns
        -------
        int
            The new lane's index.

        Raises
        ------
        QuotaExceededError
            When the session's :class:`TenantQuota` caps ``max_lanes`` and
            the window is already at it.
        """
        if (self.quota is not None
                and not self.quota.admits_lane(self.window.batch_size)):
            raise QuotaExceededError(
                f"window already holds {self.window.batch_size} lanes, "
                f"quota allows {self.quota.max_lanes}")
        self.drain()
        return self.window.add_lane(scn, R=R, rho_bar=rho_bar)

    def remove_lane(self, lane: int) -> None:
        """Drop ``lane`` and shrink B by one (buffered events drain first).

        Parameters
        ----------
        lane : int
            Lane to remove; higher lanes shift down by one and clean lanes
            stay frozen across the shrink.
        """
        self.drain()
        self.window.remove_lane(lane)

    def compact(self, *, n_max: Optional[int] = None) -> np.ndarray:
        """Re-pack the window now (buffered events drain first).

        Parameters
        ----------
        n_max : int, optional
            Target padded width (default: minimal); see
            ``AdmissionWindow.compact``.

        Returns
        -------
        np.ndarray
            (B, old_n_max) old-slot -> new-slot map (-1 where empty).
        """
        self.drain()
        return self.window.compact(n_max=n_max)


# --------------------------------------------------------------------------
# Legacy plumbing (no DeprecationWarning: mechanism, not facade)
# --------------------------------------------------------------------------


def _legacy_solve_window(window: AdmissionWindow, *, eps_bar: float = 0.03,
                         lam: float = 0.05, max_iters: int = 200,
                         integer: bool = True, sweep_fn=None, mesh=None,
                         cross_check: bool = False,
                         cross_check_atol: float = 1e-6) -> WindowSolveReport:
    """kwargs -> (config, policies) adapter used by the deprecated facades
    and ``EventEpoch.flush`` so in-repo mechanism never routes through a
    warning-emitting shim."""
    eng = CapacityEngine(
        SolverConfig(eps_bar=eps_bar, lam=lam, max_iters=max_iters,
                     sweep_fn=sweep_fn, mesh=mesh),
        Policies(rounding=RoundingPolicy(integer),
                 cross_check=CrossCheckPolicy(cross_check, cross_check_atol)))
    return eng._solve_window(window)
