"""High-level allocator facade: solve + round, centralized or distributed."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

from repro.core import game
from repro.core.centralized import solve_centralized
from repro.core.rounding import IntegerSolution, round_solution
from repro.core.types import Scenario, Solution


@dataclass
class AllocationResult:
    method: str
    fractional: Solution
    integer: Optional[IntegerSolution]
    iters: int

    @property
    def r(self):
        return self.integer.r if self.integer is not None else self.fractional.r

    @property
    def total(self):
        return (self.integer.total if self.integer is not None
                else self.fractional.total)


def solve(scn: Scenario, method: str = "distributed", *, eps_bar: float = 0.03,
          lam: float = 0.05, max_iters: int = 200,
          integer: bool = True) -> AllocationResult:
    """Solve the joint admission-control + capacity-allocation problem.

    method: "centralized" (exact optimum of P2/P3) or "distributed"
    (Algorithm 4.1 GNEP best-reply) — both feed Algorithm 4.2 when
    ``integer=True``, mirroring the paper's experimental pipeline (Sec. 5).
    """
    if method == "centralized":
        sol = solve_centralized(scn)
    elif method == "distributed":
        sol = game.solve_distributed(scn, eps_bar=eps_bar, lam=lam,
                                     max_iters=max_iters)
    elif method == "distributed-python":
        sol, _, _ = game.solve_distributed_python(
            scn, eps_bar=eps_bar, lam=lam, max_iters=max_iters)
    else:
        raise ValueError(f"unknown method {method!r}")

    if not bool(sol.feasible):
        raise InfeasibleError(
            f"instance infeasible: sum(r_low)={float(jnp.sum(scn.r_low)):.1f} "
            f"> R={float(scn.R):.1f} or some E_i >= 0")

    integer_sol = (round_solution(scn, sol.r, sol.sM, sol.sR, sol.psi)
                   if integer else None)
    return AllocationResult(method=method, fractional=sol,
                            integer=integer_sol, iters=int(sol.iters))


class InfeasibleError(RuntimeError):
    """Deadlines/SLAs cannot be met with the available capacity."""
