"""DEPRECATED facades over :mod:`repro.core.engine` (the session API).

Every ``solve_*`` function here is a thin shim that maps its legacy kwargs
onto a :class:`~repro.core.engine.CapacityEngine` and delegates — results
are bit-equal to the corresponding engine call (``tests/test_engine.py``
proves it for every historical call pattern, warm starts, meshes and
cross-checks included).  The shims stay for external users; in-repo callers
are migrated and CI promotes the :class:`DeprecationWarning` they emit to an
error (``pytest.ini`` / ``scripts/ci.sh``), so no new internal dependency on
this module can land.

Migration table (old call -> engine call): ``docs/API.md``.

The legacy result dataclasses are aliases of the unified report hierarchy:
``AllocationResult`` is :class:`~repro.core.engine.SolveReport`,
``BatchAllocationResult`` is :class:`~repro.core.engine.BatchSolveReport`,
``StreamingResult`` is :class:`~repro.core.engine.WindowSolveReport` — old
attribute access (``.fractional``, ``.integer``, ``.instance(b)``,
``.resolved``, ...) keeps working unchanged.
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence, Union

from repro.core.engine import (BatchSolveReport, CapacityEngine,
                               CrossCheckPolicy, InfeasibleError, Policies,
                               RoundingPolicy, SolveReport, SolverConfig,
                               WindowSolveReport, _legacy_solve_window)
from repro.core.streaming import AdmissionWindow, FlushPolicy
from repro.core.types import Scenario, ScenarioBatch

#: Legacy result names — aliases of the unified report hierarchy.
AllocationResult = SolveReport
BatchAllocationResult = BatchSolveReport
StreamingResult = WindowSolveReport

__all__ = [
    "AllocationResult", "BatchAllocationResult", "InfeasibleError",
    "StreamingResult", "solve", "solve_batch", "solve_coalesced",
    "solve_streaming",
]


def _warn(name: str, hint: str) -> None:
    """Emit the facade's DeprecationWarning (message prefix is load-bearing:
    ``pytest.ini`` and ``scripts/ci.sh`` promote exactly this prefix to an
    error for in-repo callers)."""
    warnings.warn(
        f"repro.core.allocator.{name} is deprecated; use "
        f"repro.core.engine.CapacityEngine — {hint} (see docs/API.md)",
        DeprecationWarning, stacklevel=3)


def solve(scn: Scenario, method: str = "distributed", *, eps_bar: float = 0.03,
          lam: float = 0.05, max_iters: int = 200,
          integer: bool = True) -> SolveReport:
    """Deprecated: solve one instance (delegates to ``CapacityEngine``).

    Parameters
    ----------
    scn : Scenario
        One allocation instance over N job classes.
    method : str, optional
        ``"centralized"``, ``"distributed"`` or ``"distributed-python"``;
        see :meth:`repro.core.engine.CapacityEngine.solve`.
    eps_bar, lam, max_iters
        Algorithm 4.1 knobs (-> ``SolverConfig``).
    integer : bool, optional
        Apply Algorithm 4.2 rounding (-> ``RoundingPolicy``).

    Returns
    -------
    SolveReport
        Bit-equal to ``CapacityEngine(config, policies).solve(scn,
        method=method)``.

    Raises
    ------
    InfeasibleError
        If ``sum(r_low) > R`` or some deadline is unattainable (E_i >= 0).
    """
    _warn("solve", "CapacityEngine(SolverConfig(...)).solve(scn)")
    eng = CapacityEngine(
        SolverConfig(eps_bar=eps_bar, lam=lam, max_iters=max_iters),
        Policies(rounding=RoundingPolicy(integer)))
    return eng.solve(scn, method=method)


def solve_batch(batch: Union[ScenarioBatch, Sequence[Scenario]],
                method: str = "distributed", *, eps_bar: float = 0.03,
                lam: float = 0.05, max_iters: int = 200, integer: bool = True,
                sweep_fn=None, mesh=None,
                check_feasible: bool = True) -> BatchSolveReport:
    """Deprecated: solve B instances at once (delegates to the engine).

    Parameters
    ----------
    batch : ScenarioBatch or Sequence[Scenario]
        A prepared batch or a plain (possibly ragged) scenario list
        (-> ``engine._coerce``).
    method : str, optional
        Only ``"distributed"`` is supported on the batched path.
    eps_bar, lam, max_iters
        Algorithm 4.1 knobs (-> ``SolverConfig``).
    integer : bool, optional
        Apply the vectorized Algorithm 4.2 rounding pass.
    sweep_fn : callable, optional
        Batched RM price-sweep override (-> ``SolverConfig.sweep_fn``).
    mesh : jax.sharding.Mesh, optional
        1-D lane mesh (-> ``SolverConfig.mesh``).
    check_feasible : bool, optional
        Raise on infeasible lanes (default) or return per-lane flags.

    Returns
    -------
    BatchSolveReport
        Bit-equal to the corresponding ``CapacityEngine.solve`` call.

    Raises
    ------
    InfeasibleError
        When ``check_feasible`` and any lane violates ``sum(r_low) <= R``
        or has some E_i >= 0.
    """
    _warn("solve_batch",
          "CapacityEngine(SolverConfig(sweep_fn=..., mesh=...)).solve(batch)")
    eng = CapacityEngine(
        SolverConfig(eps_bar=eps_bar, lam=lam, max_iters=max_iters,
                     sweep_fn=sweep_fn, mesh=mesh),
        Policies(rounding=RoundingPolicy(integer)))
    return eng.solve(batch, method=method, check_feasible=check_feasible)


def solve_streaming(window: AdmissionWindow, *, eps_bar: float = 0.03,
                    lam: float = 0.05, max_iters: int = 200,
                    integer: bool = True, sweep_fn=None, mesh=None,
                    cross_check: bool = False,
                    cross_check_atol: float = 1e-6) -> WindowSolveReport:
    """Deprecated: warm incremental window re-solve (-> ``WindowSession``).

    Parameters
    ----------
    window : AdmissionWindow
        The live window; mutated (equilibrium committed, dirty flags
        cleared) exactly as the engine path does.
    eps_bar, lam, max_iters, sweep_fn, mesh
        Solver knobs and placement (-> ``SolverConfig``).
    integer : bool, optional
        Apply the vectorized Algorithm 4.2 rounding pass
        (-> ``RoundingPolicy``).
    cross_check : bool, optional
        Attach the per-lane exact centralized (P3) gap
        (-> ``CrossCheckPolicy``).
    cross_check_atol : float, optional
        Sanity slack of the cross-check (-> ``CrossCheckPolicy.atol``).

    Returns
    -------
    WindowSolveReport
        Bit-equal to ``engine.open_window(window).solve()`` under the same
        config/policies.
    """
    _warn("solve_streaming",
          "CapacityEngine(...).open_window(window).solve()")
    return _legacy_solve_window(window, eps_bar=eps_bar, lam=lam,
                                max_iters=max_iters, integer=integer,
                                sweep_fn=sweep_fn, mesh=mesh,
                                cross_check=cross_check,
                                cross_check_atol=cross_check_atol)


def solve_coalesced(window: AdmissionWindow, events, *,
                    policy: Optional[FlushPolicy] = None,
                    eps_bar: float = 0.03, lam: float = 0.05,
                    max_iters: int = 200, integer: bool = True,
                    sweep_fn=None, mesh=None, cross_check: bool = False):
    """Deprecated: coalesced event-stream replay (-> ``WindowSession.stream``).

    Parameters
    ----------
    window : AdmissionWindow
        The live window; mutated at every flush.
    events : iterable of StreamEvent
        The event stream, in application order.
    policy : FlushPolicy, optional
        Flush triggers (default: every 8 events) (-> ``Policies.flush``).
    eps_bar, lam, max_iters, integer, sweep_fn, mesh, cross_check
        As in :func:`solve_streaming` (-> ``SolverConfig`` / ``Policies``).

    Yields
    ------
    WindowSolveReport
        One per flush, in stream order — bit-equal to
        ``engine.open_window(window).stream(events)``.
    """
    _warn("solve_coalesced",
          "CapacityEngine(...).open_window(window).stream(events)")
    eng = CapacityEngine(
        SolverConfig(eps_bar=eps_bar, lam=lam, max_iters=max_iters,
                     sweep_fn=sweep_fn, mesh=mesh),
        Policies(flush=policy if policy is not None else FlushPolicy(),
                 rounding=RoundingPolicy(integer),
                 cross_check=CrossCheckPolicy(cross_check)))
    return eng.open_window(window).stream(events)
