"""High-level allocator facade: solve + round, centralized or distributed.

Single-instance (`solve`), batched (`solve_batch`) and streaming
(`solve_streaming`) entry points share the same pipeline: fractional GNEP
solve (Algorithm 4.1) -> integer rounding (Algorithm 4.2).  The batched path
runs B scenarios as one XLA program and one vectorized rounding pass; the
streaming path re-solves only the lanes an event trace has dirtied
(see ``repro.core.streaming``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game
from repro.core.centralized import solve_centralized
from repro.core.rounding import (IntegerSolution, round_solution,
                                 round_solution_batch)
from repro.core.streaming import AdmissionWindow, EventEpoch, FlushPolicy
from repro.core.types import (Scenario, ScenarioBatch, Solution,
                              stack_scenarios)


@dataclass
class AllocationResult:
    method: str
    fractional: Solution
    integer: Optional[IntegerSolution]
    iters: int

    @property
    def r(self):
        return self.integer.r if self.integer is not None else self.fractional.r

    @property
    def total(self):
        return (self.integer.total if self.integer is not None
                else self.fractional.total)


def solve(scn: Scenario, method: str = "distributed", *, eps_bar: float = 0.03,
          lam: float = 0.05, max_iters: int = 200,
          integer: bool = True) -> AllocationResult:
    """Solve the joint admission-control + capacity-allocation problem.

    Parameters
    ----------
    scn : Scenario
        One allocation instance over N job classes.
    method : str, optional
        ``"centralized"`` (exact optimum of P2/P3 via water-filling),
        ``"distributed"`` (Algorithm 4.1 GNEP best-reply, jitted) or
        ``"distributed-python"`` (the paper-faithful serial loop) — all feed
        Algorithm 4.2 when ``integer=True``, mirroring the paper's
        experimental pipeline (Sec. 5).
    eps_bar, lam, max_iters
        Algorithm 4.1 knobs (ignored by the centralized method); see
        ``game.solve_distributed``.
    integer : bool, optional
        Apply Algorithm 4.2 rounding to the fractional solution.

    Returns
    -------
    AllocationResult
        Fractional (and, by default, integer) solutions plus iteration count.

    Raises
    ------
    InfeasibleError
        If ``sum(r_low) > R`` or some deadline is unattainable (E_i >= 0).
    """
    if method == "centralized":
        sol = solve_centralized(scn)
    elif method == "distributed":
        sol = game.solve_distributed(scn, eps_bar=eps_bar, lam=lam,
                                     max_iters=max_iters)
    elif method == "distributed-python":
        sol, _, _ = game.solve_distributed_python(
            scn, eps_bar=eps_bar, lam=lam, max_iters=max_iters)
    else:
        raise ValueError(f"unknown method {method!r}")

    if not bool(sol.feasible):
        raise InfeasibleError(
            f"instance infeasible: sum(r_low)={float(jnp.sum(scn.r_low)):.1f} "
            f"> R={float(scn.R):.1f} or some E_i >= 0")

    integer_sol = (round_solution(scn, sol.r, sol.sM, sol.sR, sol.psi)
                   if integer else None)
    return AllocationResult(method=method, fractional=sol,
                            integer=integer_sol, iters=int(sol.iters))


class InfeasibleError(RuntimeError):
    """Deadlines/SLAs cannot be met with the available capacity."""


@dataclass
class BatchAllocationResult:
    """Result of one batched solve: every leaf carries a leading B dim.

    Per-class arrays are (B, n_max) with padded classes identically zero;
    ``instance(b)`` trims lane b back to a single-instance
    :class:`AllocationResult`.
    """
    method: str
    fractional: Solution                 # batched Solution
    integer: Optional[IntegerSolution]   # batched IntegerSolution
    mask: jnp.ndarray                    # (B, n_max)
    n_classes: jnp.ndarray               # (B,)
    iters: jnp.ndarray                   # (B,)
    feasible: jnp.ndarray                # (B,)

    @property
    def batch_size(self) -> int:
        return self.mask.shape[0]

    @property
    def r(self):
        return self.integer.r if self.integer is not None else self.fractional.r

    @property
    def total(self):
        return (self.integer.total if self.integer is not None
                else self.fractional.total)

    def instance(self, b: int) -> AllocationResult:
        """Trim lane b to a single-instance view (mask-aware: works for
        streaming windows whose free slots leave holes in the mask)."""
        sel = np.asarray(self.mask[b])

        def pick(leaf):
            leaf = leaf[b]
            return leaf[sel] if getattr(leaf, "ndim", 0) else leaf

        frac = jax.tree_util.tree_map(pick, self.fractional)
        integ = (jax.tree_util.tree_map(pick, self.integer)
                 if self.integer is not None else None)
        return AllocationResult(method=self.method, fractional=frac,
                                integer=integ, iters=int(self.iters[b]))


def solve_batch(batch: Union[ScenarioBatch, Sequence[Scenario]],
                method: str = "distributed", *, eps_bar: float = 0.03,
                lam: float = 0.05, max_iters: int = 200, integer: bool = True,
                sweep_fn=None, mesh=None,
                check_feasible: bool = True) -> BatchAllocationResult:
    """Solve B independent allocation instances as one batched program.

    Parameters
    ----------
    batch : ScenarioBatch or Sequence[Scenario]
        A prepared :class:`ScenarioBatch`, or a plain list of (possibly
        ragged) Scenarios which is stacked/padded here.
    method : str, optional
        Only ``"distributed"`` (the batched GNEP engine) is supported.
    eps_bar, lam, max_iters
        Algorithm 4.1 knobs; see ``game.solve_distributed_batch``.
    integer : bool, optional
        Apply the lane-wise vmapped Algorithm 4.2 rounding pass.
    sweep_fn : callable, optional
        Batched RM price-sweep override (the Pallas kernel), forwarded to
        ``solve_distributed_batch``.
    mesh : jax.sharding.Mesh, optional
        1-D lane mesh (``repro.core.sharding.lane_mesh``): shard the B
        lanes across devices, inert-lane padding handling ragged lane
        counts; results match the unsharded path to <= 1e-6.  The rounding
        pass runs on the gathered result (it is negligible next to the
        solve).
    check_feasible : bool, optional
        With True (default) an :class:`InfeasibleError` names every
        infeasible lane; pass False to get per-lane ``feasible`` flags
        instead (what-if sweeps legitimately probe infeasible capacity
        points).

    Returns
    -------
    BatchAllocationResult
        Every leaf carries a leading B dim; ``instance(b)`` trims lane b
        back to a single-instance view.

    Raises
    ------
    InfeasibleError
        When ``check_feasible`` and any lane violates ``sum(r_low) <= R``
        or has some E_i >= 0.
    """
    if not isinstance(batch, ScenarioBatch):
        batch = stack_scenarios(batch)
    if method != "distributed":
        raise ValueError(
            f"solve_batch supports method='distributed' only, got {method!r}")

    sol = game.solve_distributed_batch(batch, eps_bar=eps_bar, lam=lam,
                                       max_iters=max_iters, sweep_fn=sweep_fn,
                                       mesh=mesh)
    if check_feasible and not bool(jnp.all(sol.feasible)):
        bad = [int(b) for b in jnp.nonzero(~sol.feasible)[0]]
        raise InfeasibleError(f"instances {bad} infeasible: "
                              "sum(r_low) > R or some E_i >= 0")

    integer_sol = (round_solution_batch(batch, sol.r, sol.sM, sol.sR, sol.psi)
                   if integer else None)
    return BatchAllocationResult(method=method, fractional=sol,
                                 integer=integer_sol, mask=batch.mask,
                                 n_classes=batch.n_classes, iters=sol.iters,
                                 feasible=sol.feasible)


@dataclass
class StreamingResult(BatchAllocationResult):
    """One streaming re-solve: a batch result plus incremental bookkeeping.

    Attributes (beyond :class:`BatchAllocationResult`)
    --------------------------------------------------
    resolved : np.ndarray
        (B,) bool — lanes that actually iterated this call (dirty or
        never-solved); the complement was frozen at its stored equilibrium.
    centralized_gap : jnp.ndarray or None
        (B,) relative gap of the fractional GNEP total over the exact
        centralized (P3) optimum, when ``cross_check=True`` was requested.
    """
    resolved: Optional[np.ndarray] = None
    centralized_gap: Optional[jnp.ndarray] = None


def solve_streaming(window: AdmissionWindow, *, eps_bar: float = 0.03,
                    lam: float = 0.05, max_iters: int = 200,
                    integer: bool = True, sweep_fn=None, mesh=None,
                    cross_check: bool = False,
                    cross_check_atol: float = 1e-6) -> StreamingResult:
    """Incrementally re-solve a live :class:`AdmissionWindow`.

    Only lanes dirtied by events since the last call iterate Algorithm 4.1
    (restarting from the paper's cold init so they reproduce the cold
    trajectory exactly); clean lanes are frozen at their stored equilibrium
    and cost zero solver iterations.  The result is numerically equivalent
    to a cold ``solve_batch`` of the window's current state, while steady-
    state event handling stays on one compiled XLA program (no re-stacking,
    no shape changes, no retrace).  The new equilibrium is committed back to
    the window, marking every lane clean.

    Parameters
    ----------
    window : AdmissionWindow
        The live window; mutated (equilibrium state committed, dirty flags
        cleared).
    eps_bar, lam, max_iters, sweep_fn
        Forwarded to ``game.solve_distributed_batch`` (see its docstring).
    mesh : jax.sharding.Mesh, optional
        1-D lane mesh (``repro.core.sharding.lane_mesh``): the window's
        lanes shard across devices; the frozen / dirty warm-start split is
        preserved verbatim (``BatchWarmStart`` shards over the same lane
        axis, inert frozen lanes pad a ragged lane count), so per-lane
        results — including which lanes iterate — match the unsharded
        streaming path to <= 1e-6.
    integer : bool, optional
        Apply the vectorized Algorithm 4.2 rounding pass (default True).
    cross_check : bool, optional
        Also compare every lane against its exact centralized (P3) optimum
        (``solve_centralized_batch``) and attach the per-lane relative gap.
        Baseline totals are memoized per lane in the window and recomputed
        only for lanes whose scenario changed, mirroring the incremental
        distributed solve.
        Raises :class:`RuntimeError` if any feasible lane's fractional GNEP
        total undercuts the exact optimum by more than ``cross_check_atol``
        (impossible for a correct solver — the equilibrium is (P3)-feasible).
    cross_check_atol : float, optional
        Absolute slack allowed in the sanity direction of the cross-check.

    Returns
    -------
    StreamingResult
        Batch result over ALL lanes (frozen lanes carry their stored
        equilibrium) plus ``resolved`` / ``centralized_gap`` bookkeeping.
        Per-lane ``feasible`` flags report infeasible transients; no
        exception is raised for them (arrival bursts legitimately overload
        a window until load is shed).
    """
    batch = window.batch
    init = window.warm_start()
    resolved = np.asarray(init.active).copy()

    sol = game.solve_distributed_batch(batch, eps_bar=eps_bar, lam=lam,
                                       max_iters=max_iters, sweep_fn=sweep_fn,
                                       init=init, mesh=mesh)
    window.commit(sol.r, sol.aux, sol.iters)

    gap = None
    if cross_check:
        # The exact (P3) optimum of a lane only changes when its scenario
        # does, so recompute just the stale lanes and serve the rest from
        # the window's memo.  Per-lane single-instance solves keep the
        # shapes (n_max,) regardless of how many lanes are stale — one
        # compiled program per window width, never a retrace per stale
        # count the way a ragged sub-batch gather would.
        stale = np.flatnonzero(window.baseline_stale)
        for b in stale:
            lane = jax.tree_util.tree_map(lambda l: l[b], batch.scenarios)
            window.baseline_totals[b] = float(
                solve_centralized(lane, mask=batch.mask[b]).total)
        window.baseline_stale[stale] = False
        cent_total = jnp.asarray(window.baseline_totals, sol.total.dtype)
        scale = jnp.maximum(jnp.abs(cent_total), 1.0)
        gap = (sol.total - cent_total) / scale
        undercut = (sol.total < cent_total - cross_check_atol) & sol.feasible
        if bool(jnp.any(undercut)):
            bad = [int(b) for b in jnp.nonzero(undercut)[0]]
            raise RuntimeError(
                f"lanes {bad}: GNEP total beats the exact (P3) optimum — "
                "solver inconsistency (check mask/padding invariants)")

    integer_sol = (round_solution_batch(batch, sol.r, sol.sM, sol.sR, sol.psi)
                   if integer else None)
    return StreamingResult(method="streaming", fractional=sol,
                           integer=integer_sol, mask=batch.mask,
                           n_classes=batch.n_classes, iters=sol.iters,
                           feasible=sol.feasible, resolved=resolved,
                           centralized_gap=gap)


def solve_coalesced(window: AdmissionWindow, events, *,
                    policy: Optional[FlushPolicy] = None,
                    eps_bar: float = 0.03, lam: float = 0.05,
                    max_iters: int = 200, integer: bool = True,
                    sweep_fn=None, mesh=None, cross_check: bool = False):
    """Replay an event stream in coalesced re-solve epochs (a generator).

    The dynamic-window cadence driver: events accumulate in an
    :class:`~repro.core.streaming.EventEpoch` until ``policy`` triggers,
    then ONE coalesced window update (one scatter per Scenario field, not
    one dispatch per event) and ONE warm-started :func:`solve_streaming`
    re-equilibrate every lane the epoch dirtied.  Each flush's result is
    numerically equivalent to having re-solved after every single event
    (the last per-event solve of the epoch; see
    ``tests/test_coalescing.py``), so coalescing trades only *staleness
    between flushes* — never accuracy — for an ~K-fold cut in per-event
    solver dispatch (``benchmarks/streaming_perf.py --coalesce``).

    A trailing partial epoch is flushed after the stream ends, so
    consuming the generator always leaves the window clean and solved.

    Parameters
    ----------
    window : AdmissionWindow
        The live window; mutated at every flush.
    events : iterable of StreamEvent
        The event stream, in application order.  May be a lazy iterator —
        epochs are formed as events arrive.
    policy : FlushPolicy, optional
        Flush triggers (default: every 8 events; see
        :class:`~repro.core.streaming.FlushPolicy`).
    eps_bar, lam, max_iters, integer, sweep_fn, mesh, cross_check
        Forwarded to :func:`solve_streaming` verbatim (the mesh path keeps
        the frozen/dirty split sharded exactly as the per-event engine
        does).

    Yields
    ------
    StreamingResult
        One per flush, in stream order.
    """
    epoch = EventEpoch(window, policy=policy)
    kw = dict(eps_bar=eps_bar, lam=lam, max_iters=max_iters, integer=integer,
              sweep_fn=sweep_fn, mesh=mesh, cross_check=cross_check)
    for ev in events:
        if epoch.add(ev):
            yield epoch.flush(**kw)
    if len(epoch):
        yield epoch.flush(**kw)
