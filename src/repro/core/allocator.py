"""High-level allocator facade: solve + round, centralized or distributed.

Single-instance (`solve`) and batched (`solve_batch`) entry points share the
same pipeline: fractional GNEP solve (Algorithm 4.1) -> integer rounding
(Algorithm 4.2).  The batched path runs B scenarios as one XLA program and
one vectorized rounding pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import game
from repro.core.centralized import solve_centralized
from repro.core.rounding import (IntegerSolution, round_solution,
                                 round_solution_batch)
from repro.core.types import (Scenario, ScenarioBatch, Solution,
                              stack_scenarios)


@dataclass
class AllocationResult:
    method: str
    fractional: Solution
    integer: Optional[IntegerSolution]
    iters: int

    @property
    def r(self):
        return self.integer.r if self.integer is not None else self.fractional.r

    @property
    def total(self):
        return (self.integer.total if self.integer is not None
                else self.fractional.total)


def solve(scn: Scenario, method: str = "distributed", *, eps_bar: float = 0.03,
          lam: float = 0.05, max_iters: int = 200,
          integer: bool = True) -> AllocationResult:
    """Solve the joint admission-control + capacity-allocation problem.

    method: "centralized" (exact optimum of P2/P3) or "distributed"
    (Algorithm 4.1 GNEP best-reply) — both feed Algorithm 4.2 when
    ``integer=True``, mirroring the paper's experimental pipeline (Sec. 5).
    """
    if method == "centralized":
        sol = solve_centralized(scn)
    elif method == "distributed":
        sol = game.solve_distributed(scn, eps_bar=eps_bar, lam=lam,
                                     max_iters=max_iters)
    elif method == "distributed-python":
        sol, _, _ = game.solve_distributed_python(
            scn, eps_bar=eps_bar, lam=lam, max_iters=max_iters)
    else:
        raise ValueError(f"unknown method {method!r}")

    if not bool(sol.feasible):
        raise InfeasibleError(
            f"instance infeasible: sum(r_low)={float(jnp.sum(scn.r_low)):.1f} "
            f"> R={float(scn.R):.1f} or some E_i >= 0")

    integer_sol = (round_solution(scn, sol.r, sol.sM, sol.sR, sol.psi)
                   if integer else None)
    return AllocationResult(method=method, fractional=sol,
                            integer=integer_sol, iters=int(sol.iters))


class InfeasibleError(RuntimeError):
    """Deadlines/SLAs cannot be met with the available capacity."""


@dataclass
class BatchAllocationResult:
    """Result of one batched solve: every leaf carries a leading B dim.

    Per-class arrays are (B, n_max) with padded classes identically zero;
    ``instance(b)`` trims lane b back to a single-instance
    :class:`AllocationResult`.
    """
    method: str
    fractional: Solution                 # batched Solution
    integer: Optional[IntegerSolution]   # batched IntegerSolution
    mask: jnp.ndarray                    # (B, n_max)
    n_classes: jnp.ndarray               # (B,)
    iters: jnp.ndarray                   # (B,)
    feasible: jnp.ndarray                # (B,)

    @property
    def batch_size(self) -> int:
        return self.mask.shape[0]

    @property
    def r(self):
        return self.integer.r if self.integer is not None else self.fractional.r

    @property
    def total(self):
        return (self.integer.total if self.integer is not None
                else self.fractional.total)

    def instance(self, b: int) -> AllocationResult:
        n = int(self.n_classes[b])

        def pick(leaf):
            leaf = leaf[b]
            return leaf[:n] if getattr(leaf, "ndim", 0) else leaf

        frac = jax.tree_util.tree_map(pick, self.fractional)
        integ = (jax.tree_util.tree_map(pick, self.integer)
                 if self.integer is not None else None)
        return AllocationResult(method=self.method, fractional=frac,
                                integer=integ, iters=int(self.iters[b]))


def solve_batch(batch: Union[ScenarioBatch, Sequence[Scenario]],
                method: str = "distributed", *, eps_bar: float = 0.03,
                lam: float = 0.05, max_iters: int = 200, integer: bool = True,
                sweep_fn=None,
                check_feasible: bool = True) -> BatchAllocationResult:
    """Solve B independent allocation instances as one batched program.

    ``batch`` may be a prepared :class:`ScenarioBatch` or a plain list of
    (possibly ragged) Scenarios, which is stacked/padded here.  Only the
    distributed GNEP method is batched; Algorithm 4.2 rounding is applied
    lane-wise in one vmapped pass.  ``sweep_fn`` forwards a *batched* RM
    sweep (the Pallas kernel) to ``solve_distributed_batch``.

    With ``check_feasible=True`` (default) an :class:`InfeasibleError` names
    every infeasible lane; pass False to get per-lane ``feasible`` flags
    instead (what-if sweeps legitimately probe infeasible capacity points).
    """
    if not isinstance(batch, ScenarioBatch):
        batch = stack_scenarios(batch)
    if method != "distributed":
        raise ValueError(
            f"solve_batch supports method='distributed' only, got {method!r}")

    sol = game.solve_distributed_batch(batch, eps_bar=eps_bar, lam=lam,
                                       max_iters=max_iters, sweep_fn=sweep_fn)
    if check_feasible and not bool(jnp.all(sol.feasible)):
        bad = [int(b) for b in jnp.nonzero(~sol.feasible)[0]]
        raise InfeasibleError(f"instances {bad} infeasible: "
                              "sum(r_low) > R or some E_i >= 0")

    integer_sol = (round_solution_batch(batch, sol.r, sol.sM, sol.sR, sol.psi)
                   if integer else None)
    return BatchAllocationResult(method=method, fractional=sol,
                                 integer=integer_sol, mask=batch.mask,
                                 n_classes=batch.n_classes, iters=sol.iters,
                                 feasible=sol.feasible)
