"""Algorithm 4.2 — integer solution heuristic (paper Sec. 4.5).

The paper's pseudocode, vectorized exactly:

1. sort classes by increasing alpha;
2. r <- ceil(r_hat); one pass decrements each r_j (in sorted order) while
   sum(r) > R.  Prop. 4.2 guarantees a single pass suffices, hence exactly
   k = max(0, sum(ceil(r_hat)) - floor(R)) decrements happen: the first k
   classes in alpha-order.
3. s <- ceil(s_hat); per class, decrement s^R (then s^M if still violated)
   until s^M/c^M + s^R/c^R <= r.  Prop. 4.3 bounds this by
   omega_i + 1 <= min(c^M, c^R) + 1 iterations, so a fixed-bound fori_loop
   implements it exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Scenario, ScenarioBatch


class IntegerSolution(NamedTuple):
    r: jnp.ndarray
    sM: jnp.ndarray
    sR: jnp.ndarray
    h: jnp.ndarray      # integer admitted concurrency after rounding
    psi: jnp.ndarray
    cost: jnp.ndarray
    penalty: jnp.ndarray
    total: jnp.ndarray


def round_solution(scn: Scenario, r_hat, sM_hat, sR_hat, psi_hat=None,
                   max_slot_iters: int = 8, mask=None) -> IntegerSolution:
    """Vectorized Algorithm 4.2; returns an integer-feasible allocation.

    Per the paper (Sec. 4.5) the rounded solution is feasible w.r.t. all
    constraints *except* the approximate deadline formula (P4d): admission h
    is kept at the continuous optimum (rounded to the nearest integer in the
    SLA box), it is NOT re-tightened against the rounded slots.

    ``mask``: optional (N,) validity mask for padded batch lanes.  Padded
    classes keep r = sM = sR = h = 0, sort after every valid class in the
    alpha order (they can never absorb a capacity decrement), and contribute
    nothing to cost or penalty.
    """
    dt = r_hat.dtype
    valid = jnp.ones(r_hat.shape, bool) if mask is None else mask
    vf = valid.astype(dt)

    # ---- lines 1-7: capacity-feasible integer r -----------------------------
    r = jnp.ceil(r_hat) * vf
    overshoot = jnp.maximum(jnp.sum(r) - jnp.floor(scn.R), 0.0)
    alpha_eff = jnp.where(valid, scn.alpha, jnp.inf)
    order = jnp.argsort(alpha_eff)               # increasing alpha
    rank = jnp.argsort(order).astype(dt)         # rank[i] = position of i
    r = r - ((rank < overshoot) & valid).astype(dt)

    # ---- lines 8-17: slot rounding ------------------------------------------
    sM = jnp.ceil(sM_hat) * vf
    sR = jnp.ceil(sR_hat) * vf

    def body(_, sMsR):
        sM, sR = sMsR
        viol = (sM / scn.cM + sR / scn.cR > r) & valid
        sR = sR - viol.astype(dt)                          # line 12
        viol2 = sM / scn.cM + sR / scn.cR > r              # line 13
        sM = sM - (viol & viol2).astype(dt)                # line 14
        return sM, sR

    sM, sR = jax.lax.fori_loop(0, max_slot_iters, body, (sM, sR))
    sM = jnp.maximum(sM, 1.0) * vf
    sR = jnp.maximum(sR, 1.0) * vf

    # ---- integer admission ---------------------------------------------------
    # (P4d) is approximate and relaxed during rounding (paper Sec. 4.5):
    # round the continuous concurrency to the nearest integer in the SLA box.
    if psi_hat is None:
        r_safe = jnp.where(r_hat > 0, r_hat, 1.0)
        psi_hat = jnp.clip(scn.K / r_safe, scn.psi_low, scn.psi_up)
    h = jnp.clip(jnp.round(1.0 / psi_hat), scn.H_low, scn.H_up) * vf
    psi = jnp.where(valid, 1.0 / jnp.where(h > 0, h, 1.0), 1.0)

    cost = scn.rho_bar * jnp.sum(r)
    penalty = jnp.sum(jnp.where(valid, scn.alpha * psi - scn.beta, 0.0))
    return IntegerSolution(r=r, sM=sM, sR=sR, h=h, psi=psi, cost=cost,
                           penalty=penalty, total=cost + penalty)


def round_solution_batch(batch: ScenarioBatch, r_hat, sM_hat, sR_hat,
                         psi_hat=None,
                         max_slot_iters: int = 8) -> IntegerSolution:
    """Algorithm 4.2 vmapped over a ScenarioBatch (leaves gain a B dim)."""
    def one(scn, r, sM, sR, psi, m):
        return round_solution(scn, r, sM, sR, psi,
                              max_slot_iters=max_slot_iters, mask=m)

    if psi_hat is None:
        psi_hat = jnp.clip(batch.scenarios.K /
                           jnp.where(r_hat > 0, r_hat, 1.0),
                           batch.scenarios.psi_low, batch.scenarios.psi_up)
    return jax.vmap(one)(batch.scenarios, r_hat, sM_hat, sR_hat, psi_hat,
                         batch.mask)
