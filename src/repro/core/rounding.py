"""Algorithm 4.2 — integer solution heuristic (paper Sec. 4.5).

The paper's pseudocode, vectorized exactly:

1. sort classes by increasing alpha;
2. r <- ceil(r_hat); one pass decrements each r_j (in sorted order) while
   sum(r) > R.  Prop. 4.2 guarantees a single pass suffices, hence exactly
   k = max(0, sum(ceil(r_hat)) - floor(R)) decrements happen: the first k
   classes in alpha-order.
3. s <- ceil(s_hat); per class, decrement s^R (then s^M if still violated)
   until s^M/c^M + s^R/c^R <= r.  Prop. 4.3 bounds this by
   omega_i + 1 <= min(c^M, c^R) + 1 iterations, so a fixed-bound fori_loop
   implements it exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Scenario


class IntegerSolution(NamedTuple):
    r: jnp.ndarray
    sM: jnp.ndarray
    sR: jnp.ndarray
    h: jnp.ndarray      # integer admitted concurrency after rounding
    psi: jnp.ndarray
    cost: jnp.ndarray
    penalty: jnp.ndarray
    total: jnp.ndarray


def round_solution(scn: Scenario, r_hat, sM_hat, sR_hat, psi_hat=None,
                   max_slot_iters: int = 8) -> IntegerSolution:
    """Vectorized Algorithm 4.2; returns an integer-feasible allocation.

    Per the paper (Sec. 4.5) the rounded solution is feasible w.r.t. all
    constraints *except* the approximate deadline formula (P4d): admission h
    is kept at the continuous optimum (rounded to the nearest integer in the
    SLA box), it is NOT re-tightened against the rounded slots.
    """
    dt = r_hat.dtype

    # ---- lines 1-7: capacity-feasible integer r -----------------------------
    r = jnp.ceil(r_hat)
    overshoot = jnp.maximum(jnp.sum(r) - jnp.floor(scn.R), 0.0)
    order = jnp.argsort(scn.alpha)               # increasing alpha
    rank = jnp.argsort(order).astype(dt)         # rank[i] = position of i
    r = r - (rank < overshoot).astype(dt)

    # ---- lines 8-17: slot rounding ------------------------------------------
    sM = jnp.ceil(sM_hat)
    sR = jnp.ceil(sR_hat)

    def body(_, sMsR):
        sM, sR = sMsR
        viol = sM / scn.cM + sR / scn.cR > r
        sR = sR - viol.astype(dt)                          # line 12
        viol2 = sM / scn.cM + sR / scn.cR > r              # line 13
        sM = sM - (viol & viol2).astype(dt)                # line 14
        return sM, sR

    sM, sR = jax.lax.fori_loop(0, max_slot_iters, body, (sM, sR))
    sM = jnp.maximum(sM, 1.0)
    sR = jnp.maximum(sR, 1.0)

    # ---- integer admission ---------------------------------------------------
    # (P4d) is approximate and relaxed during rounding (paper Sec. 4.5):
    # round the continuous concurrency to the nearest integer in the SLA box.
    if psi_hat is None:
        psi_hat = jnp.clip(scn.K / r_hat, scn.psi_low, scn.psi_up)
    h = jnp.clip(jnp.round(1.0 / psi_hat), scn.H_low, scn.H_up)
    psi = 1.0 / h

    cost = scn.rho_bar * jnp.sum(r)
    penalty = jnp.sum(scn.alpha * psi - scn.beta)
    return IntegerSolution(r=r, sM=sM, sR=sR, h=h, psi=psi, cost=cost,
                           penalty=penalty, total=cost + penalty)
