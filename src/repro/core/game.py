"""Distributed game-theoretic formulation (paper Sec. 4).

Players: one Resource Manager (RM, problem P5) and N Class Managers (CMs,
problem P4).  Algorithm 4.1 iterates best replies until the relative
allocation change drops below ``eps_bar``.

Exact sub-solvers (DESIGN.md Sec. 3):

* **CM (P4)** — closed form, Prop. 4.1:  s^M = xi^M r, s^R = xi^R r,
  psi = clip(K / r, psi_low, psi_up).

* **RM (P5)** — mixed-integer in (r, y, rho), but for a *fixed* price rho the
  binary y_i = 1{rho_i^a >= rho} is forced by the big-M constraints and the
  remaining LP in r has all-positive objective coefficients
  ((rho - rho_bar) + p_i), so the optimum is the greedy knapsack: give every
  class its guaranteed r^low, then fill the slack R - sum(r^low) in
  p_i-descending order up to each class's price-dependent upper bound.
  The optimal price lies in the bid set {rho_i^a} (raising rho strictly
  increases revenue until it crosses a bid), so an exact sweep over the <= N+2
  candidate prices solves P5 to optimality.  The sweep is one (N_cand x N)
  masked prefix-sum — fully vectorized here and tiled in Pallas in
  ``repro.kernels.gnep_sweep``.

Both a jitted whole-game solver (`solve_distributed`) and a paper-faithful
serial loop (`solve_distributed_python`, one solve per CM per iteration — the
Fig. 7 baseline) are provided.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Scenario, ScenarioBatch, Solution

# --------------------------------------------------------------------------
# Resource Manager — problem (P5)
# --------------------------------------------------------------------------
#
# The exact sweep is split into candidates -> fill -> pick so the batched
# solver can route the O(Nc x N) fill of ALL instances through one Pallas
# kernel launch while the cheap prep/pick stages stay vmapped jnp.


def _rm_candidates(scn: Scenario, bids: jnp.ndarray, mask):
    """Candidate prices + greedy-order increments for the (P5) sweep.

    ``mask`` flags valid classes; padded classes bid rho_bar (a candidate that
    is always present anyway) and expose zero increment, so they are inert.
    """
    bids_eff = jnp.where(mask, bids, scn.rho_bar)
    p_eff = jnp.where(mask, scn.p, 0.0)
    # Candidate prices: all bids + the interval ends [rho_bar, rho_hat] (P5e).
    cand = jnp.concatenate([bids_eff, jnp.stack([scn.rho_bar, scn.rho_hat])])
    # y_i = 1 when CM i bids at least the price (free at equality; choosing 1
    # can only enlarge the feasible box, hence is optimal).
    y = (bids_eff[None, :] >= cand[:, None]) & mask[None, :]    # (Nc, N)

    # Greedy fill order: p descending (fixed across candidates).  Valid
    # classes keep their relative order (argsort is stable, padded p = 0).
    order = jnp.argsort(-p_eff)
    inc_max = jnp.where(mask, scn.r_up - scn.r_low, 0.0)[order]  # (N,)
    inc = jnp.where(y[:, order], inc_max[None, :], 0.0)          # (Nc, N)
    spare = scn.R - jnp.sum(jnp.where(mask, scn.r_low, 0.0))
    return cand, inc, spare, p_eff[order], order


def _rm_pick(scn: Scenario, cand, fill, sum_fill, p_fill, order, mask):
    """Choose the best candidate row and undo the greedy permutation."""
    p_eff = jnp.where(mask, scn.p, 0.0)
    r_low = jnp.where(mask, scn.r_low, 0.0)
    sum_r = jnp.sum(r_low) + sum_fill
    p_r = jnp.sum(p_eff * r_low) + p_fill
    obj = (cand - scn.rho_bar) * sum_r + p_r \
        - jnp.sum(p_eff * jnp.where(mask, scn.r_up, 0.0))

    best = jnp.argmax(obj)
    rho = cand[best]
    inv = jnp.argsort(order)
    r = r_low + (fill[best])[inv]
    return rho, r, obj[best]


def rm_solve(scn: Scenario, bids: jnp.ndarray, *, mask=None, sweep_fn=None):
    """Exact solution of the Resource Manager's problem (P5) given CM bids.

    Parameters
    ----------
    scn : Scenario
        The instance (uses r_low/r_up/p/R/rho_bar/rho_hat).
    bids : jnp.ndarray
        (N,) current CM bids rho_i^a, each in [rho_bar, rho_up_i] [cents].
    mask : jnp.ndarray, optional
        (N,) validity mask — padded classes (mask False) never receive
        capacity and never contribute a candidate price.
    sweep_fn : callable, optional
        Override of the candidate-sweep inner loop,
        ``sweep_fn(inc (Nc, N), spare (), p_sorted (N,)) -> (fill, sum_fill,
        p_fill)`` — the Pallas kernel plugs in here.

    Returns
    -------
    rho : jnp.ndarray
        Optimal unit price (a bid or an interval end of (P5e)) [cents].
    r : jnp.ndarray
        (N,) optimal allocation: guaranteed ``r_low`` plus the greedy
        p-descending fill of the slack up to each admitted class's ``r_up``.
    objective : jnp.ndarray
        The (P5) objective at (rho, r).
    """
    if mask is None:
        mask = jnp.ones(bids.shape, bool)
    cand, inc, spare, p_sorted, order = _rm_candidates(scn, bids, mask)

    if sweep_fn is None:
        cum = jnp.cumsum(inc, axis=1)
        fill = jnp.clip(spare - (cum - inc), 0.0, inc)          # (Nc, N)
        sum_fill = jnp.sum(fill, axis=1)
        p_fill = fill @ p_sorted
    else:
        fill, sum_fill, p_fill = sweep_fn(inc, spare, p_sorted)

    return _rm_pick(scn, cand, fill, sum_fill, p_fill, order, mask)


# --------------------------------------------------------------------------
# Class Managers — problem (P4), Prop. 4.1 closed form
# --------------------------------------------------------------------------


def cm_best_response(scn: Scenario, r: jnp.ndarray, *, mask=None):
    """Closed-form optimum of each CM's (P4) given its allocation (Prop 4.1).

    Parameters
    ----------
    scn : Scenario
        The instance (uses xiM/xiR/K and the psi box).
    r : jnp.ndarray
        (N,) chips granted by the RM to each class.
    mask : jnp.ndarray, optional
        (N,) validity mask; padded classes (r = 0) get psi = psi_low (never
        "rejecting") and zero slots instead of the 0-division garbage.

    Returns
    -------
    psi : jnp.ndarray
        (N,) inverse admitted concurrency, clipped to the SLA box
        [psi_low, psi_up] = [1/H_up, 1/H_low].
    sM, sR : jnp.ndarray
        (N,) map / reduce slots, the Prop. 4.1 split ``s = xi * r``.
    """
    if mask is None:
        sM = scn.xiM * r
        sR = scn.xiR * r
        psi = jnp.clip(scn.K / r, scn.psi_low, scn.psi_up)
        return psi, sM, sR
    r_safe = jnp.where(r > 0, r, 1.0)
    psi = jnp.clip(scn.K / r_safe, scn.psi_low, scn.psi_up)
    psi = jnp.where(mask, psi, scn.psi_low)
    sM = jnp.where(mask, scn.xiM * r, 0.0)
    sR = jnp.where(mask, scn.xiR * r, 0.0)
    return psi, sM, sR


def cm_bid_update(scn: Scenario, bids, rho, psi, lam: float, *, mask=None):
    """Alg. 4.1 lines 11-13: the bid escalation (pseudo-gradient) step.

    A CM still rejecting jobs (psi > psi_low) raises its bid by a fixed
    fraction of its budget, ``lam * rho_up``, from ``max(bid, rho)``,
    clipped to the (P4b) box [rho_bar, rho_up]; satisfied CMs keep theirs.

    Parameters
    ----------
    scn : Scenario
        The instance (uses psi_low, rho_up).
    bids : jnp.ndarray
        (N,) current bids rho_i^a [cents].
    rho : jnp.ndarray
        Scalar price the RM just posted.
    psi : jnp.ndarray
        (N,) each CM's best-response inverse concurrency.
    lam : float
        Escalation step (paper uses 0.05); larger converges faster but
        overshoots the equilibrium price further.
    mask : jnp.ndarray, optional
        (N,) validity mask; padded classes never escalate.

    Returns
    -------
    jnp.ndarray
        (N,) updated bids.
    """
    rejecting = psi > scn.psi_low * (1.0 + 1e-9)
    if mask is not None:
        rejecting = rejecting & mask
    raised = jnp.minimum(jnp.maximum(bids, rho) + lam * scn.rho_up, scn.rho_up)
    return jnp.where(rejecting, raised, bids)


# --------------------------------------------------------------------------
# Algorithm 4.1 — best reply (jitted, whole game as one XLA program)
# --------------------------------------------------------------------------


class GameState(NamedTuple):
    r: jnp.ndarray
    bids: jnp.ndarray
    rho: jnp.ndarray
    eps: jnp.ndarray
    it: jnp.ndarray


@partial(jax.jit, static_argnames=("max_iters",))
def solve_distributed(scn: Scenario, *, eps_bar: float = 0.03,
                      lam: float = 0.05, max_iters: int = 200) -> Solution:
    """Algorithm 4.1 (RM/CM best-reply) for one instance, as one XLA program.

    Parameters
    ----------
    scn : Scenario
        One allocation instance over N job classes.
    eps_bar : float, optional
        Stopping tolerance on the relative allocation change
        ``sum_i |r_i' - r_i| / r_i`` (paper uses 0.03).
    lam : float, optional
        Bid-escalation step of :func:`cm_bid_update`.
    max_iters : int, optional
        Iteration cap (static jit argument).

    Returns
    -------
    Solution
        The GNEP equilibrium: ``aux`` carries the final RM price rho,
        ``iters`` the best-reply iterations run.  ``feasible`` flags
        ``sum(r_low) <= R`` and all E_i < 0; the trajectory is still
        well-defined when False, but the equilibrium is meaningless.
    """
    feasible = (jnp.sum(scn.r_low) <= scn.R) & jnp.all(scn.E < 0)
    dt = scn.A.dtype

    def cond(s: GameState):
        return (s.eps >= eps_bar) & (s.it < max_iters)

    def body(s: GameState):
        rho, r_new, _ = rm_solve(scn, s.bids)
        psi, _, _ = cm_best_response(scn, r_new)
        bids = cm_bid_update(scn, s.bids, rho, psi, lam)
        eps = jnp.sum(jnp.abs(r_new - s.r) / s.r)
        return GameState(r_new, bids, rho, eps, s.it + 1)

    init = GameState(r=scn.r_low, bids=jnp.full_like(scn.r_low, scn.rho_bar),
                     rho=scn.rho_bar.astype(dt),
                     eps=jnp.asarray(jnp.inf, dt), it=jnp.asarray(0))
    final = jax.lax.while_loop(cond, body, init)

    psi, sM, sR = cm_best_response(scn, final.r)
    cost = scn.rho_bar * jnp.sum(final.r)
    penalty = jnp.sum(scn.alpha * psi - scn.beta)
    return Solution(r=final.r, psi=psi, sM=sM, sR=sR, cost=cost,
                    penalty=penalty, total=cost + penalty, feasible=feasible,
                    iters=final.it, aux=final.rho)


# --------------------------------------------------------------------------
# Batched Algorithm 4.1 — B scenarios as ONE vmapped while_loop XLA program
# --------------------------------------------------------------------------


class BatchGameState(NamedTuple):
    r: jnp.ndarray          # (B, n_max)
    bids: jnp.ndarray       # (B, n_max)
    rho: jnp.ndarray        # (B,)
    active: jnp.ndarray     # (B,) bool — lane still iterating
    lane_iters: jnp.ndarray  # (B,) per-instance iteration count
    it: jnp.ndarray         # global loop counter


class BatchWarmStart(NamedTuple):
    """Per-lane initial state for a warm-started ``solve_distributed_batch``.

    Lanes with ``active`` False are *frozen*: the while-loop never updates
    them, so their ``r`` / ``rho`` / ``lane_iters`` pass straight through to
    the returned :class:`Solution` — this is how the streaming engine carries
    an already-converged lane's equilibrium across re-solves for free.  Lanes
    with ``active`` True iterate Algorithm 4.1 from (``r``, ``bids``) exactly
    as the cold solver would from its own init.

    Attributes
    ----------
    r : jnp.ndarray
        (B, n_max) initial allocation (stored equilibrium for frozen lanes,
        masked ``r_low`` for lanes restarting cold).
    bids : jnp.ndarray
        (B, n_max) initial CM bids.  NOTE: to reproduce the cold Alg. 4.1
        trajectory (and hence its equilibrium) a re-iterating lane must start
        from the paper's init ``bids = rho_bar`` — bids only escalate during
        the game, so carrying converged bids over changes the equilibrium.
    rho : jnp.ndarray
        (B,) initial RM price (pass-through value for frozen lanes).
    lane_iters : jnp.ndarray
        (B,) int32 starting iteration counters (stored count for frozen
        lanes so ``Solution.iters`` stays meaningful, 0 for cold restarts).
    active : jnp.ndarray
        (B,) bool — True for lanes that should iterate.
    """
    r: jnp.ndarray
    bids: jnp.ndarray
    rho: jnp.ndarray
    lane_iters: jnp.ndarray
    active: jnp.ndarray


def cold_start(batch: ScenarioBatch) -> BatchWarmStart:
    """The cold Algorithm 4.1 init for every lane of ``batch``.

    Parameters
    ----------
    batch : ScenarioBatch
        Stacked instances; padded classes get r = 0 and a neutral bid.

    Returns
    -------
    BatchWarmStart
        ``r = r_low`` (masked), ``bids = rho_bar``, ``rho = rho_bar``,
        zero iteration counters, every lane active.  Passing this to
        ``solve_distributed_batch(init=...)`` is identical to ``init=None``.
    """
    scns, mask = batch.scenarios, batch.mask
    dt = scns.A.dtype
    r0 = jnp.where(mask, scns.r_low, 0.0)
    return BatchWarmStart(
        r=r0,
        bids=jnp.broadcast_to(scns.rho_bar[:, None], r0.shape).astype(dt),
        rho=scns.rho_bar.astype(dt),
        lane_iters=jnp.zeros((batch.batch_size,), jnp.int32),
        active=jnp.ones((batch.batch_size,), bool))


def _lane_eps(r_new, r_old, mask):
    """Alg. 4.1 convergence metric, restricted to valid classes."""
    rel = jnp.abs(r_new - r_old) / jnp.where(r_old > 0, r_old, 1.0)
    return jnp.sum(jnp.where(mask, rel, 0.0))


def _solve_batch_core(batch: ScenarioBatch, eps_bar, lam, max_iters,
                      sweep_fn, init: Optional[BatchWarmStart],
                      iter_fn=None) -> Solution:
    """Traceable body of the batched Algorithm 4.1 (see the public wrapper
    ``solve_distributed_batch`` for semantics).  Called directly — on the
    local lane slice — by the shard_map body in ``repro.core.sharding``."""
    scns, mask = batch.scenarios, batch.mask
    dt = scns.A.dtype

    feasible = jax.vmap(
        lambda s, m: (jnp.sum(jnp.where(m, s.r_low, 0.0)) <= s.R)
        & jnp.all(jnp.where(m, s.E < 0, True)))(scns, mask)

    if sweep_fn is None:
        def rm_batch(bids):
            return jax.vmap(lambda s, b, m: rm_solve(s, b, mask=m)
                            )(scns, bids, mask)
    else:
        # prep/pick stay vmapped; the O(B x Nc x N) fill is one batched call.
        def rm_batch(bids):
            cand, inc, spare, p_sorted, order = jax.vmap(_rm_candidates)(
                scns, bids, mask)
            fill, sum_fill, p_fill = sweep_fn(inc, spare, p_sorted)
            return jax.vmap(_rm_pick)(scns, cand, fill.astype(dt),
                                      sum_fill.astype(dt), p_fill.astype(dt),
                                      order, mask)

    if iter_fn is not None:
        # fused path: the iteration-invariant prep (greedy order, slack,
        # r_low aggregates) is hoisted out of the while_loop once; each
        # body evaluation is one fused step (repro.kernels.gnep_iter).
        prep = iter_fn.prepare(scns, mask)

        def iterate(s: BatchGameState):
            return iter_fn.step(prep, scns, mask, s.r, s.bids, lam)
    else:
        def iterate(s: BatchGameState):
            rho, r_new, _ = rm_batch(s.bids)
            psi, _, _ = jax.vmap(
                lambda scn, r, m: cm_best_response(scn, r, mask=m)
            )(scns, r_new, mask)
            bids_new = jax.vmap(
                lambda scn, b, rh, ps, m: cm_bid_update(scn, b, rh, ps, lam,
                                                        mask=m)
            )(scns, s.bids, rho, psi, mask)
            eps = jax.vmap(_lane_eps)(r_new, s.r, mask)
            return r_new, rho, bids_new, eps

    def cond(s: BatchGameState):
        return jnp.any(s.active) & (s.it < max_iters)

    def body(s: BatchGameState):
        r_new, rho, bids_new, eps = iterate(s)

        act = s.active
        keep = act[:, None]
        return BatchGameState(
            r=jnp.where(keep, r_new, s.r),
            bids=jnp.where(keep, bids_new, s.bids),
            rho=jnp.where(act, rho, s.rho),
            active=act & (eps >= eps_bar),
            lane_iters=s.lane_iters + act.astype(s.lane_iters.dtype),
            it=s.it + 1)

    if init is None:
        init = cold_start(batch)
    state0 = BatchGameState(
        r=init.r, bids=init.bids, rho=init.rho, active=init.active,
        lane_iters=init.lane_iters.astype(jnp.int32), it=jnp.asarray(0))
    final = jax.lax.while_loop(cond, body, state0)

    psi, sM, sR = jax.vmap(lambda scn, r, m: cm_best_response(scn, r, mask=m)
                           )(scns, final.r, mask)
    cost = scns.rho_bar * jnp.sum(final.r, axis=1)
    pen = jnp.sum(jnp.where(mask, scns.alpha * psi - scns.beta, 0.0), axis=1)
    return Solution(r=final.r, psi=psi, sM=sM, sR=sR, cost=cost,
                    penalty=pen, total=cost + pen, feasible=feasible,
                    iters=final.lane_iters, aux=final.rho)


@partial(jax.jit, static_argnames=("max_iters", "sweep_fn", "iter_fn"))
def _solve_batch_jit(batch: ScenarioBatch, *, eps_bar, lam, max_iters,
                     sweep_fn, init: Optional[BatchWarmStart],
                     iter_fn=None) -> Solution:
    """The single-program (unsharded) jit of ``_solve_batch_core``."""
    return _solve_batch_core(batch, eps_bar, lam, max_iters, sweep_fn, init,
                             iter_fn=iter_fn)


def solve_distributed_batch(batch: ScenarioBatch, *, eps_bar: float = 0.03,
                            lam: float = 0.05, max_iters: int = 200,
                            sweep_fn=None,
                            init: Optional[BatchWarmStart] = None,
                            mesh=None, iter_fn=None) -> Solution:
    """Algorithm 4.1 for B stacked scenarios as a single XLA program.

    One ``while_loop`` drives all lanes; converged lanes are frozen by
    masking (their state stops updating, their iteration counter stops) so
    every lane reproduces its single-instance ``solve_distributed`` trajectory
    bit-for-bit while the loop keeps running for the stragglers.  The loop
    exits when every lane has converged (per-instance early exit).

    Parameters
    ----------
    batch : ScenarioBatch
        B stacked (padded + masked) instances; see ``stack_scenarios``.
    eps_bar : float, optional
        Alg. 4.1 stopping tolerance on the per-lane relative allocation
        change ``sum_i |r_i' - r_i| / r_i`` (paper uses 0.03).
    lam : float, optional
        Bid-escalation (pseudo-gradient) step: a rejecting CM raises its bid
        by ``lam * rho_up`` per iteration (Alg. 4.1 line 12).
    max_iters : int, optional
        Global iteration cap (static: changing it recompiles).
    sweep_fn : callable, optional
        *Batched* RM sweep override taking ``(inc (B, Nc, N), spare (B,),
        p_sorted (B, N))`` — the batched Pallas kernel
        (``repro.kernels.gnep_sweep.ops.make_batched_sweep_fn``) plugs in
        here so the price sweep of all B scenarios is one kernel launch.
        Static jit argument: pass a memoized function object.
    init : BatchWarmStart, optional
        Warm start for the streaming engine: lanes with ``init.active``
        False are frozen at their stored equilibrium (zero iterations),
        active lanes iterate from ``init.r`` / ``init.bids``.  ``None``
        (default) is the cold Alg. 4.1 init for every lane (``cold_start``).
        This is the plumbing the event-coalesced epochs ride: however many
        events an ``EventEpoch`` folds, the flush arrives here as one init
        whose ``active`` set is the union of the dirtied lanes — and after
        an ``AdmissionWindow.compact()`` the window hands in the *remapped*
        stored equilibrium, so frozen lanes pass through bit-identically on
        the packed layout.
    mesh : jax.sharding.Mesh, optional
        1-D device mesh (see ``repro.core.sharding.lane_mesh``): lanes are
        padded to a multiple of the device count with inert lanes and each
        device iterates its own slice under ``shard_map`` — per-lane
        results match the unsharded path to <= 1e-6 (in practice
        bit-equal).  ``None`` (default) keeps the whole batch on one
        device.
    iter_fn : object, optional
        Fused-iteration override (``repro.kernels.gnep_iter.ops
        .make_fused_iter_fn``): an object with ``prepare(scns, mask)``
        and ``step(prep, scns, mask, r, bids, lam)`` whose prep is
        hoisted out of the while_loop and whose step runs one full
        Alg. 4.1 inner iteration (sweep + pick + psi + bid update + eps)
        as one fused region / kernel launch.  Mutually exclusive with
        ``sweep_fn`` in spirit — when both are given, ``iter_fn`` wins
        (the fused step subsumes the sweep).  Static jit argument: pass
        a memoized object.  ``None`` (default) keeps the unfused chain.

    Returns
    -------
    Solution
        Leaves carry a leading batch dim: r/psi/sM/sR are (B, n_max) with
        padded classes identically zero; cost, penalty, total, feasible,
        iters and aux (= final RM price rho) are (B,).
    """
    if mesh is not None:
        from repro.core.sharding import solve_sharded_batch
        return solve_sharded_batch(batch, mesh, eps_bar=eps_bar, lam=lam,
                                   max_iters=max_iters, sweep_fn=sweep_fn,
                                   init=init, iter_fn=iter_fn)
    return _solve_batch_jit(batch, eps_bar=eps_bar, lam=lam,
                            max_iters=max_iters, sweep_fn=sweep_fn, init=init,
                            iter_fn=iter_fn)


# --------------------------------------------------------------------------
# Paper-faithful serial implementation (Fig. 7 baseline)
# --------------------------------------------------------------------------


def _rm_solve_np(scn, bids):
    """Numpy RM solve (single price sweep), used by the serial baseline."""
    p = np.asarray(scn.p)
    r_low, r_up = np.asarray(scn.r_low), np.asarray(scn.r_up)
    R = float(scn.R)
    rho_bar = float(scn.rho_bar)
    cand = np.concatenate([bids, [rho_bar, float(scn.rho_hat)]])
    order = np.argsort(-p)
    spare = R - r_low.sum()
    best_obj, best_rho, best_r = -np.inf, rho_bar, r_low.copy()
    const = (p * r_up).sum()
    for c in cand:
        y = bids >= c
        inc = np.where(y[order], (r_up - r_low)[order], 0.0)
        cum = np.cumsum(inc)
        fill = np.clip(spare - (cum - inc), 0.0, inc)
        r_sorted = r_low[order] + fill
        obj = (c - rho_bar) * r_sorted.sum() + (p[order] * r_sorted).sum() - const
        if obj > best_obj:
            best_obj, best_rho = obj, c
            best_r = np.empty_like(r_sorted)
            best_r[order] = r_sorted
    return best_rho, best_r


def solve_distributed_python(scn: Scenario, *, eps_bar: float = 0.03,
                             lam: float = 0.05, max_iters: int = 200,
                             per_cm_callback: Optional[Callable] = None):
    """Algorithm 4.1 exactly as written: a Python ``repeat`` loop, the RM
    solve, then one (P4) solve *per CM* in a Python for-loop.

    This mirrors the paper's serial testbed (Sec. 5.3) whose per-CM timings
    are divided by N to estimate distributed wall-clock; used as the Fig. 7
    / §Perf baseline.

    Parameters
    ----------
    scn : Scenario
        One allocation instance.
    eps_bar, lam, max_iters
        As in :func:`solve_distributed`.
    per_cm_callback : callable, optional
        ``f(i, r_i, sM_i, sR_i, psi_i)`` invoked after each CM's (P4) solve
        (instrumentation hook for the timing experiments).

    Returns
    -------
    sol : Solution
        The equilibrium (same layout as :func:`solve_distributed`).
    n_iters : int
        Best-reply iterations run.
    cm_seconds : list of float
        Wall-clock seconds of the serial CM loop, one entry per iteration.
    """
    import time

    n = scn.n
    A = np.asarray(scn.A); B = np.asarray(scn.B); E = np.asarray(scn.E)
    cMv = np.asarray(scn.cM); cRv = np.asarray(scn.cR)
    K = np.asarray(scn.K); xiM = np.asarray(scn.xiM); xiR = np.asarray(scn.xiR)
    psi_low = np.asarray(scn.psi_low); psi_up = np.asarray(scn.psi_up)
    rho_up = np.asarray(scn.rho_up)
    rho_bar = float(scn.rho_bar)

    r = np.asarray(scn.r_low).copy()
    bids = np.full(n, rho_bar)
    psi = psi_up.copy()
    cm_seconds = []
    it = 0
    rho = rho_bar
    while it < max_iters:
        r_old = r.copy()
        rho, r = _rm_solve_np(scn, bids)
        t0 = time.perf_counter()
        for i in range(n):  # executed in parallel by real CMs (paper Sec. 4.4)
            # Prop. 4.1 closed form, one scalar class at a time
            sMi = xiM[i] * r[i]
            sRi = xiR[i] * r[i]
            psi_i = min(max(K[i] / r[i], psi_low[i]), psi_up[i])
            psi[i] = psi_i
            if psi_i > psi_low[i] * (1 + 1e-9):
                bids[i] = min(max(bids[i], rho) + lam * rho_up[i], rho_up[i])
            if per_cm_callback is not None:
                per_cm_callback(i, r[i], sMi, sRi, psi_i)
        cm_seconds.append(time.perf_counter() - t0)
        it += 1
        eps = float(np.sum(np.abs(r - r_old) / r_old))
        if eps < eps_bar:
            break

    sM = xiM * r
    sR = xiR * r
    cost = rho_bar * r.sum()
    penalty = float((np.asarray(scn.alpha) * psi - np.asarray(scn.beta)).sum())
    sol = Solution(
        r=jnp.asarray(r), psi=jnp.asarray(psi), sM=jnp.asarray(sM),
        sR=jnp.asarray(sR), cost=jnp.asarray(cost), penalty=jnp.asarray(penalty),
        total=jnp.asarray(cost + penalty),
        feasible=jnp.asarray(bool((np.asarray(scn.r_low).sum() <= float(scn.R))
                                  and np.all(E < 0))),
        iters=jnp.asarray(it), aux=jnp.asarray(rho))
    return sol, it, cm_seconds


def distributed_walltime_estimate(n_cms: int, iters: int,
                                  serial_cm_seconds: float,
                                  rm_seconds: float = 0.0,
                                  net_rtt_s: float = 1.3e-4) -> float:
    """Paper Sec. 5.3 timing model for true-distributed wall-clock.

    Parameters
    ----------
    n_cms : int
        Number of Class Managers (the CM solves run in parallel).
    iters : int
        Best-reply iterations of the run being estimated.
    serial_cm_seconds : float
        Total serial CM-loop seconds measured by
        :func:`solve_distributed_python`.
    rm_seconds : float, optional
        RM solve seconds (not divided — the RM is a single player).
    net_rtt_s : float, optional
        Per-iteration network round-trip (two floats each way; default from
        a 100 Mb/s LAN micro-benchmark, ~130 us).

    Returns
    -------
    float
        Estimated distributed wall-clock seconds:
        ``serial_cm_seconds / N + rm_seconds + iters * net_rtt_s``.
    """
    return serial_cm_seconds / max(n_cms, 1) + rm_seconds + iters * net_rtt_s
