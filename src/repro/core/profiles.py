"""Job-profile generation (paper Sec. 5.1, Tables 5/6) and roofline-fitted
profiles for TPU tenant classes (hardware adaptation, DESIGN.md Sec. 2).

The paper extracts ``A_i, B_i, C_i`` from Hadoop logs via [13]; the exact
aggregation is not reproduced in the text, so we use the ARIA-style form
(documented in DESIGN.md Sec. 6):

    A = n^M * M^avg                    (map-phase work, chip-seconds)
    B = n^R * (Sh^avg_typ + R^avg)     (shuffle+reduce-phase work)
    C = M^max + R^max + Sh^max_1 + Sh^max_typ   (constant tail)

with ``X^avg = 0.8 X^max`` exactly as in Table 6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import Scenario, derive
from repro.utils import fdtype


def _u(key, lo, hi, shape=(), dtype=None):
    dtype = dtype or fdtype()
    return jax.random.uniform(key, shape, dtype=dtype, minval=lo, maxval=hi)


def _ui(key, lo, hi, shape=()):  # inclusive integer uniform
    return jax.random.randint(key, shape, lo, hi + 1)


def _table5_raw(ks, shape, deadline_scale, dt) -> dict:
    """The paper's Table 5/6 class-parameter design, drawn once.

    Single source of the distributions shared by :func:`sample_scenario`
    (vector draws) and :func:`sample_class_params` (one class): editing a
    range here keeps runtime arrivals statistically identical to
    construction-time classes.

    Parameters
    ----------
    ks : sequence of jax.random.PRNGKey
        Exactly 11 draw keys (one per Table 5 quantity, in fixed order).
    shape : tuple
        ``(n,)`` for a whole instance, ``()`` for one class.
    deadline_scale : float
        Multiplies the deadline D (< 1 tightens, paper Sec. 5.2.2).
    dt : jnp.dtype
        Float dtype of the produced arrays.

    Returns
    -------
    dict
        The :data:`repro.core.types.RAW_CLASS_FIELDS` arrays of ``shape``.
    """
    rho_up = _u(ks[0], 5.0, 20.0, shape)                  # [cents]
    H_up = _ui(ks[1], 5, 20, shape).astype(dt)
    cM = _ui(ks[2], 1, 4, shape).astype(dt)
    cR = _ui(ks[3], 1, 4, shape).astype(dt)
    m = _u(ks[4], 15000.0, 30000.0, shape)                # [cents]
    nM = _ui(ks[5], 70, 1120, shape).astype(dt)
    nR = jnp.full(shape, 64.0, dt)
    M_max = _u(ks[6], 16.0, 120.0, shape)                 # [s]
    R_max = _u(ks[7], 15.0, 75.0, shape)
    Sh1_max = _u(ks[8], 10.0, 30.0, shape)
    Shtyp_max = _u(ks[9], 30.0, 150.0, shape)
    D = _u(ks[10], 900.0, 1500.0, shape) * deadline_scale  # [s]

    # Table 6 derivations (X^avg = 0.8 X^max)
    M_avg, R_avg, Shtyp_avg = 0.8 * M_max, 0.8 * R_max, 0.8 * Shtyp_max
    H_low = jnp.maximum(jnp.floor(0.8 * H_up), 1.0)
    A = nM * M_avg
    B = nR * (Shtyp_avg + R_avg)
    C = M_max + R_max + Sh1_max + Shtyp_max
    return {"A": A, "B": B, "E": C - D, "cM": cM, "cR": cR, "H_up": H_up,
            "H_low": H_low, "m": m, "rho_up": rho_up}


def sample_scenario(key, n_classes: int, *, capacity_factor: float = 1.1,
                    capacity=None, deadline_scale: float = 1.0) -> Scenario:
    """Random instance per the paper's design of experiments (Table 5).

    ``capacity_factor``: R = factor * R^o with R^o = sum(r_up) (Sec. 5.2.1).
    ``deadline_scale``: multiplies D_i (Sec. 5.2.2 uses < 1 to tighten).
    ``capacity``: overrides R directly when given.
    """
    dt = fdtype()
    ks = jax.random.split(key, 16)
    raw = _table5_raw(ks[:11], (n_classes,), deadline_scale, dt)

    # cost model, Eq. 15 (v=2 fixed; one draw per cluster)
    v = 2.0
    d = _u(ks[11], 3.0, 5.0)
    pue = _u(ks[12], 1.2, 2.2)
    energy = _u(ks[13], 0.06009, 0.06690)
    srv = 2.0615
    rho_bar = (pue * energy + srv) * v / d

    scn = derive(**raw, R=jnp.asarray(0.0, dt), rho_bar=rho_bar)
    if capacity is None:
        capacity = capacity_factor * jnp.sum(scn.r_up)
    return scn.replace(R=jnp.asarray(capacity, dt))


def sample_class_params(key, *, deadline_scale: float = 1.0) -> dict:
    """Raw parameters of ONE job class per the paper's Table 5/6 design.

    The streaming admission engine's arrival events carry exactly this dict
    (see :class:`repro.core.types.ClassArrival`); distributions match
    :func:`sample_scenario` so a class admitted at runtime is statistically
    identical to one present at window construction.

    Parameters
    ----------
    key : jax.random.PRNGKey
        Draw key.
    deadline_scale : float, optional
        Multiplies the deadline D_i (< 1 tightens, paper Sec. 5.2.2).

    Returns
    -------
    dict
        ``{A, B, E, cM, cR, H_up, H_low, m, rho_up}`` as python floats —
        the :data:`repro.core.types.RAW_CLASS_FIELDS` of one class
        (E = C - D is always negative under Table 5 ranges).
    """
    ks = jax.random.split(key, 11)
    raw = _table5_raw(ks, (), deadline_scale, fdtype())
    return {k: float(v) for k, v in raw.items()}


def from_roofline(compute_s, collective_s, overhead_s, deadline_s, *,
                  chips_ref: float, H_up, H_low, m, rho_up, R,
                  rho_bar: float = 1.0) -> Scenario:
    """Fit paper job profiles from dry-run roofline terms (TPU adaptation).

    A tenant job profiled at ``chips_ref`` chips spends ``compute_s`` seconds in
    math (the "map wave"), ``collective_s`` seconds in collectives (the
    "reduce wave") and ``overhead_s`` fixed time per SLA window.  Both wave
    terms scale ~1/chips, exactly the paper's ``A h / s`` form with h=1 job:

        T(r) = A / sM + B / sR + C,  sM = sR = r  (cM = cR = 1 slot/chip).
    """
    dt = fdtype()
    A = jnp.asarray(compute_s, dt) * chips_ref
    B = jnp.asarray(collective_s, dt) * chips_ref
    C = jnp.asarray(overhead_s, dt)
    E = C - jnp.asarray(deadline_s, dt)
    ones = jnp.ones_like(A)
    return derive(A, B, E, ones, ones, jnp.asarray(H_up, dt),
                  jnp.asarray(H_low, dt), jnp.asarray(m, dt),
                  jnp.asarray(rho_up, dt), R=jnp.asarray(R, dt),
                  rho_bar=jnp.asarray(rho_bar, dt))
