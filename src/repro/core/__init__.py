"""The paper's contribution: GNEP-based runtime capacity allocation."""
from repro.core.allocator import AllocationResult, InfeasibleError, solve
from repro.core.centralized import kkt_residual, objective_of_r, solve_centralized
from repro.core.game import (cm_best_response, distributed_walltime_estimate,
                             rm_solve, solve_distributed,
                             solve_distributed_python)
from repro.core.profiles import from_roofline, sample_scenario
from repro.core.rounding import IntegerSolution, round_solution
from repro.core.types import Scenario, Solution, deadline_lhs, derive, objective

__all__ = [
    "AllocationResult", "InfeasibleError", "IntegerSolution", "Scenario",
    "Solution", "cm_best_response", "deadline_lhs", "derive",
    "distributed_walltime_estimate", "from_roofline", "kkt_residual",
    "objective", "objective_of_r", "rm_solve", "round_solution",
    "sample_scenario", "solve", "solve_centralized", "solve_distributed",
    "solve_distributed_python",
]
