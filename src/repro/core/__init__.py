"""The paper's contribution: GNEP-based runtime capacity allocation."""
from repro.core.allocator import (AllocationResult, BatchAllocationResult,
                                  InfeasibleError, solve, solve_batch)
from repro.core.centralized import kkt_residual, objective_of_r, solve_centralized
from repro.core.game import (cm_best_response, cm_bid_update,
                             distributed_walltime_estimate, rm_solve,
                             solve_distributed, solve_distributed_batch,
                             solve_distributed_python)
from repro.core.profiles import from_roofline, sample_scenario
from repro.core.rounding import (IntegerSolution, round_solution,
                                 round_solution_batch)
from repro.core.types import (Scenario, ScenarioBatch, Solution, deadline_lhs,
                              derive, objective, pad_scenario, stack_scenarios)

__all__ = [
    "AllocationResult", "BatchAllocationResult", "InfeasibleError",
    "IntegerSolution", "Scenario", "ScenarioBatch", "Solution",
    "cm_best_response", "cm_bid_update", "deadline_lhs", "derive",
    "distributed_walltime_estimate", "from_roofline", "kkt_residual",
    "objective", "objective_of_r", "pad_scenario", "rm_solve",
    "round_solution", "round_solution_batch", "sample_scenario", "solve",
    "solve_batch", "solve_centralized", "solve_distributed",
    "solve_distributed_batch", "solve_distributed_python", "stack_scenarios",
]
