"""The paper's contribution: GNEP-based runtime capacity allocation.

The documented entry point is the session API in :mod:`repro.core.engine`
(:class:`CapacityEngine` / :class:`WindowSession` + :class:`SolverConfig` /
:class:`Policies`); the ``solve_*`` facades from earlier revisions remain as
deprecated bit-equal shims (see ``docs/API.md`` for the migration table).
"""
from repro.core.allocator import (AllocationResult, BatchAllocationResult,
                                  StreamingResult, solve, solve_batch,
                                  solve_coalesced, solve_streaming)
from repro.core.centralized import (kkt_residual, objective_of_r,
                                    solve_centralized, solve_centralized_batch)
from repro.core.engine import (BatchSolveReport, CapacityEngine,
                               CompactionPolicy, CrossCheckPolicy,
                               InfeasibleError, Policies, QuotaExceededError,
                               RoundingPolicy, SolveReport, SolverConfig,
                               TenantQuota, WindowSession, WindowSolveReport)
from repro.core.game import (BatchWarmStart, cm_best_response, cm_bid_update,
                             cold_start, distributed_walltime_estimate,
                             rm_solve, solve_distributed,
                             solve_distributed_batch, solve_distributed_python)
from repro.core.planning import (Candidate, PlanReport, PlanSpec, VMTier,
                                 generate_grid, solve_plan)
from repro.core.profiles import (from_roofline, sample_class_params,
                                 sample_scenario)
from repro.core.rounding import (IntegerSolution, round_solution,
                                 round_solution_batch)
from repro.core.sharding import (LANE_AXIS, lane_mesh, lane_sharding,
                                 pad_batch_lanes, pad_warm_start,
                                 padded_lane_count, shard_batch,
                                 solve_sharded_batch)
from repro.core.streaming import (AdmissionWindow, EventEpoch, FlushPolicy,
                                  grown_n_max, replay, sample_event_trace)
from repro.core.traces import (ARRIVAL_PROFILES, bursty_times, diurnal_times,
                               flash_crowd_times, poisson_times,
                               straggler_times)
from repro.core.types import (CapacityChange, ClassArrival, ClassDeparture,
                              RAW_CLASS_FIELDS, Scenario, ScenarioBatch,
                              SLAEdit, Solution, StreamEvent, WindowState,
                              deadline_lhs, derive, neutral_class_values,
                              objective, pad_scenario, stack_scenarios)

__all__ = [
    "ARRIVAL_PROFILES",
    "AdmissionWindow", "AllocationResult", "BatchAllocationResult",
    "BatchSolveReport", "BatchWarmStart", "Candidate", "CapacityChange",
    "CapacityEngine",
    "ClassArrival", "ClassDeparture", "CompactionPolicy", "CrossCheckPolicy",
    "EventEpoch", "FlushPolicy", "InfeasibleError", "IntegerSolution",
    "PlanReport", "PlanSpec",
    "Policies", "QuotaExceededError", "RAW_CLASS_FIELDS", "RoundingPolicy",
    "SLAEdit", "TenantQuota", "VMTier",
    "Scenario", "ScenarioBatch", "Solution", "SolveReport", "SolverConfig",
    "StreamEvent", "StreamingResult", "WindowSession", "WindowSolveReport",
    "WindowState", "LANE_AXIS", "bursty_times", "cm_best_response",
    "cm_bid_update",
    "cold_start", "deadline_lhs", "derive", "distributed_walltime_estimate",
    "diurnal_times", "flash_crowd_times",
    "from_roofline", "generate_grid", "grown_n_max", "kkt_residual",
    "lane_mesh", "lane_sharding",
    "neutral_class_values", "objective", "objective_of_r", "pad_batch_lanes",
    "pad_scenario", "pad_warm_start", "padded_lane_count", "poisson_times",
    "replay",
    "rm_solve", "round_solution", "round_solution_batch", "shard_batch",
    "sample_class_params", "sample_event_trace", "sample_scenario",
    "solve", "solve_batch", "solve_coalesced", "solve_plan",
    "solve_centralized", "solve_centralized_batch", "solve_distributed",
    "solve_distributed_batch", "solve_distributed_python",
    "solve_sharded_batch", "solve_streaming", "stack_scenarios",
    "straggler_times",
]
