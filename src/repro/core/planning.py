"""Fleet-scale capacity planner: design-space exploration over what-if batches.

The paper's allocator answers "how many chips does each class get *right
now*"; this module builds the system D-SPACE4Cloud (PAPERS.md) shows on top
of exactly such an allocator — a design-tool loop that sweeps cluster size /
VM tier / deadline tightness / penalty scaling and returns the cheapest
feasible design:

* :class:`PlanSpec` declares the fleet design space (axes) plus the workload
  it is sized for — one of the shared trace profiles of
  :mod:`repro.core.traces`, so what-if planning and the always-on admission
  daemon are driven by the same workloads;
* :func:`generate_grid` expands the spec into a deterministic, seeded list
  of :class:`Candidate` design points, each carrying a fully derived
  :class:`~repro.core.types.Scenario` (the deadline axis is the innermost
  grid dimension, so adjacent candidates differ only in deadline tightness);
* :func:`solve_plan` packs candidates into fixed-width, inert-lane-padded
  :class:`~repro.core.types.ScenarioBatch` chunks and pushes them through
  the existing :class:`~repro.core.engine.CapacityEngine` batch path
  (mesh-sharded when the config carries one).  Lanes are independent and
  padding is solver-inert, so the chunked results are **bit-equal** to one
  direct ``CapacityEngine.solve`` over all candidates
  (``tests/test_planning.py`` proves it, sharded and unsharded).  An
  opt-in warm-start mode seeds each deadline step's allocation from the
  previous step's equilibrium (bids restart cold, so the Alg. 4.1 iterate
  trajectory is preserved and only the stopping time can differ);
* :class:`PlanReport` reduces the per-candidate solutions into the paper's
  objective decomposition (power cost vs rejection penalty, per-lane
  feasibility = "deadline attainable under this design"), with Pareto
  frontier extraction and a cheapest-feasible-design query.

CLI: ``python -m repro.launch.plan``; benchmark: ``benchmarks/plan_perf.py``
(candidates/sec, gated by ``scripts/check_bench.py``); operator guide:
``docs/OPERATIONS.md`` "Capacity planning".
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game, sharding
from repro.core.engine import (CapacityEngine, Policies, RoundingPolicy,
                               SolverConfig, _cast_floats)
from repro.core.profiles import sample_class_params
from repro.core.traces import ARRIVAL_PROFILES
from repro.core.types import Scenario, ScenarioBatch, derive, stack_scenarios
from repro.utils import fdtype


@dataclass(frozen=True)
class VMTier:
    """One VM/chip SKU the planner may build the cluster from.

    Attributes
    ----------
    name : str
        SKU label (appears in candidate coordinates and reports).
    slots : float
        Slot multiplier over the workload's per-VM baseline: candidate
        scenarios scale their per-class ``cM`` / ``cR`` by it (a
        ``slots=2`` tier packs twice the map and reduce slots per VM).
    price : float
        Unit-time cost of one VM of this tier [cents] — the candidate's
        ``rho_bar``, so tier choice trades power cost against the smaller
        per-job chip share ``K`` that more slots buy.
    """
    name: str
    slots: float
    price: float


@dataclass(frozen=True)
class PlanSpec:
    """A fleet design space plus the workload it is sized for.

    The four axes (``cluster_sizes`` x ``vm_tiers`` x ``penalty_scales`` x
    ``deadline_scales``) expand into ``len(cluster_sizes) * len(vm_tiers) *
    len(penalty_scales) * len(deadline_scales)`` candidates;
    :func:`generate_grid` orders them with the deadline axis innermost.
    The workload half (``profile`` / ``rate`` / ``trace_events`` /
    ``n_classes``) shapes the per-class demand mix: a trace from
    :data:`repro.core.traces.ARRIVAL_PROFILES` is histogrammed into
    ``n_classes`` equal time windows and the per-window load modulates each
    class's SLA concurrency, so a bursty workload is planned against a
    skewed demand mix while a steady one is planned against a flat mix.

    Attributes
    ----------
    n_classes : int
        Job classes per candidate scenario (base parameters follow the
        paper's Table 5/6 design via
        :func:`repro.core.profiles.sample_class_params`).
    profile : str
        Workload-trace profile name (a :data:`ARRIVAL_PROFILES` key).
    rate : float
        Mean arrival rate [events/s] of the sizing trace.
    trace_events : int
        Events in the sizing trace (more events -> smoother demand mix).
    cluster_sizes : tuple of float
        Candidate cluster capacities R (number of VMs/chips).
    vm_tiers : tuple of VMTier
        Candidate VM SKUs (slot multiplier + unit price).
    deadline_scales : tuple of float
        Deadline-tightness multipliers on D_i (< 1 tightens, paper
        Sec. 5.2.2); the innermost grid axis, which is what the
        warm-start mode exploits.
    penalty_scales : tuple of float
        Multipliers on the per-class rejection penalty ``m``.
    seed : int
        Seed for both the class-parameter draws and the sizing trace; the
        grid is a pure function of the spec (same spec -> bit-identical
        candidates).
    """
    n_classes: int = 4
    profile: str = "poisson"
    rate: float = 50.0
    trace_events: int = 512
    cluster_sizes: Tuple[float, ...] = (1500.0, 3000.0, 6000.0)
    vm_tiers: Tuple[VMTier, ...] = (VMTier("small", 1.0, 6.0),
                                    VMTier("large", 2.0, 10.0))
    deadline_scales: Tuple[float, ...] = (0.8, 1.0, 1.2)
    penalty_scales: Tuple[float, ...] = (1.0,)
    seed: int = 0

    @property
    def grid_shape(self) -> Tuple[int, int, int, int]:
        """Axis lengths in grid order: (clusters, tiers, penalties,
        deadlines)."""
        return (len(self.cluster_sizes), len(self.vm_tiers),
                len(self.penalty_scales), len(self.deadline_scales))

    @property
    def n_candidates(self) -> int:
        """Total design points the spec expands into."""
        n = 1
        for axis in self.grid_shape:
            n *= axis
        return n


@dataclass(frozen=True)
class Candidate:
    """One design point of an expanded :class:`PlanSpec` grid.

    Attributes
    ----------
    index : int
        Position in the grid's candidate order (deadline axis innermost).
    coords : dict
        The design coordinates that produced the scenario:
        ``cluster_size``, ``tier`` (name), ``penalty_scale``,
        ``deadline_scale``.
    scenario : repro.core.types.Scenario
        The fully derived allocation instance for this design point.
    """
    index: int
    coords: Dict[str, object]
    scenario: Scenario


def _trace_weights(spec: PlanSpec) -> np.ndarray:
    """Per-class demand weights from the spec's sizing trace.

    The trace is histogrammed into ``n_classes`` equal time windows; each
    window's share of the events, normalized to mean 1 and floored at 0.25
    (a quiet window still hosts a real class), becomes its class's demand
    weight.  A steady profile yields a flat mix, a bursty one a skewed mix.

    Parameters
    ----------
    spec : PlanSpec
        Supplies profile, seed, trace_events, rate and n_classes.

    Returns
    -------
    numpy.ndarray
        (n_classes,) float weights, mean ~1, min 0.25.
    """
    times = ARRIVAL_PROFILES[spec.profile](spec.seed, spec.trace_events,
                                           spec.rate)
    edges = np.linspace(0.0, float(times[-1]), spec.n_classes + 1)
    counts, _ = np.histogram(np.asarray(times), bins=edges)
    mean = max(float(counts.mean()), 1e-12)
    return np.maximum(counts / mean, 0.25)


def generate_grid(spec: PlanSpec) -> List[Candidate]:
    """Expand ``spec`` into its deterministic candidate list.

    Base class parameters follow the paper's Table 5/6 design, drawn once
    per (class, deadline_scale) with a per-class fold of ``spec.seed`` —
    the SAME key at every deadline scale, so two candidates differing only
    in ``deadline_scale`` share every draw and differ only through the
    scaled deadline (this is what makes warm-starting along the deadline
    axis meaningful).  The sizing trace's demand weights modulate each
    class's SLA concurrency (``H_up``, with ``H_low = max(floor(0.8 *
    H_up), 1)`` per Table 6); the tier scales ``cM`` / ``cR`` by its slot
    count and prices the candidate's ``rho_bar``; the penalty scale
    multiplies ``m``.

    Candidate order: ``cluster_sizes`` (outermost) x ``vm_tiers`` x
    ``penalty_scales`` x ``deadline_scales`` (innermost), so
    ``index = (((ci * T) + ti) * P + pi) * D + di``.

    Parameters
    ----------
    spec : PlanSpec
        The design space; any empty axis yields an empty grid.

    Returns
    -------
    list of Candidate
        ``spec.n_candidates`` design points with derived scenarios.

    Raises
    ------
    ValueError
        Unknown ``spec.profile``, or non-positive ``n_classes`` /
        ``trace_events``.
    """
    if spec.profile not in ARRIVAL_PROFILES:
        raise ValueError(f"unknown profile {spec.profile!r} — expected one "
                         f"of {sorted(ARRIVAL_PROFILES)}")
    if spec.n_classes < 1:
        raise ValueError(f"n_classes={spec.n_classes} must be >= 1")
    if spec.trace_events < 1:
        raise ValueError(f"trace_events={spec.trace_events} must be >= 1")
    if spec.n_candidates == 0:
        return []

    dt = fdtype()
    w = _trace_weights(spec)
    key = jax.random.PRNGKey(spec.seed)
    # one draw per (deadline scale, class); the same fold at every scale
    # keeps the cross-scale draws identical (only D scales)
    base = {
        d: [sample_class_params(jax.random.fold_in(key, i),
                                deadline_scale=float(d))
            for i in range(spec.n_classes)]
        for d in spec.deadline_scales
    }

    candidates: List[Candidate] = []
    idx = 0
    for R in spec.cluster_sizes:
        for tier in spec.vm_tiers:
            for pen in spec.penalty_scales:
                for d in spec.deadline_scales:
                    cols = base[d]
                    H_up = np.asarray(
                        [max(round(p["H_up"] * w[i]), 1.0)
                         for i, p in enumerate(cols)], dt)
                    H_low = np.maximum(np.floor(0.8 * H_up), 1.0)
                    scn = derive(
                        A=np.asarray([p["A"] for p in cols], dt),
                        B=np.asarray([p["B"] for p in cols], dt),
                        E=np.asarray([p["E"] for p in cols], dt),
                        cM=np.asarray([p["cM"] * tier.slots for p in cols],
                                      dt),
                        cR=np.asarray([p["cR"] * tier.slots for p in cols],
                                      dt),
                        H_up=H_up, H_low=H_low,
                        m=np.asarray([p["m"] * pen for p in cols], dt),
                        rho_up=np.asarray([p["rho_up"] for p in cols], dt),
                        R=float(R), rho_bar=float(tier.price))
                    coords = {"cluster_size": float(R), "tier": tier.name,
                              "penalty_scale": float(pen),
                              "deadline_scale": float(d)}
                    candidates.append(Candidate(idx, coords, scn))
                    idx += 1
    return candidates


@dataclass
class PlanReport:
    """Per-candidate solutions of a plan solve, plus frontier queries.

    Every array is host-side numpy with one row per candidate, in grid
    order.  ``cost`` / ``penalty`` / ``total`` are the paper's objective
    decomposition (P2a: power cost ``rho_bar * sum r`` + rejection penalty
    ``sum alpha * psi - beta``); ``feasible`` is the per-design
    deadline-attainability flag (``sum(r_low) <= R`` and all ``E_i < 0``)
    — an infeasible design point is a legitimate probe result, not an
    error.

    Attributes
    ----------
    candidates : list of Candidate
        The solved design points (grid order).
    cost : numpy.ndarray
        (B,) power cost per candidate.
    penalty : numpy.ndarray
        (B,) rejection penalty per candidate.
    total : numpy.ndarray
        (B,) objective total (cost + penalty).
    r : numpy.ndarray
        (B, n_max) equilibrium chip allocation per candidate.
    iters : numpy.ndarray
        (B,) Algorithm 4.1 iterations per candidate.
    feasible : numpy.ndarray
        (B,) bool deadline-attainability per candidate.
    config : SolverConfig
        The solver config the plan ran under.
    chunk : int
        Lane width candidates were packed into.
    n_chunks : int
        Solve dispatches the plan took.
    warm_start : bool
        Whether the deadline-axis warm-start mode ran.
    elapsed_s : float
        Host wall-clock of the whole plan solve.
    """
    candidates: List[Candidate]
    cost: np.ndarray
    penalty: np.ndarray
    total: np.ndarray
    r: np.ndarray
    iters: np.ndarray
    feasible: np.ndarray
    config: SolverConfig
    chunk: int
    n_chunks: int
    warm_start: bool
    elapsed_s: float = 0.0

    @property
    def n_candidates(self) -> int:
        """Number of solved design points."""
        return len(self.candidates)

    def pareto_frontier(self) -> np.ndarray:
        """Indices of the feasible (cost, penalty) Pareto frontier.

        A feasible candidate is on the frontier iff no other feasible
        candidate weakly dominates it (cost <= and penalty <=, one
        strictly); of exact (cost, penalty) duplicates only the lowest
        index survives.  The sweep sorts by (cost, penalty, index) and
        keeps strict penalty improvements, so the returned indices have
        strictly increasing cost and strictly decreasing penalty.

        Returns
        -------
        numpy.ndarray
            Frontier candidate indices, sorted by increasing cost; empty
            when no candidate is feasible.
        """
        feas = np.flatnonzero(self.feasible)
        if feas.size == 0:
            return np.empty(0, dtype=int)
        order = feas[np.lexsort((feas, self.penalty[feas], self.cost[feas]))]
        front: List[int] = []
        best_pen = np.inf
        for i in order:
            if self.penalty[i] < best_pen:
                front.append(int(i))
                best_pen = self.penalty[i]
        return np.asarray(front, dtype=int)

    def cheapest_feasible(self, max_penalty: Optional[float] = None
                          ) -> Optional[int]:
        """The D-SPACE4Cloud query: cheapest design meeting every deadline.

        Parameters
        ----------
        max_penalty : float, optional
            Also require the candidate's rejection penalty to stay at or
            under this budget; ``None`` places no penalty constraint.

        Returns
        -------
        int or None
            Index of the minimum-cost feasible candidate (ties broken by
            lower penalty, then lower index); ``None`` when nothing in the
            space qualifies.
        """
        ok = self.feasible.astype(bool).copy()
        if max_penalty is not None:
            ok &= self.penalty <= max_penalty
        idx = np.flatnonzero(ok)
        if idx.size == 0:
            return None
        order = np.lexsort((idx, self.penalty[idx], self.cost[idx]))
        return int(idx[order[0]])

    def point(self, i: int) -> Dict[str, object]:
        """One candidate's coordinates + solved metrics as a flat dict.

        Parameters
        ----------
        i : int
            Candidate index.

        Returns
        -------
        dict
            ``index``, the design ``coords``, and ``cost`` / ``penalty`` /
            ``total`` / ``feasible`` / ``iters``.
        """
        return {"index": int(i), **self.candidates[i].coords,
                "cost": float(self.cost[i]),
                "penalty": float(self.penalty[i]),
                "total": float(self.total[i]),
                "feasible": bool(self.feasible[i]),
                "iters": int(self.iters[i])}

    def to_json(self) -> Dict[str, object]:
        """JSON-serializable summary (the ``--json`` payload of the CLI).

        Returns
        -------
        dict
            Candidate/feasible counts, solver provenance, the frontier
            points and the cheapest feasible design (``None`` when the
            space holds no feasible point).
        """
        cheapest = self.cheapest_feasible()
        return {
            "n_candidates": self.n_candidates,
            "n_feasible": int(np.count_nonzero(self.feasible)),
            "chunk": self.chunk, "n_chunks": self.n_chunks,
            "warm_start": self.warm_start,
            "elapsed_s": self.elapsed_s,
            "solver_config": self.config.fingerprint(),
            "frontier": [self.point(i) for i in self.pareto_frontier()],
            "cheapest_feasible": (None if cheapest is None
                                  else self.point(cheapest)),
        }


def _empty_report(cfg: SolverConfig, chunk: int,
                  warm_start: bool) -> PlanReport:
    """The zero-candidate :class:`PlanReport` (empty design space)."""
    z = np.empty(0)
    return PlanReport(candidates=[], cost=z, penalty=z, total=z,
                      r=np.empty((0, 0)), iters=np.empty(0, dtype=int),
                      feasible=np.empty(0, dtype=bool), config=cfg,
                      chunk=chunk, n_chunks=0, warm_start=warm_start)


def _chunk_targets(chunk: int, cfg: SolverConfig) -> int:
    """Padded lane width of every solve dispatch: ``chunk``, rounded up to
    the mesh's lane multiple when the config shards."""
    if cfg.mesh is None:
        return chunk
    return sharding.padded_lane_count(chunk, cfg.mesh.devices.size)


def _solve_cold(candidates: Sequence[Candidate], cfg: SolverConfig,
                chunk: int, n_max: int):
    """Chunked cold solves through the engine's batched path.

    Every chunk is inert-lane padded to the same fixed width (one compiled
    program for the whole plan); results are trimmed back to real lanes.
    Bit-equal to one ``CapacityEngine.solve`` over all candidates because
    lanes are independent and the padding is solver-inert.

    Parameters
    ----------
    candidates : sequence of Candidate
        Design points, grid order.
    cfg : SolverConfig
        Solver knobs / kernel / mesh.
    chunk : int
        Real lanes per dispatch.
    n_max : int
        Shared padded class width of every chunk.

    Returns
    -------
    tuple
        ``(fields, n_chunks)`` with ``fields`` the per-candidate metric
        arrays dict.
    """
    engine = CapacityEngine(cfg, Policies(rounding=RoundingPolicy(False)))
    target = _chunk_targets(chunk, cfg)
    out = {k: [] for k in ("cost", "penalty", "total", "r", "iters",
                           "feasible")}
    n_chunks = 0
    for start in range(0, len(candidates), chunk):
        part = candidates[start:start + chunk]
        batch = stack_scenarios([c.scenario for c in part], n_max=n_max)
        real = batch.batch_size
        batch = sharding.pad_batch_lanes(batch, target)
        report = engine.solve(batch, check_feasible=False)
        sol = report.fractional
        out["cost"].append(np.asarray(sol.cost)[:real])
        out["penalty"].append(np.asarray(sol.penalty)[:real])
        out["total"].append(np.asarray(sol.total)[:real])
        out["r"].append(np.asarray(sol.r)[:real])
        out["iters"].append(np.asarray(report.iters)[:real])
        out["feasible"].append(np.asarray(report.feasible)[:real])
        n_chunks += 1
    return {k: np.concatenate(v) for k, v in out.items()}, n_chunks


def _solve_warm(spec: PlanSpec, candidates: Sequence[Candidate],
                cfg: SolverConfig, chunk: int, n_max: int):
    """Deadline-axis warm-started solves (opt-in ``solve_plan`` mode).

    The grid's deadline axis is innermost, so the candidates factor into
    ``cross = B / D`` deadline-sweep chains of length ``D``.  Chains are
    chunked into fixed lane sets; each chain solves its first deadline
    step cold, then seeds every later step's initial allocation from the
    previous step's equilibrium — with bids restarted at the cold
    ``rho_bar`` init, which preserves the exact Alg. 4.1 iterate
    trajectory (iterates are bid-driven; the init ``r`` enters only the
    first iteration's convergence metric, so results match the cold solve
    bit-for-bit whenever both runs stop at the same iteration, and stay
    within the stopping tolerance otherwise).

    Parameters
    ----------
    spec : PlanSpec
        Supplies the deadline-axis length (chain structure).
    candidates : sequence of Candidate
        The spec's full grid, grid order.
    cfg : SolverConfig
        Solver knobs / kernel / mesh.
    chunk : int
        Real lanes (chains) per dispatch.
    n_max : int
        Shared padded class width of every chunk.

    Returns
    -------
    tuple
        ``(fields, n_chunks)`` as in the cold path.
    """
    D = len(spec.deadline_scales)
    B = len(candidates)
    cross = B // D
    target = _chunk_targets(chunk, cfg)
    dt = cfg.effective_dtype()

    fields = {
        "cost": np.empty(B), "penalty": np.empty(B), "total": np.empty(B),
        "r": np.empty((B, n_max)), "iters": np.empty(B, dtype=int),
        "feasible": np.empty(B, dtype=bool),
    }
    n_chunks = 0
    for c0 in range(0, cross, chunk):
        chains = range(c0, min(c0 + chunk, cross))
        prev_r = None
        for d in range(D):
            part = [candidates[ci * D + d] for ci in chains]
            batch = stack_scenarios([c.scenario for c in part], n_max=n_max)
            real = batch.batch_size
            batch = sharding.pad_batch_lanes(batch, target)
            if dt is not None:
                batch = ScenarioBatch(
                    scenarios=_cast_floats(batch.scenarios, dt),
                    mask=batch.mask, n_classes=batch.n_classes)
            init = game.cold_start(batch)
            if prev_r is not None:
                init = init._replace(
                    r=jnp.where(batch.mask, prev_r, init.r))
            sol = game.solve_distributed_batch(
                batch, eps_bar=cfg.eps_bar, lam=cfg.lam,
                max_iters=cfg.max_iters, sweep_fn=cfg.sweep_fn, init=init,
                mesh=cfg.mesh, iter_fn=cfg.iter_fn)
            prev_r = sol.r
            rows = [c.index for c in part]
            fields["cost"][rows] = np.asarray(sol.cost)[:real]
            fields["penalty"][rows] = np.asarray(sol.penalty)[:real]
            fields["total"][rows] = np.asarray(sol.total)[:real]
            fields["r"][rows] = np.asarray(sol.r)[:real]
            fields["iters"][rows] = np.asarray(sol.iters)[:real]
            fields["feasible"][rows] = np.asarray(sol.feasible)[:real]
            n_chunks += 1
    return fields, n_chunks


def solve_plan(plan: Union[PlanSpec, Sequence[Candidate]], *,
               config: Optional[SolverConfig] = None, chunk: int = 64,
               warm_start: bool = False) -> PlanReport:
    """Solve every design point of a plan and reduce to a frontier report.

    Candidates are packed into fixed-width inert-lane-padded chunks and
    solved on the engine's batched Algorithm 4.1 path (one compiled
    program for the whole plan, lane-sharded over ``config.mesh`` when
    set).  Rounding is off — planning compares *fractional* equilibria,
    as the paper's what-if sweeps do — and infeasible candidates are
    reported via their ``feasible`` flag rather than raised (probing
    undersized clusters is the point of the sweep).

    Parameters
    ----------
    plan : PlanSpec or sequence of Candidate
        A spec (expanded via :func:`generate_grid` here) or an
        already-expanded candidate list.
    config : SolverConfig, optional
        Solver knobs / kernel / mesh (default: the paper's).
    chunk : int, optional
        Real candidates per solve dispatch (the padded lane width; rounded
        up to the mesh's lane multiple when sharded).  Results are
        independent of ``chunk`` bit-for-bit.
    warm_start : bool, optional
        Seed each deadline step's allocation from the previous step's
        equilibrium along the grid's innermost (deadline) axis.  Requires
        ``plan`` to be a :class:`PlanSpec` (the chain structure comes from
        its axes).  Iterate trajectories are preserved (bids restart
        cold), so per-candidate results are bit-equal to the cold solve
        whenever both stop at the same iteration and within the stopping
        tolerance otherwise.

    Returns
    -------
    PlanReport
        Per-candidate objective decomposition + feasibility, with Pareto
        and cheapest-feasible queries.

    Raises
    ------
    ValueError
        ``chunk < 1``, or ``warm_start=True`` with a plain candidate list.
    """
    cfg = config if config is not None else SolverConfig()
    if chunk < 1:
        raise ValueError(f"chunk={chunk} must be >= 1")
    if isinstance(plan, PlanSpec):
        spec: Optional[PlanSpec] = plan
        candidates = generate_grid(plan)
    else:
        spec = None
        candidates = list(plan)
    if warm_start and spec is None:
        raise ValueError(
            "warm_start=True needs a PlanSpec (the deadline-axis chain "
            "structure comes from its axes) — pass the spec, not the "
            "expanded candidate list")
    t0 = time.perf_counter()
    if not candidates:
        return _empty_report(cfg, chunk, warm_start)
    n_max = max(c.scenario.n for c in candidates)
    if warm_start:
        fields, n_chunks = _solve_warm(spec, candidates, cfg, chunk, n_max)
    else:
        fields, n_chunks = _solve_cold(candidates, cfg, chunk, n_max)
    return PlanReport(candidates=list(candidates), config=cfg, chunk=chunk,
                      n_chunks=n_chunks, warm_start=warm_start,
                      elapsed_s=time.perf_counter() - t0, **fields)
