"""Device-sharded scenario fleets: lane-parallel GNEP solves via shard_map.

The batched engine (``game.solve_distributed_batch``) already solves B
independent lanes as one XLA program; this module places those lanes on a
1-D :class:`jax.sharding.Mesh` so the fleet splits across devices — the
distributed-by-construction structure of the paper (independent Class
Managers per game, independent games per lane) maps directly onto hardware:

* :func:`lane_mesh` builds the 1-D mesh over the ``"lanes"`` axis;
* :func:`pad_batch_lanes` pads the lane count to a multiple of the device
  count with *inert* lanes — the lane-axis analog of the per-class padding
  convention (``types.neutral_class_values``): an inert lane has an
  all-False mask, unit capacity/cost scalars and converges in one
  iteration, so it never changes any real lane's trajectory.  The same
  construction backs dynamic windows: ``AdmissionWindow.add_lane`` builds
  its new row with it, and because :func:`solve_sharded_batch` re-derives
  the padding from the *current* lane count on every call, windows that
  grow, shrink or compact between solves stay valid on a resident mesh
  (the repad is mesh-aware by construction);
* :func:`solve_sharded_batch` runs Algorithm 4.1 under
  ``jax.experimental.shard_map.shard_map``: each device iterates a local
  ``while_loop`` over its own lane slice, with the per-lane convergence
  freezing and :class:`~repro.core.game.BatchWarmStart` warm starts of the
  unsharded solver fully preserved.

Because every update in the batched solver is lane-local (the only
cross-lane coupling is the *global* loop condition, and converged lanes
are frozen by masking), each device's local loop reproduces its lanes'
unsharded trajectories exactly — and exits as soon as *its own* lanes
converge instead of spinning until the globally slowest lane does.  The
sharded result therefore matches the unsharded solver to float precision
(``tests/test_sharding.py`` asserts <= 1e-6; in practice bit-equal) while
scaling lane throughput with the device count
(``benchmarks/allocator_perf.py --shard``).

Works anywhere: on CPU, force a multi-device topology with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (what
``tests/conftest.py`` and ``scripts/ci.sh`` do).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import game
from repro.core.types import (Scenario, ScenarioBatch, Solution, WindowState,
                              neutral_class_values)

#: Default name of the single mesh axis the lane dimension is sharded over.
LANE_AXIS = "lanes"


def lane_mesh(n_devices: Optional[int] = None, *, devices=None,
              axis_name: str = LANE_AXIS) -> Mesh:
    """Build the 1-D device mesh the lane axis shards over.

    Parameters
    ----------
    n_devices : int, optional
        How many devices to use; defaults to every addressable device.
        Must not exceed the available count.
    devices : sequence of jax.Device, optional
        Explicit device list (overrides ``n_devices``); defaults to a
        prefix of ``jax.devices()``.
    axis_name : str, optional
        Mesh axis name (default :data:`LANE_AXIS`).

    Returns
    -------
    jax.sharding.Mesh
        A 1-D mesh suitable for every ``mesh=`` parameter in this repo's
        solver stack (``solve_distributed_batch``, ``solve_batch``,
        ``solve_streaming``, ``epoch_batch``, ``epoch_stream``).
    """
    if devices is None:
        avail = jax.devices()
        n = len(avail) if n_devices is None else int(n_devices)
        if not 1 <= n <= len(avail):
            raise ValueError(
                f"n_devices={n} out of range [1, {len(avail)}] "
                "(on CPU, force more with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        devices = avail[:n]
    return Mesh(np.asarray(devices), (axis_name,))


def lane_sharding(mesh: Mesh) -> NamedSharding:
    """The sharding every lane-axis leaf uses: first dim split over ``mesh``.

    Parameters
    ----------
    mesh : jax.sharding.Mesh
        1-D mesh from :func:`lane_mesh`.

    Returns
    -------
    jax.sharding.NamedSharding
        ``PartitionSpec(axis)`` over the mesh's single axis — valid for
        every leaf of a :class:`ScenarioBatch` / ``BatchWarmStart`` /
        ``Solution`` (all carry the lane dim first).
    """
    (axis,) = mesh.axis_names
    return NamedSharding(mesh, PartitionSpec(axis))


def shard_batch(batch: ScenarioBatch, mesh: Mesh) -> ScenarioBatch:
    """Pad ``batch`` to the mesh's lane multiple and place it on the mesh.

    :func:`solve_sharded_batch` does this internally per call; for
    steady-state throughput (fleet sweeps re-solving a resident batch) do
    it ONCE and pass the result — subsequent solves then start with zero
    host->device resharding, which is where the sharded engine's
    near-linear lane throughput comes from
    (``benchmarks/allocator_perf.py --shard``).

    Parameters
    ----------
    batch : ScenarioBatch
        The real B lanes (any placement).
    mesh : jax.sharding.Mesh
        1-D lane mesh the batch will be solved on.

    Returns
    -------
    ScenarioBatch
        Inert-lane padded to a multiple of the device count, every leaf
        device_put with :func:`lane_sharding`.  Note the padding is part
        of the batch from here on: solves of the resident batch return the
        padded lane count (trim with the mask / ``n_classes``, or index
        the original B lanes).
    """
    padded = pad_batch_lanes(
        batch, padded_lane_count(batch.batch_size, mesh.devices.size))
    sh = lane_sharding(mesh)
    return jax.tree_util.tree_map(lambda leaf: jax.device_put(leaf, sh),
                                  padded)


def padded_lane_count(batch_size: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` that is >= ``batch_size``.

    Parameters
    ----------
    batch_size : int
        Real lane count B.
    n_shards : int
        Device count of the lane mesh.

    Returns
    -------
    int
        The lane count after inert-lane padding (shard_map needs the
        sharded axis divisible by the mesh size).
    """
    if batch_size < 1 or n_shards < 1:
        raise ValueError("batch_size and n_shards must be >= 1")
    return -(-batch_size // n_shards) * n_shards


def pad_batch_lanes(batch: ScenarioBatch, target_b: int) -> ScenarioBatch:
    """Append inert lanes so ``batch`` has exactly ``target_b`` lanes.

    The lane-axis analog of the per-class padding convention: an inert lane
    holds a full row of neutral classes (:func:`~repro.core.types
    .neutral_class_values`), an all-False mask row, and unit scalars
    (``R = rho_bar = rho_hat = 1``) so every solver formula stays finite,
    the lane is trivially feasible, and its convergence metric is 0 — it
    freezes after at most one iteration and exchanges nothing with real
    lanes (lanes are independent by construction).

    Parameters
    ----------
    batch : ScenarioBatch
        The real B lanes.
    target_b : int
        Lane count after padding; must be >= ``batch.batch_size``.

    Returns
    -------
    ScenarioBatch
        ``batch`` itself when ``target_b == batch.batch_size``, else a new
        batch with ``target_b - B`` inert lanes appended.
    """
    b = batch.batch_size
    if target_b == b:
        return batch
    if target_b < b:
        raise ValueError(f"target_b={target_b} < batch_size={b}")
    pad, n_max = target_b - b, batch.n_max
    dt = batch.scenarios.A.dtype
    neutral = neutral_class_values(1.0)
    kw = {}
    for f in dataclasses.fields(Scenario):
        leaf = getattr(batch.scenarios, f.name)
        if f.name in neutral:                           # per-class (B, n_max)
            fill = jnp.full((pad, n_max), neutral[f.name], dt)
        else:                                           # scalar (B,)
            fill = jnp.ones((pad,), dt)
        kw[f.name] = jnp.concatenate([leaf, fill], axis=0)
    return ScenarioBatch(
        scenarios=Scenario(**kw),
        mask=jnp.concatenate(
            [batch.mask, jnp.zeros((pad, n_max), bool)], axis=0),
        n_classes=jnp.concatenate(
            [batch.n_classes,
             jnp.zeros((pad,), batch.n_classes.dtype)], axis=0))


def pad_warm_start(init: game.BatchWarmStart,
                   target_b: int) -> game.BatchWarmStart:
    """Append *frozen* inert-lane state so ``init`` covers ``target_b`` lanes.

    Padded lanes get ``active = False`` (the while-loop never touches them:
    zero iterations, zero work), a zero allocation, and bids/price pinned to
    the inert lane's ``rho_bar = 1`` — consistent with
    :func:`pad_batch_lanes` so the pass-through state is self-consistent.

    Parameters
    ----------
    init : game.BatchWarmStart
        Warm start over the real B lanes.
    target_b : int
        Lane count after padding; must be >= B.

    Returns
    -------
    game.BatchWarmStart
        ``init`` itself when already ``target_b`` lanes, else the padded
        warm start.
    """
    b = init.active.shape[0]
    if target_b == b:
        return init
    if target_b < b:
        raise ValueError(f"target_b={target_b} < batch_size={b}")
    pad, n_max = target_b - b, init.r.shape[1]
    dt = init.r.dtype
    return game.BatchWarmStart(
        r=jnp.concatenate([init.r, jnp.zeros((pad, n_max), dt)], axis=0),
        bids=jnp.concatenate([init.bids, jnp.ones((pad, n_max), dt)], axis=0),
        rho=jnp.concatenate([init.rho, jnp.ones((pad,), dt)], axis=0),
        lane_iters=jnp.concatenate(
            [init.lane_iters, jnp.zeros((pad,), init.lane_iters.dtype)],
            axis=0),
        active=jnp.concatenate(
            [init.active, jnp.zeros((pad,), bool)], axis=0))


def pad_window_state(state: WindowState, target_b: int) -> WindowState:
    """Append *inert-lane* equilibrium rows so ``state`` covers ``target_b``
    lanes.

    The stored-state analog of :func:`pad_warm_start`: padded lanes get a
    zero allocation, price pinned at the inert lane's ``rho_bar = 1``, zero
    iteration counts and ``solved = True`` — so a resident warm start built
    from the padded state freezes them (``active = False``) exactly like
    :func:`pad_warm_start` does, and they never iterate.

    Parameters
    ----------
    state : WindowState
        Committed equilibrium over the real B lanes.
    target_b : int
        Lane count after padding; must be >= B.

    Returns
    -------
    WindowState
        ``state`` itself when already ``target_b`` lanes, else the padded
        state.
    """
    b = state.solved.shape[0]
    if target_b == b:
        return state
    if target_b < b:
        raise ValueError(f"target_b={target_b} < batch_size={b}")
    pad, n_max = target_b - b, state.r.shape[1]
    dt = state.r.dtype
    return WindowState(
        r=jnp.concatenate([state.r, jnp.zeros((pad, n_max), dt)], axis=0),
        rho=jnp.concatenate([state.rho, jnp.ones((pad,), dt)], axis=0),
        lane_iters=jnp.concatenate(
            [state.lane_iters, jnp.zeros((pad,), state.lane_iters.dtype)],
            axis=0),
        solved=jnp.concatenate([state.solved, jnp.ones((pad,), bool)],
                               axis=0))


@jax.jit
def _resident_warm_builder(batch: ScenarioBatch, r, rho, lane_iters, solved,
                           dirty) -> game.BatchWarmStart:
    # Same frozen/dirty split as AdmissionWindow.warm_start, computed
    # on-device over the PADDED resident leaves (sharding propagates, so the
    # init comes out lane-sharded with zero host round-trips).  Every output
    # leaf passes through an optimization_barrier: the donated-init contract
    # of solve_resident_batch requires leaves that are fresh buffers, and
    # the barrier breaks any jaxpr-level passthrough (e.g. same-dtype
    # ``astype`` in cold_start returning its operand) that would otherwise
    # alias an init leaf to live window state.
    cold = game.cold_start(batch)
    frozen = solved & jnp.logical_not(dirty)
    init = game.BatchWarmStart(
        r=jnp.where(frozen[:, None], r, cold.r),
        bids=cold.bids,
        rho=jnp.where(frozen, rho, cold.rho),
        lane_iters=jnp.where(frozen, lane_iters, jnp.zeros_like(lane_iters)),
        active=jnp.logical_not(frozen))
    return jax.tree_util.tree_map(jax.lax.optimization_barrier, init)


@jax.jit
def _resident_cold_builder(batch: ScenarioBatch) -> game.BatchWarmStart:
    # Barrier for the same donation-safety reason as _resident_warm_builder:
    # cold_start's rho/bids are same-dtype casts of batch.rho_bar and would
    # otherwise pass the batch leaf straight through to the donated init.
    return jax.tree_util.tree_map(jax.lax.optimization_barrier,
                                  game.cold_start(batch))


def resident_warm_init(batch: ScenarioBatch, state: WindowState,
                       dirty) -> game.BatchWarmStart:
    """Build the donation-safe warm start for a mesh-resident window solve.

    Frozen lanes (``state.solved`` and not ``dirty``) pass their stored
    equilibrium through with ``active = False``; dirty or never-solved lanes
    restart from the cold Algorithm 4.1 init — bit-identical to
    ``AdmissionWindow.warm_start`` + :func:`pad_warm_start`, but computed in
    one jitted program over the already-padded resident leaves, so nothing
    round-trips through the host.  Every leaf of the result is a *fresh*
    buffer (an ``optimization_barrier`` guards against jaxpr passthrough
    aliasing), which is what lets :func:`solve_resident_batch` donate it.

    Parameters
    ----------
    batch : ScenarioBatch
        The resident (lane-padded, mesh-placed) batch.
    state : WindowState
        Committed equilibrium over the same padded lane count
        (:func:`pad_window_state`).
    dirty : jnp.ndarray
        (padded B,) bool — lanes whose scenario changed since ``state``
        (padding rows False).

    Returns
    -------
    game.BatchWarmStart
        Lane-sharded init whose buffers are safe to donate.
    """
    return _resident_warm_builder(batch, state.r, state.rho,
                                  state.lane_iters, state.solved, dirty)


def resident_cold_init(batch: ScenarioBatch) -> game.BatchWarmStart:
    """Donation-safe cold Algorithm 4.1 init for a mesh-resident batch.

    Value-identical to ``game.cold_start`` (so a resident first solve
    reproduces the round-trip cold trajectory exactly), with fresh buffers
    per the same barrier argument as :func:`resident_warm_init`.

    Parameters
    ----------
    batch : ScenarioBatch
        The resident (lane-padded, mesh-placed) batch.

    Returns
    -------
    game.BatchWarmStart
        Lane-sharded cold init whose buffers are safe to donate.
    """
    return _resident_cold_builder(batch)


@lru_cache(maxsize=None)
def _resident_solver(mesh: Mesh, eps_bar: float, lam: float, max_iters: int,
                     sweep_fn, iter_fn):
    """Memoized donating variant of :func:`_sharded_solver`.

    Identical program to the ``with_init=True`` sharded solver, but the
    warm-start argument's buffers are DONATED (``donate_argnums``) — XLA
    reuses them for the solution outputs, so steady-state resident
    streaming allocates no fresh equilibrium buffers per flush (the
    ``serving/engine.py`` decode-cache idiom applied to the GNEP loop).
    """
    (axis,) = mesh.axis_names
    spec = PartitionSpec(axis)

    def local_solve(batch: ScenarioBatch, init: game.BatchWarmStart):
        return game._solve_batch_core(batch, eps_bar, lam, max_iters,
                                      sweep_fn, init, iter_fn=iter_fn)

    sharded = shard_map(local_solve, mesh=mesh, in_specs=(spec, spec),
                        out_specs=spec, check_rep=False)
    return jax.jit(sharded, donate_argnums=(1,))


def solve_resident_batch(batch: ScenarioBatch, mesh: Mesh, *,
                         eps_bar: float = 0.03, lam: float = 0.05,
                         max_iters: int = 200, sweep_fn=None,
                         init: game.BatchWarmStart, iter_fn=None) -> Solution:
    """Algorithm 4.1 over an already mesh-resident, lane-padded batch.

    The zero-copy flush path of device-resident window sessions: ``batch``
    must already be lane-padded to the mesh multiple and placed with
    :func:`lane_sharding` (a resident ``AdmissionWindow`` maintains exactly
    that), and ``init`` must come from :func:`resident_warm_init` /
    :func:`resident_cold_init` — its buffers are **donated** to the solve
    and must not be read afterwards.  Unlike :func:`solve_sharded_batch`
    nothing is padded, placed or trimmed here: the returned
    :class:`Solution` keeps the PADDED lane count and stays resident on the
    mesh, ready to be committed as the next warm-start state.

    Parameters
    ----------
    batch : ScenarioBatch
        Mesh-resident padded batch (lane count divisible by the device
        count).
    mesh : jax.sharding.Mesh
        1-D lane mesh the batch lives on.
    eps_bar : float, optional
        Alg. 4.1 stopping tolerance (compiled into the program).
    lam : float, optional
        Bid-escalation step (compiled in).
    max_iters : int, optional
        Per-device iteration cap (compiled in).
    sweep_fn : callable, optional
        Batched RM sweep override; pass a memoized function object.
    init : game.BatchWarmStart
        Fresh-buffer warm start over the padded lanes; donated.
    iter_fn : object, optional
        Fused-iteration override (see ``game.solve_distributed_batch``);
        inside ``shard_map`` its prep/step see the *local* lane slice.
        Pass a memoized object (it keys the program cache).

    Returns
    -------
    Solution
        Padded-lane-count solution, resident on ``mesh``.
    """
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"lane sharding needs a 1-D mesh, got axes {mesh.axis_names}")
    if batch.batch_size % mesh.devices.size:
        raise ValueError(
            f"resident batch has {batch.batch_size} lanes, not a multiple "
            f"of the {mesh.devices.size}-device mesh — pad with "
            "pad_batch_lanes/padded_lane_count first")
    solver = _resident_solver(mesh, float(eps_bar), float(lam),
                              int(max_iters), sweep_fn, iter_fn)
    return solver(batch, init)


@lru_cache(maxsize=None)
def _sharded_solver(mesh: Mesh, eps_bar: float, lam: float, max_iters: int,
                    sweep_fn, iter_fn, with_init: bool):
    """Memoized jitted shard_map'd Algorithm 4.1 for one solver config.

    Cached on (mesh, knobs, sweep_fn identity) so repeated solves — the
    streaming engine's steady state — reuse one compiled program exactly
    like the unsharded jit cache does.  ``with_init`` False compiles the
    cold start INTO the program (cold solves of a mesh-resident batch then
    run with zero per-call host-side work).
    """
    (axis,) = mesh.axis_names
    spec = PartitionSpec(axis)

    def local_solve(batch: ScenarioBatch, *init: game.BatchWarmStart):
        # Each device runs the plain batched solver over its own lane
        # slice: lane updates are lane-local and converged lanes freeze,
        # so local trajectories == unsharded trajectories, but the local
        # while_loop exits when the *local* lanes converge.
        return game._solve_batch_core(batch, eps_bar, lam, max_iters,
                                      sweep_fn, init[0] if init else None,
                                      iter_fn=iter_fn)

    sharded = shard_map(local_solve, mesh=mesh,
                        in_specs=(spec, spec) if with_init else (spec,),
                        out_specs=spec, check_rep=False)
    return jax.jit(sharded)


def solve_sharded_batch(batch: ScenarioBatch, mesh: Mesh, *,
                        eps_bar: float = 0.03, lam: float = 0.05,
                        max_iters: int = 200, sweep_fn=None,
                        init: Optional[game.BatchWarmStart] = None,
                        iter_fn=None) -> Solution:
    """Algorithm 4.1 over B lanes sharded across the devices of ``mesh``.

    Semantics are identical to ``game.solve_distributed_batch`` (same
    per-lane trajectories, per-lane convergence freezing, warm starts); the
    lane axis is padded with inert lanes up to a multiple of the device
    count, each device solves its slice under ``shard_map``, and the
    padding is trimmed off the result.  Matches the unsharded solver to
    <= 1e-6 (in practice bit-equal) on every lane.

    Parameters
    ----------
    batch : ScenarioBatch
        B stacked (padded + masked) instances; B need *not* divide the
        device count — inert-lane padding handles ragged fleets.
    mesh : jax.sharding.Mesh
        1-D mesh from :func:`lane_mesh` (exactly one axis).
    eps_bar : float, optional
        Alg. 4.1 stopping tolerance (paper uses 0.03).  Unlike the
        unsharded path this is compiled into the program (one recompile
        per distinct value) — solver knobs, not data.
    lam : float, optional
        Bid-escalation step of ``cm_bid_update`` (same compile note).
    max_iters : int, optional
        Per-device iteration cap.
    sweep_fn : callable, optional
        Batched RM sweep override (e.g. the Pallas kernel); inside
        ``shard_map`` it sees the *local* ``(B/D, Nc, N)`` shapes.  Pass a
        memoized function object (it keys the program cache).
    init : game.BatchWarmStart, optional
        Warm start over the real B lanes (the streaming engine's frozen /
        dirty split); padded lanes are added frozen.  ``None`` = cold
        start.
    iter_fn : object, optional
        Fused-iteration override (see ``game.solve_distributed_batch``);
        inside ``shard_map`` its prep/step see the *local* lane slice.
        Pass a memoized object (it keys the program cache).

    Returns
    -------
    Solution
        Same layout as ``solve_distributed_batch``: leaves carry the REAL
        leading B dim (inert-lane padding already trimmed).
    """
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"lane sharding needs a 1-D mesh, got axes {mesh.axis_names}")
    b = batch.batch_size
    n_shards = mesh.devices.size
    target = padded_lane_count(b, n_shards)
    solver = _sharded_solver(mesh, float(eps_bar), float(lam),
                             int(max_iters), sweep_fn, iter_fn,
                             init is not None)
    # device_put is a no-op for leaves already placed by shard_batch, so the
    # steady state (resident sharded batch, e.g. fleet sweeps) pays zero
    # per-call resharding; a one-shot unsharded batch is placed here.  The
    # cold init is compiled into the program rather than materialized here.
    sh = lane_sharding(mesh)
    args = (jax.device_put(pad_batch_lanes(batch, target), sh),)
    if init is not None:
        args += (jax.device_put(pad_warm_start(init, target), sh),)
    sol = solver(*args)
    if target == b:
        return sol
    return jax.tree_util.tree_map(lambda leaf: leaf[:b], sol)
