"""Centralized solver for the reduced convex program (P3).

The paper solves (P2)/(P3) with AMPL+Knitro.  (P3) is separable with a single
coupling constraint, so its KKT system is solved *exactly* by water-filling on
the capacity multiplier ``a``:

    stationarity:  rho_bar + a - alpha_i K_i / r_i^2 = 0   (interior)
    =>             r_i(a) = clip( sqrt(alpha_i K_i / (rho_bar + a)),
                                  r_i^low, r_i^up )

``sum_i r_i(a)`` is continuous and non-increasing in ``a``; complementary
slackness picks a = 0 if the box solution fits in R, else the unique root of
``sum r_i(a) = R``, found by bisection to machine precision.  The full
(psi, s^M, s^R) solution is recovered through Prop. 3.3.  This replaces the
paper's generic NLP solver with a closed-form method (see DESIGN.md Sec. 3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import Scenario, Solution, objective

_BISECT_ITERS = 120


def _r_of_a(scn: Scenario, a):
    r_unc = jnp.sqrt(scn.alpha * scn.K / (scn.rho_bar + a))
    return jnp.clip(r_unc, scn.r_low, scn.r_up)


@partial(jax.jit, static_argnames=())
def solve_centralized(scn: Scenario) -> Solution:
    """Exact optimum of (P3) + Prop. 3.3 recovery. Pure function, jittable."""
    feasible = (jnp.sum(scn.r_low) <= scn.R) & jnp.all(scn.E < 0)

    r0 = _r_of_a(scn, 0.0)
    fits = jnp.sum(r0) <= scn.R

    # upper bracket: multiplier pushing every class to its lower bound
    a_hi = jnp.max(scn.alpha * scn.K / (scn.r_low ** 2)) - scn.rho_bar + 1.0
    a_hi = jnp.maximum(a_hi, 1.0)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_big = jnp.sum(_r_of_a(scn, mid)) > scn.R
        return jnp.where(too_big, mid, lo), jnp.where(too_big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body,
                               (jnp.zeros_like(a_hi), a_hi))
    a = jnp.where(fits, 0.0, hi)
    r = _r_of_a(scn, a)

    # Prop. 3.3 recovery
    sM = scn.xiM * r
    sR = scn.xiR * r
    psi = jnp.clip(scn.K / r, scn.psi_low, scn.psi_up)

    cost = scn.rho_bar * jnp.sum(r)
    penalty = jnp.sum(scn.alpha * psi - scn.beta)
    return Solution(r=r, psi=psi, sM=sM, sR=sR, cost=cost, penalty=penalty,
                    total=cost + penalty, feasible=feasible,
                    iters=jnp.asarray(_BISECT_ITERS), aux=a)


def kkt_residual(scn: Scenario, r, a) -> jnp.ndarray:
    """Max KKT violation of a candidate (P3) solution (used by tests).

    Checks stationarity with box multipliers eliminated by sign conditions,
    primal feasibility and complementary slackness of the capacity constraint.
    """
    g = scn.rho_bar + a - scn.alpha * scn.K / (r ** 2)   # dL/dr (box mults out)
    tol_r = 1e-6 * jnp.maximum(scn.r_up, 1.0)
    at_low = r <= scn.r_low + tol_r
    at_up = r >= scn.r_up - tol_r
    interior = ~(at_low | at_up)
    scale = jnp.maximum(scn.rho_bar + a, 1.0)
    stat = jnp.max(jnp.where(interior, jnp.abs(g), 0.0) / scale)
    sign_low = jnp.max(jnp.where(at_low, jnp.maximum(-g, 0.0), 0.0) / scale)
    sign_up = jnp.max(jnp.where(at_up, jnp.maximum(g, 0.0), 0.0) / scale)
    primal = jnp.maximum(jnp.sum(r) - scn.R, 0.0) / jnp.maximum(scn.R, 1.0)
    box = jnp.max(jnp.maximum(scn.r_low - r, r - scn.r_up) /
                  jnp.maximum(scn.r_up, 1.0))
    comp = jnp.abs(a * (jnp.sum(r) - scn.R)) / jnp.maximum(scn.R * scale, 1.0)
    return jnp.max(jnp.stack([stat, sign_low, sign_up, primal, box, comp]))


def objective_of_r(scn: Scenario, r) -> jnp.ndarray:
    """(P3a) objective for an arbitrary feasible r (psi via Prop. 3.3)."""
    psi = jnp.clip(scn.K / r, scn.psi_low, scn.psi_up)
    return objective(scn, r, psi)
