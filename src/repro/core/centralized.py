"""Centralized solver for the reduced convex program (P3).

The paper solves (P2)/(P3) with AMPL+Knitro.  (P3) is separable with a single
coupling constraint, so its KKT system is solved *exactly* by water-filling on
the capacity multiplier ``a``:

    stationarity:  rho_bar + a - alpha_i K_i / r_i^2 = 0   (interior)
    =>             r_i(a) = clip( sqrt(alpha_i K_i / (rho_bar + a)),
                                  r_i^low, r_i^up )

``sum_i r_i(a)`` is continuous and non-increasing in ``a``; complementary
slackness picks a = 0 if the box solution fits in R, else the unique root of
``sum r_i(a) = R``, found by bisection to machine precision.  The full
(psi, s^M, s^R) solution is recovered through Prop. 3.3.  This replaces the
paper's generic NLP solver with a closed-form method (see docs/PAPER_MAP.md).

Both a single-instance (`solve_centralized`, optionally mask-aware) and a
batched (`solve_centralized_batch`, one vmapped program over a
:class:`ScenarioBatch`) entry point are provided; the batched form is the
exact-optimum baseline the streaming engine cross-checks against.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import Scenario, ScenarioBatch, Solution, objective

_BISECT_ITERS = 120


def _r_of_a(scn: Scenario, a, valid):
    """Box-clipped stationarity solution r(a); masked classes pin to 0."""
    num = jnp.where(valid, scn.alpha * scn.K, 0.0)
    r_unc = jnp.sqrt(num / (scn.rho_bar + a))
    return jnp.clip(r_unc, jnp.where(valid, scn.r_low, 0.0),
                    jnp.where(valid, scn.r_up, 0.0))


@partial(jax.jit, static_argnames=())
def solve_centralized(scn: Scenario, *, mask=None) -> Solution:
    """Exact optimum of (P3) + Prop. 3.3 recovery.  Pure function, jittable.

    Parameters
    ----------
    scn : Scenario
        One allocation instance (per-class leaves (N,), scalars 0-d).
    mask : jnp.ndarray, optional
        (N,) bool validity mask for padded batch lanes.  Masked-off classes
        receive r = sM = sR = 0, psi = psi_low, and contribute nothing to
        the capacity constraint, cost or penalty.  ``None`` treats every
        class as valid (the plain single-instance solve).

    Returns
    -------
    Solution
        The exact (P3) optimum: ``aux`` carries the KKT capacity multiplier
        ``a`` (0 when capacity is slack), ``iters`` the fixed bisection
        budget.  ``feasible`` flags ``sum(r_low) <= R`` and all deadlines
        attainable (E < 0); the returned point is the box/capacity projection
        regardless, so callers must check the flag.
    """
    valid = jnp.ones(scn.A.shape, bool) if mask is None else mask
    r_low = jnp.where(valid, scn.r_low, 0.0)
    feasible = (jnp.sum(r_low) <= scn.R) & jnp.all(
        jnp.where(valid, scn.E < 0, True))

    r0 = _r_of_a(scn, 0.0, valid)
    fits = jnp.sum(r0) <= scn.R

    # upper bracket: multiplier pushing every valid class to its lower bound
    # (valid classes have r_low = K * H_low > 0, so the ratio is finite)
    a_hi = jnp.max(jnp.where(
        valid, scn.alpha * scn.K / jnp.maximum(r_low, 1e-30) ** 2,
        0.0)) - scn.rho_bar + 1.0
    a_hi = jnp.maximum(a_hi, 1.0)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_big = jnp.sum(_r_of_a(scn, mid, valid)) > scn.R
        return jnp.where(too_big, mid, lo), jnp.where(too_big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body,
                               (jnp.zeros_like(a_hi), a_hi))
    a = jnp.where(fits, 0.0, hi)
    r = _r_of_a(scn, a, valid)

    # Prop. 3.3 recovery
    sM = jnp.where(valid, scn.xiM * r, 0.0)
    sR = jnp.where(valid, scn.xiR * r, 0.0)
    psi = jnp.clip(scn.K / jnp.where(r > 0, r, 1.0), scn.psi_low, scn.psi_up)
    psi = jnp.where(valid, psi, scn.psi_low)

    cost = scn.rho_bar * jnp.sum(r)
    penalty = jnp.sum(jnp.where(valid, scn.alpha * psi - scn.beta, 0.0))
    return Solution(r=r, psi=psi, sM=sM, sR=sR, cost=cost, penalty=penalty,
                    total=cost + penalty, feasible=feasible,
                    iters=jnp.asarray(_BISECT_ITERS), aux=a)


@jax.jit
def solve_centralized_batch(batch: ScenarioBatch) -> Solution:
    """Exact (P3) optimum of every lane of a batch, as one vmapped program.

    This is the batch-scale exact-optimum baseline: the streaming facade
    (``allocator.solve_streaming(cross_check=True)``) compares the GNEP
    equilibrium total of every lane against this lower bound.

    Parameters
    ----------
    batch : ScenarioBatch
        B stacked (padded + masked) instances.

    Returns
    -------
    Solution
        Leaves carry a leading batch dim (same layout as
        ``solve_distributed_batch``): r/psi/sM/sR are (B, n_max) with padded
        classes inert, scalars are (B,); ``aux`` is the per-lane KKT
        multiplier ``a``.
    """
    return jax.vmap(lambda s, m: solve_centralized(s, mask=m))(
        batch.scenarios, batch.mask)


def kkt_residual(scn: Scenario, r, a) -> jnp.ndarray:
    """Max KKT violation of a candidate (P3) solution (used by tests).

    Checks stationarity with box multipliers eliminated by sign conditions,
    primal feasibility and complementary slackness of the capacity
    constraint.

    Parameters
    ----------
    scn : Scenario
        The instance the candidate solves.
    r : jnp.ndarray
        (N,) candidate allocation.
    a : jnp.ndarray
        Scalar candidate capacity multiplier.

    Returns
    -------
    jnp.ndarray
        Scalar max of the (scale-normalised) violation terms; ~0 at the
        exact optimum.
    """
    g = scn.rho_bar + a - scn.alpha * scn.K / (r ** 2)   # dL/dr (box mults out)
    tol_r = 1e-6 * jnp.maximum(scn.r_up, 1.0)
    at_low = r <= scn.r_low + tol_r
    at_up = r >= scn.r_up - tol_r
    interior = ~(at_low | at_up)
    scale = jnp.maximum(scn.rho_bar + a, 1.0)
    stat = jnp.max(jnp.where(interior, jnp.abs(g), 0.0) / scale)
    sign_low = jnp.max(jnp.where(at_low, jnp.maximum(-g, 0.0), 0.0) / scale)
    sign_up = jnp.max(jnp.where(at_up, jnp.maximum(g, 0.0), 0.0) / scale)
    primal = jnp.maximum(jnp.sum(r) - scn.R, 0.0) / jnp.maximum(scn.R, 1.0)
    box = jnp.max(jnp.maximum(scn.r_low - r, r - scn.r_up) /
                  jnp.maximum(scn.r_up, 1.0))
    comp = jnp.abs(a * (jnp.sum(r) - scn.R)) / jnp.maximum(scn.R * scale, 1.0)
    return jnp.max(jnp.stack([stat, sign_low, sign_up, primal, box, comp]))


def objective_of_r(scn: Scenario, r) -> jnp.ndarray:
    """(P3a) objective for an arbitrary feasible r (psi via Prop. 3.3).

    Parameters
    ----------
    scn : Scenario
        The instance.
    r : jnp.ndarray
        (N,) allocation in the (P3) feasible box.

    Returns
    -------
    jnp.ndarray
        Scalar running cost + rejection penalty (cents per unit time).
    """
    psi = jnp.clip(scn.K / r, scn.psi_low, scn.psi_up)
    return objective(scn, r, psi)
