"""Streaming admission: the paper's *runtime* capacity-allocation loop.

Job classes arrive, renegotiate SLAs and leave while the window stays live:
each event dirties exactly one lane, and ``solve_streaming`` re-equilibrates
only that lane (warm-started incremental re-solve) while every other
cluster's equilibrium is frozen for free.  Every solve is cross-checked
against the exact centralized (P3) optimum.

    PYTHONPATH=src python examples/streaming_admission.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (AdmissionWindow, sample_class_params, sample_scenario,
                        solve_streaming)


def show(tag, window, res):
    print(f"\n=== {tag} ===")
    print(f"  re-solved lanes: {np.flatnonzero(res.resolved).tolist()} "
          f"(iters: {np.asarray(res.iters)[res.resolved].tolist()})")
    for b in range(window.batch_size):
        n = int(window.n_classes[b])
        gap = float(res.centralized_gap[b])
        print(f"  cluster {b}: n={n:2d}  chips={int(np.sum(res.integer.r[b]))}"
              f"  total={float(res.integer.total[b]):12.1f} cents"
              f"  gap-to-optimal={100 * gap:5.2f}%"
              f"  {'feasible' if bool(res.feasible[b]) else 'INFEASIBLE'}")


def main():
    # four clusters (lanes) with ragged class counts, slot headroom of 8
    scns = [sample_scenario(jax.random.PRNGKey(i), n, capacity_factor=1.2)
            for i, n in enumerate([5, 8, 3, 6])]
    window = AdmissionWindow(scns, n_max=8)

    res = solve_streaming(window, cross_check=True)
    show("initial window (all lanes solve cold)", window, res)

    # a new job class arrives at cluster 2 — only lane 2 re-iterates
    key = jax.random.PRNGKey(100)
    slot = window.arrive(2, **sample_class_params(key))
    res = solve_streaming(window, cross_check=True)
    show(f"arrival at cluster 2 (granted slot {slot})", window, res)

    # the class in slot 0 of cluster 1 departs; its slot is recycled
    window.depart(1, window.occupied(1)[0])
    res = solve_streaming(window, cross_check=True)
    show("departure from cluster 1 (slot recycled)", window, res)

    # cluster 0 renegotiates one SLA: tighter deadline, higher penalty
    s0 = window.occupied(0)[0]
    window.edit(0, s0, E=-700.0, m=29000.0)
    res = solve_streaming(window, cross_check=True)
    show("SLA renegotiation at cluster 0", window, res)

    # nodes fail at cluster 3: capacity drops 30% (paper Fig. 2, live)
    window.set_capacity(3, 0.7 * float(window.batch.scenarios.R[3]))
    res = solve_streaming(window, cross_check=True)
    show("30% capacity loss at cluster 3", window, res)

    # burst of arrivals at cluster 2 forces the window to grow past n_max
    for i in range(6):
        window.arrive(2, **sample_class_params(jax.random.PRNGKey(200 + i)))
    res = solve_streaming(window, cross_check=True)
    show(f"arrival burst at cluster 2 (window grew to n_max={window.n_max})",
         window, res)


if __name__ == "__main__":
    main()
