"""Streaming admission: the paper's *runtime* capacity-allocation loop.

One ``CapacityEngine`` session drives four running clusters: job classes
arrive, renegotiate SLAs and leave while the window stays live.  Events
buffer in the session and flush into ONE coalesced re-solve; only dirtied
lanes iterate while every other cluster's equilibrium is frozen for free.
The cross-check policy compares every solve against the exact centralized
(P3) optimum, and a deadline-aware flush policy shows an SLA-critical event
jumping the coalescing queue.

    PYTHONPATH=src python examples/streaming_admission.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (CapacityChange, CapacityEngine, ClassArrival,
                        ClassDeparture, CrossCheckPolicy, FlushPolicy,
                        Policies, SLAEdit, sample_class_params,
                        sample_scenario)


def show(tag, session, res):
    print(f"\n=== {tag} ===")
    print(f"  re-solved lanes: {np.flatnonzero(res.resolved).tolist()} "
          f"(iters: {np.asarray(res.iters)[res.resolved].tolist()})")
    window = session.window
    for b in range(window.batch_size):
        n = int(window.n_classes[b])
        gap = float(res.centralized_gap[b])
        print(f"  cluster {b}: n={n:2d}  chips={int(np.sum(res.integer.r[b]))}"
              f"  total={float(res.integer.total[b]):12.1f} cents"
              f"  gap-to-optimal={100 * gap:5.2f}%"
              f"  {'feasible' if bool(res.feasible[b]) else 'INFEASIBLE'}")


def main():
    # four clusters (lanes) with ragged class counts, slot headroom of 8.
    # Deadline-aware cadence: bulk events coalesce (up to 8 per flush), but
    # an SLA-critical event — a tightened deadline, or an arrival within
    # 300 s of infeasibility — forces an immediate re-solve.
    engine = CapacityEngine(policies=Policies(
        flush=FlushPolicy.deadline(300.0, max_events=8),
        cross_check=CrossCheckPolicy(True)))
    scns = [sample_scenario(jax.random.PRNGKey(i), n, capacity_factor=1.2)
            for i, n in enumerate([5, 8, 3, 6])]
    session = engine.open_window(scns, n_max=8)

    show("initial window (all lanes solve cold)", session, session.solve())

    # a new job class arrives at cluster 2 — only lane 2 re-iterates
    session.apply(ClassArrival(
        lane=2, params=sample_class_params(jax.random.PRNGKey(100))))
    res = session.flush()
    show(f"arrival at cluster 2 (granted slot {session.last_slots[0]})",
         session, res)

    # bulk churn coalesces: a departure, a *relaxing* SLA renegotiation and
    # a 30% capacity loss (paper Fig. 2, live) fold into ONE re-solve
    window = session.window
    session.apply(
        ClassDeparture(lane=1, slot=window.occupied(1)[0]),
        SLAEdit(lane=0, slot=window.occupied(0)[0],
                updates={"E": -1400.0, "m": 29000.0}),
        CapacityChange(lane=3, R=0.7 * float(window.batch.scenarios.R[3])))
    show("coalesced epoch: departure + relaxed SLA + 30% capacity loss",
         session, session.flush())

    # TIGHTENING a deadline is SLA-critical: the deadline policy flushes it
    # immediately instead of letting it wait out a coalescing epoch
    slot0 = session.window.occupied(0)[0]
    res = session.apply(SLAEdit(lane=0, slot=slot0, updates={"E": -800.0}))
    assert res is not None, "tightened SLA should have flushed immediately"
    show("SLA-critical edit at cluster 0 (tightened deadline, immediate "
         "flush)", session, res)

    # so is an arrival whose deadline is nearly exhausted (E within the
    # 300 s slack threshold)
    hot = sample_class_params(jax.random.PRNGKey(7))
    hot["E"] = -120.0
    res = session.apply(ClassArrival(lane=1, params=hot))
    assert res is not None, "near-deadline arrival should have flushed"
    show("SLA-critical arrival at cluster 1 (immediate flush)", session, res)

    # burst of arrivals at cluster 2 forces the window to grow past n_max
    session.apply(*[
        ClassArrival(lane=2,
                     params=sample_class_params(jax.random.PRNGKey(200 + i)))
        for i in range(6)])
    res = session.flush()
    show(f"arrival burst at cluster 2 (window grew to "
         f"n_max={session.window.n_max})", session, res)


if __name__ == "__main__":
    main()
