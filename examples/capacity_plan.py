"""Fleet capacity planning in miniature: sweep a small design space
(cluster size x VM tier x deadline tightness) sized against a bursty
workload trace, then query the cheapest feasible design and the
cost/penalty Pareto frontier — the D-SPACE4Cloud design-tool loop built on
the paper's allocator (docs/OPERATIONS.md "Capacity planning").

    PYTHONPATH=src python examples/capacity_plan.py
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import PlanSpec, VMTier, generate_grid, solve_plan

SPEC = PlanSpec(
    n_classes=4,
    profile="bursty",                  # size the fleet for bursty load
    rate=50.0,
    cluster_sizes=(1000.0, 2500.0, 6000.0),
    vm_tiers=(VMTier("small", 1.0, 6.0), VMTier("large", 2.0, 10.0)),
    deadline_scales=(0.8, 1.0, 1.2),
    penalty_scales=(1.0, 2.0),
    seed=7,
)


def main():
    grid = generate_grid(SPEC)
    print(f"=== design space: {'x'.join(map(str, SPEC.grid_shape))} grid "
          f"= {len(grid)} candidates, profile={SPEC.profile} ===")

    report = solve_plan(SPEC, chunk=12)
    n_feas = int(report.feasible.sum())
    print(f"solved in {report.elapsed_s:.2f}s "
          f"({report.n_chunks} chunks of {report.chunk}); "
          f"{n_feas}/{report.n_candidates} designs feasible")

    cheapest = report.cheapest_feasible()
    if cheapest is None:
        print("no feasible design — grow the cluster axis")
    else:
        p = report.point(cheapest)
        print(f"\ncheapest feasible design: R={p['cluster_size']:.0f} "
              f"tier={p['tier']} deadline_scale={p['deadline_scale']}")
        print(f"  power cost {p['cost']:.1f} cents, "
              f"rejection penalty {p['penalty']:.1f} cents")

    print("\n(cost, penalty) Pareto frontier over feasible designs:")
    print(f"{'idx':>5} {'R':>7} {'tier':>7} {'dl':>5} {'pen_scale':>9} "
          f"{'cost':>11} {'penalty':>11}")
    for i in report.pareto_frontier():
        p = report.point(int(i))
        print(f"{p['index']:>5} {p['cluster_size']:>7.0f} {p['tier']:>7} "
              f"{p['deadline_scale']:>5} {p['penalty_scale']:>9} "
              f"{p['cost']:>11.1f} {p['penalty']:>11.1f}")

    # a penalty budget turns the frontier into a constrained pick
    budget = 1000.0
    j = report.cheapest_feasible(max_penalty=budget)
    if j is not None:
        p = report.point(j)
        print(f"\ncheapest design under a {budget:.0f}-cent penalty budget: "
              f"#{p['index']} (R={p['cluster_size']:.0f}, tier={p['tier']}, "
              f"cost {p['cost']:.1f})")


if __name__ == "__main__":
    main()
