"""Reproduce the paper's experimental campaign in miniature: the
decreasing-capacity sweep (Fig. 2), decreasing deadlines (Fig. 4) and the
tolerance analysis (Fig. 8), printed as tables.

    PYTHONPATH=src python examples/capacity_allocation.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import sample_scenario, solve_centralized, solve_distributed


def sweep_capacity(n=100):
    print(f"=== Fig. 2: decreasing capacity (N={n}) ===")
    base = sample_scenario(jax.random.PRNGKey(0), n, capacity_factor=1.0)
    R_o = float(jnp.sum(base.r_up))
    print(f"{'R/R^o':>6} {'feasible':>9} {'C_centralized':>14} "
          f"{'C_distributed':>14} {'chi':>8}")
    for f in (1.1, 1.0, 0.95, 0.9, 0.85, 0.8, 0.75):
        scn = base.replace(R=jnp.asarray(f * R_o, base.A.dtype))
        c, d = solve_centralized(scn), solve_distributed(scn)
        chi = (float(d.total) - float(c.total)) / max(float(c.total), 1e-9)
        print(f"{f:6.2f} {str(bool(c.feasible)):>9} {float(c.total):14.0f} "
              f"{float(d.total):14.0f} {chi:8.4f}")


def sweep_deadlines(n=100):
    print(f"\n=== Fig. 4: decreasing deadlines (N={n}) ===")
    base = sample_scenario(jax.random.PRNGKey(0), n, capacity_factor=1.1)
    R = float(base.R)
    print(f"{'Dscale':>7} {'feasible':>9} {'C_centralized':>14} {'penalty':>12}")
    for s in (1.0, 0.9, 0.8, 0.7, 0.6):
        scn = sample_scenario(jax.random.PRNGKey(0), n, deadline_scale=s,
                              capacity=R)
        c = solve_centralized(scn)
        print(f"{s:7.1f} {str(bool(c.feasible)):>9} {float(c.total):14.0f} "
              f"{float(c.penalty):12.0f}")


def sweep_tolerance(n=100):
    print(f"\n=== Fig. 8: tolerance sensitivity (N={n}) ===")
    scn = sample_scenario(jax.random.PRNGKey(1), n, capacity_factor=0.93)
    c = solve_centralized(scn)
    for eps in (0.01, 0.03, 0.05, 0.10):
        d = solve_distributed(scn, eps_bar=eps)
        chi = (float(d.total) - float(c.total)) / float(c.total)
        print(f"eps_bar={eps:5.2f}: chi={chi:.4f} iters={int(d.iters)}")


if __name__ == "__main__":
    sweep_capacity()
    sweep_deadlines()
    sweep_tolerance()
