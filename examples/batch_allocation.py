"""Batched multi-scenario allocation: what-if capacity sweeps and multi-fleet
epochs solved as ONE XLA program (paper Algorithm 4.1, vmapped).

    PYTHONPATH=src python examples/batch_allocation.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import CapacityEngine, sample_scenario, stack_scenarios

ENGINE = CapacityEngine()              # one configured engine for every solve


def whatif_capacity_sweep():
    """Paper Fig. 2, batched: re-solve one workload at B capacity points."""
    print("=== what-if capacity sweep (one batched solve) ===")
    base = sample_scenario(jax.random.PRNGKey(0), n_classes=40,
                           capacity_factor=1.1)
    factors = np.linspace(0.88, 1.2, 16)
    R0 = float(jnp.sum(base.r_up))
    scns = [base.replace(R=jnp.asarray(f * R0, base.A.dtype)) for f in factors]
    res = ENGINE.solve(scns)
    for f, tot, it in zip(factors, np.asarray(res.total),
                          np.asarray(res.iters)):
        print(f"  R = {f:4.2f} * R_o  ->  total = {tot:12.1f} cents  "
              f"(iters={int(it)})")


def ragged_tenant_mix():
    """Thousands of clusters with different class counts: one ragged batch."""
    print("\n=== ragged multi-cluster batch ===")
    ns = [5, 12, 40, 17, 64, 8]
    scns = [sample_scenario(jax.random.PRNGKey(i), n, capacity_factor=0.95)
            for i, n in enumerate(ns)]
    res = ENGINE.solve(stack_scenarios(scns))
    for b, n in enumerate(ns):
        inst = res.instance(b)
        print(f"  cluster {b}: n={n:3d}  chips={int(jnp.sum(inst.integer.r))}"
              f"  total={float(inst.integer.total):12.1f} cents")


if __name__ == "__main__":
    whatif_capacity_sweep()
    ragged_tenant_mix()
