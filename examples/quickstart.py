"""Quickstart: solve one capacity-allocation instance + train a tiny LM.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import CapacityEngine, sample_scenario


def allocator_demo():
    print("=== GNEP capacity allocation (the paper) ===")
    scn = sample_scenario(jax.random.PRNGKey(0), n_classes=50,
                          capacity_factor=0.92)
    engine = CapacityEngine()          # paper-default SolverConfig + Policies
    for method in ("centralized", "distributed"):
        res = engine.solve(scn, method=method)
        it = res.integer
        print(f"{method:12s}: total={float(it.total):12.1f} cents  "
              f"chips={int(jnp.sum(it.r))}/{int(scn.R)}  "
              f"admitted={int(jnp.sum(it.h))}/{int(jnp.sum(scn.H_up))} jobs  "
              f"iters={res.iters}")
    gap = (float(engine.solve(scn).fractional.total)
           / float(engine.solve(scn, method='centralized').fractional.total)
           - 1)
    print(f"equilibrium vs optimum gap: {gap*100:.2f}%  (paper: <= ~2%)")


def train_demo():
    print("\n=== tiny LM training on the same substrate ===")
    from repro.launch.train import main as train_main
    train_main(["--arch", "qwen3-0.6b", "--reduced", "--steps", "30",
                "--global-batch", "4", "--seq", "64", "--log-every", "10"])


if __name__ == "__main__":
    allocator_demo()
    train_demo()
