"""End-to-end fleet simulation: the paper's game allocates TPU chips across
tenant (arch x shape) classes, with a live node-failure event (capacity drop
-> re-solve -> elastic re-mesh) and a straggler mitigation event.

Profiles are fitted from the dry-run roofline terms when available, else from
built-in estimates, via core.profiles.from_roofline.

    PYTHONPATH=src python examples/multi_tenant_cluster.py
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.cluster import FleetSimulator, TenantSpec, epoch_batch

# (compute_s, collective_s, overhead_s) per job at 256 chips — taken from the
# dry-run roofline table (fallbacks if the sweep hasn't been run)
FALLBACK = {
    "qwen3-8b-train": (1.8, 0.9, 1.0),
    "qwen3-32b-serve": (0.6, 0.24, 1.0),
    "deepseek-serve": (0.3, 0.2, 1.0),
    "rwkv6-long": (0.2, 0.1, 1.0),
}

TENANTS = [
    TenantSpec("qwen3-8b-train", "qwen3-8b", "train_4k", deadline_s=120.0,
               H_up=12, H_low=4, penalty_per_job=20000.0),
    TenantSpec("qwen3-32b-serve", "qwen3-32b", "prefill_32k", deadline_s=30.0,
               H_up=16, H_low=8, penalty_per_job=30000.0),
    TenantSpec("deepseek-serve", "deepseek-moe-16b", "decode_32k",
               deadline_s=15.0, H_up=20, H_low=8, penalty_per_job=15000.0),
    TenantSpec("rwkv6-long", "rwkv6-7b", "long_500k", deadline_s=60.0,
               H_up=8, H_low=2, penalty_per_job=18000.0),
]


def show(tag, alloc):
    print(f"\n--- {tag}: total cost {alloc.total_cost:.0f} cents, "
          f"{alloc.iters} game iterations ---")
    for name, chips in alloc.chips.items():
        print(f"  {name:18s} chips={chips:5d} mesh={alloc.meshes[name]} "
              f"admitted_jobs={alloc.h[name]}")


def main():
    fleet = FleetSimulator(total_chips=900, tenants=TENANTS)
    try:
        alloc = fleet.epoch()
        print("(profiles fitted from dry-run roofline JSONs)")
    except (FileNotFoundError, AssertionError, KeyError):
        alloc = fleet.epoch(profiles=FALLBACK)
        print("(dry-run results not found; using fallback profiles)")
    profiles = None if fleet.history else FALLBACK
    show("epoch 0: steady state", alloc)

    # node failure: 256 chips (a pod slice) die -> capacity drop -> re-solve.
    # Running jobs re-mesh from checkpoints (repro.checkpoint reshards).
    alloc = fleet.fail_nodes(300)
    show("epoch 1: after losing 300 chips (paper Fig. 2, live)", alloc)

    # straggler mitigation: qwen3-8b-train shows stragglers; over-provision
    alloc = fleet.mark_straggler("qwen3-8b-train", factor=1.3)
    show("epoch 2: straggler over-provisioning on qwen3-8b-train", alloc)

    # capacity restored
    alloc = fleet.restore_nodes(300)
    show("epoch 3: capacity restored", alloc)

    # multi-fleet epoch: three regional fleets (different sizes / tenant
    # mixes) solved as ONE batched GNEP program — each fleet is a lane.
    fleets = [fleet,
              FleetSimulator(total_chips=600, tenants=TENANTS[:3]),
              FleetSimulator(total_chips=1400, tenants=TENANTS[1:])]
    allocs = epoch_batch(fleets, profiles=[None, FALLBACK, FALLBACK])
    for i, alloc in enumerate(allocs):
        show(f"epoch 4, fleet {i} (batched multi-fleet solve)", alloc)


if __name__ == "__main__":
    main()
