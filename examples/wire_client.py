"""Walkthrough of the allocd wire protocol: an `AllocClient` tenant talking
to an `AllocServer` over a real loopback socket.

By default the script starts its own in-process server (one command, no
setup); pass ``--connect HOST:PORT`` to drive an already-running daemon
started with ``python -m repro.launch.allocd --listen HOST:PORT`` instead.

The flow mirrors a real remote tenant:

1. connect, register a tenant window with a `TenantQuota`,
2. pipeline a sampled event trace as `offer` frames (no await between
   sends — admission acks and flush reports resolve asynchronously),
3. force one mid-trace flush, then `drain` and print the decoded
   `WireFlushReport`s — which are bit-equal to an offline
   `WindowSession.stream` replay of the accepted subtrace.

    PYTHONPATH=src python examples/wire_client.py
"""
import argparse
import asyncio

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (AdmissionWindow, CapacityEngine, FlushPolicy,
                        Policies, RoundingPolicy, SolverConfig, TenantQuota,
                        sample_event_trace, sample_scenario)
from repro.serving import AllocClient, AllocDaemon, AllocServer

B, N, N_MAX = 3, 4, 8                  # window geometry: lanes x classes
FLUSH_K = 3                            # coalesce 3 events per re-solve
QUOTA = TenantQuota(max_queued=32, max_lanes=B)


def make_engine():
    return CapacityEngine(SolverConfig(),
                          Policies(flush=FlushPolicy(max_events=FLUSH_K),
                                   rounding=RoundingPolicy(enabled=False)))


def make_lanes(seed=0):
    key = jax.random.PRNGKey(seed)
    return [sample_scenario(jax.random.fold_in(key, lane), N,
                            capacity_factor=1.3) for lane in range(B)]


async def run_tenant(host, port):
    lanes = make_lanes()
    events = sample_event_trace(7, AdmissionWindow(lanes, n_max=N_MAX), 8)

    client = await AllocClient.connect(host, port)
    try:
        # Lanes cross the wire as raw Table-5 fields + deterministic
        # re-derivation, so the server's window is bit-identical to ours.
        await client.register_tenant("demo", lanes, n_max=N_MAX, quota=QUOTA)

        # Pipelined offers: each send returns a WireTicket immediately.
        tickets = [client.offer("demo", ev) for ev in events[:5]]
        for i, t in enumerate(tickets):
            ok = await t.ack()         # admission decision (quota/backstop)
            print(f"offer {i}: accepted={ok}"
                  + ("" if ok else f" penalty={t.penalty:.1f}"))

        # Force the buffered partial epoch to re-equilibrate NOW — same
        # effect as an explicit WindowSession.flush at this boundary.
        await client.flush("demo")

        # More offers, then a graceful drain: fold queued events, flush
        # the trailing partial epoch, then return.
        tickets += [client.offer("demo", ev) for ev in events[5:]]
        await client.drain()

        for i, t in enumerate(tickets):
            report = await t.result()  # the flush that folded this event
            if report is not None:
                print(f"offer {i}: slot={t.slot} -> flush "
                      f"#{report.flush_seq} total="
                      f"{np.asarray(report.fractional.total).sum():.1f} "
                      f"iters={int(np.max(np.asarray(report.iters)))}")

        print(f"\n{len(client.reports('demo'))} flush reports decoded; "
              "each is bit-equal to the server-side daemon report and to "
              "an offline WindowSession.stream replay (tests/test_wire.py).")
    finally:
        await client.close()


async def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--connect", metavar="HOST:PORT", default=None,
                    help="drive an existing `launch.allocd --listen` daemon "
                         "instead of starting an in-process server")
    args = ap.parse_args()

    if args.connect:
        host, _, port = args.connect.rpartition(":")
        await run_tenant(host or "127.0.0.1", int(port))
        return

    daemon = AllocDaemon(make_engine(), queue_limit=256)
    server = AllocServer(daemon, host="127.0.0.1", port=0)
    await server.start()
    host, port = server.address
    print(f"in-process AllocServer listening on {host}:{port}\n")
    try:
        await run_tenant(host, port)
    finally:
        await server.close(drain=True)


if __name__ == "__main__":
    asyncio.run(main())
