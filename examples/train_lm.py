"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + auto-resume.

Default is a CPU-sized run; pass --full-100m for the ~100M configuration
(slower on CPU; the config is the point, not the wall-clock).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full-100m]
"""
import argparse

import jax
import numpy as np

from repro.launch.train import main as train_main
from repro.models import ModelConfig


def config_100m():
    # ~100M params: 12L, d=640, 10 heads, tied embeddings, 32k vocab
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=640,
        n_heads=10, n_kv=5, d_ff=2560, vocab=32768, head_dim=64,
        qk_norm=True, tie_embeddings=True, dtype="float32",
        param_dtype="float32", attn_q_chunk=256, attn_kv_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full_100m:
        from repro.models import init_params
        cfg = config_100m()
        n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))))
        print(f"[train_lm] model: {n/1e6:.0f}M params")
        train_main(["--steps", str(args.steps), "--global-batch", "4",
                    "--seq", "256", "--ckpt-dir", args.ckpt_dir,
                    "--schedule", "wsd"], cfg_override=cfg)
    else:
        train_main(["--arch", "qwen3-0.6b", "--reduced",
                    "--steps", str(args.steps), "--global-batch", "8",
                    "--seq", "128", "--ckpt-dir", args.ckpt_dir])


if __name__ == "__main__":
    main()
