"""Capacity-planner throughput: design-space candidates solved per second.

Expands a :class:`repro.core.planning.PlanSpec` fleet grid and times
``solve_plan``'s chunked batch path end-to-end (grid stacking + padded
chunk solves + frontier reduction), reported in candidates/sec — the
number that says how large a design space an operator can sweep
interactively.

``--shard`` additionally times the same plan lane-sharded over a 1-D
device mesh (forced host devices on CPU — the flag is injected
automatically when missing).  The warm-start mode (deadline-axis seeding)
is timed as an ungated context row: its benefit depends on how close
adjacent deadline points' equilibria are, which is workload-dependent.

``--json PATH`` writes the machine-readable record (``BENCH_plan.json``
by convention) that ``scripts/check_bench.py`` gates CI against; the
``grid`` / ``profile`` / ``fleet`` tags are config — records measured
over different design spaces are never compared.
"""
import argparse
import sys

# Forced host devices must be configured BEFORE jax initializes its backend,
# hence the sys.argv sniff at import time; programmatic main([...]) callers
# import jax first and must set the topology themselves.
if "--shard" in sys.argv:
    from repro._env import force_host_devices
    force_host_devices()

import jax
import numpy as np

from benchmarks.common import row, timed, write_bench_json
from repro.core import SolverConfig, lane_mesh
from repro.core.planning import (PlanSpec, VMTier, generate_grid,
                                 solve_plan)

TIERS = (VMTier("small", 1.0, 6.0), VMTier("mid", 2.0, 10.0),
         VMTier("large", 4.0, 16.0), VMTier("xlarge", 8.0, 28.0))


def make_spec(smoke: bool) -> PlanSpec:
    """The benchmark's fixed design space (smoke: 48, full: 1024 points)."""
    if smoke:
        return PlanSpec(
            n_classes=12, profile="bursty", rate=50.0, trace_events=256,
            cluster_sizes=(2000.0, 6000.0, 18000.0, 54000.0),
            vm_tiers=TIERS[:2], penalty_scales=(1.0, 2.0),
            deadline_scales=(0.8, 1.0, 1.2), seed=0)
    return PlanSpec(
        n_classes=12, profile="bursty", rate=50.0, trace_events=1024,
        cluster_sizes=tuple(float(r) for r in
                            np.geomspace(1000.0, 128000.0, 8).round()),
        vm_tiers=TIERS, penalty_scales=(0.5, 1.0, 2.0, 4.0),
        deadline_scales=tuple(np.linspace(0.7, 1.4, 8).round(2)), seed=0)


def fleet_tag(spec: PlanSpec) -> str:
    """Compact design-space descriptor recorded as a config tag."""
    return "x".join(map(str, spec.grid_shape))


def run_grid(spec, candidates, *, chunk, mesh=None, iters=3,
             warm=False) -> dict:
    """Time one plan solve configuration; returns its metrics section."""
    cfg = SolverConfig(mesh=mesh)
    B = len(candidates)

    def once():
        # warm mode needs the spec (chain structure); cold mode takes the
        # pre-expanded list so repeated timings don't re-derive the grid
        return solve_plan(spec if warm else candidates, config=cfg,
                          chunk=chunk, warm_start=warm)

    t = timed(once, iters=iters)
    rep = once()
    cps = B / t
    name = (f"plan_{fleet_tag(spec)}_chunk{chunk}"
            f"{'_warm' if warm else ''}"
            f"{f'_dev{mesh.devices.size}' if mesh is not None else ''}")
    row(name, t, f"candidates={B};cps={cps:.0f};"
        f"feasible={int(rep.feasible.sum())};chunks={rep.n_chunks}")
    return {"B": chunk, "n": spec.n_classes, "grid": B,
            "profile": spec.profile, "fleet": fleet_tag(spec),
            "candidates_per_sec": cps,
            "feasible_frac": float(rep.feasible.mean())}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shard", action="store_true",
                    help="also time the plan lane-sharded over a device "
                         "mesh")
    ap.add_argument("--chunk", type=int, default=None,
                    help="candidates per solve dispatch (default: 16 "
                         "smoke / 64 full)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: 48-candidate grid")
    ap.add_argument("--json", nargs="?", const="BENCH_plan.json",
                    default=None, metavar="PATH",
                    help="write machine-readable results "
                         "(default PATH: BENCH_plan.json)")
    args = ap.parse_args(argv)

    spec = make_spec(args.smoke)
    chunk = args.chunk if args.chunk is not None else (16 if args.smoke
                                                      else 64)
    candidates = generate_grid(spec)
    iters = 3

    results = {}
    results["grid"] = run_grid(spec, candidates, chunk=chunk, iters=iters)
    # warm-start context row (ungated): merged into the grid section so the
    # two cadences share one config block
    warm = run_grid(spec, candidates, chunk=chunk, iters=iters, warm=True)
    results["grid"]["warm_candidates_per_sec"] = warm["candidates_per_sec"]

    if args.shard:
        if jax.device_count() == 1:
            print("plan_perf: WARNING single-device topology — set "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8 (or "
                  "call repro._env.force_host_devices) before jax "
                  "initializes; the shard row measures nothing sharded",
                  file=sys.stderr)
        mesh = lane_mesh()
        shard = run_grid(spec, candidates, chunk=chunk, mesh=mesh,
                         iters=iters)
        shard["max_devices"] = mesh.devices.size
        results["grid_shard"] = shard

    if args.json:
        # solver-config provenance: check_bench.py treats the fingerprint as
        # configuration and refuses cross-config compares.  The sections
        # above run under SolverConfig() / SolverConfig(mesh=...) — the
        # mesh lives in the per-section max_devices tag instead.
        write_bench_json(args.json, "plan", results, smoke=args.smoke,
                         solver_config=SolverConfig().fingerprint())


if __name__ == "__main__":
    main()
