"""§Perf hillclimb runner: re-lower a cell under config variants and compare
roofline terms against the paper-faithful baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen3-32b \
        --shape train_4k --variants baseline,triangle,seqpar
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "perf"

VARIANTS = {
    "baseline": {},
    "triangle": {"attn_triangle": True},
    "seqpar": {"seq_parallel": True},
    "triangle+seqpar": {"attn_triangle": True, "seq_parallel": True},
    "remat_dots": {"remat": "dots"},
    "accum_half": {},          # handled specially: grad_accum // 2
    "accum_double": {},        # grad_accum * 2
    "cf1.0": {},               # MoE capacity_factor 1.0
    "loss_chunks16": {"loss_chunks": 16},
    "no_flash_decode": {"flash_decode": False},
    "flash_decode": {"flash_decode": True},
    "serve_tp_only": {"fsdp": False},   # serving: weights replicated over
                                        # 'data', sharded on 'model' only —
                                        # no FSDP gathers per token
}


def apply_variant(cfg, name):
    if name == "accum_half":
        return cfg.replace(grad_accum=max(1, cfg.grad_accum // 2))
    if name == "accum_double":
        return cfg.replace(grad_accum=cfg.grad_accum * 2)
    if name == "cf1.0":
        assert cfg.moe is not None
        import dataclasses
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=1.0))
    return cfg.replace(**VARIANTS[name])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline,triangle")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell

    RESULTS.mkdir(parents=True, exist_ok=True)
    base_terms = None
    for name in args.variants.split(","):
        cfg = apply_variant(get_config(args.arch), name)
        rec = lower_cell(args.arch, args.shape, cfg_override=cfg,
                         verbose=False)
        rf = rec["roofline"]
        out = RESULTS / f"{args.arch}__{args.shape}__{name}.json"
        out.write_text(json.dumps(rec, indent=1))
        msg = (f"{name:18s} t_c={rf['t_compute']:.4f} t_m={rf['t_memory']:.3f} "
               f"t_coll={rf['t_collective']:.4f} peak={rec['memory'].get('peak_gb', -1):.1f}GB "
               f"useful={rec['useful_ratio']:.2f} compile={rec['compile_s']}s")
        if base_terms is None:
            base_terms = rf
        else:
            msg += (f"  [d_c {rf['t_compute']/max(base_terms['t_compute'],1e-12)-1:+.1%}"
                    f" d_coll {rf['t_collective']/max(base_terms['t_collective'],1e-12)-1:+.1%}]")
        print(msg)


if __name__ == "__main__":
    main()
