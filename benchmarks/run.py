"""Benchmark harness — one module per paper table/figure + roofline/perf.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from benchmarks import (allocator_perf, paper_capacity, paper_deadlines,
                            paper_scalability, paper_tolerance, roofline)

    print("name,us_per_call,derived")
    paper_capacity.run(n_values=(100,) if args.quick else (100, 1000))
    paper_deadlines.run(n_values=(100,) if args.quick else (100, 1000))
    paper_scalability.run(sizes=(20, 100) if args.quick
                          else (20, 100, 200, 300, 400, 500))
    paper_tolerance.run(sizes=(60,) if args.quick else (60, 180, 300))
    allocator_perf.run(sizes=(100, 500) if args.quick
                       else (100, 500, 1000, 2000))
    roofline.run()


if __name__ == '__main__':
    main()
