"""Paper Figs. 4/5 — decreasing deadlines at fixed capacity (100 & 1000 CMs)."""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import sample_scenario, solve_centralized, solve_distributed


def run(n_values=(100, 1000), scales=(1.0, 0.9, 0.8, 0.7, 0.6, 0.5)):
    out = []
    for n in n_values:
        base = sample_scenario(jax.random.PRNGKey(0), n, capacity_factor=1.1)
        R = float(base.R)
        D0 = -(base.E) + 0.0  # D - C
        for s in scales:
            # tighten deadlines: E = C - s*D  => E' = E - (s-1)*D
            scn = sample_scenario(jax.random.PRNGKey(0), n,
                                  deadline_scale=s, capacity=R)
            c = solve_centralized(scn)
            d = solve_distributed(scn)
            feas = bool(c.feasible)
            t = timed(lambda: solve_distributed(scn).total, iters=2)
            gap = (float(d.total) - float(c.total)) / max(abs(float(c.total)),
                                                          1e-9)
            row(f"fig4_deadline_n{n}_s{s:.1f}", t,
                f"N={n};Dscale={s};feasible={feas};Cc={float(c.total):.0f};"
                f"Cd={float(d.total):.0f};chi={gap:.4f}")
            out.append((n, s, feas, float(c.total)))
    for n in n_values:
        tots = [c for (nn, s, feas, c) in out if nn == n and feas]
        assert all(t2 >= t1 - 1e-6 for t1, t2 in zip(tots, tots[1:])), tots
    return out


if __name__ == "__main__":
    run()
