"""Allocator solve-time hillclimb measurements (§Perf, measured CPU wall):

  paper-faithful serial loop  ->  jit whole-game  (->  Pallas RM sweep on TPU)
"""
import time

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.core import (sample_scenario, solve_centralized,
                        solve_distributed, solve_distributed_python)


def run(sizes=(100, 500, 1000, 2000)):
    for n in sizes:
        scn = sample_scenario(jax.random.PRNGKey(0), n, capacity_factor=0.95)
        t0 = time.perf_counter()
        _, iters, _ = solve_distributed_python(scn)
        t_serial = time.perf_counter() - t0
        t_jit = timed(lambda: solve_distributed(scn).total, iters=3)
        t_cent = timed(lambda: solve_centralized(scn).total, iters=3)
        row(f"alloc_n{n}", t_jit,
            f"paper_serial_s={t_serial:.4f};jit_s={t_jit:.5f};"
            f"centralized_s={t_cent:.5f};speedup={t_serial/t_jit:.0f}x")


if __name__ == "__main__":
    run()
