"""Allocator solve-time hillclimb measurements (§Perf, measured CPU wall):

  paper-faithful serial loop  ->  jit whole-game  (->  Pallas RM sweep on TPU)

``--batch`` benchmarks the batched multi-scenario engine: B independent
scenarios solved by one vmapped ``solve_distributed_batch`` program vs. a
per-instance Python loop over the jitted single solver, reported in
scenarios/sec.

``--shard`` benchmarks the device-sharded engine: the same batch solved
unsharded vs over a 1-D lane mesh at growing device counts (forced host
devices on CPU — the flag is injected automatically when missing), in
scenarios/sec per device count.  Each device's shard exits as soon as its
own lanes converge, so throughput scales with devices even before real
parallel hardware enters.

``--fused`` benchmarks the fused Alg. 4.1 iteration kernel
(``repro.kernels.gnep_iter``) against the unfused dispatch chain at a
pinned iteration count (``eps_bar=0`` + ``max_iters=steps`` forces every
lane through exactly ``steps`` iterations, so the wall-clock ratio is a
pure per-iteration cost ratio).  The gated ``speedup`` compares f64
against f64 — same element width, pure fusion win; the f32 fast-path
ratio is recorded ungated because CPU runners make it noise-dominated
(see docs/OPERATIONS.md on dtype policies).

``--json PATH`` additionally writes the machine-readable record
(``BENCH_allocator.json`` by convention) that ``scripts/check_bench.py``
gates CI against.
"""
import argparse
import sys
import time

# Forced host devices must be configured BEFORE jax initializes its backend,
# hence the sys.argv sniff at import time; programmatic main([...]) callers
# import jax first and must set the topology themselves (run_shard warns
# when it finds a single device).
if "--shard" in sys.argv:
    from repro._env import force_host_devices
    force_host_devices()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed, write_bench_json
from repro.core import (SolverConfig, lane_mesh, sample_scenario, shard_batch,
                        solve_centralized, solve_distributed,
                        solve_distributed_batch, solve_distributed_python,
                        stack_scenarios)


def run(sizes=(100, 500, 1000, 2000)):
    out = {}
    for n in sizes:
        scn = sample_scenario(jax.random.PRNGKey(0), n, capacity_factor=0.95)
        t0 = time.perf_counter()
        _, iters, _ = solve_distributed_python(scn)
        t_serial = time.perf_counter() - t0
        t_jit = timed(lambda: solve_distributed(scn).total, iters=3)
        t_cent = timed(lambda: solve_centralized(scn).total, iters=3)
        row(f"alloc_n{n}", t_jit,
            f"paper_serial_s={t_serial:.4f};jit_s={t_jit:.5f};"
            f"centralized_s={t_cent:.5f};speedup={t_serial/t_jit:.0f}x")
        out[n] = {"n": n, "jit_s": t_jit, "serial_s": t_serial,
                  "speedup": t_serial / t_jit}
    return out[max(out)]


def make_scenarios(B, n, ragged, seed0=0):
    ns = ([max(3, n - (i % 5) * (n // 5)) for i in range(B)]
          if ragged else [n] * B)
    return [sample_scenario(jax.random.PRNGKey(seed0 + i), ni,
                            capacity_factor=0.95)
            for i, ni in enumerate(ns)]


def run_batch(batch_sizes=(16, 64, 256), n=17, ragged=False, iters=3):
    """Batched engine vs per-instance loop at each B (one CSV row per B);
    returns the metrics dict of the LAST batch size only."""
    last = {}
    for B in batch_sizes:
        scns = make_scenarios(B, n, ragged)
        batch = stack_scenarios(scns)

        def loop():
            # one dispatch of the jitted single-instance program per scenario
            return [solve_distributed(s).total for s in scns]

        t_loop = timed(loop, iters=iters)
        t_batch = timed(lambda: solve_distributed_batch(batch).total,
                        iters=iters)
        sps_loop = B / t_loop
        sps_batch = B / t_batch
        last = {"B": B, "n": n, "ragged": ragged,
                "scenarios_per_sec": sps_batch,
                "loop_scenarios_per_sec": sps_loop,
                "speedup": sps_batch / sps_loop}
        row(f"alloc_batch_B{B}_n{n}{'_ragged' if ragged else ''}", t_batch,
            f"loop_s={t_loop:.4f};batch_s={t_batch:.5f};"
            f"loop_sps={sps_loop:.0f};batch_sps={sps_batch:.0f};"
            f"speedup={last['speedup']:.1f}x")
    return last


def run_fused(B=64, n=17, steps=48, iters=7):
    """Fused vs unfused iteration throughput at a pinned iteration count.

    ``eps_bar=0.0`` (never satisfiable) with ``max_iters=steps`` pins every
    lane to exactly ``steps`` best-reply iterations, so the fused and
    unfused programs do identical algorithmic work and their wall-clock
    ratio isolates per-iteration cost (hoisted prep + one fused body vs the
    re-derived dispatch chain).  Median of ``iters`` timed runs after a
    compile warmup; the f32 row reuses the fused program on a down-cast
    batch and is reported ungated.
    """
    import dataclasses  # local: only this mode rewrites batch leaf dtypes

    from repro.kernels.gnep_iter.ops import make_fused_iter_fn

    scns = make_scenarios(B, n, ragged=False)
    batch = stack_scenarios(scns)
    it_fn = make_fused_iter_fn()

    def cast32(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(jnp.float32)
        return x

    batch32 = jax.tree_util.tree_map(cast32, batch)

    def bench(b, iter_fn):
        def once():
            sol = solve_distributed_batch(b, eps_bar=0.0, lam=0.05,
                                          max_iters=steps, iter_fn=iter_fn)
            jax.block_until_ready(sol.r)
        return timed(once, iters=iters)

    t_unfused = bench(batch, None)
    t_fused = bench(batch, it_fn)
    t_fused32 = bench(batch32, it_fn)
    ips = B * steps / t_fused
    out = {"B": B, "n": n, "steps": steps,
           "iter": it_fn.__name__, "dtype_policy": "f64-vs-f64",
           "iterations_per_sec": ips,
           "unfused_iterations_per_sec": B * steps / t_unfused,
           "speedup": t_unfused / t_fused,
           "f32_speedup": t_unfused / t_fused32}
    row(f"alloc_fused_B{B}_n{n}_steps{steps}", t_fused,
        f"unfused_s={t_unfused:.4f};fused_s={t_fused:.4f};"
        f"fused32_s={t_fused32:.4f};ips={ips:.0f};"
        f"speedup={out['speedup']:.2f}x;f32_speedup={out['f32_speedup']:.2f}x")
    return out


def run_shard(B=256, n=96, ragged=True, iters=3, device_counts=None):
    """Sharded engine across growing lane-mesh sizes, steady state: the
    batch is placed on the mesh ONCE (``shard_batch``, the fleet-sweep
    resident-batch pattern) so repeated solves pay zero resharding.
    Returns the metrics at the largest device count plus the scaling over
    1 device (near-linear up to the physical core count on CPU)."""
    avail = jax.device_count()
    if avail == 1:
        print("run_shard: WARNING single-device topology — set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 (or call "
              "repro._env.force_host_devices) before jax initializes; "
              "nothing sharded will be measured", file=sys.stderr)
    if device_counts is None:
        device_counts = [d for d in (1, 2, 4, 8, 16) if d <= avail]
    scns = make_scenarios(B, n, ragged)
    batch = stack_scenarios(scns)

    t_plain = timed(lambda: solve_distributed_batch(batch).total, iters=iters)
    row(f"alloc_shard_B{B}_n{n}_unsharded", t_plain,
        f"sps={B / t_plain:.0f}")

    per_dev = {}
    for d in device_counts:
        mesh = lane_mesh(d)
        resident = shard_batch(batch, mesh)
        t = timed(
            lambda: solve_distributed_batch(resident, mesh=mesh).total,
            iters=iters)
        per_dev[d] = B / t
        row(f"alloc_shard_B{B}_n{n}_dev{d}", t,
            f"sps={per_dev[d]:.0f};vs_unsharded={t_plain / t:.2f}x;"
            f"vs_dev1={per_dev[d] / per_dev[device_counts[0]]:.2f}x")
    d_max = device_counts[-1]
    return {"B": B, "n": n, "ragged": ragged, "max_devices": d_max,
            "scenarios_per_sec": per_dev[d_max],
            "unsharded_scenarios_per_sec": B / t_plain,
            "per_device_count": {str(d): s for d, s in per_dev.items()},
            "scaling": per_dev[d_max] / per_dev[device_counts[0]]}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", action="store_true",
                    help="benchmark the batched multi-scenario engine")
    ap.add_argument("--shard", action="store_true",
                    help="benchmark the device-sharded engine (lane mesh)")
    ap.add_argument("--fused", action="store_true",
                    help="benchmark the fused Alg. 4.1 iteration kernel vs "
                         "the unfused dispatch chain")
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=[16, 64, 256])
    ap.add_argument("--n", type=int, default=17, help="classes per scenario")
    ap.add_argument("--ragged", action="store_true",
                    help="vary class counts across the batch")
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[100, 500, 1000, 2000],
                    help="per-instance mode: class counts to sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: tiny sweep, 1 timing iter")
    ap.add_argument("--json", nargs="?", const="BENCH_allocator.json",
                    default=None, metavar="PATH",
                    help="write machine-readable results "
                         "(default PATH: BENCH_allocator.json)")
    args = ap.parse_args(argv)

    # 3 timing iters even in smoke: the regression gate needs medians, and
    # the smoke's savings come from the smaller sizes, not fewer samples
    iters = 3
    results = {}
    if args.shard:
        # fixed sizes (not --n): lanes must carry real per-iteration work
        # for device scaling to be visible over dispatch overhead; the
        # smoke trims the device sweep, not the problem size
        dc = ([d for d in (1, 2, 8) if d <= jax.device_count()]
              if args.smoke else None)
        results["shard"] = run_shard(iters=iters, device_counts=dc)
    if args.batch:
        bs = [16] if args.smoke else args.batch_sizes
        results["batch"] = run_batch(bs, n=args.n, ragged=args.ragged,
                                     iters=iters)
    if args.fused:
        # same sizes in smoke and full: the fixed-iteration methodology is
        # already small (B*steps solves of n=17), and the gated ratio needs
        # the ISSUE-9 reference point (B=64) verbatim
        results["fused"] = run_fused(iters=7)
    if not (args.batch or args.shard or args.fused):
        results["single"] = run([100] if args.smoke else tuple(args.sizes))

    if args.json:
        # solver-config provenance: check_bench.py treats the fingerprint as
        # configuration and refuses cross-config (or pre-redesign) compares
        write_bench_json(args.json, "allocator", results, smoke=args.smoke,
                         solver_config=SolverConfig().fingerprint())


if __name__ == "__main__":
    main()
