"""Allocator solve-time hillclimb measurements (§Perf, measured CPU wall):

  paper-faithful serial loop  ->  jit whole-game  (->  Pallas RM sweep on TPU)

``--batch`` benchmarks the batched multi-scenario engine: B independent
scenarios solved by one vmapped ``solve_distributed_batch`` program vs. a
per-instance Python loop over the jitted single solver, reported in
scenarios/sec.
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.core import (sample_scenario, solve_centralized, solve_distributed,
                        solve_distributed_batch, solve_distributed_python,
                        stack_scenarios)


def run(sizes=(100, 500, 1000, 2000)):
    for n in sizes:
        scn = sample_scenario(jax.random.PRNGKey(0), n, capacity_factor=0.95)
        t0 = time.perf_counter()
        _, iters, _ = solve_distributed_python(scn)
        t_serial = time.perf_counter() - t0
        t_jit = timed(lambda: solve_distributed(scn).total, iters=3)
        t_cent = timed(lambda: solve_centralized(scn).total, iters=3)
        row(f"alloc_n{n}", t_jit,
            f"paper_serial_s={t_serial:.4f};jit_s={t_jit:.5f};"
            f"centralized_s={t_cent:.5f};speedup={t_serial/t_jit:.0f}x")


def run_batch(batch_sizes=(16, 64, 256), n=17, ragged=False, iters=3):
    """Batched engine vs per-instance loop at each B; returns the speedups."""
    speedups = {}
    for B in batch_sizes:
        ns = ([max(3, n - (i % 5) * (n // 5)) for i in range(B)]
              if ragged else [n] * B)
        scns = [sample_scenario(jax.random.PRNGKey(i), ni,
                                capacity_factor=0.95)
                for i, ni in enumerate(ns)]
        batch = stack_scenarios(scns)

        def loop():
            # one dispatch of the jitted single-instance program per scenario
            return [solve_distributed(s).total for s in scns]

        t_loop = timed(loop, iters=iters)
        t_batch = timed(lambda: solve_distributed_batch(batch).total,
                        iters=iters)
        sps_loop = B / t_loop
        sps_batch = B / t_batch
        speedups[B] = sps_batch / sps_loop
        row(f"alloc_batch_B{B}_n{n}{'_ragged' if ragged else ''}", t_batch,
            f"loop_s={t_loop:.4f};batch_s={t_batch:.5f};"
            f"loop_sps={sps_loop:.0f};batch_sps={sps_batch:.0f};"
            f"speedup={speedups[B]:.1f}x")
    return speedups


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", action="store_true",
                    help="benchmark the batched multi-scenario engine")
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=[16, 64, 256])
    ap.add_argument("--n", type=int, default=17, help="classes per scenario")
    ap.add_argument("--ragged", action="store_true",
                    help="vary class counts across the batch")
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[100, 500, 1000, 2000],
                    help="per-instance mode: class counts to sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: tiny sweep, 1 timing iter")
    args = ap.parse_args(argv)

    if args.batch:
        bs = [16] if args.smoke else args.batch_sizes
        run_batch(bs, n=args.n, ragged=args.ragged,
                  iters=1 if args.smoke else 3)
    else:
        run([100] if args.smoke else tuple(args.sizes))


if __name__ == "__main__":
    main()
