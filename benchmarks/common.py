import json
import subprocess
import time
from pathlib import Path

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)


def timed(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name, seconds, derived=""):
    print(f"{name},{seconds*1e6:.1f},{derived}")


def git_sha() -> str:
    """Short sha of HEAD, or "unknown" outside a git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=Path(__file__).resolve().parent,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def bench_meta(**extra) -> dict:
    """Provenance header every BENCH_*.json carries (see check_bench.py)."""
    return {
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "x64": bool(jax.config.jax_enable_x64),
        **extra,
    }


def write_bench_json(path, benchmark: str, results: dict, **meta) -> None:
    """Write one machine-readable benchmark record (the perf trajectory).

    ``results`` maps mode name -> flat dict of config + metric leaves.
    Exactly the metric names in ``scripts/check_bench.py``'s ``GATED``
    table (``scenarios_per_sec``, ``events_per_sec``, ``speedup``,
    ``scaling``) are regression-gated; context metrics like
    ``loop_scenarios_per_sec`` / ``unsharded_events_per_sec`` are
    recorded but not compared.
    """
    payload = {"benchmark": benchmark, **bench_meta(**meta),
               "results": results}
    p = Path(path)
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {p}")
