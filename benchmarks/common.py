import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)


def timed(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name, seconds, derived=""):
    print(f"{name},{seconds*1e6:.1f},{derived}")
