"""Paper Figs. 6/7 — cost gap & solve time vs number of CMs.

Three solver variants are timed:
  * centralized            — exact water-filling (jit), replaces AMPL+Knitro
  * distributed-serial     — Algorithm 4.1 exactly as the paper ran it
                             (python loop, one (P4) solve per CM); the
                             distributed wall-clock estimate divides the CM
                             loop by N and adds network RTTs (paper Sec. 5.3)
  * distributed-jit        — beyond-paper: the whole game as one XLA program
"""
import time

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.core import (distributed_walltime_estimate, sample_scenario,
                        solve_centralized, solve_distributed,
                        solve_distributed_python)


def run(sizes=(20, 100, 200, 300, 400, 500), seeds=(0, 1, 2), cf=0.95):
    for n in sizes:
        gaps, t_c, t_dj, t_est, iters_all = [], [], [], [], []
        for s in seeds:
            scn = sample_scenario(jax.random.PRNGKey(s), n,
                                  capacity_factor=cf)
            c = solve_centralized(scn)
            d = solve_distributed(scn)
            gaps.append((float(d.total) - float(c.total))
                        / max(abs(float(c.total)), 1e-9))
            t_c.append(timed(lambda: solve_centralized(scn).total, iters=2))
            t_dj.append(timed(lambda: solve_distributed(scn).total, iters=2))
            t0 = time.perf_counter()
            _, iters, cm_secs = solve_distributed_python(scn)
            serial = time.perf_counter() - t0
            t_est.append(distributed_walltime_estimate(
                n, iters, sum(cm_secs), rm_seconds=serial - sum(cm_secs)))
            iters_all.append(iters)
        row(f"fig6_gap_n{n}", float(np.mean(t_dj)),
            f"chi_mean={np.mean(gaps):.4f};chi_max={np.max(gaps):.4f}")
        row(f"fig7_time_n{n}", float(np.mean(t_dj)),
            f"centralized_s={np.mean(t_c):.4g};"
            f"distributed_jit_s={np.mean(t_dj):.4g};"
            f"distributed_paper_est_s={np.mean(t_est):.4g};"
            f"iters={np.mean(iters_all):.1f}")


if __name__ == "__main__":
    run()
