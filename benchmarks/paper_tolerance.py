"""Paper Fig. 8 — sensitivity of the cost gap to the stopping tolerance."""
import jax
import numpy as np

from benchmarks.common import row, timed
from repro.core import sample_scenario, solve_centralized, solve_distributed


def run(sizes=(60, 180, 300), seeds=(0, 1, 2),
        tolerances=(0.01, 0.03, 0.05, 0.10)):
    for eps in tolerances:
        gaps = []
        for n in sizes:
            for s in seeds:
                scn = sample_scenario(jax.random.PRNGKey(s), n,
                                      capacity_factor=0.93)
                c = solve_centralized(scn)
                d = solve_distributed(scn, eps_bar=eps)
                gaps.append((float(d.total) - float(c.total))
                            / max(abs(float(c.total)), 1e-9))
        t = timed(lambda: solve_distributed(scn, eps_bar=eps).total, iters=2)
        row(f"fig8_tolerance_eps{eps:.2f}", t,
            f"chi_mean={np.mean(gaps):.4f};chi_max={np.max(gaps):.4f}")


if __name__ == "__main__":
    run()
