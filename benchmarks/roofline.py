"""Roofline table assembly from the dry-run JSONs (EXPERIMENTS.md §Roofline).

Prints one CSV row per (arch x shape) cell with the three terms, bottleneck,
MODEL_FLOPS/HLO_FLOPs, and flags the hillclimb candidates (worst compute
fraction / most collective-bound / technique-representative).

``--fused-iter`` (implied by ``--smoke``) adds one LIVE row for the fused
Alg. 4.1 iteration kernel (``repro.kernels.gnep_iter``): the analytic
flop/byte tally of the O(B x Nc x N) middle plus the measured iteration
rate at a pinned iteration count, so the arithmetic-intensity picture that
motivates the f32 dtype policy (halved bytes, identical flops) is a
number in CI output rather than prose.  ``--smoke`` is what
``scripts/ci.sh`` runs in the full tier."""
import argparse
import json
from pathlib import Path

from benchmarks.common import row

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"
HBM_BW = 819e9


def load(mesh="single"):
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def memory_model_seconds(rec, chips=256):
    """Analytic per-device HBM-traffic estimate (XLA:CPU 'bytes accessed' is
    an UN-FUSED upper bound; this models what a fused TPU executable reads/
    writes: weights per pass, residuals, KV cache, optimizer state).

    Returns seconds at 819 GB/s.  See EXPERIMENTS.md §Roofline notes."""
    from repro.configs import get_config
    from repro.models.config import SHAPES_BY_NAME
    cfg = get_config(rec["arch"])
    shape = SHAPES_BY_NAME[rec["shape"]]
    pb = rec["params_total"] * 2 / chips                 # bf16 weights/device
    B, S = shape.global_batch, shape.seq_len
    L, d = cfg.n_layers, cfg.d_model
    if shape.kind == "train":
        M = cfg.grad_accum
        tok_dev = B * S / 16 / max(M, 1)                 # dp=16, per micro
        act = 8 * tok_dev * d * 2 * L                    # r/w few times/layer
        resid = 2 * 2 * tok_dev * d * 2 * L              # save+read residuals
        opt = rec["params_total"] * 12 / chips           # m/v/master r/w
        bytes_ = M * (3 * pb + act + resid) + opt
    elif shape.kind == "prefill":
        tok_dev = B * S / 16
        bytes_ = pb + 8 * tok_dev * d * 2 * L
    else:  # decode: every weight + the whole cache read once per token
        n_attn = sum(1 for m, _ in cfg.layer_kinds() if m == "attn")
        cache = (2 * B * S * cfg.n_kv * cfg.hd * 2 * n_attn) / chips
        bytes_ = pb + cache + B * d * 2 * L / chips * 8
    return bytes_ / HBM_BW


def enrich(r):
    """Add the model-based memory term + model bottleneck/fraction."""
    rf = r["roofline"]
    tm_model = memory_model_seconds(r)
    terms = {"compute": rf["t_compute"], "memory": tm_model,
             "collective": rf["t_collective"]}
    rf["t_memory_model"] = tm_model
    rf["bottleneck_model"] = max(terms, key=terms.get)
    rf["compute_fraction_model"] = rf["t_compute"] / max(max(terms.values()),
                                                         1e-30)
    return r


def run(mesh="single"):
    recs = [enrich(r) for r in load(mesh)]
    for r in recs:
        rf = r["roofline"]
        name = f"roofline_{r['arch']}_{r['shape']}"
        t_bound = max(rf["t_compute"], rf["t_memory_model"],
                      rf["t_collective"])
        derived = (f"t_compute={rf['t_compute']:.4g};"
                   f"t_memory_hlo={rf['t_memory']:.4g};"
                   f"t_memory_model={rf['t_memory_model']:.4g};"
                   f"t_collective={rf['t_collective']:.4g};"
                   f"bottleneck={rf['bottleneck_model']};"
                   f"compute_frac={rf['compute_fraction_model']:.3f};"
                   f"useful_ratio={r['useful_ratio']:.3f};"
                   f"peak_gb={r['memory'].get('peak_gb', -1):.1f}")
        row(name, t_bound, derived)
    if recs:
        worst = min(recs, key=lambda r: r["roofline"]["compute_fraction_model"])
        coll = max(recs, key=lambda r: (r["roofline"]["t_collective"]
                                        / max(r["roofline"]["t_compute"],
                                              1e-12)))
        print(f"# hillclimb candidates: worst_fraction="
              f"{worst['arch']}:{worst['shape']}  most_collective="
              f"{coll['arch']}:{coll['shape']}")
    return recs


def run_fused_iter(B=64, n=17, steps=12, iters=3):
    """One live roofline row for the fused Alg. 4.1 iteration kernel.

    Analytic tally of the fused middle per iteration (Nc = n + 2
    candidates): ~6 flops per (candidate, class) cell (compare, two adds,
    clip's two compares, multiply-accumulate) against the minimum unique
    traffic — the three (B, N) class streams and the (B, Nc) candidate
    row read once, the three (B, Nc) accumulators kept resident (that
    residency is the kernel's VMEM-scratch point, so the model charges
    them once, not per class column).  The measured side pins the
    iteration count (``eps_bar=0`` + ``max_iters=steps``) and divides
    wall-clock across the whole fused body, so the achieved flop rate is
    a conservative lower bound for the middle alone.
    """
    import jax

    from benchmarks.common import timed
    from repro.core.game import solve_distributed_batch
    from repro.core.profiles import sample_scenario
    from repro.core.types import stack_scenarios
    from repro.kernels.gnep_iter.ops import make_fused_iter_fn

    batch = stack_scenarios(
        [sample_scenario(jax.random.PRNGKey(i), n, capacity_factor=0.95)
         for i in range(B)])
    nc = n + 2
    itemsize = jax.numpy.asarray(batch.scenarios.p).dtype.itemsize
    flops = 6.0 * B * nc * n
    bytes_ = float(itemsize) * B * (3 * n + 4 * nc)
    intensity = flops / bytes_

    it_fn = make_fused_iter_fn()

    def once():
        sol = solve_distributed_batch(batch, eps_bar=0.0, lam=0.05,
                                      max_iters=steps, iter_fn=it_fn)
        jax.block_until_ready(sol.r)

    t = timed(once, iters=iters)
    t_iter = t / steps
    row(f"roofline_fused_iter_B{B}_n{n}", t_iter,
        f"flops_per_iter={flops:.3g};min_bytes_per_iter={bytes_:.3g};"
        f"intensity_flops_per_byte={intensity:.2f};"
        f"iters_per_sec={B * steps / t:.0f};"
        f"achieved_gflops={flops / t_iter / 1e9:.3f}")
    return {"B": B, "n": n, "steps": steps, "flops_per_iter": flops,
            "min_bytes_per_iter": bytes_, "intensity": intensity,
            "iter_s": t_iter}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="single",
                    help="which dry-run mesh's JSONs to assemble")
    ap.add_argument("--fused-iter", action="store_true",
                    help="measure the live fused Alg. 4.1 iteration row")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: assemble whatever dry-run rows "
                         "exist and measure the fused-iteration row at a "
                         "short pinned iteration count")
    args = ap.parse_args(argv)
    recs = run(args.mesh)
    if args.smoke or args.fused_iter:
        run_fused_iter(steps=12 if args.smoke else 48)
    return recs


if __name__ == "__main__":
    main()
