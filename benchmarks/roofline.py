"""Roofline table assembly from the dry-run JSONs (EXPERIMENTS.md §Roofline).

Prints one CSV row per (arch x shape) cell with the three terms, bottleneck,
MODEL_FLOPS/HLO_FLOPs, and flags the hillclimb candidates (worst compute
fraction / most collective-bound / technique-representative)."""
import json
from pathlib import Path

from benchmarks.common import row

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"
HBM_BW = 819e9


def load(mesh="single"):
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def memory_model_seconds(rec, chips=256):
    """Analytic per-device HBM-traffic estimate (XLA:CPU 'bytes accessed' is
    an UN-FUSED upper bound; this models what a fused TPU executable reads/
    writes: weights per pass, residuals, KV cache, optimizer state).

    Returns seconds at 819 GB/s.  See EXPERIMENTS.md §Roofline notes."""
    from repro.configs import get_config
    from repro.models.config import SHAPES_BY_NAME
    cfg = get_config(rec["arch"])
    shape = SHAPES_BY_NAME[rec["shape"]]
    pb = rec["params_total"] * 2 / chips                 # bf16 weights/device
    B, S = shape.global_batch, shape.seq_len
    L, d = cfg.n_layers, cfg.d_model
    if shape.kind == "train":
        M = cfg.grad_accum
        tok_dev = B * S / 16 / max(M, 1)                 # dp=16, per micro
        act = 8 * tok_dev * d * 2 * L                    # r/w few times/layer
        resid = 2 * 2 * tok_dev * d * 2 * L              # save+read residuals
        opt = rec["params_total"] * 12 / chips           # m/v/master r/w
        bytes_ = M * (3 * pb + act + resid) + opt
    elif shape.kind == "prefill":
        tok_dev = B * S / 16
        bytes_ = pb + 8 * tok_dev * d * 2 * L
    else:  # decode: every weight + the whole cache read once per token
        n_attn = sum(1 for m, _ in cfg.layer_kinds() if m == "attn")
        cache = (2 * B * S * cfg.n_kv * cfg.hd * 2 * n_attn) / chips
        bytes_ = pb + cache + B * d * 2 * L / chips * 8
    return bytes_ / HBM_BW


def enrich(r):
    """Add the model-based memory term + model bottleneck/fraction."""
    rf = r["roofline"]
    tm_model = memory_model_seconds(r)
    terms = {"compute": rf["t_compute"], "memory": tm_model,
             "collective": rf["t_collective"]}
    rf["t_memory_model"] = tm_model
    rf["bottleneck_model"] = max(terms, key=terms.get)
    rf["compute_fraction_model"] = rf["t_compute"] / max(max(terms.values()),
                                                         1e-30)
    return r


def run(mesh="single"):
    recs = [enrich(r) for r in load(mesh)]
    for r in recs:
        rf = r["roofline"]
        name = f"roofline_{r['arch']}_{r['shape']}"
        t_bound = max(rf["t_compute"], rf["t_memory_model"],
                      rf["t_collective"])
        derived = (f"t_compute={rf['t_compute']:.4g};"
                   f"t_memory_hlo={rf['t_memory']:.4g};"
                   f"t_memory_model={rf['t_memory_model']:.4g};"
                   f"t_collective={rf['t_collective']:.4g};"
                   f"bottleneck={rf['bottleneck_model']};"
                   f"compute_frac={rf['compute_fraction_model']:.3f};"
                   f"useful_ratio={r['useful_ratio']:.3f};"
                   f"peak_gb={r['memory'].get('peak_gb', -1):.1f}")
        row(name, t_bound, derived)
    if recs:
        worst = min(recs, key=lambda r: r["roofline"]["compute_fraction_model"])
        coll = max(recs, key=lambda r: (r["roofline"]["t_collective"]
                                        / max(r["roofline"]["t_compute"],
                                              1e-12)))
        print(f"# hillclimb candidates: worst_fraction="
              f"{worst['arch']}:{worst['shape']}  most_collective="
              f"{coll['arch']}:{coll['shape']}")
    return recs


if __name__ == "__main__":
    run()
