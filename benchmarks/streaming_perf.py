"""Streaming admission engine throughput (events/sec) and re-solve latency.

After every event (class arrival / departure / SLA edit / capacity change)
the window must be re-equilibrated.  Two ways:

* **warm** — the engine path: a ``CapacityEngine`` session over the live
  ``AdmissionWindow`` (free-slot recycling, no re-stacking);
  ``session.apply`` with a per-event flush policy re-solves only the
  dirtied lane, clean lanes are frozen at their stored equilibrium.
* **cold** — the PR-1 status quo, what ``epoch_batch`` did per epoch:
  rebuild the per-lane Scenario list from the window, ``stack_scenarios``
  the whole batch and ``solve_distributed_batch`` every lane from the cold
  Algorithm 4.1 init.

Both produce numerically equivalent equilibria (verified at the end of each
run); the streaming engine's win is doing only the dirty lane's iterations
and none of the host-side re-stacking.  Acceptance (ISSUE 2): >= 3x higher
events/sec than cold at B = 64 on CPU.

``--coalesce [K ...]`` adds the *epoch-coalesced* path
(``session.stream`` under ``FlushPolicy(max_events=K)``: fold K events into
one scatter-per-field window update + ONE warm re-solve) against the
per-event warm path — per-event streaming is dispatch-bound on CPU (the
PR 3 caveat), so coalescing is the amortization knob.  Acceptance
(ISSUE 4): >= 2x higher events/sec than per-event at B = 64 on CPU.

``--shard`` adds the device-sharded coalesced path
(``SolverConfig(mesh=...)`` over a 1-D lane mesh; forced host devices are
injected on CPU when missing): shards whose lanes are all clean exit with
zero iterations, and an epoch's dirty lanes spread across shards.  It
measures both residency modes — the host-round-trip status quo (window
state re-placed on the mesh every flush) and the device-resident sessions
(``SolverConfig(residency="resident")``: events scattered into
mesh-resident arrays, warm-start buffers donated between solves) — and
gates resident-vs-round-trip speedup (ISSUE 7 acceptance: >= 2x).

``--json PATH`` writes the machine-readable record (``BENCH_streaming.json``)
that ``scripts/check_bench.py`` gates CI against; every section carries a
``path`` tag (``per-event`` / ``coalesced-epochs`` / ``shard-coalesced``)
and the sharded sections a ``residency`` tag (``round-trip`` /
``resident``) so the per-event, coalesced, sharded and resident events/sec
can never be conflated, and the record carries the ``SolverConfig``
fingerprint so engine-path numbers are never compared against
pre-redesign baselines.

    PYTHONPATH=src python -m benchmarks.streaming_perf            # full
    PYTHONPATH=src python -m benchmarks.streaming_perf --smoke    # CI
"""
import argparse
import sys
import time

# Forced host devices must be configured BEFORE jax initializes its backend,
# hence the sys.argv sniff at import time; programmatic main([...]) callers
# import jax first and must set the topology themselves (run_shard warns
# when it finds a single device).
if "--shard" in sys.argv:
    from repro._env import force_host_devices
    force_host_devices()

import jax
import numpy as np

from benchmarks.common import row, write_bench_json
from repro.core import (AdmissionWindow, CapacityEngine, FlushPolicy,
                        Policies, RoundingPolicy, SolverConfig, lane_mesh,
                        sample_event_trace, sample_scenario,
                        solve_distributed_batch, stack_scenarios)


def make_engine(k, *, mesh=None, residency="round-trip"):
    """Benchmark engine: flush every ``k`` events, rounding off (both paths
    time the fractional solve, as the pre-redesign benchmark did).
    ``residency="resident"`` opts the session into device-resident sharded
    state (requires ``mesh``)."""
    return CapacityEngine(
        SolverConfig(mesh=mesh, residency=residency),
        Policies(flush=FlushPolicy(max_events=k),
                 rounding=RoundingPolicy(False)))


def build_window(B, n, *, headroom=2.0, seed=0):
    """B lanes of n classes each, with slot headroom to avoid growth repads
    mid-benchmark (growth is correct but recompiles both paths)."""
    scns = [sample_scenario(jax.random.PRNGKey(seed + i), n,
                            capacity_factor=1.3) for i in range(B)]
    return AdmissionWindow(scns, n_max=int(n * headroom))


def cold_resolve(window):
    """The naive full re-solve: re-stack every lane's Scenario, solve cold."""
    scns = [window.batch.instance(b) for b in range(window.batch_size)]
    batch = stack_scenarios(scns, n_max=window.n_max)
    return batch, solve_distributed_batch(batch)


def stream_events(build, trace, *, mesh=None):
    """Per-event warm path (``session.apply``, flush every event); returns
    (total_s, per-solve latencies, result).

    ``build`` is a zero-arg window factory: a full untimed replay on a
    throwaway window warms every compile cache (solver program AND the
    fused event-write scatters) so the timed pass measures steady-state
    dispatch, not one-off XLA compiles.
    """
    eng = make_engine(1, mesh=mesh)
    sess = eng.open_window(build())
    jax.block_until_ready(sess.solve().fractional.r)
    for ev in trace:                              # compile-cache warmup pass
        jax.block_until_ready(sess.apply(ev).fractional.r)

    sess = eng.open_window(build())
    jax.block_until_ready(sess.solve().fractional.r)
    lat = []
    t0 = time.perf_counter()
    res = None
    for ev in trace:
        t1 = time.perf_counter()
        res = sess.apply(ev)
        jax.block_until_ready(res.fractional.r)
        lat.append(time.perf_counter() - t1)
    return time.perf_counter() - t0, lat, res


def stream_coalesced(build, trace, k, *, mesh=None, residency="round-trip"):
    """Coalesced warm path (``session.stream``, k events per flush);
    returns (total_s, final result).  Same ``build``-factory warmup
    convention as :func:`stream_events`.  With ``residency="resident"``
    the initial untimed solve makes the window device-resident, so the
    timed replay measures the steady state the daemon would see: event
    scatters into mesh-resident arrays, donated warm-start buffers, zero
    per-flush host->mesh re-placement."""
    eng = make_engine(k, mesh=mesh, residency=residency)

    def replay(w):
        sess = eng.open_window(w)
        res = None
        for res in sess.stream(trace):
            jax.block_until_ready(res.fractional.r)
        return res

    w = build()                                   # compile-cache warmup pass
    jax.block_until_ready(
        make_engine(1, mesh=mesh, residency=residency)
        .open_window(w).solve().fractional.r)
    replay(w)

    window = build()
    jax.block_until_ready(
        make_engine(1, mesh=mesh, residency=residency)
        .open_window(window).solve().fractional.r)
    t0 = time.perf_counter()
    res = replay(window)
    return time.perf_counter() - t0, res


def assert_equiv(window, warm_r, cold_r):
    """Final warm equilibrium == final cold equilibrium (through the mask).

    The cold re-stack compacts each lane's classes to a prefix while the
    live window keeps them in their (recycled) slots, so gather through the
    mask before comparing.  Tolerance is loose only to absorb the
    summation-order difference of the two layouts; the layout-identical
    equivalence (<= 1e-6) is asserted in tests/test_streaming.py and
    tests/test_sharding.py.
    """
    warm_r, cold_r = np.asarray(warm_r), np.asarray(cold_r)
    for b in range(window.batch_size):
        sel = np.flatnonzero(window._mask[b])
        np.testing.assert_allclose(warm_r[b, sel], cold_r[b, :sel.size],
                                   rtol=1e-5, atol=1e-5)


def run(B=64, n=12, n_events=120, seed=0):
    """Time warm vs cold event handling; returns the metrics dict."""
    trace = sample_event_trace(seed + 1, build_window(B, n, seed=seed),
                               n_events)

    t_warm, lat_w, res_w = stream_events(
        lambda: build_window(B, n, seed=seed), trace)

    # -- cold: re-stack + full batched re-solve per event -------------------
    c = build_window(B, n, seed=seed)
    jax.block_until_ready(cold_resolve(c)[1].r)      # compile once
    lat_c = []
    t0 = time.perf_counter()
    for ev in trace:
        t1 = time.perf_counter()
        c.apply(ev)
        _, res_c = cold_resolve(c)
        jax.block_until_ready(res_c.r)
        lat_c.append(time.perf_counter() - t1)
    t_cold = time.perf_counter() - t0

    # same trace -> same final mask, so the cold window addresses both
    assert_equiv(c, res_w.fractional.r, res_c.r)

    eps_w, eps_c = n_events / t_warm, n_events / t_cold
    speedup = eps_w / eps_c
    row(f"stream_B{B}_n{n}_ev{n_events}", t_warm / n_events,
        f"warm_evps={eps_w:.1f};cold_evps={eps_c:.1f};"
        f"warm_p50_ms={1e3 * np.median(lat_w):.2f};"
        f"cold_p50_ms={1e3 * np.median(lat_c):.2f};"
        f"speedup={speedup:.1f}x")
    return {"B": B, "n": n, "n_events": n_events, "path": "per-event",
            "events_per_sec": eps_w, "cold_events_per_sec": eps_c,
            "warm_p50_ms": 1e3 * float(np.median(lat_w)),
            "speedup": speedup}


def run_coalesce(B=64, n=12, n_events=120, seed=0, ks=(2, 4, 8, 16)):
    """Coalesced epochs (``session.stream``) vs the per-event warm path on
    the same trace; returns the largest factor's metrics.  ``speedup`` is
    events/sec at the largest K over per-event events/sec — the ISSUE 4
    acceptance asks >= 2x at B = 64 on CPU."""
    trace = sample_event_trace(seed + 1, build_window(B, n, seed=seed),
                               n_events)

    t1, _, res1 = stream_events(lambda: build_window(B, n, seed=seed), trace)
    evps = {1: n_events / t1}
    row(f"stream_coalesce_B{B}_n{n}_k1", t1 / n_events,
        f"evps={evps[1]:.1f}")

    for k in ks:
        t, res_k = stream_coalesced(lambda: build_window(B, n, seed=seed),
                                    trace, k)
        evps[k] = n_events / t
        row(f"stream_coalesce_B{B}_n{n}_k{k}", t / n_events,
            f"evps={evps[k]:.1f};vs_per_event={evps[k] / evps[1]:.2f}x")
        # every flush boundary lands on the per-event equilibrium; the final
        # one is checked here (intermediate ones in tests/test_coalescing.py)
        np.testing.assert_allclose(np.asarray(res_k.fractional.r),
                                   np.asarray(res1.fractional.r),
                                   rtol=1e-6, atol=1e-6)
    k_max = ks[-1]
    return {"B": B, "n": n, "n_events": n_events, "coalesce": k_max,
            "path": "coalesced-epochs",
            "events_per_sec": evps[k_max],
            "per_event_events_per_sec": evps[1],
            "per_coalesce_factor": {str(k): s for k, s in evps.items()},
            "speedup": evps[k_max] / evps[1]}


def run_shard(B=64, n=24, n_events=64, seed=0, chunk=8, device_counts=None,
              resident_sweep=True):
    """Coalesced streaming epochs (``chunk`` events per flush, the
    ``epoch_stream`` pattern) under a lane mesh at growing device counts vs
    the unsharded coalesced path; returns the ``(round-trip, resident)``
    section pair.  Coalescing matters: a single dirty lane keeps one shard
    busy, ``chunk`` dirty lanes spread across all of them.

    The round-trip sweep re-places window state on the mesh every flush
    (the pre-residency status quo whose scaling regressed 0.59 -> 0.31
    across PRs 3-5); the resident sweep keeps it device-resident
    (``SolverConfig(residency="resident")``).  The gated ``speedup`` in
    the resident section is resident evps over round-trip evps at the
    largest device count — the ISSUE 7 acceptance asks >= 2x.  With
    ``resident_sweep=False`` (the CI smoke) residency is only measured at
    the largest count, skipping the per-mesh-size recompiles."""
    avail = jax.device_count()
    if avail == 1:
        print("run_shard: WARNING single-device topology — set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 (or call "
              "repro._env.force_host_devices) before jax initializes; "
              "nothing sharded will be measured", file=sys.stderr)
    if device_counts is None:
        device_counts = [d for d in (1, 2, 4, 8, 16) if d <= avail]
    trace = sample_event_trace(seed + 1, build_window(B, n, seed=seed),
                               n_events)

    t_plain, res_plain = stream_coalesced(
        lambda: build_window(B, n, seed=seed), trace, chunk)
    row(f"stream_shard_B{B}_n{n}_c{chunk}_unsharded", t_plain / n_events,
        f"evps={n_events / t_plain:.1f}")

    per_dev = {}
    for d in device_counts:
        mesh = lane_mesh(d)
        t, res_d = stream_coalesced(lambda: build_window(B, n, seed=seed),
                                    trace, chunk, mesh=mesh)
        per_dev[d] = n_events / t
        row(f"stream_shard_B{B}_n{n}_c{chunk}_dev{d}", t / n_events,
            f"evps={per_dev[d]:.1f};vs_unsharded={t_plain / t:.2f}x;"
            f"vs_dev1={per_dev[d] / per_dev[device_counts[0]]:.2f}x")
        # sharded warm path lands on the same equilibria
        np.testing.assert_allclose(np.asarray(res_d.fractional.r),
                                   np.asarray(res_plain.fractional.r),
                                   rtol=1e-6, atol=1e-6)
    d_max = device_counts[-1]
    roundtrip = {"B": B, "n": n, "n_events": n_events, "chunk": chunk,
                 "path": "shard-coalesced", "residency": "round-trip",
                 "max_devices": d_max,
                 "events_per_sec": per_dev[d_max],
                 "unsharded_events_per_sec": n_events / t_plain,
                 "per_device_count": {str(d): s for d, s in per_dev.items()},
                 "scaling": per_dev[d_max] / per_dev[device_counts[0]]}

    # -- device-resident sessions: state stays on the mesh across flushes --
    res_counts = list(device_counts) if resident_sweep else [d_max]
    per_res = {}
    for d in res_counts:
        mesh = lane_mesh(d)
        t, res_d = stream_coalesced(lambda: build_window(B, n, seed=seed),
                                    trace, chunk, mesh=mesh,
                                    residency="resident")
        per_res[d] = n_events / t
        row(f"stream_shard_B{B}_n{n}_c{chunk}_dev{d}_resident",
            t / n_events,
            f"evps={per_res[d]:.1f};vs_roundtrip={per_res[d] / per_dev[d]:.2f}x")
        # residency is a layout change only: same equilibria
        np.testing.assert_allclose(np.asarray(res_d.fractional.r),
                                   np.asarray(res_plain.fractional.r),
                                   rtol=1e-6, atol=1e-6)
    resident = {"B": B, "n": n, "n_events": n_events, "chunk": chunk,
                "path": "shard-coalesced", "residency": "resident",
                "max_devices": d_max,
                "events_per_sec": per_res[d_max],
                "roundtrip_events_per_sec": per_dev[d_max],
                "unsharded_events_per_sec": n_events / t_plain,
                "per_device_count": {str(d): s for d, s in per_res.items()},
                "speedup": per_res[d_max] / per_dev[d_max]}
    if len(res_counts) > 1:
        resident["scaling"] = per_res[d_max] / per_res[res_counts[0]]
    return roundtrip, resident


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch-size", "-B", type=int, default=64)
    ap.add_argument("--n", type=int, default=12, help="initial classes/lane")
    ap.add_argument("--events", type=int, default=120)
    ap.add_argument("--shard", action="store_true",
                    help="also benchmark the device-sharded coalesced path")
    ap.add_argument("--coalesce", nargs="*", type=int, default=None,
                    metavar="K",
                    help="also benchmark epoch-coalesced streaming at these "
                         "factors (bare flag: the default 2 4 8 16 sweep)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: tiny window and trace")
    ap.add_argument("--json", nargs="?", const="BENCH_streaming.json",
                    default=None, metavar="PATH",
                    help="write machine-readable results "
                         "(default PATH: BENCH_streaming.json)")
    args = ap.parse_args(argv)

    results = {}
    if args.smoke:
        results["stream"] = run(B=8, n=6, n_events=12)
    else:
        results["stream"] = run(B=args.batch_size, n=args.n,
                                n_events=args.events)
    if args.coalesce is not None:
        ks = tuple(sorted(args.coalesce)) or (2, 4, 8, 16)
        # fixed sizes in the smoke (the gate needs a stable config)
        results["coalesce"] = (run_coalesce(B=8, n=6, n_events=24,
                                            ks=ks if args.coalesce else (2, 8))
                               if args.smoke
                               else run_coalesce(B=args.batch_size, n=args.n,
                                                 n_events=args.events, ks=ks))
    if args.shard:
        # fixed sizes (not -B/--n): the sharded section needs lanes with
        # enough per-solve work for the comparison to measure anything,
        # and the gate needs a stable config; the smoke trims the trace
        # and measures residency only at the largest device count
        shard, shard_res = (run_shard(n_events=32, resident_sweep=False)
                            if args.smoke else run_shard())
        results["shard"] = shard
        results["shard_resident"] = shard_res

    if args.json:
        # the engine-config fingerprint is part of the record's identity:
        # check_bench.py refuses to compare records measured under
        # different solver configs (or pre-redesign records without one)
        write_bench_json(args.json, "streaming", results, smoke=args.smoke,
                         solver_config=SolverConfig().fingerprint())


if __name__ == "__main__":
    main()
