"""Streaming admission engine throughput (events/sec) and re-solve latency.

After every event (class arrival / departure / SLA edit / capacity change)
the window must be re-equilibrated.  Two ways:

* **warm** — the streaming engine: apply the event to the live
  ``AdmissionWindow`` (free-slot recycling, no re-stacking) and
  ``solve_streaming`` (only the dirtied lane iterates; clean lanes are
  frozen at their stored equilibrium).
* **cold** — the PR-1 status quo, what ``epoch_batch`` does per epoch:
  rebuild the per-lane Scenario list from the window, ``stack_scenarios``
  the whole batch and ``solve_distributed_batch`` every lane from the cold
  Algorithm 4.1 init.

Both produce numerically equivalent equilibria (verified at the end of each
run); the streaming engine's win is doing only the dirty lane's iterations
and none of the host-side re-stacking.  Acceptance (ISSUE 2): >= 3x higher
events/sec than cold at B = 64 on CPU.

    PYTHONPATH=src python -m benchmarks.streaming_perf            # full
    PYTHONPATH=src python -m benchmarks.streaming_perf --smoke    # CI
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import (AdmissionWindow, sample_event_trace, sample_scenario,
                        solve_distributed_batch, solve_streaming,
                        stack_scenarios)


def build_window(B, n, *, headroom=2.0, seed=0):
    """B lanes of n classes each, with slot headroom to avoid growth repads
    mid-benchmark (growth is correct but recompiles both paths)."""
    scns = [sample_scenario(jax.random.PRNGKey(seed + i), n,
                            capacity_factor=1.3) for i in range(B)]
    return AdmissionWindow(scns, n_max=int(n * headroom))


def cold_resolve(window):
    """The naive full re-solve: re-stack every lane's Scenario, solve cold."""
    scns = [window.batch.instance(b) for b in range(window.batch_size)]
    batch = stack_scenarios(scns, n_max=window.n_max)
    return batch, solve_distributed_batch(batch)


def run(B=64, n=12, n_events=120, seed=0):
    """Time warm vs cold event handling; returns the events/sec speedup."""
    trace = sample_event_trace(seed + 1, build_window(B, n, seed=seed),
                               n_events)

    # -- warm: streaming engine ---------------------------------------------
    w = build_window(B, n, seed=seed)
    jax.block_until_ready(solve_streaming(w, integer=False).fractional.r)
    lat_w = []
    t0 = time.perf_counter()
    for ev in trace:
        t1 = time.perf_counter()
        w.apply(ev)
        res_w = solve_streaming(w, integer=False)
        jax.block_until_ready(res_w.fractional.r)
        lat_w.append(time.perf_counter() - t1)
    t_warm = time.perf_counter() - t0

    # -- cold: re-stack + full batched re-solve per event -------------------
    c = build_window(B, n, seed=seed)
    jax.block_until_ready(cold_resolve(c)[1].r)      # compile once
    lat_c = []
    t0 = time.perf_counter()
    for ev in trace:
        t1 = time.perf_counter()
        c.apply(ev)
        _, res_c = cold_resolve(c)
        jax.block_until_ready(res_c.r)
        lat_c.append(time.perf_counter() - t1)
    t_cold = time.perf_counter() - t0

    # -- equivalence of the final equilibria --------------------------------
    # The cold re-stack compacts each lane's classes to a prefix while the
    # live window keeps them in their (recycled) slots, so gather through
    # the mask before comparing.  Tolerance is loose only to absorb the
    # summation-order difference of the two layouts; the layout-identical
    # equivalence (<= 1e-6) is asserted in tests/test_streaming.py.
    warm_r, cold_r = np.asarray(res_w.fractional.r), np.asarray(res_c.r)
    for b in range(w.batch_size):
        sel = np.flatnonzero(w._mask[b])
        np.testing.assert_allclose(warm_r[b, sel], cold_r[b, :sel.size],
                                   rtol=1e-5, atol=1e-5)

    eps_w, eps_c = n_events / t_warm, n_events / t_cold
    speedup = eps_w / eps_c
    row(f"stream_B{B}_n{n}_ev{n_events}", t_warm / n_events,
        f"warm_evps={eps_w:.1f};cold_evps={eps_c:.1f};"
        f"warm_p50_ms={1e3 * np.median(lat_w):.2f};"
        f"cold_p50_ms={1e3 * np.median(lat_c):.2f};"
        f"speedup={speedup:.1f}x")
    return speedup


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch-size", "-B", type=int, default=64)
    ap.add_argument("--n", type=int, default=12, help="initial classes/lane")
    ap.add_argument("--events", type=int, default=120)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: tiny window and trace")
    args = ap.parse_args(argv)
    if args.smoke:
        run(B=8, n=6, n_events=12)
    else:
        run(B=args.batch_size, n=args.n, n_events=args.events)


if __name__ == "__main__":
    main()
