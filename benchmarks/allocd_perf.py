"""Admission daemon (allocd) latency + sustained throughput benchmark.

Drives the asyncio :class:`repro.serving.allocd.AllocDaemon` — many tenant
``WindowSession``s over one shared ``CapacityEngine`` — under the load
regimes the Hadoop utilization literature reports:

* **poisson** — open-loop Poisson arrivals at ``--rate`` events/s: the
  steady baseline regime.  Admission latency (scheduled arrival time to
  covering-flush completion, so queueing delay is included) is the
  headline metric.
* **flash** — the same baseline with the middle 40% of events arriving
  8x faster: the flash-crowd spike.  p99 admission latency under the
  burst and the post-burst drain throughput are what the daemon's
  deadline-aware, slack-ordered flush scheduling is for.
* **diurnal** — sinusoidal rate modulation between the baseline and a 4x
  peak over two full cycles: the smooth day/night swing, where the flush
  cadence has time to adapt.

``--wire`` additionally measures every profile over the daemon's socket
transport (``repro.serving.server`` / ``client`` on a loopback
connection): latency is then *end-to-end* — offer frame out to flush
frame decoded — so framing, JSON codec and scheduling overhead are all
on the clock.  Wire sections are named ``wire_<arrival>`` and tagged
``transport: "wire"``; in-process sections carry ``transport:
"inproc"``.  Both ``transport`` and ``arrival`` are config keys in
``scripts/check_bench.py``, so socket and in-process records (or
different arrival processes) are never silently compared.

Per section the record carries ``admission_p50_ms`` /
``admission_p99_ms`` (gated as *latency*: fresh must not exceed the
baseline by more than the latency band) and ``events_per_sec`` (gated as
throughput).

Before the timed run, every tenant's trace is replayed through an offline
``WindowSession.stream`` — this both warms the jitted solver programs
(the timed daemon run measures dispatch, not compile) and provides the
bit-equality conformance oracle: the daemon's flush-boundary equilibria
must match the offline replay exactly, or the run aborts.

    PYTHONPATH=src python -m benchmarks.allocd_perf            # full
    PYTHONPATH=src python -m benchmarks.allocd_perf --smoke    # CI
"""
import argparse
import asyncio
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json
from repro.core import (AdmissionWindow, CapacityEngine, FlushPolicy,
                        Policies, RoundingPolicy, SolverConfig,
                        sample_event_trace, sample_scenario)
from repro.serving.allocd import (ARRIVAL_PROFILES, AllocDaemon,
                                  drive_open_loop, interleave_traces)
from repro.serving.client import AllocClient
from repro.serving.server import AllocServer


def make_engine(flush_k: int) -> CapacityEngine:
    return CapacityEngine(
        SolverConfig(),
        Policies(flush=FlushPolicy(max_events=flush_k),
                 rounding=RoundingPolicy(enabled=False)))


def make_lanes(tenant: int, lanes: int, n: int, seed: int) -> list:
    key = jax.random.PRNGKey(seed)
    return [sample_scenario(jax.random.fold_in(key, tenant * 97 + lane),
                            n, capacity_factor=1.3)
            for lane in range(lanes)]


def make_window(tenant: int, lanes: int, n: int, seed: int
                ) -> AdmissionWindow:
    return AdmissionWindow(make_lanes(tenant, lanes, n, seed), n_max=2 * n)


def assert_conformant(name, got, want):
    assert len(got) == len(want), \
        f"{name}: {len(got)} daemon flushes vs {len(want)} offline"
    for i, (a, b) in enumerate(zip(got, want)):
        la = jax.tree_util.tree_flatten(a.fractional)[0]
        lb = jax.tree_util.tree_flatten(b.fractional)[0]
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"{name}: flush {i} != offline replay")


async def _drive(engine, traces, windows, times, queue_limit):
    daemon = AllocDaemon(engine, queue_limit=queue_limit)
    for name, window in windows.items():
        daemon.add_tenant(name, window)
    schedule = interleave_traces(traces, times)
    await daemon.start()
    await drive_open_loop(daemon, schedule)
    await daemon.shutdown(drain=True)
    return daemon


async def _drive_wire(engine, traces, lanes_by_tenant, n_max, times,
                      queue_limit):
    daemon = AllocDaemon(engine, queue_limit=queue_limit)
    server = AllocServer(daemon)
    await server.start()
    client = await AllocClient.connect(*server.address)
    for name, scns in lanes_by_tenant.items():
        await client.register_tenant(name, scns, n_max=n_max)
    schedule = interleave_traces(traces, times)
    t0 = time.perf_counter()
    tickets = []
    for t_off, tenant, event in schedule:
        delay = (t0 + t_off) - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        tickets.append(client.offer(tenant, event, t_submit=t0 + t_off))
    await client.drain()
    for tk in tickets:
        assert await tk.result() is not None, "wire benchmark event lost"
    reports = {name: list(client.reports(name)) for name in traces}
    rejected = daemon.rejected
    flushes = sum(daemon.tenant_stats(n)["flushes"] for n in traces)
    await client.close()
    await server.close()
    lat = np.asarray([tk.t_done - tk.t_submit for tk in tickets])
    elapsed = max(max(tk.t_done for tk in tickets) - t0, 1e-9)
    return reports, rejected, {
        "events_per_sec": float(len(tickets) / elapsed),
        "admission_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "admission_p99_ms": float(np.percentile(lat, 99) * 1e3),
        "flushes": float(flushes), "elapsed_s": float(elapsed)}


def run_arrival(arrival: str, *, tenants: int, lanes: int, n: int,
                n_events: int, rate: float, flush_k: int, seed: int,
                queue_limit: int, transport: str = "inproc") -> dict:
    engine = make_engine(flush_k)
    traces = {f"tenant-{t}": sample_event_trace(
        seed + 7919 * t, make_window(t, lanes, n, seed), n_events)
        for t in range(tenants)}

    # offline replays: compile warmup + the conformance oracle
    offline = {}
    for t in range(tenants):
        name = f"tenant-{t}"
        sess = engine.open_window(make_window(t, lanes, n, seed))
        offline[name] = list(sess.stream(traces[name]))

    total = tenants * n_events
    times = ARRIVAL_PROFILES[arrival](seed, total, rate)
    if transport == "wire":
        # end-to-end over a loopback socket: frames, codec and scheduling
        # all inside the measured admission latency
        lanes_by_tenant = {
            f"tenant-{t}": make_lanes(t, lanes, n, seed)
            for t in range(tenants)}
        reports, rejected, rep = asyncio.run(_drive_wire(
            engine, traces, lanes_by_tenant, 2 * n, times, queue_limit))
        assert rejected == 0, "sizing error: benchmark load was shed"
        for name in traces:
            assert_conformant(name, reports[name], offline[name])
    else:
        windows = {f"tenant-{t}": make_window(t, lanes, n, seed)
                   for t in range(tenants)}
        daemon = asyncio.run(
            _drive(engine, traces, windows, times, queue_limit))
        assert daemon.rejected == 0, "sizing error: benchmark load was shed"
        for name in traces:
            assert_conformant(name, daemon.reports(name), offline[name])
        rep = daemon.report()

    return {"arrival": arrival, "transport": transport, "tenants": tenants,
            "B": lanes, "n": n,
            "n_events": n_events, "rate": rate, "flush_k": flush_k,
            "queue_limit": queue_limit,
            "events_per_sec": rep["events_per_sec"],
            "admission_p50_ms": rep["admission_p50_ms"],
            "admission_p99_ms": rep["admission_p99_ms"],
            "flushes": rep["flushes"], "elapsed_s": rep["elapsed_s"]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--wire", action="store_true",
                    help="also run every arrival profile over the daemon's "
                         "loopback socket transport (wire_* sections)")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = dict(tenants=3, lanes=4, n=4, n_events=18, rate=400.0,
                   flush_k=4, seed=args.seed, queue_limit=4096)
    else:
        cfg = dict(tenants=8, lanes=8, n=8, n_events=48, rate=400.0,
                   flush_k=8, seed=args.seed, queue_limit=4096)

    runs = [("inproc", a) for a in ("poisson", "flash", "diurnal")]
    if args.wire:
        runs += [("wire", a) for a in ("poisson", "flash", "diurnal")]

    results = {}
    for transport, arrival in runs:
        section = arrival if transport == "inproc" else f"wire_{arrival}"
        t0 = time.perf_counter()
        res = run_arrival(arrival, transport=transport, **cfg)
        res["wall_s"] = time.perf_counter() - t0
        results[section] = res
        print(f"{section:13s} {res['tenants']}x{res['n_events']}ev "
              f"B={res['B']} n={res['n']}: "
              f"{res['events_per_sec']:8.1f} ev/s  "
              f"p50 {res['admission_p50_ms']:7.1f} ms  "
              f"p99 {res['admission_p99_ms']:7.1f} ms  "
              f"({res['flushes']:.0f} flushes, conformant)")

    if args.json:
        write_bench_json(args.json, "allocd", results, smoke=args.smoke,
                         solver_config=SolverConfig().fingerprint())
    return results


if __name__ == "__main__":
    main()
