"""Admission daemon (allocd) latency + sustained throughput benchmark.

Drives the asyncio :class:`repro.serving.allocd.AllocDaemon` — many tenant
``WindowSession``s over one shared ``CapacityEngine`` — under the two load
regimes the Hadoop utilization literature reports:

* **poisson** — open-loop Poisson arrivals at ``--rate`` events/s: the
  steady diurnal-baseline regime.  Admission latency (scheduled arrival
  time to covering-flush completion, so queueing delay is included) is
  the headline metric.
* **flash** — the same baseline with the middle 40% of events arriving
  8x faster: the flash-crowd spike.  p99 admission latency under the
  burst and the post-burst drain throughput are what the daemon's
  deadline-aware, slack-ordered flush scheduling is for.

Per arrival process the record carries ``admission_p50_ms`` /
``admission_p99_ms`` (gated as *latency*: fresh must not exceed the
baseline by more than the latency band) and ``events_per_sec`` (gated as
throughput).  Every section carries an ``arrival`` tag in its config keys
so Poisson and flash-crowd records are never silently compared.

Before the timed run, every tenant's trace is replayed through an offline
``WindowSession.stream`` — this both warms the jitted solver programs
(the timed daemon run measures dispatch, not compile) and provides the
bit-equality conformance oracle: the daemon's flush-boundary equilibria
must match the offline replay exactly, or the run aborts.

    PYTHONPATH=src python -m benchmarks.allocd_perf            # full
    PYTHONPATH=src python -m benchmarks.allocd_perf --smoke    # CI
"""
import argparse
import asyncio
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json
from repro.core import (AdmissionWindow, CapacityEngine, FlushPolicy,
                        Policies, RoundingPolicy, SolverConfig,
                        sample_event_trace, sample_scenario)
from repro.serving.allocd import (AllocDaemon, drive_open_loop,
                                  flash_crowd_times, interleave_traces,
                                  poisson_times)


def make_engine(flush_k: int) -> CapacityEngine:
    return CapacityEngine(
        SolverConfig(),
        Policies(flush=FlushPolicy(max_events=flush_k),
                 rounding=RoundingPolicy(enabled=False)))


def make_window(tenant: int, lanes: int, n: int, seed: int
                ) -> AdmissionWindow:
    key = jax.random.PRNGKey(seed)
    scns = [sample_scenario(jax.random.fold_in(key, tenant * 97 + lane),
                            n, capacity_factor=1.3)
            for lane in range(lanes)]
    return AdmissionWindow(scns, n_max=2 * n)


def assert_conformant(name, got, want):
    assert len(got) == len(want), \
        f"{name}: {len(got)} daemon flushes vs {len(want)} offline"
    for i, (a, b) in enumerate(zip(got, want)):
        la = jax.tree_util.tree_flatten(a.fractional)[0]
        lb = jax.tree_util.tree_flatten(b.fractional)[0]
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"{name}: flush {i} != offline replay")


async def _drive(engine, traces, windows, times, queue_limit):
    daemon = AllocDaemon(engine, queue_limit=queue_limit)
    for name, window in windows.items():
        daemon.add_tenant(name, window)
    schedule = interleave_traces(traces, times)
    await daemon.start()
    await drive_open_loop(daemon, schedule)
    await daemon.shutdown(drain=True)
    return daemon


def run_arrival(arrival: str, *, tenants: int, lanes: int, n: int,
                n_events: int, rate: float, flush_k: int, seed: int,
                queue_limit: int) -> dict:
    engine = make_engine(flush_k)
    traces = {f"tenant-{t}": sample_event_trace(
        seed + 7919 * t, make_window(t, lanes, n, seed), n_events)
        for t in range(tenants)}

    # offline replays: compile warmup + the conformance oracle
    offline = {}
    for t in range(tenants):
        name = f"tenant-{t}"
        sess = engine.open_window(make_window(t, lanes, n, seed))
        offline[name] = list(sess.stream(traces[name]))

    total = tenants * n_events
    times = (poisson_times(seed, total, rate) if arrival == "poisson"
             else flash_crowd_times(seed, total, rate))
    windows = {f"tenant-{t}": make_window(t, lanes, n, seed)
               for t in range(tenants)}
    daemon = asyncio.run(
        _drive(engine, traces, windows, times, queue_limit))
    assert daemon.rejected == 0, "sizing error: benchmark load was shed"
    for name in traces:
        assert_conformant(name, daemon.reports(name), offline[name])

    rep = daemon.report()
    return {"arrival": arrival, "tenants": tenants, "B": lanes, "n": n,
            "n_events": n_events, "rate": rate, "flush_k": flush_k,
            "queue_limit": queue_limit,
            "events_per_sec": rep["events_per_sec"],
            "admission_p50_ms": rep["admission_p50_ms"],
            "admission_p99_ms": rep["admission_p99_ms"],
            "flushes": rep["flushes"], "elapsed_s": rep["elapsed_s"]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = dict(tenants=3, lanes=4, n=4, n_events=18, rate=400.0,
                   flush_k=4, seed=args.seed, queue_limit=4096)
    else:
        cfg = dict(tenants=8, lanes=8, n=8, n_events=48, rate=400.0,
                   flush_k=8, seed=args.seed, queue_limit=4096)

    results = {}
    for arrival in ("poisson", "flash"):
        t0 = time.perf_counter()
        res = run_arrival(arrival, **cfg)
        res["wall_s"] = time.perf_counter() - t0
        results[arrival] = res
        print(f"{arrival:8s} {res['tenants']}x{res['n_events']}ev "
              f"B={res['B']} n={res['n']}: "
              f"{res['events_per_sec']:8.1f} ev/s  "
              f"p50 {res['admission_p50_ms']:7.1f} ms  "
              f"p99 {res['admission_p99_ms']:7.1f} ms  "
              f"({res['flushes']:.0f} flushes, conformant)")

    if args.json:
        write_bench_json(args.json, "allocd", results, smoke=args.smoke,
                         solver_config=SolverConfig().fingerprint())
    return results


if __name__ == "__main__":
    main()
