"""Paper Figs. 2/3 — decreasing capacity at fixed deadlines (100 & 1000 CMs).

Expected: flat cost with slack capacity, penalties as R approaches the
minimum aggregate requirement, infeasible below sum(r_low)."""
import jax
import numpy as np

from benchmarks.common import row, timed
from repro.core import sample_scenario, solve_centralized, solve_distributed


def run(n_values=(100, 1000), factors=(1.1, 1.05, 1.0, 0.95, 0.9, 0.85, 0.8)):
    out = []
    for n in n_values:
        # one dataset, shrunk capacity (paper Sec. 5.2)
        base = sample_scenario(jax.random.PRNGKey(0), n, capacity_factor=1.0)
        R_o = float(jax.numpy.sum(base.r_up))
        for f in factors:
            scn = base.replace(R=jax.numpy.asarray(f * R_o, base.A.dtype))
            c = solve_centralized(scn)
            d = solve_distributed(scn)
            feas = bool(c.feasible)
            gap = (float(d.total) - float(c.total)) / max(abs(float(c.total)),
                                                          1e-9)
            t = timed(lambda: solve_distributed(scn).total, iters=2)
            derived = (f"N={n};R/Ro={f:.2f};feasible={feas};"
                       f"Cc={float(c.total):.0f};Cd={float(d.total):.0f};"
                       f"chi={gap:.4f}")
            row(f"fig2_capacity_n{n}_f{f:.2f}", t, derived)
            out.append((n, f, feas, float(c.total), float(d.total)))
    # monotonicity check (the paper's qualitative claim): rows are ordered by
    # decreasing capacity, so cost must be non-decreasing
    for n in n_values:
        tots = [c for (nn, f, feas, c, d) in out if nn == n and feas]
        assert all(t2 >= t1 - 1e-6 for t1, t2 in zip(tots, tots[1:])), tots
    return out


if __name__ == "__main__":
    run()
