"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSONs.  Usage:  PYTHONPATH=src python -m benchmarks.make_experiments
"""
import json
from pathlib import Path

from benchmarks.roofline import enrich, load

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def fmt(x, p=3):
    if x == 0:
        return "0"
    if abs(x) < 1e-3 or abs(x) >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{p}f}"


def dryrun_table(mesh="single"):
    rows = ["| arch | shape | status | peak GB/dev | per-dev GFLOPs | "
            "per-dev GB moved | coll GB (wire) | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r["status"] == "ok":
            pd = r["per_device"]
            rows.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{r['memory'].get('peak_gb', -1):.1f} | "
                f"{pd['flops']/1e9:.0f} | {pd['bytes']/1e9:.0f} | "
                f"{pd['coll_bytes']/1e9:.2f} | {r['compile_s']} |")
        elif r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped "
                        f"(long-context, full-attn) | - | - | - | - | - |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - "
                        f"| - | - |")
    return "\n".join(rows)


def roofline_table():
    recs = [enrich(r) for r in load("single")]
    rows = ["| arch | shape | t_compute s | t_mem(HLO) s | t_mem(model) s | "
            "t_coll s | bottleneck | roofline frac | useful 6ND/HLO | note |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        rf = r["roofline"]
        note = ""
        if r["memory"].get("peak_gb", 0) > 16:
            note = "exceeds 16GB/dev single-pod"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['t_compute'])} | "
            f"{fmt(rf['t_memory'])} | {fmt(rf['t_memory_model'])} | "
            f"{fmt(rf['t_collective'])} | {rf['bottleneck_model']} | "
            f"{rf['compute_fraction_model']:.3f} | "
            f"{min(r['useful_ratio'], 9.99):.2f} | {note} |")
    return "\n".join(rows)


def main():
    print("### Dry-run table (single-pod 16x16)\n")
    print(dryrun_table("single"))
    multi = list(RESULTS.glob("*__multi.json"))
    if multi:
        print("\n### Dry-run table (multi-pod 2x16x16)\n")
        print(dryrun_table("multi"))
    print("\n### Roofline table (single-pod)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
