"""Randomized trace-conformance harness for the allocd admission daemon.

The daemon's contract (``src/repro/serving/allocd.py``): per tenant, the
flush-boundary equilibria it produces are BIT-EQUAL to an offline
``WindowSession.stream`` replay of that tenant's delivered events — under
multi-tenant interleaving, forced backpressure, mid-trace graceful drain
and mid-trace abort.  Plus the scheduling properties: slack-ordered
flushing across sessions and round-robin intake fairness.
"""
import asyncio
import itertools

import jax
import numpy as np
import pytest

from repro.core import (AdmissionWindow, CapacityEngine, ClassArrival,
                        FlushPolicy, Policies, RoundingPolicy, SolverConfig,
                        sample_class_params, sample_event_trace,
                        sample_scenario)
from repro.serving.allocd import (AllocDaemon, drive_open_loop,
                                  flash_crowd_times, interleave_traces,
                                  poisson_times, rejection_penalty)

B, N, N_MAX = 3, 4, 8          # one shared window shape: compile once


def make_engine(flush_k=3, slack=None):
    flush = (FlushPolicy.deadline(slack, max_events=flush_k)
             if slack is not None else FlushPolicy(max_events=flush_k))
    return CapacityEngine(SolverConfig(),
                          Policies(flush=flush,
                                   rounding=RoundingPolicy(enabled=False)))


def make_window(seed):
    key = jax.random.PRNGKey(seed)
    lanes = [sample_scenario(jax.random.fold_in(key, lane), N,
                             capacity_factor=1.3) for lane in range(B)]
    return AdmissionWindow(lanes, n_max=N_MAX)


def arrival(seed, E=None):
    params = dict(sample_class_params(jax.random.PRNGKey(seed)))
    if E is not None:
        params["E"] = E
    return ClassArrival(lane=seed % B, params=params)


def assert_reports_bitequal(got, want, *, prefix=False):
    if prefix:
        assert len(got) <= len(want)
    else:
        assert len(got) == len(want)
    for a, b in zip(got, want):
        la = jax.tree_util.tree_flatten(a.fractional)[0]
        lb = jax.tree_util.tree_flatten(b.fractional)[0]
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(a.iters),
                                      np.asarray(b.iters))
        np.testing.assert_array_equal(np.asarray(a.mask),
                                      np.asarray(b.mask))


def offline_replay(engine, seed, events):
    session = engine.open_window(make_window(seed))
    return list(session.stream(events))


async def submit_interleaved(daemon, traces, *, yield_between=True):
    """Round-robin submission; optionally let the scheduler interleave."""
    tickets = {name: [] for name in traces}
    for evs in itertools.zip_longest(*traces.values()):
        for name, ev in zip(traces, evs):
            if ev is not None:
                tickets[name].append(daemon.submit(name, ev))
        if yield_between:
            await asyncio.sleep(0)
    return tickets


# --------------------------------------------------------------------------
# Conformance: randomized multi-tenant traces
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_daemon_conformant_random_traces(seed):
    """Full random event mix (arrivals/departures/edits/capacity/bursts)
    through the daemon == offline per-tenant stream replays, bit-equal."""
    engine = make_engine(flush_k=3)
    traces = {f"t{i}": sample_event_trace(seed + 31 * i, make_window(i), 16)
              for i in range(3)}

    async def run():
        daemon = AllocDaemon(engine, queue_limit=None)
        for i in range(3):
            daemon.add_tenant(f"t{i}", make_window(i))
        await daemon.start()
        await submit_interleaved(daemon, traces)
        await daemon.shutdown(drain=True)
        return daemon

    daemon = asyncio.run(run())
    for i in range(3):
        want = offline_replay(engine, i, traces[f"t{i}"])
        assert_reports_bitequal(daemon.reports(f"t{i}"), want)
    rep = daemon.report()
    assert rep["rejected"] == 0
    assert rep["events_folded"] == sum(len(t) for t in traces.values())


def test_daemon_conformant_open_loop_schedules():
    """The timed (Poisson / flash-crowd) submission path conforms too."""
    engine = make_engine(flush_k=4)
    traces = {f"t{i}": sample_event_trace(11 + i, make_window(i), 8)
              for i in range(2)}
    times = poisson_times(3, 16, rate=5000.0)
    assert np.all(np.diff(times) >= 0)
    assert np.all(np.diff(flash_crowd_times(3, 100, 100.0)) >= 0)

    async def run():
        daemon = AllocDaemon(engine, queue_limit=64)
        for i in range(2):
            daemon.add_tenant(f"t{i}", make_window(i))
        await daemon.start()
        await drive_open_loop(daemon, interleave_traces(traces, times))
        await daemon.shutdown(drain=True)
        return daemon

    daemon = asyncio.run(run())
    assert daemon.rejected == 0
    for i in range(2):
        want = offline_replay(engine, i, traces[f"t{i}"])
        assert_reports_bitequal(daemon.reports(f"t{i}"), want)
    rep = daemon.report()
    assert rep["admission_p99_ms"] >= rep["admission_p50_ms"] >= 0.0


# --------------------------------------------------------------------------
# Backpressure
# --------------------------------------------------------------------------

def test_backpressure_rejects_with_penalty_and_stays_conformant():
    """Burst past the bounded queue: the overflow is rejected and charged
    the paper's rejection cost (m * H_up per arrival), and the ACCEPTED
    subtrace still replays bit-equal offline."""
    engine = make_engine(flush_k=4)
    # arrival-only trace: rejections cannot invalidate later events
    trace = [arrival(s) for s in range(12)]
    limit = 5

    async def run():
        daemon = AllocDaemon(engine, queue_limit=limit)
        daemon.add_tenant("t0", make_window(0))
        await daemon.start()
        # tight loop, no yield: the scheduler cannot drain between submits
        tickets = [daemon.submit("t0", ev) for ev in trace]
        await daemon.shutdown(drain=True)
        return daemon, tickets

    daemon, tickets = asyncio.run(run())
    rejected = [t for t in tickets if not t.accepted]
    accepted = [t for t in tickets if t.accepted]
    assert len(rejected) == len(trace) - limit
    want_cost = sum(rejection_penalty(t.event) for t in rejected)
    assert want_cost > 0.0
    assert daemon.rejection_cost == pytest.approx(want_cost)
    for t in rejected:
        assert t.report is None and t.penalty > 0.0
    want = offline_replay(engine, 0, [t.event for t in accepted])
    assert_reports_bitequal(daemon.reports("t0"), want)


def test_rejection_penalty_values():
    ev = arrival(0)
    assert rejection_penalty(ev) == pytest.approx(
        abs(float(ev.params["m"])) * abs(float(ev.params["H_up"])))
    from repro.core import ClassDeparture
    assert rejection_penalty(ClassDeparture(lane=0, slot=0)) == 0.0


# --------------------------------------------------------------------------
# Mid-trace shutdown: graceful drain and abort
# --------------------------------------------------------------------------

def test_mid_trace_graceful_drain_flushes_partial_epochs():
    """Stopping after a prefix: drain delivers everything queued and
    flushes the trailing partial epoch — exactly stream(prefix)."""
    engine = make_engine(flush_k=4)
    traces = {f"t{i}": sample_event_trace(41 + i, make_window(i), 13)
              for i in range(2)}
    half = {name: tr[:7] for name, tr in traces.items()}

    async def run():
        daemon = AllocDaemon(engine, queue_limit=None)
        for i in range(2):
            daemon.add_tenant(f"t{i}", make_window(i))
        await daemon.start()
        tickets = await submit_interleaved(daemon, half)
        await daemon.shutdown(drain=True)
        return daemon, tickets

    daemon, tickets = asyncio.run(run())
    for i in range(2):
        want = offline_replay(engine, i, half[f"t{i}"])
        assert_reports_bitequal(daemon.reports(f"t{i}"), want)
        # 7 events under flush_k=4: one full epoch + a drained partial
        assert len(daemon.reports(f"t{i}")) == 2
        for t in tickets[f"t{i}"]:
            assert t.report is not None and not t.cancelled


def test_mid_trace_abort_cancels_and_keeps_flushed_prefix():
    """drain=False: buffered/queued events are discarded, their tickets
    cancelled, and the reports so far are a bit-equal PREFIX of the full
    offline replay (sessions stay at their last flushed state)."""
    engine = make_engine(flush_k=4)
    trace = sample_event_trace(77, make_window(0), 11)

    async def run():
        daemon = AllocDaemon(engine, queue_limit=None)
        daemon.add_tenant("t0", make_window(0))
        await daemon.start()
        tickets = [daemon.submit("t0", ev) for ev in trace]
        # give the scheduler a few rounds, then yank the cord mid-trace
        for _ in range(8):
            await asyncio.sleep(0)
        await daemon.shutdown(drain=False)
        return daemon, tickets

    daemon, tickets = asyncio.run(run())
    session = daemon._tenants["t0"].session
    assert session.pending == ()          # buffers dropped, not half-applied
    cancelled = [t for t in tickets if t.cancelled]
    delivered = [t for t in tickets if t.report is not None]
    assert len(cancelled) + len(delivered) == len(trace)
    assert len(daemon.reports("t0")) >= 1   # it DID flush before the abort
    want = offline_replay(engine, 0, trace)
    assert_reports_bitequal(daemon.reports("t0"), want, prefix=True)
    with pytest.raises(RuntimeError):
        daemon.submit("t0", trace[0])     # closed daemons refuse work


def test_idle_daemon_shutdown_is_a_noop():
    """Draining a daemon that never saw an event performs no solve."""
    engine = make_engine()

    async def run():
        daemon = AllocDaemon(engine)
        daemon.add_tenant("t0", make_window(0))
        await daemon.start()
        await daemon.shutdown(drain=True)
        return daemon

    daemon = asyncio.run(run())
    assert daemon.reports("t0") == []
    assert daemon._tenants["t0"].session.flushes == 0
    assert daemon.report()["events_per_sec"] == 0.0


# --------------------------------------------------------------------------
# Scheduling: deadline ordering and fairness
# --------------------------------------------------------------------------

def test_due_sessions_flush_tightest_slack_first():
    """Two sessions due in the same round: the one holding the event with
    the least SLA slack (max E) re-equilibrates first."""
    engine = make_engine(flush_k=2)

    async def run():
        daemon = AllocDaemon(engine)
        daemon.add_tenant("loose", make_window(0))
        daemon.add_tenant("tight", make_window(1))
        await daemon.start()
        # both become due on their 2nd event, within one intake round
        daemon.submit("loose", arrival(0, E=-100.0))
        daemon.submit("tight", arrival(1, E=-1.0))
        daemon.submit("loose", arrival(2, E=-90.0))
        daemon.submit("tight", arrival(3, E=-50.0))
        await daemon.shutdown(drain=True)
        return daemon

    daemon = asyncio.run(run())
    assert [name for name, _ in daemon.flush_log] == ["tight", "loose"]
    slacks = dict(daemon.flush_log)
    assert slacks["tight"] == pytest.approx(1.0)   # min slack = -max(E)
    assert slacks["loose"] == pytest.approx(90.0)


def test_pending_slack_orders_sessions():
    engine = make_engine(flush_k=100)
    s = engine.open_window(make_window(0))
    assert s.pending_slack() == np.inf            # no deadline-carrying evs
    s.offer(arrival(0, E=-30.0))
    assert s.pending_slack() == pytest.approx(30.0)
    s.offer(arrival(1, E=-5.0))
    assert s.pending_slack() == pytest.approx(5.0)
    s.discard_pending()
    assert s.pending_slack() == np.inf


def test_round_robin_intake_is_fair_to_quiet_tenants():
    """A chatty tenant submitting 24 events before a quiet tenant's 4
    cannot starve it: round-robin intake interleaves from round one."""
    engine = make_engine(flush_k=1000)    # no auto-flush: pure intake order

    async def run():
        daemon = AllocDaemon(engine)
        daemon.add_tenant("chatty", make_window(0))
        daemon.add_tenant("quiet", make_window(1))
        await daemon.start()
        for s in range(24):
            daemon.submit("chatty", arrival(s))
        for s in range(4):
            daemon.submit("quiet", arrival(100 + s))
        await daemon.shutdown(drain=True)
        return daemon

    daemon = asyncio.run(run())
    last_quiet = max(i for i, n in enumerate(daemon.fold_log)
                     if n == "quiet")
    assert last_quiet <= 2 * 4             # interleaved, not appended
    assert daemon.fold_log.count("quiet") == 4
    assert daemon.fold_log.count("chatty") == 24


def test_critical_event_preempts_bulk_coalescing():
    """Under FlushPolicy.deadline, an SLA-critical arrival makes its
    session due immediately (mid-epoch) — through the daemon path too."""
    engine = make_engine(flush_k=50, slack=10.0)

    async def run():
        daemon = AllocDaemon(engine)
        daemon.add_tenant("t0", make_window(0))
        await daemon.start()
        daemon.submit("t0", arrival(0, E=-500.0))   # bulk: keeps buffering
        await asyncio.sleep(0)
        await asyncio.sleep(0)
        assert daemon._tenants["t0"].session.flushes == 0
        daemon.submit("t0", arrival(1, E=-2.0))     # critical: E >= -10
        await daemon.shutdown(drain=True)
        return daemon

    daemon = asyncio.run(run())
    assert len(daemon.reports("t0")) == 1
    assert daemon._tenants["t0"].session.flushes == 1


# --------------------------------------------------------------------------
# Per-tenant quotas (PR 8) + arrival profiles
# --------------------------------------------------------------------------

def test_tenant_quota_engine_level_guards():
    """QuotaExceededError from the session layer: window wider than
    max_lanes at open, add_lane past the cap, and the offer backstop."""
    from repro.core import QuotaExceededError, TenantQuota
    engine = make_engine(flush_k=100)
    with pytest.raises(QuotaExceededError):
        engine.open_window(make_window(0), quota=TenantQuota(max_lanes=B - 1))

    session = engine.open_window(make_window(0),
                                 quota=TenantQuota(max_lanes=B))
    with pytest.raises(QuotaExceededError):
        session.add_lane(sample_scenario(jax.random.PRNGKey(9), N,
                                         capacity_factor=1.3))

    session = engine.open_window(make_window(1),
                                 quota=TenantQuota(max_queued=2))
    session.offer(arrival(0))
    session.offer(arrival(1))
    with pytest.raises(QuotaExceededError):
        session.offer(arrival(2))
    # the buffered epoch is still flushable after the refusal
    report = session.flush()
    assert report.fractional is not None


def test_per_tenant_quota_rejections_and_stats():
    """Quota exhaustion rejects with the paper penalty, is accounted per
    tenant, leaves other tenants untouched, and the accepted subtrace
    stays bit-equal to its offline replay."""
    from repro.core import TenantQuota
    engine = make_engine(flush_k=100)          # nothing flushes early
    events = [arrival(i) for i in range(5)]

    async def run():
        daemon = AllocDaemon(engine, queue_limit=64)
        daemon.add_tenant("capped", make_window(0),
                          quota=TenantQuota(max_queued=2))
        daemon.add_tenant("free", make_window(1))
        await daemon.start()
        capped = [daemon.submit("capped", ev) for ev in events]
        free = [daemon.submit("free", ev) for ev in events]
        await daemon.shutdown(drain=True)
        return daemon, capped, free

    daemon, capped, free = asyncio.run(run())
    assert [tk.accepted for tk in capped] == [True, True] + [False] * 3
    assert all(tk.accepted for tk in free)
    for tk in capped[2:]:
        assert tk.penalty == rejection_penalty(tk.event) > 0.0
    stats = daemon.tenant_stats("capped")
    assert stats["submitted"] == 5.0 and stats["rejected"] == 3.0
    assert stats["rejection_cost"] == pytest.approx(
        sum(tk.penalty for tk in capped[2:]))
    assert daemon.tenant_stats("free")["rejected"] == 0.0
    assert daemon.rejected == 3 and daemon.submitted == 10
    assert_reports_bitequal(
        daemon.reports("capped"),
        list(make_engine(flush_k=100).open_window(make_window(0))
             .stream(events[:2])))
    assert_reports_bitequal(
        daemon.reports("free"),
        list(make_engine(flush_k=100).open_window(make_window(1))
             .stream(events)))


def test_drain_tenant_is_single_tenant_graceful_drain():
    """drain_tenant folds ONE tenant's backlog and flushes its trailing
    partial — report list equals the full offline replay — while the
    other tenant's backlog is untouched until the daemon-wide drain."""
    engine = make_engine(flush_k=3)
    traces = {"a": [arrival(i) for i in range(5)],
              "b": [arrival(10 + i) for i in range(4)]}

    async def run():
        daemon = AllocDaemon(engine)
        daemon.add_tenant("a", make_window(0))
        daemon.add_tenant("b", make_window(1))
        await daemon.start()
        for name, evs in traces.items():
            for ev in evs:
                daemon.submit(name, ev)
        daemon.drain_tenant("a")
        reports_a = list(daemon.reports("a"))
        await daemon.shutdown(drain=True)
        return daemon, reports_a

    daemon, reports_a = asyncio.run(run())
    want_a = list(make_engine(flush_k=3).open_window(make_window(0))
                  .stream(traces["a"]))
    assert_reports_bitequal(reports_a, want_a)      # complete at drain time
    want_b = list(make_engine(flush_k=3).open_window(make_window(1))
                  .stream(traces["b"]))
    assert_reports_bitequal(daemon.reports("b"), want_b)


def test_diurnal_times_profile():
    """Sinusoidal modulation: monotone offsets, peak regions denser than
    troughs by roughly the peak factor."""
    from repro.serving.allocd import ARRIVAL_PROFILES, diurnal_times
    n = 2000
    times = diurnal_times(0, n, 10.0, peak_factor=4.0, cycles=2.0)
    assert times.shape == (n,)
    assert np.all(np.diff(times) > 0)
    gaps = np.diff(times)
    # cycles=2: troughs at k ~ 0 and n/2, peaks at k ~ n/4 and 3n/4
    trough = np.mean(gaps[: n // 20])
    peak = np.mean(gaps[n // 4 - n // 40: n // 4 + n // 40])
    assert trough / peak > 2.0
    # the daemon's original profiles survive in the shared library
    # (core/traces.py may carry more — tests/test_planning.py pins the set)
    assert {"poisson", "flash", "diurnal"} <= set(ARRIVAL_PROFILES)
    assert ARRIVAL_PROFILES["diurnal"] is diurnal_times
