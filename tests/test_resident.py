"""Randomized trace-conformance harness for device-resident window sessions.

The residency contract (``SolverConfig(residency="resident")``, ISSUE 7):
a ``WindowSession`` whose state lives lane-sharded on the mesh across
flushes — events scattered into resident arrays, warm-start buffers built
on-device and donated to the solve — produces flush-boundary reports
BIT-EQUAL to the classic host-round-trip path under random event traces,
through growth past ``n_max``, mid-stream departures, compaction (slot_map
permutation), lane add/remove crossing mesh-padding boundaries, and
abort-then-reuse.  Property tests (hypothesis, loud skip when absent)
check the resident scatter path against a host-side epoch simulation for
arbitrary event prefixes, and that buffer donation never invalidates
arrays inside already-returned ``WindowSolveReport``s (the PR 6 zero-copy
aliasing bug class).
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import (AdmissionWindow, CapacityEngine, ClassArrival,
                        ClassDeparture, FlushPolicy, Policies,
                        RoundingPolicy, SolverConfig, lane_mesh,
                        sample_class_params, sample_event_trace,
                        sample_scenario)

D = jax.device_count()
needs_devices = pytest.mark.skipif(
    D < 2, reason="needs >= 2 devices (conftest forces 8 on CPU)")

B, N, N_MAX = 5, 4, 8          # one shared window shape: compile once
MESH_D = min(4, D)             # small mesh keeps per-dispatch cost down


def make_window(seed=0, *, lanes=B, n_max=N_MAX):
    key = jax.random.PRNGKey(seed)
    scns = [sample_scenario(jax.random.fold_in(key, lane), N,
                            capacity_factor=1.3) for lane in range(lanes)]
    return AdmissionWindow(scns, n_max=n_max)


def make_session(residency, *, flush_k=1, seed=0, lanes=B, n_max=N_MAX,
                 mesh=None):
    eng = CapacityEngine(
        SolverConfig(mesh=mesh or lane_mesh(MESH_D), residency=residency),
        Policies(flush=FlushPolicy(max_events=flush_k),
                 rounding=RoundingPolicy(False)))
    return eng.open_window(make_window(seed, lanes=lanes, n_max=n_max))


def session_pair(**kw):
    """(resident, round-trip) sessions over identically seeded windows."""
    return make_session("resident", **kw), make_session("round-trip", **kw)


def assert_reports_bitequal(a, b):
    la = jax.tree_util.tree_flatten(a.fractional)[0]
    lb = jax.tree_util.tree_flatten(b.fractional)[0]
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(a.iters), np.asarray(b.iters))
    np.testing.assert_array_equal(a.resolved, b.resolved)
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
    np.testing.assert_array_equal(np.asarray(a.n_classes),
                                  np.asarray(b.n_classes))


def window_state_equal(w_res, w_ref):
    """The resident window's LOGICAL state equals the host window's."""
    np.testing.assert_array_equal(w_res._mask, w_ref._mask)
    assert w_res._raw == w_ref._raw
    a, b = w_res.batch, w_ref.batch
    for x, y in zip(jax.tree_util.tree_flatten(a)[0],
                    jax.tree_util.tree_flatten(b)[0]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# Randomized trace conformance: resident == round-trip, bit for bit
# --------------------------------------------------------------------------

@needs_devices
@pytest.mark.parametrize("seed", [11, 23])
def test_random_trace_bitequal(seed):
    """Per-event flushes over a random trace (arrivals, departures, SLA
    edits, capacity changes — arrivals drive growth past n_max)."""
    s_res, s_rt = session_pair(seed=seed)
    assert_reports_bitequal(s_res.solve(), s_rt.solve())
    assert s_res.window.is_resident and not s_rt.window.is_resident
    trace = sample_event_trace(seed + 1, make_window(seed), 20)
    for ev in trace:
        s_res.window.apply(ev)
        s_rt.window.apply(ev)
        assert_reports_bitequal(s_res.solve(), s_rt.solve())
    assert s_res.window.is_resident        # residency survived the trace


@needs_devices
def test_coalesced_epochs_bitequal():
    """The coalesced path (one fused epoch commit + one resident solve per
    flush) lands on the same flush-boundary equilibria."""
    s_res, s_rt = session_pair(flush_k=4, seed=3)
    s_res.solve(), s_rt.solve()
    trace = sample_event_trace(7, make_window(3), 24)
    got = list(s_res.stream(trace))
    want = list(s_rt.stream(trace))
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert_reports_bitequal(a, b)


@needs_devices
def test_growth_past_n_max():
    """Arrivals overflowing a lane grow the padded width in place; the
    resident leaves re-pad on the mesh without a host round-trip."""
    s_res, s_rt = session_pair(seed=5, n_max=N)      # zero headroom
    s_res.solve(), s_rt.solve()
    for i in range(3):                               # forces two growths
        params = dict(sample_class_params(jax.random.PRNGKey(100 + i)))
        assert s_res.window.arrive(1, **params) == s_rt.window.arrive(
            1, **params)
        assert_reports_bitequal(s_res.solve(), s_rt.solve())
    assert s_res.window.n_max == s_rt.window.n_max > N
    assert s_res.window.is_resident


@needs_devices
def test_departures_and_compaction_slot_map():
    """Mid-stream departures fragment the window; compaction yields the
    identical slot_map permutation on both paths and stays bit-equal
    after (clean lanes frozen through the compaction)."""
    s_res, s_rt = session_pair(seed=9)
    s_res.solve(), s_rt.solve()
    for lane, slot in [(0, 1), (2, 0), (2, 2), (4, 3)]:
        s_res.window.depart(lane, slot)
        s_rt.window.depart(lane, slot)
    assert_reports_bitequal(s_res.solve(), s_rt.solve())
    m_res, m_rt = s_res.compact(), s_rt.compact()
    np.testing.assert_array_equal(m_res, m_rt)
    assert s_res.window.is_resident                  # re-established
    assert s_res.window.n_max == s_rt.window.n_max < N_MAX
    assert_reports_bitequal(s_res.solve(), s_rt.solve())
    ev = ClassArrival(lane=2, params=dict(
        sample_class_params(jax.random.PRNGKey(77))))
    s_res.window.apply(ev), s_rt.window.apply(ev)
    assert_reports_bitequal(s_res.solve(), s_rt.solve())


@needs_devices
def test_lane_count_crossing_mesh_padding():
    """add_lane / remove_lane across the mesh-multiple boundary: the
    padded lane count changes (5 -> pad 8, 9 -> pad 12 on a 4-device
    mesh), residency is dropped and re-established internally, results
    stay bit-equal throughout."""
    s_res, s_rt = session_pair(seed=13)
    s_res.solve(), s_rt.solve()
    key = jax.random.PRNGKey(500)
    for i in range(4):                               # B: 5 -> 9
        scn = sample_scenario(jax.random.fold_in(key, i), N,
                              capacity_factor=1.3)
        assert (s_res.window.add_lane(scn)
                == s_rt.window.add_lane(scn))
        assert_reports_bitequal(s_res.solve(), s_rt.solve())
    assert s_res.window.batch_size == 9
    for lane in (6, 0):                              # B: 9 -> 7
        s_res.window.remove_lane(lane)
        s_rt.window.remove_lane(lane)
        assert_reports_bitequal(s_res.solve(), s_rt.solve())
    assert s_res.window.is_resident


@needs_devices
def test_release_resident_indistinguishable():
    """release_resident returns the window to the classic layout: same
    logical state, and a round-trip engine solves it bit-equal."""
    s_res, s_rt = session_pair(seed=17)
    s_res.solve(), s_rt.solve()
    for ev in sample_event_trace(18, make_window(17), 6):
        s_res.window.apply(ev), s_rt.window.apply(ev)
    s_res.window.release_resident()
    assert not s_res.window.is_resident
    window_state_equal(s_res.window, s_rt.window)
    eng_rt = CapacityEngine(
        SolverConfig(mesh=lane_mesh(MESH_D)),
        Policies(flush=FlushPolicy(max_events=1),
                 rounding=RoundingPolicy(False)))
    assert_reports_bitequal(eng_rt.open_window(s_res.window).solve(),
                            s_rt.solve())


# --------------------------------------------------------------------------
# Abort-then-reuse: drain / discard_pending on a resident session
# --------------------------------------------------------------------------

@needs_devices
def test_abort_discard_pending_then_reuse():
    """discard_pending mid-epoch leaves the resident device buffers at the
    last consistent state — the already-flushed prefix is preserved
    on-device and the session keeps producing bit-equal reports."""
    s_res, s_rt = session_pair(flush_k=3, seed=21)
    s_res.solve(), s_rt.solve()
    trace = sample_event_trace(22, make_window(21), 10)
    for ev in trace[:6]:                             # two full flushes
        s_res.apply(ev), s_rt.apply(ev)
    s_res.apply(trace[6]), s_rt.apply(trace[6])      # one buffered event
    dropped_res = s_res.discard_pending()
    dropped_rt = s_rt.discard_pending()
    assert dropped_res == dropped_rt == (trace[6],)
    window_state_equal(s_res.window, s_rt.window)
    assert_reports_bitequal(s_res.solve(), s_rt.solve())
    for ev in trace[7:]:                             # session is reusable
        a, b = s_res.apply(ev), s_rt.apply(ev)
        assert (a is None) == (b is None)
        if a is not None:
            assert_reports_bitequal(a, b)


@needs_devices
def test_abort_invalid_event_keeps_residency_consistent():
    """A rejected event (missing SLA fields / bad slot) must not mutate
    either the host book-keeping or the resident device buffers."""
    s_res, s_rt = session_pair(seed=25)
    s_res.solve(), s_rt.solve()
    for w in (s_res.window, s_rt.window):
        with pytest.raises(ValueError):
            w.arrive(0, A=1.0)                       # missing raw fields
        with pytest.raises(IndexError):
            w.apply_epoch([ClassDeparture(lane=0, slot=N_MAX - 1)])
    window_state_equal(s_res.window, s_rt.window)
    assert_reports_bitequal(s_res.solve(), s_rt.solve())


@needs_devices
def test_drain_folds_without_solving():
    """drain() folds the buffered epoch into the resident arrays without a
    re-solve; the following solve is bit-equal to the round-trip path."""
    s_res, s_rt = session_pair(flush_k=100, seed=29)
    s_res.solve(), s_rt.solve()
    trace = sample_event_trace(30, make_window(29), 8)
    for ev in trace:
        assert s_res.apply(ev) is None and s_rt.apply(ev) is None
    assert s_res.drain() == s_rt.drain()
    window_state_equal(s_res.window, s_rt.window)
    assert_reports_bitequal(s_res.solve(), s_rt.solve())


# --------------------------------------------------------------------------
# Engine plumbing and guard rails
# --------------------------------------------------------------------------

def test_residency_config_validation():
    with pytest.raises(ValueError):
        CapacityEngine(SolverConfig(residency="resident"))   # needs a mesh
    with pytest.raises(ValueError):
        CapacityEngine(SolverConfig(residency="wat"))
    assert "residency" not in SolverConfig().fingerprint()
    fp = SolverConfig(mesh=lane_mesh(1), residency="resident").fingerprint()
    assert "residency=resident" in fp


@needs_devices
def test_host_warm_start_refused_while_resident():
    """warm_start() is the host path; on a resident window it would build
    an init at the wrong (unpadded) lane count — refuse loudly."""
    s_res, _ = session_pair(seed=33)
    s_res.solve()
    with pytest.raises(RuntimeError):
        s_res.window.warm_start()
    s_res.window.release_resident()
    assert s_res.window.warm_start() is not None


def test_make_resident_rejects_2d_mesh():
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    w = make_window(37)
    with pytest.raises(ValueError):
        w.make_resident(Mesh(devs, ("a", "b")))


# --------------------------------------------------------------------------
# Property tests (hypothesis; loud skip when not installed)
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), k=st.integers(0, 12))
def test_prop_resident_scatter_equals_host_epoch(seed, k):
    """Arbitrary event prefixes, folded epoch-wise into a RESIDENT window,
    leave device leaves (trimmed of mesh padding) bit-identical to a
    plain host-layout window that applied the same epochs."""
    if D < 2:
        pytest.skip("needs >= 2 devices")
    w_res, w_host = make_window(seed), make_window(seed)
    w_res.make_resident(lane_mesh(MESH_D))
    trace = sample_event_trace(seed + 1, make_window(seed), 12)[:k]
    for i in range(0, len(trace), 3):
        epoch = trace[i:i + 3]
        assert w_res.apply_epoch(epoch) == w_host.apply_epoch(epoch)
    window_state_equal(w_res, w_host)
    # the device mask mirror agrees with the authoritative host mask
    pad_b = int(w_res._mask_dev.shape[0])
    full = np.zeros((pad_b, w_res.n_max), bool)
    full[:w_res.batch_size] = w_res._mask
    np.testing.assert_array_equal(np.asarray(w_res._mask_dev), full)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_prop_donation_never_corrupts_returned_reports(seed):
    """Regression guard for the PR 6 zero-copy aliasing bug class: the
    resident solve donates its warm-start init, and later flushes keep
    donating — no buffer inside an already-returned WindowSolveReport may
    ever be invalidated or change value."""
    if D < 2:
        pytest.skip("needs >= 2 devices")
    s_res, _ = session_pair(seed=seed % 100)
    reports, snapshots = [], []
    trace = sample_event_trace(seed + 1, make_window(seed % 100), 8)
    rep = s_res.solve()
    reports.append(rep)
    snapshots.append(jax.tree_util.tree_map(
        lambda leaf: np.asarray(leaf).copy(), rep.fractional))
    for ev in trace:
        s_res.window.apply(ev)
        rep = s_res.solve()
        reports.append(rep)
        snapshots.append(jax.tree_util.tree_map(
            lambda leaf: np.asarray(leaf).copy(), rep.fractional))
    for rep, snap in zip(reports, snapshots):
        got = jax.tree_util.tree_flatten(rep.fractional)[0]
        want = jax.tree_util.tree_flatten(snap)[0]
        for x, y in zip(got, want):      # a donated buffer would raise here
            np.testing.assert_array_equal(np.asarray(x), y)


if not HAVE_HYPOTHESIS:
    pass  # @given shims the tests into loud skips (tests/_hypothesis_compat)
