"""Event-coalescing + dynamic-window tests.

Core guarantees under test:

* ``AdmissionWindow.apply_epoch`` is bit-identical to applying the same
  events one by one with ``apply`` (slot grants, mask, every Scenario leaf,
  raw-parameter book-keeping), and is atomic under invalid events;
* a coalesced replay (``WindowSession.stream``) lands on the per-event
  equilibria at every flush boundary — including across window growth, lane
  add/remove, compaction and under a device mesh (<= 1e-6, matching the
  PR 2 convention; checked against a cold ``solve_distributed_batch`` of
  the same window, the ground truth both paths must agree with);
* ``compact()`` remaps stored equilibria and warm starts so clean lanes
  stay *frozen* (zero iterations) through the re-layout;
* ``FlushPolicy`` triggers on event count, dirty-lane fraction, and —
  deadline-aware (``FlushPolicy.deadline``) — on SLA-critical events.
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (AdmissionWindow, CapacityEngine, ClassArrival,
                        ClassDeparture, CrossCheckPolicy, EventEpoch,
                        FlushPolicy, Policies, RoundingPolicy, SLAEdit,
                        SolverConfig, lane_mesh, replay, sample_class_params,
                        sample_event_trace, sample_scenario,
                        solve_distributed_batch)


def solve_streaming(window, *, integer=True, mesh=None, cross_check=False):
    """Engine-path stand-in for the retired allocator.solve_streaming facade
    (shims themselves are covered by tests/test_engine.py)."""
    return CapacityEngine(
        SolverConfig(mesh=mesh),
        Policies(rounding=RoundingPolicy(integer),
                 cross_check=CrossCheckPolicy(cross_check))
    ).open_window(window).solve()


def solve_coalesced(window, events, *, policy=None, integer=True, mesh=None):
    """Engine-path stand-in for the retired allocator.solve_coalesced
    facade: a ``WindowSession.stream`` generator."""
    eng = CapacityEngine(
        SolverConfig(mesh=mesh),
        Policies(flush=policy if policy is not None else FlushPolicy(),
                 rounding=RoundingPolicy(integer)))
    return eng.open_window(window).stream(events)

D = jax.device_count()
needs_devices = pytest.mark.skipif(
    D < 2, reason="needs >= 2 devices (conftest forces 8 on CPU)")

SCN_FIELDS = ("A", "B", "E", "r_low", "r_up", "p", "alpha", "beta", "K",
              "rho_up", "rho_hat", "R", "rho_bar")


def make_window(ns=(5, 8, 3, 6), cf=1.2, n_max=None, seed0=0):
    scns = [sample_scenario(jax.random.PRNGKey(seed0 + i), n,
                            capacity_factor=cf)
            for i, n in enumerate(ns)]
    return AdmissionWindow(scns, n_max=n_max)


def assert_windows_identical(w1, w2):
    np.testing.assert_array_equal(w1._mask, w2._mask)
    for f in SCN_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(w1._scn, f)),
                                      np.asarray(getattr(w2._scn, f)), f)
    assert w1._raw == w2._raw
    np.testing.assert_array_equal(w1.dirty, w2.dirty)


def assert_equiv_cold(window, res, tol=1e-6):
    """Streaming/coalesced result == cold batched re-solve of the window."""
    cold = solve_distributed_batch(window.batch)
    np.testing.assert_allclose(np.asarray(res.fractional.r),
                               np.asarray(cold.r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(res.fractional.total),
                               np.asarray(cold.total), rtol=tol)
    np.testing.assert_array_equal(np.asarray(res.iters),
                                  np.asarray(cold.iters))
    np.testing.assert_array_equal(np.asarray(res.feasible),
                                  np.asarray(cold.feasible))


# --------------------------------------------------------------------------
# apply_epoch == sequential apply
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_apply_epoch_matches_sequential(seed):
    """Coalesced application is bit-identical to event-by-event apply,
    including growth past n_max and in-epoch slot recycling."""
    w_seq, w_co = make_window(n_max=9, seed0=3 * seed), \
        make_window(n_max=9, seed0=3 * seed)
    trace = sample_event_trace(40 + seed, w_seq, 30)
    seq_slots = [w_seq.apply(ev) for ev in trace]
    co_slots = w_co.apply_epoch(trace)
    assert seq_slots == co_slots
    assert_windows_identical(w_seq, w_co)
    assert any(s is not None for s in co_slots)


def test_apply_epoch_folds_arrive_edit_depart_chain():
    """In-epoch chains (arrive -> edit -> depart of the same slot) fold to
    the same net state sequential application produces."""
    w_seq, w_co = make_window(ns=(3, 4)), make_window(ns=(3, 4))
    p1 = sample_class_params(jax.random.PRNGKey(0))
    p2 = sample_class_params(jax.random.PRNGKey(1))
    events = [
        ClassArrival(lane=0, params=p1),             # -> slot 3
        SLAEdit(lane=0, slot=3, updates={"E": -450.0, "m": 31000.0}),
        ClassDeparture(lane=0, slot=0),
        ClassArrival(lane=0, params=p2),             # recycles slot 0
        ClassDeparture(lane=0, slot=3),              # in-epoch class leaves
        ClassDeparture(lane=1, slot=2),
    ]
    for ev in events:
        w_seq.apply(ev)
    w_co.apply_epoch(events)
    assert_windows_identical(w_seq, w_co)
    assert w_co.occupied(0) == [0, 1, 2]


def test_apply_epoch_is_atomic():
    """An invalid event anywhere in the epoch raises before ANY mutation."""
    w = make_window(ns=(3, 4))
    before_mask = w._mask.copy()
    before_A = np.asarray(w._scn.A).copy()
    good = ClassArrival(lane=1, params=sample_class_params(
        jax.random.PRNGKey(2)))
    with pytest.raises(IndexError):
        w.apply_epoch([good, ClassDeparture(lane=0, slot=3)])  # empty slot
    with pytest.raises(ValueError):
        w.apply_epoch([good, SLAEdit(lane=0, slot=0, updates={"nope": 1.0})])
    with pytest.raises(ValueError):
        w.apply_epoch([ClassArrival(lane=0, params={"A": 1.0})])
    with pytest.raises(TypeError):
        w.apply_epoch([good, "not-an-event"])
    np.testing.assert_array_equal(w._mask, before_mask)
    np.testing.assert_array_equal(np.asarray(w._scn.A), before_A)
    assert not w.dirty.any()
    assert w.apply_epoch([]) == []


# --------------------------------------------------------------------------
# apply_epoch invariants as PROPERTIES (hypothesis; loud skip without it)
# --------------------------------------------------------------------------

@settings(deadline=None, max_examples=15, derandomize=True)
@given(seed=st.integers(0, 10_000), n_events=st.integers(1, 40),
       p_depart=st.floats(0.0, 0.6), n_max=st.sampled_from([9, 12, 16]))
def test_property_apply_epoch_matches_sequential(seed, n_events, p_depart,
                                                 n_max):
    """For ANY seeded trace (length, churn mixture, headroom — including
    traces that force growth and in-epoch slot recycling), one coalesced
    apply_epoch is bit-identical to event-by-event apply."""
    w_seq = make_window(n_max=n_max, seed0=seed % 7)
    w_co = make_window(n_max=n_max, seed0=seed % 7)
    trace = sample_event_trace(seed, w_seq, n_events, p_depart=p_depart)
    seq_slots = [w_seq.apply(ev) for ev in trace]
    co_slots = w_co.apply_epoch(trace)
    assert seq_slots == co_slots
    assert_windows_identical(w_seq, w_co)


@settings(deadline=None, max_examples=15, derandomize=True)
@given(seed=st.integers(0, 10_000), n_good=st.integers(0, 12),
       bad_kind=st.sampled_from(["empty-slot", "bad-field", "missing",
                                 "bad-lane"]))
def test_property_apply_epoch_atomic_under_any_prefix(seed, n_good,
                                                      bad_kind):
    """An invalid event after ANY valid prefix aborts the whole epoch
    with ZERO mutation — mask, scenario leaves, raw registry, dirt."""
    w = make_window(ns=(3, 4), n_max=8)
    good = sample_event_trace(seed, w, n_good, p_depart=0.0, p_edit=0.0,
                              p_capacity=0.0) if n_good else []
    bad = {"empty-slot": ClassDeparture(lane=0, slot=7),
           "bad-field": SLAEdit(lane=0, slot=0, updates={"nope": 1.0}),
           "missing": ClassArrival(lane=0, params={"A": 1.0}),
           "bad-lane": ClassDeparture(lane=99, slot=0)}[bad_kind]
    before_mask = w._mask.copy()
    before_raw = dict(w._raw)
    before_A = np.asarray(w._scn.A).copy()
    with pytest.raises((IndexError, ValueError)):
        w.apply_epoch([*good, bad])
    np.testing.assert_array_equal(w._mask, before_mask)
    np.testing.assert_array_equal(np.asarray(w._scn.A), before_A)
    assert w._raw == before_raw
    assert not w.dirty.any()


# --------------------------------------------------------------------------
# Flush policies + EventEpoch
# --------------------------------------------------------------------------

def test_flush_policy_triggers():
    count = FlushPolicy(max_events=3)
    assert not count.should_flush(n_events=2, n_dirty=2, batch_size=4)
    assert count.should_flush(n_events=3, n_dirty=0, batch_size=4)
    frac = FlushPolicy(max_events=None, max_dirty_fraction=0.5)
    assert not frac.should_flush(n_events=100, n_dirty=1, batch_size=4)
    assert frac.should_flush(n_events=1, n_dirty=2, batch_size=4)
    manual = FlushPolicy(max_events=None, max_dirty_fraction=None)
    assert not manual.should_flush(n_events=10 ** 6, n_dirty=4, batch_size=4)


def test_event_epoch_accumulates_and_flushes():
    window = make_window()
    solve_streaming(window, integer=False)
    epoch = EventEpoch(window, policy=FlushPolicy(max_events=2))
    ev1 = ClassArrival(lane=1, params=sample_class_params(
        jax.random.PRNGKey(5)))
    assert epoch.add(ev1) is False
    assert epoch.pending == (ev1,) and epoch.dirty_lanes == {1}
    assert not window.dirty.any()               # nothing applied yet
    ev2 = ClassDeparture(lane=2, slot=0)
    assert epoch.add(ev2) is True               # count trigger fires
    res = epoch.flush(integer=False)
    np.testing.assert_array_equal(res.resolved, [False, True, True, False])
    assert epoch.flushes == 1 and epoch.events_folded == 2
    assert len(epoch) == 0 and epoch.last_slots[0] is not None
    assert_equiv_cold(window, res)
    # an empty flush is legal and freezes every lane
    res2 = epoch.flush(integer=False)
    assert not res2.resolved.any()


def test_dirty_fraction_policy_flushes_early():
    window = make_window()
    epoch = EventEpoch(window, policy=FlushPolicy(
        max_events=None, max_dirty_fraction=0.5))
    assert epoch.add(ClassDeparture(lane=0, slot=0)) is False   # 1/4 dirty
    assert epoch.add(ClassDeparture(lane=0, slot=1)) is False   # still 1/4
    assert epoch.add(ClassDeparture(lane=3, slot=0)) is True    # 2/4 dirty


# --------------------------------------------------------------------------
# Deadline-aware FlushPolicy (SLA-critical events jump the coalescing queue)
# --------------------------------------------------------------------------

def hot_params(seed, E=-150.0):
    p = sample_class_params(jax.random.PRNGKey(seed))
    p["E"] = E
    return p


def test_deadline_policy_criticality_rules():
    window = make_window()
    pol = FlushPolicy.deadline(300.0, max_events=16)
    assert pol.deadline_slack_s == 300.0 and pol.flush_on_sla_tightening
    assert pol.max_events == 16
    # arrivals: critical iff the deadline is nearly exhausted
    assert pol.is_critical(
        ClassArrival(lane=0, params=hot_params(0)), window)
    assert not pol.is_critical(
        ClassArrival(lane=0, params=hot_params(1, E=-2000.0)), window)
    # SLA edits: tightening (E toward 0) is critical, relaxing is not,
    # non-deadline edits never are
    slot = window.occupied(1)[0]
    old_E = window._raw[(1, slot)]["E"]
    assert pol.is_critical(
        SLAEdit(lane=1, slot=slot, updates={"E": old_E + 50.0}), window)
    assert not pol.is_critical(
        SLAEdit(lane=1, slot=slot, updates={"E": old_E - 50.0}), window)
    assert not pol.is_critical(
        SLAEdit(lane=1, slot=slot, updates={"m": 12345.0}), window)
    # bulk kinds are never critical; plain policies have no deadline trigger
    assert not pol.is_critical(ClassDeparture(lane=1, slot=slot), window)
    assert not FlushPolicy().is_critical(
        ClassArrival(lane=0, params=hot_params(2)), window)
    # tightening=False keeps only the slack trigger
    lax = FlushPolicy.deadline(300.0, tightening=False)
    assert not lax.is_critical(
        SLAEdit(lane=1, slot=slot, updates={"E": old_E + 50.0}), window)
    assert lax.is_critical(
        SLAEdit(lane=1, slot=slot, updates={"E": -100.0}), window)
    # EventEpoch.add reports the critical flush demand
    epoch = EventEpoch(window, policy=pol)
    assert epoch.add(ClassArrival(lane=0, params=hot_params(3))) is True


def test_deadline_policy_session_flushes_critical_immediately():
    """Bulk events coalesce under the loose count bound; an SLA-critical
    event forces the flush at once, folding the buffered bulk events in."""
    eng = CapacityEngine(policies=Policies(
        flush=FlushPolicy.deadline(300.0, max_events=64),
        rounding=RoundingPolicy(False)))
    sess = eng.open_window(make_window())
    sess.solve()
    assert sess.apply(ClassArrival(
        lane=0, params=hot_params(10, E=-2000.0))) is None   # bulk: buffers
    assert sess.apply(ClassDeparture(
        lane=2, slot=sess.window.occupied(2)[0])) is None
    rep = sess.apply(ClassArrival(lane=1, params=hot_params(11)))
    assert rep is not None and not sess.pending
    np.testing.assert_array_equal(np.flatnonzero(rep.resolved), [0, 1, 2])
    assert_equiv_cold(sess.window, rep)


def test_solve_coalesced_deadline_policy_flush_boundaries():
    """A critical event mid-trace splits the epochs early; every flush still
    equals the cold solve of the window at that boundary."""
    window = make_window(n_max=9)
    solve_streaming(window, integer=False)
    slot = window.occupied(0)[0]
    tighten = SLAEdit(lane=0, slot=slot,
                      updates={"E": window._raw[(0, slot)]["E"] + 25.0})
    events = [
        ClassArrival(lane=2, params=hot_params(20, E=-1500.0)),
        ClassArrival(lane=3, params=hot_params(21, E=-1800.0)),
        tighten,                                 # critical -> flush of 3
        ClassArrival(lane=1, params=hot_params(22, E=-1600.0)),
    ]
    reports = list(solve_coalesced(
        window, events, policy=FlushPolicy.deadline(300.0, max_events=10),
        integer=False))
    assert len(reports) == 2                     # critical flush + trailing
    np.testing.assert_array_equal(np.flatnonzero(reports[0].resolved),
                                  [0, 2, 3])
    np.testing.assert_array_equal(np.flatnonzero(reports[1].resolved), [1])
    assert_equiv_cold(window, reports[-1])


# --------------------------------------------------------------------------
# Coalesced replay == per-event replay at flush boundaries
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 7])
def test_solve_coalesced_matches_per_event_at_boundaries(k):
    """Every flush of a coalesced replay equals the last per-event solve of
    its epoch (and hence the cold solve of the window at that point)."""
    w_co, w_ref = make_window(n_max=9), make_window(n_max=9)
    solve_streaming(w_co, integer=False)
    solve_streaming(w_ref, integer=False)
    trace = sample_event_trace(77, w_co, 20)
    boundary = 0
    for res in solve_coalesced(w_co, trace, policy=FlushPolicy(max_events=k),
                               integer=False):
        n_applied = min(boundary + k, len(trace))
        ref = None
        for ev in trace[boundary:n_applied]:     # per-event reference path
            w_ref.apply(ev)
            ref = solve_streaming(w_ref, integer=False)
        boundary = n_applied
        np.testing.assert_allclose(np.asarray(res.fractional.r),
                                   np.asarray(ref.fractional.r),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(res.iters),
                                      np.asarray(ref.iters))
        assert_equiv_cold(w_co, res)
    assert boundary == len(trace)                # trailing epoch flushed


def test_solve_coalesced_across_growth():
    """A coalesced epoch whose arrivals overflow n_max grows the window
    mid-epoch exactly like per-event application, and stays equivalent."""
    w = make_window(ns=(4, 5), n_max=5)
    solve_streaming(w, integer=False)
    events = [ClassArrival(lane=1, params=sample_class_params(
        jax.random.PRNGKey(60 + i))) for i in range(4)]
    results = list(solve_coalesced(w, events,
                                   policy=FlushPolicy(max_events=10),
                                   integer=False))
    assert len(results) == 1                     # one trailing flush
    assert w.n_max == 10                         # grew past 5
    np.testing.assert_array_equal(results[0].resolved, [False, True])
    assert_equiv_cold(w, results[0])


# --------------------------------------------------------------------------
# Dynamic lane count: add_lane / remove_lane
# --------------------------------------------------------------------------

def test_add_lane_freezes_existing_lanes():
    window = make_window()
    first = solve_streaming(window, integer=False)
    b = window.add_lane(sample_scenario(jax.random.PRNGKey(50), 4,
                                        capacity_factor=1.2))
    assert b == 4 and window.batch_size == 5
    res = solve_streaming(window, integer=False)
    np.testing.assert_array_equal(res.resolved, [False] * 4 + [True])
    for lane in range(4):                        # untouched lanes pass through
        np.testing.assert_array_equal(np.asarray(res.fractional.r[lane]),
                                      np.asarray(first.fractional.r[lane]))
    assert_equiv_cold(window, res)
    # the new lane is live: events address it like any other
    window.arrive(b, **sample_class_params(jax.random.PRNGKey(51)))
    assert_equiv_cold(window, solve_streaming(window, integer=False))


def test_add_empty_lane_and_validation():
    window = make_window(ns=(3, 4))
    solve_streaming(window, integer=False)
    with pytest.raises(ValueError):
        window.add_lane()                        # needs R= and rho_bar=
    b = window.add_lane(R=400.0, rho_bar=3.0)
    res = solve_streaming(window, integer=False)
    assert np.all(np.asarray(res.fractional.r[b]) == 0.0)
    assert bool(res.feasible[b])                 # empty lane trivially ok
    assert_equiv_cold(window, res)
    slot = window.arrive(b, **sample_class_params(jax.random.PRNGKey(8)))
    assert slot == 0
    assert_equiv_cold(window, solve_streaming(window, integer=False))


def test_add_lane_wider_than_window_grows_first():
    window = make_window(ns=(3,), n_max=4)
    solve_streaming(window, integer=False)
    wide = sample_scenario(jax.random.PRNGKey(9), 7, capacity_factor=1.2)
    b = window.add_lane(wide)
    assert window.n_max == 7 and window.n_classes[b] == 7
    assert_equiv_cold(window, solve_streaming(window, integer=False))


def test_remove_lane_shifts_and_freezes():
    window = make_window(ns=(5, 8, 3, 6))
    first = solve_streaming(window, integer=False)
    window.remove_lane(1)
    assert window.batch_size == 3
    res = solve_streaming(window, integer=False)
    assert not res.resolved.any()                # survivors stay frozen
    for new, old in enumerate((0, 2, 3)):
        np.testing.assert_array_equal(np.asarray(res.fractional.r[new]),
                                      np.asarray(first.fractional.r[old]))
    assert_equiv_cold(window, res)
    # raw book-keeping shifted: events address the post-shift lanes
    window.depart(1, window.occupied(1)[0])      # was lane 2 pre-removal
    res = solve_streaming(window, integer=False)
    np.testing.assert_array_equal(res.resolved, [False, True, False])
    assert_equiv_cold(window, res)
    window.remove_lane(2)
    window.remove_lane(1)
    with pytest.raises(ValueError):
        window.remove_lane(0)                    # never below one lane
    with pytest.raises(IndexError):
        window.remove_lane(5)


# --------------------------------------------------------------------------
# Compaction
# --------------------------------------------------------------------------

def sparsify(window, keep=2, lanes=None):
    """Depart all but ``keep`` classes per lane (lowest slots kept)."""
    for lane in (range(window.batch_size) if lanes is None else lanes):
        for slot in window.occupied(lane)[keep:]:
            window.depart(lane, slot)


def test_compact_keeps_clean_lanes_frozen():
    window = make_window(ns=(6, 7, 5, 6), n_max=12)
    sparsify(window, keep=2)
    pre = solve_streaming(window, integer=False)
    pre_occ = [window.occupied(b) for b in range(4)]
    slot_map = window.compact()
    assert window.n_max == 2 and window.occupancy == 1.0
    # stored equilibrium was remapped -> nothing re-iterates, values move
    post = solve_streaming(window, integer=False)
    assert not post.resolved.any()
    for b in range(4):
        np.testing.assert_array_equal(
            np.asarray(post.fractional.r[b]),
            np.asarray(pre.fractional.r[b])[pre_occ[b]])
        for old_slot, new_slot in zip(pre_occ[b], range(len(pre_occ[b]))):
            assert slot_map[b, old_slot] == new_slot
    assert_equiv_cold(window, post)
    # dirtying one lane after compaction re-solves it on the packed layout
    window.arrive(2, **sample_class_params(jax.random.PRNGKey(21)))
    res = solve_streaming(window, integer=False)
    np.testing.assert_array_equal(res.resolved, [False, False, True, False])
    assert_equiv_cold(window, res)


def test_compact_width_validation_and_headroom():
    window = make_window(ns=(4, 2))
    solve_streaming(window, integer=False)
    with pytest.raises(ValueError):
        window.compact(n_max=3)                  # below the widest lane
    slot_map = window.compact(n_max=6)           # explicit headroom
    assert window.n_max == 6
    assert (slot_map >= -1).all()
    res = solve_streaming(window, integer=False)
    assert not res.resolved.any()
    assert_equiv_cold(window, res)
    # idempotent fast path: already packed at this width
    again = window.compact(n_max=6)
    np.testing.assert_array_equal(again[:, :4],
                                  np.where(window._mask[:, :4],
                                           np.arange(4)[None, :], -1))


def test_compact_preserves_baseline_memo():
    window = make_window(cf=0.95)
    res = solve_streaming(window, integer=False, cross_check=True)
    gaps = np.asarray(res.centralized_gap).copy()
    totals = window.baseline_totals.copy()
    sparsify(window, keep=2, lanes=[1])
    solve_streaming(window, integer=False, cross_check=True)
    totals[1] = window.baseline_totals[1]
    window.compact()
    assert not window.baseline_stale.any()       # memo survives the re-layout
    res2 = solve_streaming(window, integer=False, cross_check=True)
    np.testing.assert_array_equal(window.baseline_totals, totals)
    assert np.all(np.asarray(res2.centralized_gap) >= -1e-9)
    del gaps


# --------------------------------------------------------------------------
# Mesh composition: shrink -> regrow -> compact (the PR 3 untested corner)
# --------------------------------------------------------------------------

@needs_devices
def test_shrink_then_regrow_under_mesh():
    """Lane removal below the device multiple, re-growth past it, and
    compaction all compose with the sharded streaming path."""
    mesh = lane_mesh()
    w_mesh, w_ref = make_window(ns=(5, 8, 3, 6, 4, 7)), \
        make_window(ns=(5, 8, 3, 6, 4, 7))
    solve_streaming(w_mesh, integer=False, mesh=mesh)
    solve_streaming(w_ref, integer=False)

    for lane in (4, 1, 0):                       # shrink 6 -> 3 lanes
        w_mesh.remove_lane(lane)
        w_ref.remove_lane(lane)
    res_m = solve_streaming(w_mesh, integer=False, mesh=mesh)
    res_r = solve_streaming(w_ref, integer=False)
    assert not res_m.resolved.any()
    np.testing.assert_allclose(np.asarray(res_m.fractional.r),
                               np.asarray(res_r.fractional.r),
                               rtol=1e-6, atol=1e-6)

    for i in range(2):                           # regrow 3 -> 5 lanes
        scn = sample_scenario(jax.random.PRNGKey(70 + i), 4 + i,
                              capacity_factor=1.2)
        w_mesh.add_lane(scn)
        w_ref.add_lane(scn)
    sparsify(w_mesh, keep=2)
    sparsify(w_ref, keep=2)
    sm_mesh = w_mesh.compact()
    sm_ref = w_ref.compact()
    np.testing.assert_array_equal(sm_mesh, sm_ref)
    res_m = solve_streaming(w_mesh, integer=False, mesh=mesh)
    res_r = solve_streaming(w_ref, integer=False)
    np.testing.assert_array_equal(res_m.resolved, res_r.resolved)
    np.testing.assert_allclose(np.asarray(res_m.fractional.r),
                               np.asarray(res_r.fractional.r),
                               rtol=1e-6, atol=1e-6)
    assert_equiv_cold(w_mesh, res_m)


@needs_devices
def test_coalesced_random_trace_under_mesh_with_compaction():
    """The acceptance criterion: a coalesced replay of a random trace under
    a mesh — across growth and a compaction at a flush boundary — equals the
    cold solve of the window at every flush."""
    mesh = lane_mesh()
    window = make_window(n_max=9)
    solve_streaming(window, integer=False, mesh=mesh)
    trace = sample_event_trace(123, window, 18)
    for res in solve_coalesced(window, trace, policy=FlushPolicy(max_events=6),
                               integer=False, mesh=mesh):
        assert_equiv_cold(window, res)
    window.compact()                             # flush boundary re-layout
    res = solve_streaming(window, integer=False, mesh=mesh)
    assert not res.resolved.any()
    assert_equiv_cold(window, res)
    # traces sampled post-compaction keep streaming on the packed layout
    trace2 = sample_event_trace(124, window, 8)
    for res in solve_coalesced(window, trace2,
                               policy=FlushPolicy(max_events=4),
                               integer=False, mesh=mesh):
        assert_equiv_cold(window, res)


# --------------------------------------------------------------------------
# Fleet integration: clusters joining/leaving + compaction policy
# --------------------------------------------------------------------------

def test_epoch_stream_fleet_arrive_depart_and_compaction():
    from repro.cluster import FleetSimulator, TenantSpec, epoch_stream

    def tenants(k, start=0):
        return [TenantSpec(f"t{start + i}", "x", "train_4k",
                           deadline_s=100.0 + 7.0 * (start + i),
                           H_up=10 + (start + i), H_low=4,
                           penalty_per_job=20000.0 + 500.0 * (start + i))
                for i in range(k)]

    profiles = {f"t{i}": (1.0 + 0.2 * i, 0.5, 1.0) for i in range(10)}
    mk = lambda chips, k, start=0: FleetSimulator(
        total_chips=chips, tenants=tenants(k, start=start))
    streamed = [mk(800, 4), mk(1200, 5)]
    for f in streamed:
        f._profiles = dict(profiles)
    newcomer_fleet = mk(600, 2, start=7)
    newcomer_fleet._profiles = dict(profiles)

    epochs = [
        [],
        [("fleet-arrive", newcomer_fleet),
         ("arrive", 2, tenants(1, start=9)[0])],  # event lands in new lane
        [("fleet-depart", 0),                     # indices shift down
         ("depart", 0, "t1"), ("depart", 0, "t2"), ("depart", 0, "t3"),
         ("capacity", 1, 500)],
    ]
    got = list(epoch_stream(streamed, epochs, compact_below=0.6))
    assert [len(a) for a in got] == [2, 3, 2]

    # end state: fleet 0 == original fleet 1 shrunk, fleet 1 == newcomer + t9
    fresh0 = mk(1200, 5)
    fresh0.tenants = [t for t in fresh0.tenants
                      if t.name not in ("t1", "t2", "t3")]
    fresh1 = mk(500, 2, start=7)
    fresh1.tenants.append(tenants(1, start=9)[0])
    for f in (fresh0, fresh1):
        f._profiles = dict(profiles)
    want0, want1 = fresh0.epoch(), fresh1.epoch()
    assert got[-1][0].chips == want0.chips and got[-1][0].h == want0.h
    assert got[-1][1].chips == want1.chips and got[-1][1].h == want1.h
    assert got[-1][0].total_cost == pytest.approx(want0.total_cost, rel=1e-6)
    assert got[-1][1].total_cost == pytest.approx(want1.total_cost, rel=1e-6)
