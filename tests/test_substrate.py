"""Substrate tests: data determinism, checkpoint atomicity/resume, optimizer
tiers & schedules, fleet simulator behavior."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.cluster import FleetSimulator, TenantSpec
from repro.data import MemmapTokens, SyntheticLM
from repro.optim import OptConfig, adamw_init, adamw_update, make_schedule


def test_synthetic_data_deterministic_and_host_sharded():
    a = SyntheticLM(1000, 32, 8, seed=1)(step=5)
    b = SyntheticLM(1000, 32, 8, seed=1)(step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(1000, 32, 8, seed=1)(step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding: two hosts see different slices, same global determinism
    h0 = SyntheticLM(1000, 32, 8, seed=1, n_hosts=2, host_id=0)(5)
    h1 = SyntheticLM(1000, 32, 8, seed=1, n_hosts=2, host_id=1)(5)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])


def test_memmap_tokens():
    with tempfile.NamedTemporaryFile(suffix=".bin") as f:
        arr = np.arange(10000, dtype=np.uint16) % 512
        arr.tofile(f.name)
        src = MemmapTokens(f.name, seq_len=16, global_batch=4, seed=0)
        b1, b2 = src(0), src(0)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].shape == (4, 16)


def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save(tree, s, d, keep_last=2)
        assert latest_step(d) == 5
        # GC kept only the last 2
        assert sorted(int(p.split("_")[1]) for p in os.listdir(d)) == [4, 5]
        out, manifest = restore(tree, 5, d)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16
        assert manifest["step"] == 5


@pytest.mark.parametrize("tier", ["f32", "bf16", "int8"])
def test_adamw_converges_quadratic(tier):
    oc = OptConfig(lr=0.1, weight_decay=0.0, state_dtype=tier,
                   schedule="const", warmup_steps=0, total_steps=100)
    params = {"w": jnp.full((300,), 5.0)}
    state = adamw_init(params, oc)
    for _ in range(60):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = adamw_update(params, g, state, oc)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.8, tier


def test_int8_state_memory_is_small():
    oc = OptConfig(state_dtype="int8")
    params = {"w": jnp.zeros((64, 1024), jnp.bfloat16)}
    st = adamw_init(params, oc)
    m = st["mu"]["w"]["m"]
    assert m["q"].dtype == jnp.int8
    assert m["q"].size == 64 * 1024
    assert m["scale"].size == 64 * 4   # 1024/256 blocks per row


def test_schedules():
    for kind in ("cosine", "wsd", "const"):
        oc = OptConfig(lr=1.0, schedule=kind, warmup_steps=10,
                       total_steps=100)
        s = make_schedule(oc)
        assert float(s(0)) == 0.0
        assert float(s(10)) == pytest.approx(1.0, rel=1e-6)
        if kind != "const":
            assert float(s(100)) <= 0.15


def _tenants():
    return [
        TenantSpec("a", "x", "train_4k", deadline_s=100, H_up=10, H_low=4,
                   penalty_per_job=20000),
        TenantSpec("b", "y", "decode_32k", deadline_s=50, H_up=8, H_low=2,
                   penalty_per_job=10000),
    ]


PROFILES = {"a": (1.0, 0.5, 1.0), "b": (0.5, 0.3, 1.0)}


def test_fleet_failure_reallocates():
    fleet = FleetSimulator(total_chips=800, tenants=_tenants())
    a0 = fleet.epoch(profiles=PROFILES)
    assert sum(a0.chips.values()) <= 800
    a1 = fleet.fail_nodes(500)
    assert sum(a1.chips.values()) <= 300
    # capacity loss cannot reduce total cost (penalties kick in)
    assert a1.total_cost >= a0.total_cost - 1e-6
    a2 = fleet.restore_nodes(500)
    assert a2.total_cost <= a1.total_cost + 1e-6


def test_fleet_straggler_overprovisions():
    fleet = FleetSimulator(total_chips=800, tenants=_tenants())
    a0 = fleet.epoch(profiles=PROFILES)
    a1 = fleet.mark_straggler("a", factor=1.5)
    assert a1.chips["a"] > a0.chips["a"]


def test_fleet_mesh_plan():
    assert FleetSimulator.mesh_plan(137, 16) == (8, 16)
    assert FleetSimulator.mesh_plan(8, 16) == (1, 8)
