"""Layer-level oracle cross-checks + decode consistency for the model zoo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (LOCAL, MambaConfig, ModelConfig, MoEConfig,
                          decode_step, forward, init_params, loss_fn, prefill)
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.serving import generate, pad_attn_cache

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# attention oracle sweep
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (2, 64, 4, 2, 16), (1, 128, 8, 1, 32), (2, 96, 6, 6, 8),
])
def test_attention_chunked_vs_reference(dtype, causal, B, S, Hq, Hkv, hd):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    ref = attn_mod.reference(q, k, v, causal=causal)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    for loops in ("scan", "unroll"):
        out = attn_mod.attention(q, k, v, causal=causal, q_chunk=32,
                                 kv_chunk=32, loops=loops)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)
    if causal:
        out = attn_mod.attention(q, k, v, causal=True, q_chunk=32,
                                 kv_chunk=32, triangle=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)


def test_decode_attention_matches_reference():
    ks = jax.random.split(KEY, 3)
    B, S, H, hd = 2, 64, 4, 16
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    # valid length 40: zero out the tail, compare against truncated reference
    out = attn_mod.decode_attention(q, k, v, kv_len=40)
    ref = attn_mod.reference(q, k[:, :40], v[:, :40], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# RWKV6 chunked vs recurrent oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv_chunked_matches_recurrent(chunk):
    B, T, H, K = 2, 128, 3, 8
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, K))
    k = jax.random.normal(ks[1], (B, T, H, K))
    v = jax.random.normal(ks[2], (B, T, H, K))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.5 - 0.6)
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    S0 = jnp.zeros((B, H, K, K))
    y_ref, S_ref = rwkv_mod.wkv_recurrent(r, k, v, w_log, u, S0)
    for loops in ("scan", "unroll"):
        y, S = rwkv_mod.wkv_chunked(r, k, v, w_log, u, S0, chunk=chunk,
                                    loops=loops)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                                   rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# Mamba chunked scan vs naive recurrence
# --------------------------------------------------------------------------

def test_mamba_scan_matches_naive():
    B, T, d_in, N = 2, 64, 8, 4
    ks = jax.random.split(KEY, 3)
    decay = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, d_in, N)))
    inc = jax.random.normal(ks[1], (B, T, d_in, N)) * 0.1
    h0 = jax.random.normal(ks[2], (B, d_in, N))
    for loops, chunk in (("scan", 16), ("unroll", 32)):
        ys, h_last = mamba_mod._ssm_scan_chunked(decay, inc, h0, chunk=chunk,
                                                 loops=loops)
        h = h0
        outs = []
        for t in range(T):
            h = decay[:, t] * h + inc[:, t]
            outs.append(h)
        ref = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(ref[:, -1]),
                                   rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# MoE: capacity dispatch vs dense oracle (single shard)
# --------------------------------------------------------------------------

def _moe_cfg(cf):
    return ModelConfig(
        name="tm", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv=2,
        d_ff=64, vocab=64,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=1,
                      capacity_factor=cf),
        dtype="float32", param_dtype="float32")


def test_moe_matches_dense_oracle():
    cfg = _moe_cfg(cf=16.0)   # capacity >> load: nothing drops
    p = moe_mod.moe_init(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    gates, idx, aux = moe_mod.route(cfg, p, x)
    out = moe_mod.moe_apply(cfg, p, x, gates, idx, LOCAL)
    ref = moe_mod.moe_dense_ref(cfg, p, x, gates, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert float(aux) > 0.0


def test_moe_capacity_drops_are_bounded():
    """With cf=1, drops happen but the output stays finite & close-ish."""
    cfg = _moe_cfg(cf=1.0)
    p = moe_mod.moe_init(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    gates, idx, _ = moe_mod.route(cfg, p, x)
    out = moe_mod.moe_apply(cfg, p, x, gates, idx, LOCAL)
    assert bool(jnp.all(jnp.isfinite(out)))


# --------------------------------------------------------------------------
# decode consistency across families (prefill+decode == forward)
# --------------------------------------------------------------------------

def _decode_consistency(cfg, batch_full, S):
    params = init_params(cfg, KEY)
    logits_full, _, _ = forward(cfg, params, batch_full)
    pre = {k: (v[:, :S - 1] if k == "tokens" else v)
           for k, v in batch_full.items() if k != "targets"}
    _, cache = prefill(cfg, params, pre)
    cache = pad_attn_cache(cache, 1)
    logits_step, _ = decode_step(cfg, params, cache,
                                 batch_full["tokens"][:, S - 1],
                                 jnp.int32(S - 1))
    a = np.asarray(logits_full[:, -1])
    b = np.asarray(logits_step[:, 0])
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-4, err


@pytest.mark.slow
def test_decode_consistency_dense():
    S = 16
    toks = jax.random.randint(KEY, (2, S), 0, 256)
    cfg = ModelConfig(name="t", family="dense", n_layers=3, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
                      qk_norm=True, dtype="float32", param_dtype="float32",
                      attn_q_chunk=8, attn_kv_chunk=8)
    _decode_consistency(cfg, {"tokens": toks, "targets": toks}, S)


@pytest.mark.slow
def test_decode_consistency_hybrid_moe():
    S = 16
    toks = jax.random.randint(KEY, (2, S), 0, 256)
    cfg = ModelConfig(name="tj", family="hybrid", n_layers=8, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
                      moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                                    every=2, capacity_factor=8.0),
                      mamba=MambaConfig(d_state=8), attn_every=8,
                      attn_offset=4, dtype="float32", param_dtype="float32",
                      attn_q_chunk=8, attn_kv_chunk=8)
    _decode_consistency(cfg, {"tokens": toks, "targets": toks}, S)


@pytest.mark.slow
def test_decode_consistency_rwkv():
    S = 16
    toks = jax.random.randint(KEY, (2, S), 0, 256)
    cfg = ModelConfig(name="tr", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv=4, d_ff=128, vocab=256, rwkv=True,
                      rwkv_head_dim=16, dtype="float32",
                      param_dtype="float32")
    _decode_consistency(cfg, {"tokens": toks, "targets": toks}, S)


@pytest.mark.slow
def test_decode_consistency_encdec():
    S = 16
    toks = jax.random.randint(KEY, (2, S), 0, 256)
    cfg = ModelConfig(name="tw", family="encdec", n_layers=2, d_model=64,
                      n_heads=4, n_kv=4, d_ff=128, vocab=256,
                      encoder_layers=2, max_positions=64, norm="layernorm",
                      act="gelu", dtype="float32", param_dtype="float32",
                      attn_q_chunk=8, attn_kv_chunk=8)
    batch = {"enc_embeds": jax.random.normal(KEY, (2, S, 64)),
             "tokens": toks, "targets": toks}
    _decode_consistency(cfg, batch, S)


@pytest.mark.slow
def test_generate_runs():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv=2, d_ff=64, vocab=64, head_dim=16,
                      dtype="float32", param_dtype="float32")
    params = init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 8), 0, 64)
    out = generate(cfg, params, prompt, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < 64)))


@pytest.mark.slow
def test_grad_flows_all_families():
    S, toks = 16, jax.random.randint(KEY, (2, 16), 0, 128)
    batch = {"tokens": toks, "targets": toks}
    cfgs = [
        ModelConfig(name="d", family="dense", n_layers=2, d_model=32,
                    n_heads=2, n_kv=1, d_ff=64, vocab=128, head_dim=16,
                    qk_norm=True, dtype="float32", param_dtype="float32",
                    remat="full"),
        ModelConfig(name="r", family="ssm", n_layers=2, d_model=32,
                    n_heads=2, n_kv=2, d_ff=64, vocab=128, rwkv=True,
                    rwkv_head_dim=16, dtype="float32", param_dtype="float32"),
        ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                    n_heads=2, n_kv=2, d_ff=64, vocab=128,
                    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                                  first_k_dense=1, capacity_factor=4.0),
                    dtype="float32", param_dtype="float32"),
    ]
    for cfg in cfgs:
        params = init_params(cfg, KEY)
        g = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
        gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                          for x in jax.tree_util.tree_leaves(g)))
        assert bool(jnp.isfinite(gn)) and float(gn) > 0, cfg.name
