"""Tests for the paper's GNEP capacity-allocation core (Secs. 3-5)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Real property-based search when hypothesis is installed (CI does), a
# loud per-test pytest.skip when not — never a silent one-example pass.
from _hypothesis_compat import given, settings, st  # noqa: F401

from repro.core import (CapacityEngine, InfeasibleError, deadline_lhs,
                        sample_scenario, solve_centralized, solve_distributed,
                        solve_distributed_python)
from repro.core.centralized import kkt_residual, objective_of_r
from repro.core.game import rm_solve
from repro.core.rounding import round_solution

SIZES = (3, 17, 64)   # fixed sizes -> bounded number of jit recompiles


def scn_of(seed, n=17, cf=1.0):
    return sample_scenario(jax.random.PRNGKey(seed), n, capacity_factor=cf)


# --------------------------------------------------------------------------
# Scenario generator sanity (Tables 5/6)
# --------------------------------------------------------------------------

def test_scenario_ranges():
    scn = scn_of(0, 512)
    assert np.all(np.asarray(scn.E) < 0)
    assert np.all(np.asarray(scn.K) > 0)
    assert 0.85 <= float(scn.rho_bar) <= 1.48            # Table 6
    assert np.asarray(scn.alpha).min() >= 300_000 * 0.9  # Table 6 range
    assert np.asarray(scn.alpha).max() <= 9_600_000 * 1.1
    assert np.all(np.asarray(scn.r_low) <= np.asarray(scn.r_up))
    assert np.all(np.asarray(scn.H_low) <= np.asarray(scn.H_up))
    # Eq. 8: r bounds are K * H
    np.testing.assert_allclose(np.asarray(scn.r_up),
                               np.asarray(scn.K * scn.H_up), rtol=1e-12)


# --------------------------------------------------------------------------
# Centralized solver (P3 water-filling) — exactness
# --------------------------------------------------------------------------

@settings(deadline=None, max_examples=20, derandomize=True)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SIZES),
       cf=st.floats(0.85, 1.3))
def test_centralized_kkt(seed, n, cf):
    scn = scn_of(seed, n, cf)
    sol = solve_centralized(scn)
    if not bool(sol.feasible):
        return
    assert float(kkt_residual(scn, sol.r, sol.aux)) < 1e-8
    assert float(jnp.sum(sol.r)) <= float(scn.R) * (1 + 1e-10)


@settings(deadline=None, max_examples=15, derandomize=True)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SIZES),
       cf=st.floats(0.88, 1.2), pseed=st.integers(0, 100))
def test_centralized_no_improving_perturbation(seed, n, cf, pseed):
    """Optimality: random feasible perturbations never beat the solution."""
    scn = scn_of(seed, n, cf)
    sol = solve_centralized(scn)
    if not bool(sol.feasible):
        return
    key = jax.random.PRNGKey(pseed)
    base = float(objective_of_r(scn, sol.r))
    for k in jax.random.split(key, 8):
        delta = jax.random.uniform(k, (n,), minval=-1.0, maxval=1.0)
        cand = jnp.clip(sol.r + delta * 0.05 * sol.r, scn.r_low, scn.r_up)
        # project onto capacity simplex by uniform shrink of the excess
        excess = jnp.maximum(jnp.sum(cand) - scn.R, 0.0)
        shrinkable = cand - scn.r_low
        cand = cand - excess * shrinkable / jnp.maximum(jnp.sum(shrinkable), 1e-12)
        assert float(objective_of_r(scn, cand)) >= base - 1e-7 * abs(base)


def test_prop32_constraints_active():
    """Prop. 3.2: (P2d) and (P2e) are active at the centralized optimum."""
    scn = scn_of(3, 64, 0.93)
    sol = solve_centralized(scn)
    lhs = deadline_lhs(scn, sol.psi, sol.sM, sol.sR)
    np.testing.assert_allclose(np.asarray(lhs), 0.0, atol=1e-7)
    slots = sol.sM / scn.cM + sol.sR / scn.cR
    np.testing.assert_allclose(np.asarray(slots), np.asarray(sol.r), rtol=1e-10)


def test_capacity_monotone():
    """Fig. 2 sanity: decreasing capacity never decreases total cost."""
    totals = []
    for cf in [1.1, 1.0, 0.95, 0.9, 0.87]:
        sol = solve_centralized(scn_of(11, 64, cf))
        assert bool(sol.feasible)
        totals.append(float(sol.total))
    assert all(t2 >= t1 - 1e-6 for t1, t2 in zip(totals, totals[1:]))


def test_deadline_monotone():
    """Fig. 4 sanity: tighter deadlines never decrease total cost."""
    base = sample_scenario(jax.random.PRNGKey(5), 64, capacity_factor=1.1)
    R = float(base.R)
    totals = []
    for ds in [1.0, 0.9, 0.8, 0.7]:
        scn = sample_scenario(jax.random.PRNGKey(5), 64, deadline_scale=ds,
                              capacity=R)
        sol = solve_centralized(scn)
        if bool(sol.feasible):
            totals.append(float(sol.total))
    assert len(totals) >= 2
    assert all(t2 >= t1 - 1e-6 for t1, t2 in zip(totals, totals[1:]))


def test_infeasible_raises():
    scn = scn_of(1, 17, cf=0.5)   # below sum(r_low) ~ 0.8 * sum(r_up)
    with pytest.raises(InfeasibleError):
        CapacityEngine().solve(scn, method="centralized")


# --------------------------------------------------------------------------
# RM problem (P5) — exactness of the candidate-price sweep
# --------------------------------------------------------------------------

def _rm_bruteforce(scn, bids):
    """Enumerate all 2^N y-patterns; for each, the LP in r is a greedy fill
    and the optimal price is the top of the pattern's feasible interval."""
    n = scn.n
    p = np.asarray(scn.p); r_low = np.asarray(scn.r_low)
    r_up = np.asarray(scn.r_up); R = float(scn.R)
    rho_bar, rho_hat = float(scn.rho_bar), float(scn.rho_hat)
    bids = np.asarray(bids)
    best = -np.inf
    order = np.argsort(-p)
    for pattern in itertools.product([0, 1], repeat=n):
        y = np.array(pattern, bool)
        lb = max([rho_bar] + [bids[i] for i in range(n) if not y[i]])
        ub = min([rho_hat] + [bids[i] for i in range(n) if y[i]])
        if lb > ub:
            continue
        rho = ub
        spare = R - r_low.sum()
        if spare < 0:
            continue
        r = r_low.copy()
        for i in order:
            if y[i]:
                add = min(r_up[i] - r_low[i], spare)
                r[i] += add
                spare -= add
        obj = (rho - rho_bar) * r.sum() + (p * r).sum() - (p * r_up).sum()
        best = max(best, obj)
    return best


@settings(deadline=None, max_examples=10, derandomize=True)
@given(seed=st.integers(0, 1000), bseed=st.integers(0, 1000))
def test_rm_solve_exact(seed, bseed):
    scn = scn_of(seed, 6, cf=0.9)
    key = jax.random.PRNGKey(bseed)
    bids = jax.random.uniform(key, (6,), minval=float(scn.rho_bar),
                              maxval=20.0, dtype=scn.A.dtype)
    rho, r, obj = rm_solve(scn, bids)
    brute = _rm_bruteforce(scn, bids)
    assert float(obj) >= brute - 1e-6 * abs(brute) - 1e-9
    # and the returned allocation is feasible & consistent with the objective
    assert float(jnp.sum(r)) <= float(scn.R) * (1 + 1e-12)
    assert np.all(np.asarray(r) >= np.asarray(scn.r_low) - 1e-9)
    assert np.all(np.asarray(r) <= np.asarray(scn.r_up) + 1e-9)


# --------------------------------------------------------------------------
# Distributed game (Algorithm 4.1)
# --------------------------------------------------------------------------

@settings(deadline=None, max_examples=12, derandomize=True)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SIZES),
       cf=st.floats(0.88, 1.15))
def test_distributed_near_centralized(seed, n, cf):
    """Paper Figs. 6/8: equilibrium total within a few % of the optimum.
    The bound scales with class granularity: at tiny N a single class
    ordered differently (p vs marginal-penalty order) is a large fraction."""
    scn = scn_of(seed, n, cf)
    c = solve_centralized(scn)
    if not bool(c.feasible):
        return
    d = solve_distributed(scn)
    gap = (float(d.total) - float(c.total)) / abs(float(c.total))
    assert gap >= -1e-9          # never better than the optimum
    assert gap <= (0.30 if n <= 3 else 0.12 if n <= 17 else 0.08)


def test_distributed_python_matches_jit():
    scn = scn_of(42, 17, 0.92)
    d_jit = solve_distributed(scn)
    d_py, iters, _ = solve_distributed_python(scn)
    np.testing.assert_allclose(np.asarray(d_py.r), np.asarray(d_jit.r),
                               rtol=1e-9)
    assert iters == int(d_jit.iters)


def test_distributed_respects_bounds():
    scn = scn_of(9, 64, 0.9)
    d = solve_distributed(scn)
    r = np.asarray(d.r)
    assert np.all(r >= np.asarray(scn.r_low) - 1e-9)
    assert np.all(r <= np.asarray(scn.r_up) + 1e-9)
    assert r.sum() <= float(scn.R) * (1 + 1e-12)


# --------------------------------------------------------------------------
# Rounding heuristic (Algorithm 4.2, Props. 4.2/4.3)
# --------------------------------------------------------------------------

@settings(deadline=None, max_examples=15, derandomize=True)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SIZES),
       cf=st.floats(0.88, 1.2), method=st.sampled_from(["c", "d"]))
def test_rounding_properties(seed, n, cf, method):
    scn = scn_of(seed, n, cf)
    sol = (solve_centralized(scn) if method == "c" else solve_distributed(scn))
    if not bool(sol.feasible):
        return
    it = round_solution(scn, sol.r, sol.sM, sol.sR, sol.psi)
    r, sM, sR = map(np.asarray, (it.r, it.sM, it.sR))
    # integrality
    for x in (r, sM, sR, np.asarray(it.h)):
        np.testing.assert_array_equal(x, np.round(x))
    # capacity (Prop. 4.2 single pass)
    assert r.sum() <= np.floor(float(scn.R)) + 1e-9
    assert np.all(r >= np.floor(np.asarray(sol.r)) - 1e-9)
    # slot constraint (P2e) holds after rounding
    lhs = sM / np.asarray(scn.cM) + sR / np.asarray(scn.cR)
    assert np.all(lhs <= r + 1e-9)
    # Prop. 4.3: at most omega+1 decrements per class
    omega = np.minimum(np.asarray(scn.cM), np.asarray(scn.cR))
    assert np.all(sM >= np.ceil(np.asarray(sol.sM)) - (omega + 1) - 1e-9)
    assert np.all(sR >= np.ceil(np.asarray(sol.sR)) - (omega + 1) - 1e-9)
    # admission stays in the SLA box
    h = np.asarray(it.h)
    assert np.all(h >= np.asarray(scn.H_low) - 1e-9)
    assert np.all(h <= np.asarray(scn.H_up) + 1e-9)


def test_integer_close_to_fractional():
    """Sec. 4.5: rounding error is dominated by integer-admission quantization
    (~one job per class), whose *relative* impact shrinks as N grows."""
    gaps = {}
    for n in (64, 512):
        scn = scn_of(4, n, 0.95)
        res = CapacityEngine().solve(scn, method="centralized")
        frac, integ = float(res.fractional.total), float(res.integer.total)
        gaps[n] = abs(integ - frac) / abs(frac)
    assert gaps[64] < 0.15
    assert gaps[512] < 0.06
