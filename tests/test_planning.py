"""Capacity planner (core/planning.py) + shared trace library (core/traces).

The load-bearing claim: ``solve_plan``'s chunked, inert-lane-padded sweep
is **bit-equal** to one direct ``CapacityEngine.solve`` over every
candidate — sharded and unsharded — because lanes are independent and the
padding is solver-inert.  Around it: grid determinism under the spec seed,
Pareto-frontier dominance invariants, the deadline-axis warm-start
contract (bit-equal when the stopping iteration matches, tolerance-bounded
otherwise), empty/all-infeasible spaces, and the workload-trace profile
properties (sorted, non-negative gaps, target mean rate) shared with the
admission daemon via bit-compatible re-exports."""
import dataclasses

import jax
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import sharding, traces
from repro.core.engine import (CapacityEngine, Policies, RoundingPolicy,
                               SolverConfig)
from repro.core.planning import (PlanSpec, VMTier, generate_grid,
                                 solve_plan)
from repro.core.types import stack_scenarios
from repro.serving import allocd

SPEC = PlanSpec(
    n_classes=3, profile="flash", rate=40.0, trace_events=128,
    cluster_sizes=(900.0, 4000.0),
    vm_tiers=(VMTier("small", 1.0, 6.0), VMTier("big", 2.0, 10.0)),
    deadline_scales=(0.9, 1.0, 1.15), penalty_scales=(1.0,), seed=3)

RESULT_FIELDS = ("cost", "penalty", "total", "r", "iters", "feasible")


@pytest.fixture(scope="module")
def grid():
    return generate_grid(SPEC)


@pytest.fixture(scope="module")
def report(grid):
    return solve_plan(grid, chunk=5)          # 12 candidates -> 5+5+2 ragged


def reference_solve(grid, cfg):
    """One direct CapacityEngine.solve over ALL candidates (the oracle the
    chunked planner must match bit-for-bit), trimmed to real lanes."""
    n_max = max(c.scenario.n for c in grid)
    batch = stack_scenarios([c.scenario for c in grid], n_max=n_max)
    if cfg.mesh is not None:
        batch = sharding.pad_batch_lanes(
            batch, sharding.padded_lane_count(len(grid),
                                              cfg.mesh.devices.size))
    engine = CapacityEngine(cfg, Policies(rounding=RoundingPolicy(False)))
    rep = engine.solve(batch, check_feasible=False)
    sol = rep.fractional
    B = len(grid)
    return {"cost": np.asarray(sol.cost)[:B],
            "penalty": np.asarray(sol.penalty)[:B],
            "total": np.asarray(sol.total)[:B],
            "r": np.asarray(sol.r)[:B],
            "iters": np.asarray(rep.iters)[:B],
            "feasible": np.asarray(rep.feasible)[:B]}


# --------------------------------------------------------------------------
# Grid generation
# --------------------------------------------------------------------------

def test_grid_deterministic_under_seed(grid):
    """Same spec -> bit-identical candidates; different seed -> different."""
    again = generate_grid(SPEC)
    assert len(again) == len(grid) == SPEC.n_candidates == 12
    for a, b in zip(grid, again):
        assert a.index == b.index and a.coords == b.coords
        for x, y in zip(jax.tree_util.tree_leaves(a.scenario),
                        jax.tree_util.tree_leaves(b.scenario)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    other = generate_grid(dataclasses.replace(SPEC, seed=SPEC.seed + 1))
    assert any(
        not np.array_equal(np.asarray(a.scenario.A), np.asarray(b.scenario.A))
        for a, b in zip(grid, other))


def test_grid_order_deadline_innermost(grid):
    """Candidate order: index == position, deadline axis innermost (what
    the warm-start chains rely on), coordinates round-trip the spec."""
    D = len(SPEC.deadline_scales)
    for pos, c in enumerate(grid):
        assert c.index == pos
        assert c.coords["deadline_scale"] == SPEC.deadline_scales[pos % D]
    # adjacent candidates within a chain differ ONLY in the deadline coord
    a, b = grid[0].coords, grid[1].coords
    assert a["deadline_scale"] != b["deadline_scale"]
    assert {k: v for k, v in a.items() if k != "deadline_scale"} \
        == {k: v for k, v in b.items() if k != "deadline_scale"}
    # tier slots scale capacity: same class draws, bigger cM under "big"
    small, big = grid[0].scenario, grid[D].scenario
    np.testing.assert_array_equal(np.asarray(small.A), np.asarray(big.A))
    np.testing.assert_array_equal(np.asarray(big.cM),
                                  2.0 * np.asarray(small.cM))


def test_grid_validation():
    with pytest.raises(ValueError, match="profile"):
        generate_grid(PlanSpec(profile="nope"))
    with pytest.raises(ValueError, match="n_classes"):
        generate_grid(PlanSpec(n_classes=0))
    with pytest.raises(ValueError, match="trace_events"):
        generate_grid(PlanSpec(trace_events=0))


# --------------------------------------------------------------------------
# Chunked solve == one-shot engine solve (the planner's core contract)
# --------------------------------------------------------------------------

def test_chunked_plan_bit_equal_one_shot(grid, report):
    ref = reference_solve(grid, SolverConfig())
    assert report.n_chunks == 3 and report.chunk == 5
    for k in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(report, k), ref[k],
                                      err_msg=k)


def test_chunked_plan_bit_equal_one_shot_sharded(grid):
    mesh = sharding.lane_mesh()
    cfg = SolverConfig(mesh=mesh)
    ref = reference_solve(grid, cfg)
    rep = solve_plan(grid, config=cfg, chunk=5)
    for k in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(rep, k), ref[k], err_msg=k)


def test_chunk_width_is_invisible(grid, report):
    """Any chunking of the same grid produces identical reports."""
    whole = solve_plan(grid, chunk=len(grid))
    assert whole.n_chunks == 1
    for k in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(report, k), getattr(whole, k),
                                      err_msg=k)


def test_solve_plan_accepts_spec(grid, report):
    """Passing the PlanSpec itself expands the same grid internally."""
    rep = solve_plan(SPEC, chunk=5)
    for k in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(rep, k), getattr(report, k),
                                      err_msg=k)


def test_solve_plan_rejects_bad_args(grid):
    with pytest.raises(ValueError, match="chunk"):
        solve_plan(grid, chunk=0)
    with pytest.raises(ValueError, match="warm"):
        solve_plan(grid, warm_start=True)     # plain list has no axes


# --------------------------------------------------------------------------
# Warm start along the deadline axis
# --------------------------------------------------------------------------

def test_warm_start_matches_cold(grid, report):
    """Warm-seeding preserves the bid-driven Alg. 4.1 trajectory: lanes
    that stop at the same iteration are bit-equal to the cold solve; a
    lane whose first-iteration convergence metric moved across eps_bar
    may stop at a different iteration, landing within the stopping
    tolerance of the same equilibrium."""
    warm = solve_plan(SPEC, chunk=4, warm_start=True)
    assert warm.warm_start and warm.n_candidates == report.n_candidates
    np.testing.assert_array_equal(warm.feasible, report.feasible)
    same = warm.iters == report.iters
    # the first deadline step of every chain is solved cold in both modes
    assert same[::len(SPEC.deadline_scales)].all()
    for k in ("cost", "penalty", "total", "r"):
        np.testing.assert_array_equal(
            np.asarray(getattr(warm, k))[same],
            np.asarray(getattr(report, k))[same], err_msg=k)
    scale = np.maximum(np.abs(report.r), 1.0)
    rel = np.max(np.abs(warm.r - report.r) / scale, axis=-1)
    assert np.all(rel[~same] <= 2 * SolverConfig().eps_bar)


# --------------------------------------------------------------------------
# Frontier queries
# --------------------------------------------------------------------------

def test_pareto_frontier_invariants(report):
    front = report.pareto_frontier()
    assert front.size >= 1
    assert report.feasible[front].all()
    assert np.all(np.diff(report.cost[front]) > 0)       # strictly up
    assert np.all(np.diff(report.penalty[front]) < 0)    # strictly down
    feas = np.flatnonzero(report.feasible)
    for i in front:                     # nothing feasible dominates a point
        assert not any(
            report.cost[j] <= report.cost[i]
            and report.penalty[j] <= report.penalty[i]
            and (report.cost[j] < report.cost[i]
                 or report.penalty[j] < report.penalty[i])
            for j in feas)
    for j in feas:                      # everything else is covered
        if j in front:
            continue
        assert any(report.cost[i] <= report.cost[j]
                   and report.penalty[i] <= report.penalty[j]
                   for i in front)


def test_cheapest_feasible_queries(report):
    i = report.cheapest_feasible()
    front = report.pareto_frontier()
    assert i == int(front[0])           # min cost, ties by penalty/index
    feas = np.flatnonzero(report.feasible)
    assert report.cost[i] == report.cost[feas].min()
    budget = float(np.median(report.penalty[feas]))
    j = report.cheapest_feasible(max_penalty=budget)
    qual = feas[report.penalty[feas] <= budget]
    assert j in qual and report.cost[j] == report.cost[qual].min()
    none = report.cheapest_feasible(
        max_penalty=float(report.penalty[feas].min()) - 1.0)
    assert none is None
    payload = report.to_json()
    assert payload["n_candidates"] == report.n_candidates
    assert payload["cheapest_feasible"]["index"] == i
    assert [p["index"] for p in payload["frontier"]] == [int(k) for k in
                                                         front]


def test_empty_design_space():
    empty = PlanSpec(cluster_sizes=())
    assert empty.n_candidates == 0 and generate_grid(empty) == []
    rep = solve_plan(empty)
    assert rep.n_candidates == 0 and rep.n_chunks == 0
    assert rep.pareto_frontier().size == 0
    assert rep.cheapest_feasible() is None
    assert solve_plan([], chunk=3).n_candidates == 0


def test_all_infeasible_space():
    """An undersized fleet is a legitimate probe result, not an error:
    every flag False, empty frontier, no cheapest design."""
    tiny = PlanSpec(n_classes=3, cluster_sizes=(2.0,),
                    vm_tiers=(VMTier("small", 1.0, 6.0),),
                    deadline_scales=(1.0,), seed=3)
    rep = solve_plan(tiny)
    assert rep.n_candidates == 1 and not rep.feasible.any()
    assert rep.pareto_frontier().size == 0
    assert rep.cheapest_feasible() is None
    assert rep.to_json()["cheapest_feasible"] is None


# --------------------------------------------------------------------------
# Shared workload-trace library (core/traces.py)
# --------------------------------------------------------------------------

def test_allocd_reexports_are_the_library():
    """serving.allocd re-exports core.traces bit-compatibly: the SAME
    function objects, so daemon traces and planner sizing share one
    implementation (and BENCH_allocd baselines keep their meaning)."""
    assert allocd.ARRIVAL_PROFILES is traces.ARRIVAL_PROFILES
    for name in ("poisson_times", "flash_crowd_times", "diurnal_times",
                 "bursty_times", "straggler_times"):
        assert getattr(allocd, name) is getattr(traces, name)
    assert set(traces.ARRIVAL_PROFILES) == {"poisson", "flash", "diurnal",
                                            "bursty", "straggler"}
    assert traces.ARRIVAL_PROFILES["bursty"] is traces.bursty_times


def test_trace_determinism_and_validation():
    a = traces.straggler_times(5, 64, 10.0)
    np.testing.assert_array_equal(a, traces.straggler_times(5, 64, 10.0))
    assert not np.array_equal(a, traces.straggler_times(6, 64, 10.0))
    with pytest.raises(ValueError, match="tail_index"):
        traces.straggler_times(0, 16, 10.0, tail_index=1.0)


# --------------------------------------------------------------------------
# Trace profile properties (hypothesis; loud skip when absent)
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16),
       name=st.sampled_from(sorted(traces.ARRIVAL_PROFILES)),
       rate=st.floats(5.0, 200.0))
def test_prop_trace_profiles_well_formed(seed, name, rate):
    """Every profile yields n finite, sorted, non-negative-gap arrival
    times; the stationary profiles (poisson/bursty/straggler) hit the
    target mean rate (flash/diurnal take `rate` as the baseline/trough
    rate, so their realized mean is deliberately higher)."""
    n = 512
    t = traces.ARRIVAL_PROFILES[name](seed, n, rate)
    assert t.shape == (n,) and np.all(np.isfinite(t))
    assert t[0] >= 0.0 and np.all(np.diff(t) >= 0.0)
    realized = n / t[-1]
    if name in ("poisson", "bursty", "straggler"):
        assert 0.5 * rate < realized < 1.5 * rate
    else:
        assert realized > rate              # bursts only add arrivals


if not HAVE_HYPOTHESIS:
    pass  # @given shims the tests into loud skips (tests/_hypothesis_compat)
