"""Per-assigned-architecture smoke tests: reduced config of the same family,
one forward + one train-grad step on CPU, asserting shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ARCHS, get_config, reduced_config
from repro.models import forward, init_params, loss_fn
from repro.models.config import ALL_SHAPES
from repro.configs.specs import cell_is_live, live_cells

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch_for(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"targets": toks}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.float32) * 0.02
        pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        batch["mrope_positions"] = pos.astype(jnp.int32)
    elif cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                                jnp.float32) * 0.02
        batch["tokens"] = toks
    else:
        batch["tokens"] = toks
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke(arch_id):
    cfg = reduced_config(arch_id)
    params = init_params(cfg, KEY)
    batch = _batch_for(cfg)
    logits, aux, _ = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one train step's worth of grads
    loss, metrics = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                      for x in jax.tree_util.tree_leaves(g)))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch_id)
    expected = {
        "qwen2-vl-7b": (28, 3584, 28, 4, 152064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 102400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163840),
        "qwen3-32b": (64, 5120, 64, 8, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 151936),
        "minicpm-2b": (40, 2304, 36, 36, 122753),
        "qwen3-0.6b": (28, 1024, 16, 8, 151936),
        "rwkv6-7b": (32, 4096, 64, 64, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
        "whisper-base": (6, 512, 8, 8, 51865),
    }[arch_id]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
            cfg.vocab) == expected
    moe_expected = {
        "deepseek-moe-16b": (64, 6), "kimi-k2-1t-a32b": (384, 8),
        "jamba-v0.1-52b": (16, 2),
    }
    if arch_id in moe_expected:
        assert (cfg.moe.n_experts, cfg.moe.top_k) == moe_expected[arch_id]
    # layer-kind pattern sanity
    kinds = cfg.layer_kinds()
    assert len(kinds) == cfg.n_layers
    if arch_id == "jamba-v0.1-52b":
        assert sum(1 for m, _ in kinds if m == "attn") == 4     # 1:7
        assert sum(1 for _, f in kinds if f == "moe") == 16     # every 2nd
    if arch_id == "rwkv6-7b":
        assert all(m == "rwkv" for m, _ in kinds)


def test_cell_count():
    """40 assigned cells; long_500k live only for SSM/hybrid (8 of the 10
    archs are pure full-attention) -> 32 live."""
    cells = live_cells(ARCHS, ALL_SHAPES)
    assert len(cells) == 32
    assert ("rwkv6-7b", "long_500k") in cells
    assert ("jamba-v0.1-52b", "long_500k") in cells
    assert ("qwen3-8b", "long_500k") not in cells


def test_param_counts_in_range():
    """Full configs land near their nameplate sizes (structural check)."""
    import numpy as np

    def count(cfg):
        params = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
        return sum(int(np.prod(x.shape))
                   for x in jax.tree_util.tree_leaves(params))

    expect = {
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "qwen3-8b": (7e9, 9.5e9),
        "qwen3-32b": (30e9, 36e9),
        "minicpm-2b": (2.2e9, 3.2e9),
        "qwen2-vl-7b": (6.5e9, 9e9),
        "deepseek-moe-16b": (14e9, 19e9),
        "rwkv6-7b": (6.5e9, 9e9),
        "jamba-v0.1-52b": (49e9, 56e9),
        "whisper-base": (0.05e9, 0.12e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
    }
    for aid, (lo, hi) in expect.items():
        n = count(get_config(aid))
        assert lo <= n <= hi, f"{aid}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
