"""scripts/check_bench.py gate logic: passes in-band, fails regressions,
refuses config mismatches (which would silently compare different work)."""
import importlib.util
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

spec = importlib.util.spec_from_file_location(
    "check_bench", ROOT / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)


def record(**metrics):
    return {"benchmark": "allocator", "git_sha": "test", "backend": "cpu",
            "device_count": 8, "x64": True, "smoke": True,
            "results": {"batch": {"B": 16, "n": 17, **metrics}}}


def write(d, name, payload):
    (d / name).write_text(json.dumps(payload))


def run_gate(tmp_path, baseline, fresh, monkeypatch):
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    write(bdir, "BENCH_allocator.json", baseline)
    write(fdir, "BENCH_allocator.json", fresh)
    monkeypatch.setattr(
        sys, "argv", ["check_bench", "--fresh-dir", str(fdir),
                      "--baseline-dir", str(bdir)])
    return check_bench.main()


def test_gate_passes_within_band(tmp_path, monkeypatch):
    base = record(speedup=10.0, scenarios_per_sec=1000.0)
    fresh = record(speedup=5.0, scenarios_per_sec=300.0)   # -50%, -70%: ok
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 0


def test_gate_fails_ratio_regression(tmp_path, monkeypatch):
    base = record(speedup=10.0)
    fresh = record(speedup=2.0)                 # below the -60% ratio floor
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_gate_fails_throughput_collapse(tmp_path, monkeypatch):
    base = record(scenarios_per_sec=1000.0)
    fresh = record(scenarios_per_sec=100.0)     # order-of-magnitude drop
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_gate_fails_config_mismatch(tmp_path, monkeypatch):
    base = record(speedup=10.0)
    fresh = record(speedup=10.0)
    fresh["results"]["batch"]["B"] = 8          # easier config: refuse
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_gate_fails_engine_path_mismatch(tmp_path, monkeypatch):
    """The `path` tag is config: per-event vs coalesced-epochs events/sec
    measure different engines and must never be silently compared."""
    base = record(events_per_sec=100.0)
    fresh = record(events_per_sec=100.0)
    base["results"]["batch"]["path"] = "per-event"
    fresh["results"]["batch"]["path"] = "coalesced-epochs"
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1
    fresh["results"]["batch"]["path"] = "per-event"
    again = tmp_path / "matching-paths"
    again.mkdir()
    assert run_gate(again, base, fresh, monkeypatch) == 0


def test_gate_fails_residency_mismatch(tmp_path, monkeypatch):
    """The `residency` tag is config: device-resident and host-round-trip
    streaming measure different machines (ISSUE 7) — a resident record
    must never be silently gated against a round-trip baseline."""
    base = record(events_per_sec=300.0)
    fresh = record(events_per_sec=300.0)
    base["results"]["batch"]["residency"] = "round-trip"
    fresh["results"]["batch"]["residency"] = "resident"
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1
    fresh["results"]["batch"]["residency"] = "round-trip"
    again = tmp_path / "matching-residency"
    again.mkdir()
    assert run_gate(again, base, fresh, monkeypatch) == 0


def test_gate_fails_fused_iter_config_mismatch(tmp_path, monkeypatch):
    """The `iter` / `dtype_policy` / `steps` tags are config: a fused-
    kernel speedup measured under a different iter_fn, element-width
    policy or pinned iteration count is a different experiment (ISSUE 9)
    and must hard-fail the compare instead of silently passing."""
    for key, other in [("iter", "gnep_iter(force_pallas=True)"),
                       ("dtype_policy", "f32-vs-f64"),
                       ("steps", 96)]:
        base = record(speedup=1.6)
        fresh = record(speedup=1.6)
        base["results"]["batch"].update(
            {"iter": "gnep_iter(force_pallas=False)",
             "dtype_policy": "f64-vs-f64", "steps": 48})
        fresh["results"]["batch"].update(base["results"]["batch"])
        fresh["results"]["batch"][key] = other
        d = tmp_path / f"mismatch-{key}"
        d.mkdir()
        assert run_gate(d, base, fresh, monkeypatch) == 1
    base = record(speedup=1.6)
    fresh = record(speedup=1.55)
    tags = {"iter": "gnep_iter(force_pallas=False)",
            "dtype_policy": "f64-vs-f64", "steps": 48}
    base["results"]["batch"].update(tags)
    fresh["results"]["batch"].update(tags)
    ok = tmp_path / "matching-tags"
    ok.mkdir()
    assert run_gate(ok, base, fresh, monkeypatch) == 0


def test_gate_fails_plan_grid_config_mismatch(tmp_path, monkeypatch):
    """The `grid` / `profile` / `fleet` tags are config: a planner
    candidates/sec number over a different design-space size, workload
    profile or fleet axis shape (ISSUE 10) is a different sweep and must
    hard-fail the compare instead of silently passing."""
    tags = {"grid": 48, "profile": "bursty", "fleet": "4x2x2x3"}
    for key, other in [("grid", 96), ("profile", "poisson"),
                       ("fleet", "8x4x4x8")]:
        base = record(candidates_per_sec=400.0)
        fresh = record(candidates_per_sec=400.0)
        base["results"]["batch"].update(tags)
        fresh["results"]["batch"].update({**tags, key: other})
        d = tmp_path / f"mismatch-{key}"
        d.mkdir()
        assert run_gate(d, base, fresh, monkeypatch) == 1
    base = record(candidates_per_sec=400.0)
    fresh = record(candidates_per_sec=30.0)      # matching tags, collapse
    base["results"]["batch"].update(tags)
    fresh["results"]["batch"].update(tags)
    collapse = tmp_path / "matching-tags-collapse"
    collapse.mkdir()
    assert run_gate(collapse, base, fresh, monkeypatch) == 1
    fresh["results"]["batch"]["candidates_per_sec"] = 350.0   # in band
    ok = tmp_path / "matching-tags-ok"
    ok.mkdir()
    assert run_gate(ok, base, fresh, monkeypatch) == 0


def test_gate_latency_ceiling_passes_within_band(tmp_path, monkeypatch):
    """Latency metrics gate in the opposite direction: lower is better,
    so a drop is always fine and a rise passes only inside the ceiling."""
    base = record(admission_p50_ms=10.0, admission_p99_ms=40.0)
    fresh = record(admission_p50_ms=2.0, admission_p99_ms=120.0)  # p99 3x: ok
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 0


def test_gate_fails_latency_blowup(tmp_path, monkeypatch):
    base = record(admission_p50_ms=10.0)
    fresh = record(admission_p50_ms=80.0)       # 8x > the 5x ceiling
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_gate_fails_arrival_process_mismatch(tmp_path, monkeypatch):
    """The `arrival` tag is config: Poisson and flash-crowd admission
    latencies measure different load shapes and are never comparable."""
    base = record(admission_p99_ms=40.0)
    fresh = record(admission_p99_ms=40.0)
    base["results"]["batch"]["arrival"] = "poisson"
    fresh["results"]["batch"]["arrival"] = "flash"
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1
    fresh["results"]["batch"]["arrival"] = "poisson"
    again = tmp_path / "matching-arrival"
    again.mkdir()
    assert run_gate(again, base, fresh, monkeypatch) == 0


def test_gate_fails_solver_config_mismatch(tmp_path, monkeypatch):
    """The SolverConfig fingerprint is config: engine-path numbers must
    never be compared against records measured under a different solver
    config — or against pre-redesign records that carry no fingerprint."""
    fp = "eps_bar=0.03|lam=0.05|max_iters=200|dtype=native|sweep=reference" \
         "|mesh=none"
    base = record(speedup=10.0)
    fresh = record(speedup=10.0)
    fresh["solver_config"] = fp                 # baseline pre-dates the field
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1
    base["solver_config"] = fp.replace("0.03", "0.05")   # different knobs
    other = tmp_path / "different-knobs"
    other.mkdir()
    assert run_gate(other, base, fresh, monkeypatch) == 1
    base["solver_config"] = fp
    both = tmp_path / "matching-config"
    both.mkdir()
    assert run_gate(both, base, fresh, monkeypatch) == 0


def test_gate_fails_missing_section_or_file(tmp_path, monkeypatch):
    base = record(speedup=10.0)
    fresh = record(speedup=10.0)
    del fresh["results"]["batch"]               # benchmark silently skipped
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_gate_fails_device_topology_mismatch(tmp_path, monkeypatch):
    base = record(speedup=10.0)
    fresh = record(speedup=10.0)
    fresh["device_count"] = 1                   # different forced topology
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_gate_fails_smoke_mismatch(tmp_path, monkeypatch):
    base = record(speedup=10.0)
    fresh = record(speedup=10.0)
    fresh["smoke"] = False                      # full run vs smoke baseline
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_gate_fails_backend_mismatch(tmp_path, monkeypatch):
    base = record(speedup=10.0)
    fresh = record(speedup=10.0)
    fresh["backend"] = "gpu"                    # incomparable throughputs
    assert run_gate(tmp_path, base, fresh, monkeypatch) == 1


def test_committed_baselines_parse():
    """The committed baselines are well-formed and carry gated metrics."""
    files = sorted((ROOT / "benchmarks" / "baselines").glob("BENCH_*.json"))
    assert len(files) >= 2
    for f in files:
        rec = json.loads(f.read_text())
        assert rec["device_count"] == 8 and rec["smoke"] is True
        assert "solver_config" in rec           # engine-era provenance
        gated = [m for sec in rec["results"].values()
                 for m in sec if m in check_bench.GATED]
        assert gated, f"{f.name} has no gated metrics"
