"""Engine redesign tests: legacy-shim bit-equivalence, input coercion, the
config fingerprint and the WindowSession verb set.

Every historical ``allocator.solve_*`` call-site pattern (method variants,
``Sequence[Scenario]`` vs ``ScenarioBatch``, ``mesh=``, ``sweep_fn=``, warm
starts, ``cross_check=``, coalesced replays) is asserted BIT-EQUAL against
the corresponding ``CapacityEngine`` call, and every shim must emit the
``repro.core.allocator`` DeprecationWarning that pytest.ini promotes to an
error for any other in-repo caller.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionWindow, BatchSolveReport, CapacityEngine,
                        ClassArrival, ClassDeparture, CompactionPolicy,
                        CrossCheckPolicy,
                        FlushPolicy, InfeasibleError, Policies,
                        RoundingPolicy, Scenario, ScenarioBatch, SolveReport,
                        SolverConfig, WindowSolveReport, lane_mesh,
                        sample_class_params, sample_event_trace,
                        sample_scenario, stack_scenarios)
from repro.core import allocator
from repro.core.engine import _coerce
from repro.kernels.gnep_sweep.ref import reference_batched

D = jax.device_count()
needs_devices = pytest.mark.skipif(
    D < 2, reason="needs >= 2 devices (conftest forces 8 on CPU)")

SHIM_WARNING = pytest.warns(DeprecationWarning,
                            match=r"^repro\.core\.allocator\.")


def scenarios(ns=(5, 8, 3, 6), cf=1.1, seed0=0):
    return [sample_scenario(jax.random.PRNGKey(seed0 + i), n,
                            capacity_factor=cf)
            for i, n in enumerate(ns)]


def make_window(ns=(5, 8, 3, 6), cf=1.2, n_max=None, seed0=0):
    return AdmissionWindow(scenarios(ns, cf, seed0), n_max=n_max)


def assert_reports_bitequal(a, b):
    """Every numeric leaf of two reports is bit-identical."""
    for part in ("fractional", "integer"):
        pa, pb = getattr(a, part), getattr(b, part)
        assert (pa is None) == (pb is None)
        if pa is not None:
            ja, jb = jax.tree_util.tree_flatten(pa)[0], \
                jax.tree_util.tree_flatten(pb)[0]
            for la, lb in zip(ja, jb):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_array_equal(np.asarray(a.iters), np.asarray(b.iters))
    for field in ("feasible", "resolved", "centralized_gap"):
        fa, fb = getattr(a, field, None), getattr(b, field, None)
        assert (fa is None) == (fb is None)
        if fa is not None:
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


# --------------------------------------------------------------------------
# Legacy shims: bit-equal to the engine, and they warn
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["distributed", "centralized",
                                    "distributed-python"])
def test_shim_solve_bitequal(method):
    scn = scenarios(ns=(9,), cf=0.95)[0]
    eng = CapacityEngine(SolverConfig(eps_bar=0.05, max_iters=100))
    want = eng.solve(scn, method=method)
    with SHIM_WARNING:
        got = allocator.solve(scn, method, eps_bar=0.05, max_iters=100)
    assert_reports_bitequal(got, want)
    assert got.method == want.method == method


def test_shim_solve_infeasible_and_no_rounding():
    bad = scenarios(ns=(8,), cf=0.5)[0]
    with SHIM_WARNING, pytest.raises(InfeasibleError):
        allocator.solve(bad, "centralized")
    good = scenarios(ns=(7,), cf=0.95)[0]
    want = CapacityEngine(
        policies=Policies(rounding=RoundingPolicy(False))).solve(good)
    with SHIM_WARNING:
        got = allocator.solve(good, integer=False)
    assert got.integer is None and want.integer is None
    assert_reports_bitequal(got, want)


@pytest.mark.parametrize("as_list", [True, False])
def test_shim_solve_batch_bitequal(as_list):
    scns = scenarios(ns=(5, 17, 9, 12))
    batch = scns if as_list else stack_scenarios(scns)
    want = CapacityEngine().solve(batch)
    with SHIM_WARNING:
        got = allocator.solve_batch(batch)
    assert_reports_bitequal(got, want)
    np.testing.assert_array_equal(np.asarray(got.mask), np.asarray(want.mask))


def test_shim_solve_batch_check_feasible_and_knobs():
    good, bad = scenarios(ns=(8, 8), cf=0.95)[0], \
        scenarios(ns=(8,), cf=0.5, seed0=1)[0]
    with SHIM_WARNING, pytest.raises(InfeasibleError, match=r"\[1\]"):
        allocator.solve_batch([good, bad])
    eng = CapacityEngine(SolverConfig(eps_bar=0.06, lam=0.04, max_iters=50),
                         Policies(rounding=RoundingPolicy(False)))
    want = eng.solve([good, bad], check_feasible=False)
    with SHIM_WARNING:
        got = allocator.solve_batch([good, bad], eps_bar=0.06, lam=0.04,
                                    max_iters=50, integer=False,
                                    check_feasible=False)
    assert_reports_bitequal(got, want)
    assert not bool(got.feasible[1])


def test_shim_solve_batch_sweep_fn_bitequal():
    def sweep(inc, spare, p_sorted):
        return reference_batched(inc, spare, p_sorted)

    scns = scenarios(ns=(5, 9, 7))
    want = CapacityEngine(SolverConfig(sweep_fn=sweep)).solve(scns)
    with SHIM_WARNING:
        got = allocator.solve_batch(scns, sweep_fn=sweep)
    assert_reports_bitequal(got, want)


@needs_devices
def test_shim_solve_batch_mesh_bitequal():
    mesh = lane_mesh()
    scns = scenarios(ns=(5, 17, 9, 12, 3))     # not divisible by the devices
    want = CapacityEngine(SolverConfig(mesh=mesh)).solve(scns)
    with SHIM_WARNING:
        got = allocator.solve_batch(scns, mesh=mesh)
    assert_reports_bitequal(got, want)


@needs_devices
def test_shim_solve_streaming_bitequal_warm_cross_check_mesh():
    """The full streaming pattern: cold solve, events, warm re-solve with
    cross_check and a mesh — shim and session bit-equal at every step."""
    mesh = lane_mesh()
    w_shim, w_eng = make_window(), make_window()
    eng = CapacityEngine(
        SolverConfig(mesh=mesh),
        Policies(rounding=RoundingPolicy(False),
                 cross_check=CrossCheckPolicy(True)))
    sess = eng.open_window(w_eng)
    with SHIM_WARNING:
        got = allocator.solve_streaming(w_shim, integer=False, mesh=mesh,
                                        cross_check=True)
    assert_reports_bitequal(got, sess.solve())

    params = sample_class_params(jax.random.PRNGKey(3))
    w_shim.arrive(1, **params)
    w_eng.arrive(1, **params)
    with SHIM_WARNING:
        got = allocator.solve_streaming(w_shim, integer=False, mesh=mesh,
                                        cross_check=True)
    want = sess.solve()
    assert_reports_bitequal(got, want)
    np.testing.assert_array_equal(got.resolved,
                                  [False, True, False, False])


def test_shim_solve_coalesced_bitequal():
    w_shim, w_eng = make_window(n_max=9), make_window(n_max=9)
    trace = sample_event_trace(11, w_shim, 14)
    eng = CapacityEngine(
        policies=Policies(flush=FlushPolicy(max_events=5),
                          rounding=RoundingPolicy(False)))
    want_reports = list(eng.open_window(w_eng).stream(trace))
    with SHIM_WARNING:
        got_gen = allocator.solve_coalesced(
            w_shim, trace, policy=FlushPolicy(max_events=5), integer=False)
        got_reports = list(got_gen)
    assert len(got_reports) == len(want_reports) == 3   # 5 + 5 + trailing 4
    for got, want in zip(got_reports, want_reports):
        assert_reports_bitequal(got, want)


def test_legacy_result_types_are_report_aliases():
    assert allocator.AllocationResult is SolveReport
    assert allocator.BatchAllocationResult is BatchSolveReport
    assert allocator.StreamingResult is WindowSolveReport


# --------------------------------------------------------------------------
# Input coercion (_coerce): one helper, every entry point
# --------------------------------------------------------------------------

def test_engine_solve_accepts_all_input_forms():
    scns = scenarios(ns=(4, 6, 3))
    eng = CapacityEngine()
    from_list = eng.solve(scns)
    from_batch = eng.solve(stack_scenarios(scns))
    assert_reports_bitequal(from_list, from_batch)
    from_window = eng.solve(AdmissionWindow(scns))
    assert_reports_bitequal(from_list, from_window)
    single = eng.solve(scns[0])                  # single-instance pipeline
    assert isinstance(single, SolveReport)
    assert not isinstance(single, BatchSolveReport)
    np.testing.assert_allclose(np.asarray(single.fractional.r),
                               np.asarray(from_list.instance(0).fractional.r),
                               rtol=1e-6, atol=1e-6)


def test_coerce_rejects_garbage_and_mixed_sequences():
    eng = CapacityEngine()
    with pytest.raises(TypeError, match="cannot coerce"):
        eng.solve(42)
    with pytest.raises(TypeError, match="Scenario instances only"):
        eng.solve([scenarios(ns=(4,))[0], "nope"])
    with pytest.raises(TypeError, match="cannot coerce"):
        _coerce("a string is not a batch")


def test_open_window_accepts_all_lane_forms():
    """The legacy drift — streaming paths rejecting Sequence[Scenario] — is
    gone: list, ScenarioBatch and AdmissionWindow all open sessions, and
    the solves agree bit-exactly."""
    scns = scenarios(ns=(4, 6, 3))
    eng = CapacityEngine(policies=Policies(rounding=RoundingPolicy(False)))
    res_list = eng.open_window(scns, n_max=8).solve()
    res_batch = eng.open_window(stack_scenarios(scns, n_max=8)).solve()
    res_window = eng.open_window(AdmissionWindow(scns, n_max=8)).solve()
    assert_reports_bitequal(res_list, res_batch)
    assert_reports_bitequal(res_list, res_window)


def test_config_dtype_coerces_leaves():
    scns = scenarios(ns=(4, 5))
    eng = CapacityEngine(SolverConfig(dtype=jnp.float32),
                         Policies(rounding=RoundingPolicy(False)))
    res = eng.solve(scns)
    assert res.fractional.r.dtype == jnp.float32
    single = eng.solve(scns[0])
    assert single.fractional.r.dtype == jnp.float32


def test_sweep_fn_reaches_streaming_path():
    """Regression for the kwargs drift: a configured sweep kernel must be
    traced into the warm streaming solve, not silently dropped."""
    calls = {"n": 0}

    def counting_sweep(inc, spare, p_sorted):
        calls["n"] += 1
        return reference_batched(inc, spare, p_sorted)

    eng = CapacityEngine(SolverConfig(sweep_fn=counting_sweep),
                         Policies(rounding=RoundingPolicy(False)))
    sess = eng.open_window(scenarios(ns=(4, 6)))
    res = sess.solve()
    assert calls["n"] >= 1                       # traced into the program
    ref = CapacityEngine(
        policies=Policies(rounding=RoundingPolicy(False))).open_window(
            scenarios(ns=(4, 6))).solve()
    np.testing.assert_allclose(np.asarray(res.fractional.r),
                               np.asarray(ref.fractional.r),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# SolverConfig: hashable, fingerprinted
# --------------------------------------------------------------------------

def test_solver_config_fingerprint_stable_and_distinct():
    a, b = SolverConfig(), SolverConfig()
    assert a == b and hash(a) == hash(b)
    assert a.fingerprint() == b.fingerprint()
    assert "eps_bar=0.03" in a.fingerprint()
    assert SolverConfig(eps_bar=0.05).fingerprint() != a.fingerprint()
    assert SolverConfig(dtype=jnp.float32).fingerprint() != a.fingerprint()

    def my_sweep(inc, spare, p):                # named kernels fingerprint
        return reference_batched(inc, spare, p)

    assert "sweep=my_sweep" in SolverConfig(sweep_fn=my_sweep).fingerprint()


@needs_devices
def test_solver_config_fingerprint_names_mesh():
    fp = SolverConfig(mesh=lane_mesh(2)).fingerprint()
    assert "mesh=2:lanes" in fp


# --------------------------------------------------------------------------
# Reports
# --------------------------------------------------------------------------

def test_report_carries_config_timing_convergence():
    scns = scenarios(ns=(5, 7))
    cfg = SolverConfig(max_iters=100)
    res = CapacityEngine(cfg).solve(scns)
    assert res.config is cfg
    assert res.elapsed_s >= 0.0
    assert np.asarray(res.converged).all()       # well under the cap
    inst = res.instance(1)
    assert inst.config is cfg and inst.iters == int(res.iters[1])
    # a capped solve reports non-convergence
    capped = CapacityEngine(SolverConfig(max_iters=1),
                            Policies(rounding=RoundingPolicy(False))
                            ).solve(scns, check_feasible=False)
    assert not np.asarray(capped.converged).any()


# --------------------------------------------------------------------------
# WindowSession verbs
# --------------------------------------------------------------------------

def test_session_apply_auto_flushes_on_count():
    eng = CapacityEngine(
        policies=Policies(flush=FlushPolicy(max_events=2),
                          rounding=RoundingPolicy(False)))
    sess = eng.open_window(scenarios())
    sess.solve()
    ev = lambda i: ClassArrival(
        lane=i % 2, params=sample_class_params(jax.random.PRNGKey(i)))
    assert sess.apply(ev(0)) is None and len(sess.pending) == 1
    rep = sess.apply(ev(1))                      # count trigger fires
    assert isinstance(rep, WindowSolveReport) and not sess.pending
    assert sess.flushes == 1 and sess.events_folded == 2
    assert sorted(np.flatnonzero(rep.resolved)) == [0, 1]
    # one apply call carrying enough events for two flushes returns the last
    rep2 = sess.apply(ev(2), ev(3), ev(4), ev(5))
    assert sess.flushes == 3 and len(sess.pending) == 0
    assert isinstance(rep2, WindowSolveReport)


def test_session_stream_equals_manual_replay():
    w_a, w_b = make_window(n_max=9), make_window(n_max=9)
    trace = sample_event_trace(21, w_a, 12)
    pol = Policies(flush=FlushPolicy(max_events=4),
                   rounding=RoundingPolicy(False))
    reports = list(CapacityEngine(policies=pol).open_window(w_a)
                   .stream(trace))
    assert len(reports) == 3
    sess_b = CapacityEngine(policies=pol).open_window(w_b)
    manual = []
    for ev in trace:
        rep = sess_b.apply(ev)
        if rep is not None:
            manual.append(rep)
    if sess_b.pending:
        manual.append(sess_b.flush())
    assert len(manual) == len(reports)
    for got, want in zip(manual, reports):
        assert_reports_bitequal(got, want)


def test_session_compaction_policy_repacks_and_reports_slot_map():
    """Churn below the occupancy threshold auto-compacts at the flush
    boundary; the report's slot_map records the re-layout and clean lanes
    stay frozen (bit-equal equilibria through the permutation)."""
    eng = CapacityEngine(policies=Policies(
        flush=FlushPolicy(max_events=None),      # manual flushes
        compaction=CompactionPolicy(occupancy=0.5),
        rounding=RoundingPolicy(False)))
    sess = eng.open_window(make_window(ns=(6, 7, 5, 6), n_max=12))
    pre = sess.solve()
    window = sess.window
    pre_occ = [window.occupied(b) for b in range(4)]
    for b in range(4):                           # depart all but 2 per lane
        for slot in window.occupied(b)[2:]:
            sess.apply(ClassDeparture(lane=b, slot=slot))
    rep = sess.flush()
    assert rep.slot_map is not None and window.n_max == 2
    for b in range(4):
        kept = [s for s in pre_occ[b] if rep.slot_map[b, s] >= 0]
        np.testing.assert_array_equal(
            np.asarray(rep.fractional.r[b]),
            np.asarray(pre.fractional.r[b])[kept])
    # next flush without churn: no compaction, no slot map
    assert sess.flush().slot_map is None


def test_session_geometry_verbs_drain_first():
    eng = CapacityEngine(
        policies=Policies(flush=FlushPolicy(max_events=None),
                          rounding=RoundingPolicy(False)))
    sess = eng.open_window(scenarios(ns=(4, 5)))
    sess.solve()
    sess.apply(ClassArrival(
        lane=0, params=sample_class_params(jax.random.PRNGKey(1))))
    b = sess.add_lane(R=300.0, rho_bar=2.0)      # drains the pending arrival
    assert not sess.pending and sess.last_slots == [4]
    assert b == 2 and sess.window.batch_size == 3
    res = sess.flush()
    np.testing.assert_array_equal(res.resolved, [True, False, True])
    sess.remove_lane(b)
    assert sess.window.batch_size == 2
    slot_map = sess.compact()
    assert slot_map.shape[0] == 2


# --------------------------------------------------------------------------
# Policies corner cases (PR 6) + empty-drain/flush regressions
# --------------------------------------------------------------------------

def test_flush_policy_deadline_zero_slack():
    """slack_s=0: only events AT or past infeasibility (E >= 0) are
    critical; an attainable deadline by any margin keeps coalescing."""
    pol = FlushPolicy.deadline(0.0, max_events=100)
    w = make_window(ns=(3, 4), n_max=8)
    params = sample_class_params(jax.random.PRNGKey(0))
    attainable = ClassArrival(lane=0, params={**params, "E": -1e-9})
    boundary = ClassArrival(lane=0, params={**params, "E": 0.0})
    missed = ClassArrival(lane=0, params={**params, "E": 3.0})
    assert not pol.is_critical(attainable, w)
    assert pol.is_critical(boundary, w)
    assert pol.is_critical(missed, w)


def test_flush_policy_deadline_negative_slack():
    """Negative slack: the criticality frontier moves PAST infeasibility —
    only events already missing the deadline by |slack| trigger (the
    operator's 'don't panic until it's truly lost' setting)."""
    pol = FlushPolicy.deadline(-5.0, max_events=100)
    w = make_window(ns=(3, 4), n_max=8)
    params = sample_class_params(jax.random.PRNGKey(0))
    infeasible_by_4 = ClassArrival(lane=0, params={**params, "E": 4.0})
    infeasible_by_5 = ClassArrival(lane=0, params={**params, "E": 5.0})
    assert not pol.is_critical(infeasible_by_4, w)
    assert pol.is_critical(infeasible_by_5, w)
    # and through the session: the sub-threshold event keeps buffering
    eng = CapacityEngine(policies=Policies(
        flush=pol, rounding=RoundingPolicy(False)))
    sess = eng.open_window(make_window(ns=(3, 4), n_max=8))
    assert sess.apply(infeasible_by_4) is None and len(sess.pending) == 1
    assert sess.apply(infeasible_by_5) is not None and not sess.pending


def test_compaction_policy_on_already_compact_window_is_identity():
    """CompactionPolicy firing on a window already packed at its minimal
    width must report an IDENTITY slot_map (occupied slots map to
    themselves) and change nothing."""
    # 2 classes per lane packed at slots [0, 1], n_max equal to the widest
    # lane -> occupancy 2/3 < 0.9 fires the policy, but there is nothing
    # to move and nothing to shrink
    eng = CapacityEngine(policies=Policies(
        flush=FlushPolicy(max_events=None),
        compaction=CompactionPolicy(occupancy=0.9, headroom=1.0),
        rounding=RoundingPolicy(False)))
    sess = eng.open_window(make_window(ns=(2, 2, 3), n_max=3))
    before_mask = sess.window._mask.copy()
    rep = sess.flush()
    assert rep.slot_map is not None              # the policy DID fire
    # identity: every occupied slot keeps its index, every hole is -1,
    # and the window's geometry/occupancy is untouched
    for b in range(before_mask.shape[0]):
        idx = np.flatnonzero(before_mask[b])
        np.testing.assert_array_equal(rep.slot_map[b, idx], idx)
        holes = np.flatnonzero(~before_mask[b])
        np.testing.assert_array_equal(rep.slot_map[b, holes],
                                      np.full(holes.size, -1))
    np.testing.assert_array_equal(sess.window._mask, before_mask)
    assert sess.window.n_max == 3


def test_cross_check_policy_on_all_empty_window():
    """CrossCheckPolicy on a window whose lanes are ALL empty: the exact
    baseline degenerates to 0, the gap is exactly 0, nothing raises."""
    eng = CapacityEngine(policies=Policies(
        flush=FlushPolicy(max_events=None),
        cross_check=CrossCheckPolicy(enabled=True),
        rounding=RoundingPolicy(False)))
    sess = eng.open_window(make_window(ns=(2, 3), n_max=4))
    for b in range(2):
        for slot in sess.window.occupied(b):
            sess.apply(ClassDeparture(lane=b, slot=slot))
    rep = sess.flush()
    assert not sess.window._mask.any()
    np.testing.assert_array_equal(np.asarray(rep.centralized_gap),
                                  np.zeros(2))
    np.testing.assert_array_equal(np.asarray(rep.fractional.total),
                                  np.zeros(2))


def test_empty_drain_returns_empty_without_solve():
    """Satellite regression: drain with zero buffered events returns []
    and performs no window work at all."""
    eng = CapacityEngine(policies=Policies(rounding=RoundingPolicy(False)))
    sess = eng.open_window(make_window(ns=(3, 4), n_max=8))
    assert sess.drain() == []
    assert sess.events_folded == 0 and sess.flushes == 0
    assert sess.window.state is None             # nothing was solved


def test_empty_flush_is_a_noop_echo():
    """Satellite regression: flush on a clean, solved, geometry-unchanged
    session echoes the last report (slot_map cleared) with NO solve
    dispatch — counters do not advance."""
    eng = CapacityEngine(policies=Policies(
        flush=FlushPolicy(max_events=2), rounding=RoundingPolicy(False)))
    sess = eng.open_window(make_window(ns=(3, 4), n_max=8))
    first = sess.flush()                         # initial solve (dirty lanes)
    assert sess.flushes == 1
    again = sess.flush()                         # clean + solved: no-op
    assert sess.flushes == 1 and sess.events_folded == 0
    assert again.slot_map is None
    assert again.fractional is first.fractional  # the SAME solution object
    np.testing.assert_array_equal(np.asarray(again.mask),
                                  np.asarray(first.mask))
    # geometry changes invalidate the echo: a real solve runs again
    sess.add_lane(R=300.0, rho_bar=2.0)
    third = sess.flush()
    assert sess.flushes == 2
    assert np.asarray(third.mask).shape[0] == 3
