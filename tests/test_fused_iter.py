"""Differential conformance harness for the fused Alg. 4.1 iteration kernel.

The fused path (``repro.kernels.gnep_iter``, ISSUE 9) makes a two-sided
numerics promise and this file is its enforcement:

* **kernel side, bitwise**: the Pallas kernel (interpret mode off-TPU) is
  bit-equal to the pure-jnp reference ``ref.py`` at ANY tiling, per
  iteration and at the converged equilibrium — under ragged masks, inert
  padded lanes, warm starts, a sharded lane mesh and device-resident
  window sessions.  The mesh case doubles as the regression pin for the
  while_loop + shard_map gather miscompile ``ref.iter_step`` works
  around (its body is gather-free for exactly that reason).
* **unfused side, tolerance**: against the unfused dispatch chain the
  fused formulation reorders prefix sums, so equilibria agree to ULPs
  (``tests/_tolerance.py``), not bits — with identical iteration counts.

Also here: the ``SolverConfig`` golden-fingerprint table (every knob,
including ``iter_fn`` / ``dtype_policy``), the ``dtype_policy``
validation matrix, the ``f32_checked`` cross-check behavior, and the
PR 6/7 donation-aliasing regression properties on the fused resident
path.  Hypothesis properties skip loudly when the package is absent
(``tests/_hypothesis_compat``).
"""
import dataclasses
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from _tolerance import assert_bitwise_equal, assert_ulp_close
from repro.core import (AdmissionWindow, CapacityEngine, FlushPolicy,
                        Policies, RoundingPolicy, SolverConfig, lane_mesh,
                        sample_event_trace, sample_scenario)
from repro.core.engine import _dtype_check
from repro.core.game import cold_start, solve_distributed_batch
from repro.core.sharding import solve_sharded_batch
from repro.core.types import Solution, stack_scenarios
from repro.kernels.gnep_iter import ref
from repro.kernels.gnep_iter.kernel import fused_iter_sweep
from repro.kernels.gnep_iter.ops import make_fused_iter_fn

D = jax.device_count()
needs_devices = pytest.mark.skipif(
    D < 2, reason="needs >= 2 devices (conftest forces 8 on CPU)")

RAGGED_NS = (5, 12, 3, 9, 12, 7)       # ragged: n_max never matches lane 0
IT_JNP = make_fused_iter_fn()
IT_PALLAS = make_fused_iter_fn(force_pallas=True)
SOLUTION_FIELDS = [f.name for f in dataclasses.fields(Solution)]


def make_batch(seed=0, ns=RAGGED_NS):
    key = jax.random.PRNGKey(seed)
    return stack_scenarios(
        [sample_scenario(jax.random.fold_in(key, i), n, capacity_factor=0.95)
         for i, n in enumerate(ns)])


def cold_state(batch):
    """(prep, r, bids) at the paper's cold init."""
    scns, mask = batch.scenarios, batch.mask
    prep = ref.prepare(scns, mask)
    r = jnp.where(mask, scns.r_low, 0.0)
    bids = jnp.broadcast_to(scns.rho_bar[:, None],
                            mask.shape).astype(r.dtype)
    return prep, r, bids


def middle_inputs(batch, steps=0):
    """Kernel-middle inputs after ``steps`` reference iterations."""
    scns, mask = batch.scenarios, batch.mask
    prep, r, bids = cold_state(batch)
    for _ in range(steps):
        r, _, bids, _ = ref.iter_step(prep, scns, mask, r, bids, 0.05)
    bids_eff = jnp.where(mask, bids, scns.rho_bar[:, None])
    cand = jnp.concatenate(
        [bids_eff, scns.rho_bar[:, None], scns.rho_hat[:, None]], axis=1)
    bids_sorted = jnp.take_along_axis(bids_eff, prep.order, axis=1)
    return prep, cand, bids_sorted


def assert_solutions_bitequal(a, b, fields=SOLUTION_FIELDS):
    for fld in fields:
        assert_bitwise_equal(np.asarray(getattr(a, fld)),
                             np.asarray(getattr(b, fld)), label=fld)


# --------------------------------------------------------------------------
# Kernel vs scan reference: bit-equal at any tiling, any iteration
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bc,bn", [(128, 512), (7, 5), (16, 8), (1, 1)])
@pytest.mark.parametrize("steps", [0, 3])
def test_kernel_bitwise_vs_reference_any_tiling(bc, bn, steps):
    """fused_iter_sweep == middle_reference bit for bit: full fill tensor,
    objective, argmax and winning price — including tiles that straddle
    the candidate/class extents and the degenerate (1, 1) tiling, on both
    cold bids and a mid-trajectory bid state."""
    prep, cand, bids_sorted = middle_inputs(make_batch(), steps=steps)
    f_r, o_r, b_r, r_r = ref.middle_reference(prep, cand, bids_sorted)
    f_k, o_k, b_k, r_k = fused_iter_sweep(
        bids_sorted, prep.inc_max_sorted, prep.p_sorted, cand, prep.spare,
        prep.rho_bar, prep.sum_r_low, prep.p_r_low, prep.const,
        block_c=bc, block_n=bn, interpret=True)
    assert_bitwise_equal(np.asarray(f_k), np.asarray(f_r), label="fill")
    assert_bitwise_equal(np.asarray(o_k), np.asarray(o_r), label="obj")
    # argmax indices: value equality (the kernel's running argmax is i32,
    # jnp.argmax under x64 is i64 — width is representation, not numerics)
    np.testing.assert_array_equal(np.asarray(b_k), np.asarray(b_r),
                                  err_msg="best")
    assert_bitwise_equal(np.asarray(r_k), np.asarray(r_r), label="rho")


def test_fused_step_pallas_bitwise_vs_jnp_chain():
    """The full fused step (candidate build -> middle -> un-permute -> CM
    responses -> bid update -> eps) with the Pallas middle plugged in is
    bit-equal to the pure-jnp step, iterated feeding back its own state."""
    batch = make_batch(seed=2)
    scns, mask = batch.scenarios, batch.mask
    prep = IT_PALLAS.prepare(scns, mask)
    _, r_j, bids_j = cold_state(batch)
    r_p, bids_p = r_j, bids_j
    for _ in range(4):
        r_j, rho_j, bids_j, eps_j = IT_JNP.step(
            prep, scns, mask, r_j, bids_j, 0.05)
        r_p, rho_p, bids_p, eps_p = IT_PALLAS.step(
            prep, scns, mask, r_p, bids_p, 0.05)
        assert_bitwise_equal(np.asarray(r_p), np.asarray(r_j), label="r")
        assert_bitwise_equal(np.asarray(rho_p), np.asarray(rho_j),
                             label="rho")
        assert_bitwise_equal(np.asarray(bids_p), np.asarray(bids_j),
                             label="bids")
        assert_bitwise_equal(np.asarray(eps_p), np.asarray(eps_j),
                             label="eps")


def test_fused_solve_pallas_bitwise_vs_jnp():
    """Converged equilibria of the jnp-middle and Pallas-middle fused
    solves are bit-identical across every Solution field."""
    batch = make_batch(seed=3)
    sol_j = solve_distributed_batch(batch, iter_fn=IT_JNP)
    sol_p = solve_distributed_batch(batch, iter_fn=IT_PALLAS)
    assert_solutions_bitequal(sol_j, sol_p)


# --------------------------------------------------------------------------
# Fused vs unfused dispatch chain: ULP-tolerance equilibria, same iters
# --------------------------------------------------------------------------

def test_fused_vs_unfused_equilibrium_ulp():
    """The fused formulation reorders the prefix sums (running scan vs
    cumsum), so against the unfused chain the converged allocations agree
    to a few ULPs at the allocation scale — with IDENTICAL per-lane
    iteration counts (the eps trajectory crosses the threshold at the
    same step, or the fusion changed semantics)."""
    batch = make_batch(seed=4)
    sol_u = solve_distributed_batch(batch)
    sol_f = solve_distributed_batch(batch, iter_fn=IT_JNP)
    assert_bitwise_equal(np.asarray(sol_f.iters), np.asarray(sol_u.iters),
                         label="iters")
    assert_bitwise_equal(np.asarray(sol_f.feasible),
                         np.asarray(sol_u.feasible), label="feasible")
    for fld in ("r", "psi", "sM", "sR"):
        assert_ulp_close(getattr(sol_f, fld), getattr(sol_u, fld), ulps=64,
                         scale=np.asarray(sol_u.r), err_msg=fld)
    for fld in ("cost", "penalty", "total"):
        assert_ulp_close(getattr(sol_f, fld), getattr(sol_u, fld), ulps=64,
                         scale=np.asarray(sol_u.total), err_msg=fld)


def test_fused_warm_start_and_frozen_lanes():
    """Warm-start semantics are shared with the unfused solver: an
    explicit cold_start equals the implicit one bitwise, and frozen lanes
    (active=False) pass their stored state straight through while active
    lanes converge exactly as in an all-active solve."""
    batch = make_batch(seed=5)
    sol_a = solve_distributed_batch(batch, iter_fn=IT_JNP)
    sol_b = solve_distributed_batch(batch, init=cold_start(batch),
                                    iter_fn=IT_JNP)
    assert_solutions_bitequal(sol_a, sol_b)

    frozen = np.zeros(len(RAGGED_NS), bool)
    frozen[[1, 3]] = True
    init = cold_start(batch)
    sentinel_r = jnp.where(jnp.asarray(frozen)[:, None],
                           jnp.full_like(init.r, 7.25), init.r)
    init = init._replace(
        r=sentinel_r,
        rho=jnp.where(jnp.asarray(frozen), 3.5, init.rho),
        lane_iters=jnp.where(jnp.asarray(frozen), 11,
                             init.lane_iters).astype(init.lane_iters.dtype),
        active=jnp.asarray(~frozen))
    sol_w = solve_distributed_batch(batch, init=init, iter_fn=IT_JNP)
    r = np.asarray(sol_w.r)
    assert_bitwise_equal(r[frozen], np.asarray(sentinel_r)[frozen],
                         label="frozen r pass-through")
    np.testing.assert_array_equal(np.asarray(sol_w.iters)[frozen], 11)
    assert_bitwise_equal(r[~frozen], np.asarray(sol_a.r)[~frozen],
                         label="active lanes vs all-active solve")


def test_fused_padded_scenario_slots_inert():
    """Garbage in masked-out scenario slots must not perturb the fused
    solve — every prep/step input is masked before use."""
    batch = make_batch(seed=6)
    mask = np.asarray(batch.mask)

    def poison(x):
        arr = np.asarray(x)
        if arr.ndim == 2 and arr.shape == mask.shape:
            return jnp.asarray(np.where(mask, arr, 1e6))
        return x

    poisoned = dataclasses.replace(
        batch, scenarios=jax.tree_util.tree_map(poison, batch.scenarios))
    sol_a = solve_distributed_batch(batch, iter_fn=IT_JNP)
    sol_b = solve_distributed_batch(poisoned, iter_fn=IT_JNP)
    # valid entries bit-equal; padded slots may echo their (poisoned)
    # inputs in psi (existing engine convention: r/sM are zeroed there,
    # psi is not), so the contract covers masked entries + lane scalars
    for fld in ("r", "psi", "sM", "sR"):
        assert_bitwise_equal(np.asarray(getattr(sol_a, fld))[mask],
                             np.asarray(getattr(sol_b, fld))[mask],
                             label=fld)
    for fld in ("cost", "penalty", "total", "feasible", "iters"):
        assert_bitwise_equal(np.asarray(getattr(sol_a, fld)),
                             np.asarray(getattr(sol_b, fld)), label=fld)


# --------------------------------------------------------------------------
# Residency: mesh-sharded and device-resident fused solves, bit for bit
# --------------------------------------------------------------------------

@needs_devices
def test_fused_mesh_bitwise_vs_unsharded():
    """Regression pin for the while_loop + shard_map gather miscompile
    (jax 0.4.37, CPU): with any gather in the loop body every device but
    the first computes wrong lanes.  ``ref.iter_step`` is gather-free so
    the sharded fused solve — inert-lane padding included (6 lanes over a
    4-mesh pads 2) — must equal the unsharded one bitwise."""
    mesh = lane_mesh(min(4, D))
    batch = make_batch(seed=7)
    sol_1 = solve_distributed_batch(batch, iter_fn=IT_JNP)
    sol_m = solve_sharded_batch(batch, mesh, iter_fn=IT_JNP)
    assert_solutions_bitequal(sol_1, sol_m)


def _session_pair(iter_fn, residency_pair=("resident", "round-trip"),
                  seed=0, lanes=4, n=4, n_max=8):
    mesh = lane_mesh(min(4, D))
    key = jax.random.PRNGKey(seed)

    def make():
        scns = [sample_scenario(jax.random.fold_in(key, i), n,
                                capacity_factor=1.3) for i in range(lanes)]
        return AdmissionWindow(scns, n_max=n_max)

    sessions = []
    for residency in residency_pair:
        eng = CapacityEngine(
            SolverConfig(mesh=mesh, residency=residency, iter_fn=iter_fn),
            Policies(flush=FlushPolicy(max_events=1),
                     rounding=RoundingPolicy(False)))
        sessions.append(eng.open_window(make()))
    return sessions, make()


@needs_devices
def test_fused_resident_bitequal_and_donation_safe():
    """Device-resident window sessions with the fused iteration: every
    flush report is bit-equal to the host-round-trip session's, and — the
    PR 6/7 zero-copy regression class — the donated warm-start buffers of
    later flushes never invalidate or rewrite arrays inside reports that
    were already returned."""
    (s_res, s_rt), trace_window = _session_pair(IT_JNP, seed=8)
    reports, snapshots = [], []

    def record(rep_res, rep_rt):
        la = jax.tree_util.tree_flatten(rep_res.fractional)[0]
        lb = jax.tree_util.tree_flatten(rep_rt.fractional)[0]
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            assert_bitwise_equal(np.asarray(x), np.asarray(y),
                                 label="flush report leaf")
        reports.append(rep_res)
        snapshots.append(jax.tree_util.tree_map(
            lambda leaf: np.asarray(leaf).copy(), rep_res.fractional))

    record(s_res.solve(), s_rt.solve())
    for ev in sample_event_trace(9, trace_window, 6):
        s_res.window.apply(ev)
        s_rt.window.apply(ev)
        record(s_res.solve(), s_rt.solve())
    assert s_res.window.is_resident and not s_rt.window.is_resident
    for rep, snap in zip(reports, snapshots):
        got = jax.tree_util.tree_flatten(rep.fractional)[0]
        want = jax.tree_util.tree_flatten(snap)[0]
        for x, y in zip(got, want):     # donated buffers would raise here
            np.testing.assert_array_equal(np.asarray(x), y)


# --------------------------------------------------------------------------
# SolverConfig: golden fingerprints, dtype-policy validation, f32_checked
# --------------------------------------------------------------------------

def test_fingerprint_golden_table():
    """Every knob's fingerprint contribution, pinned verbatim.  The
    default string must stay EXACTLY stable — committed benchmark
    baselines key on it — and non-default residency/iter/dtype_policy
    append in that fixed order so pre-knob records remain comparable."""
    base = ("eps_bar=0.03|lam=0.05|max_iters=200|dtype=native"
            "|sweep=reference|mesh=none")
    assert SolverConfig().fingerprint() == base

    def named_sweep():
        pass  # only the __name__ is fingerprinted

    mesh = lane_mesh(min(2, D))
    table = [
        (SolverConfig(eps_bar=0.1),
         base.replace("eps_bar=0.03", "eps_bar=0.1")),
        (SolverConfig(lam=0.2), base.replace("lam=0.05", "lam=0.2")),
        (SolverConfig(max_iters=50),
         base.replace("max_iters=200", "max_iters=50")),
        (SolverConfig(dtype="float32"),
         base.replace("dtype=native", "dtype=float32")),
        (SolverConfig(sweep_fn=named_sweep),
         base.replace("sweep=reference", "sweep=named_sweep")),
        (SolverConfig(mesh=mesh),
         base.replace("mesh=none", f"mesh={mesh.devices.shape[0]}:lanes")),
        (SolverConfig(mesh=mesh, residency="resident"),
         base.replace("mesh=none", f"mesh={mesh.devices.shape[0]}:lanes")
         + "|residency=resident"),
        (SolverConfig(iter_fn=IT_JNP),
         base + "|iter=gnep_iter(force_pallas=False)"),
        (SolverConfig(iter_fn=IT_PALLAS),
         base + "|iter=gnep_iter(force_pallas=True)"),
        (SolverConfig(dtype_policy="f64"), base + "|dtype_policy=f64"),
        (SolverConfig(dtype_policy="f32_checked"),
         base + "|dtype_policy=f32_checked"),
        (SolverConfig(dtype_policy="f32_checked[:2]"),
         base + "|dtype_policy=f32_checked[:2]"),
        (SolverConfig(mesh=mesh, residency="resident", iter_fn=IT_JNP),
         base.replace("mesh=none", f"mesh={mesh.devices.shape[0]}:lanes")
         + "|residency=resident|iter=gnep_iter(force_pallas=False)"),
        (SolverConfig(iter_fn=IT_JNP, dtype_policy="f32_checked"),
         base + "|iter=gnep_iter(force_pallas=False)"
         + "|dtype_policy=f32_checked"),
    ]
    for cfg, want in table:
        assert cfg.fingerprint() == want, (
            f"fingerprint drift: {cfg.fingerprint()!r} != {want!r}")


def test_dtype_policy_validation():
    """The policy grammar is closed: exactly "f64", "f32_checked" and
    "f32_checked[:k]" (k >= 1) parse; everything else — and combining a
    policy with a raw dtype — is a construction-time ValueError."""
    assert SolverConfig(dtype_policy="f64").effective_dtype() == jnp.float64
    cfg = SolverConfig(dtype_policy="f32_checked")
    assert cfg.effective_dtype() == jnp.float32 and cfg.check_sample() == 4
    assert SolverConfig(dtype_policy="f32_checked[:2]").check_sample() == 2
    assert SolverConfig().check_sample() == 0
    assert SolverConfig(dtype="float32").effective_dtype() == "float32"
    for bad in ("f32", "f32_checked[:0]", "f32_checked[2]", "F32_CHECKED",
                "f32_checked[:-1]", "f64 "):
        with pytest.raises(ValueError):
            SolverConfig(dtype_policy=bad)
    with pytest.raises(ValueError):
        SolverConfig(dtype="float32", dtype_policy="f64")


@needs_devices
def test_f32_checked_refused_with_resident_residency():
    """Resident sessions donate their warm-start buffers, so the shadow
    f64 re-solve could never see the same init — the engine must refuse
    the combination up front rather than check the wrong thing."""
    with pytest.raises(ValueError):
        CapacityEngine(SolverConfig(dtype_policy="f32_checked",
                                    mesh=lane_mesh(min(2, D)),
                                    residency="resident"))


def test_f32_checked_refused_without_x64():
    """With x64 disabled the f64 reference re-solve silently truncates to
    float32 and the cross-check compares the fast path against itself —
    the solve must refuse loudly instead of reporting a vacuous pass.
    Runs in a subprocess because conftest pins x64 on for this one."""
    import subprocess
    import sys
    code = (
        "import jax\n"
        "from repro.core import CapacityEngine, SolverConfig, "
        "sample_scenario\n"
        "scns = [sample_scenario(jax.random.PRNGKey(i), 5) "
        "for i in range(3)]\n"
        "eng = CapacityEngine(SolverConfig(dtype_policy='f32_checked[:2]'))\n"
        "try:\n"
        "    eng.solve(scns)\n"
        "except RuntimeError as e:\n"
        "    assert 'jax_enable_x64' in str(e), e\n"
        "else:\n"
        "    raise SystemExit('f32_checked passed without x64')\n")
    env = {**os.environ, "JAX_PLATFORMS": "cpu", "JAX_ENABLE_X64": "0",
           "PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src")}
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_f32_checked_batch_solve_reports_check():
    """The f32 fast path solves in float32 and the report carries the
    cross-check measurement: k evenly-spaced lanes re-solved in f64, the
    worst relative L1 deviation, and the documented bound."""
    scns = [sample_scenario(jax.random.PRNGKey(i), n, capacity_factor=0.95)
            for i, n in enumerate((5, 9, 3, 7, 6))]
    rep = CapacityEngine(
        SolverConfig(dtype_policy="f32_checked[:3]", iter_fn=IT_JNP)
    ).solve(scns)
    assert rep.fractional.r.dtype == jnp.float32
    chk = rep.dtype_check
    assert chk is not None and len(chk["lanes"]) == 3
    assert chk["max_rel"] <= chk["bound"]
    assert chk["bound"] == pytest.approx(2 * 0.03 + 1e-6)

    rep64 = CapacityEngine(SolverConfig(dtype_policy="f64")).solve(scns)
    assert rep64.fractional.r.dtype == jnp.float64
    assert rep64.dtype_check is None


def test_f32_checked_violation_raises_naming_lanes():
    """A solution outside the f64 equilibrium's basin must raise, and the
    error must say WHICH lanes failed (that is what makes the check
    actionable in a fleet log)."""
    batch = make_batch(seed=10)
    batch32 = jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32)
                   if hasattr(x, "dtype")
                   and jnp.issubdtype(x.dtype, jnp.floating) else x), batch)
    cfg = SolverConfig(dtype_policy="f32_checked[:2]")
    sol = solve_distributed_batch(batch32)
    assert _dtype_check(cfg, batch32, sol)["max_rel"] <= 2 * 0.03 + 1e-6
    bad = dataclasses.replace(sol, r=sol.r * 1.5)
    with pytest.raises(RuntimeError, match="lane"):
        _dtype_check(cfg, batch32, bad)


# --------------------------------------------------------------------------
# Properties (hypothesis; loud skip when the package is absent)
# --------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_prop_fused_step_bitwise_any_batch(seed):
    """For arbitrary scenario batches and bid states, one Pallas-middle
    fused step is bit-equal to the jnp-middle step."""
    rng = np.random.RandomState(seed)
    ns = tuple(int(x) for x in rng.randint(2, 11, size=4))
    batch = make_batch(seed=seed % 1000, ns=ns)
    scns, mask = batch.scenarios, batch.mask
    prep = ref.prepare(scns, mask)
    _, r, bids = cold_state(batch)
    bids = bids * (1.0 + 0.3 * jnp.asarray(rng.rand(*bids.shape)))
    out_j = IT_JNP.step(prep, scns, mask, r, bids, 0.05)
    out_p = IT_PALLAS.step(prep, scns, mask, r, bids, 0.05)
    for x, y, nm in zip(out_p, out_j, ("r", "rho", "bids", "eps")):
        assert_bitwise_equal(np.asarray(x), np.asarray(y), label=nm)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_prop_fused_equilibrium_matches_unfused(seed):
    """For arbitrary batches the fused solve reaches the unfused
    equilibrium: identical iteration counts, allocations within ULPs."""
    rng = np.random.RandomState(seed)
    ns = tuple(int(x) for x in rng.randint(2, 11, size=4))
    batch = make_batch(seed=seed % 1000, ns=ns)
    sol_u = solve_distributed_batch(batch)
    sol_f = solve_distributed_batch(batch, iter_fn=IT_JNP)
    assert_bitwise_equal(np.asarray(sol_f.iters), np.asarray(sol_u.iters),
                         label="iters")
    assert_ulp_close(sol_f.r, sol_u.r, ulps=64, scale=np.asarray(sol_u.r),
                     err_msg="r")


if not HAVE_HYPOTHESIS:
    pass  # @given shims the tests into loud skips (tests/_hypothesis_compat)
