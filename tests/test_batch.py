"""Batched GNEP engine tests: batched-vs-loop equivalence, mask invariance,
RM-sweep optimality against a dense price grid, and Algorithm 4.2 rounding
invariants on batched output."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CapacityEngine, Policies, RoundingPolicy,
                        SolverConfig, sample_scenario, solve_distributed,
                        solve_distributed_batch, stack_scenarios)
from repro.core.game import _rm_candidates, _rm_pick, rm_solve
from repro.core.types import pad_scenario
from repro.kernels.gnep_sweep.kernel import rm_sweep_batched
from repro.kernels.gnep_sweep.ops import make_batched_sweep_fn
from repro.kernels.gnep_sweep.ref import reference_batched

# 10 instances, ragged class counts (several n_i < n_max = 31)
RAGGED_NS = [5, 17, 17, 9, 31, 3, 17, 12, 26, 7]


def solve_batch(batch, *, mesh=None, integer=True, check_feasible=True):
    """Engine-path stand-in for the retired allocator.solve_batch facade
    (the shim itself is covered by tests/test_engine.py)."""
    return CapacityEngine(
        SolverConfig(mesh=mesh),
        Policies(rounding=RoundingPolicy(integer))).solve(
            batch, check_feasible=check_feasible)


def make_batch(ns=RAGGED_NS, cf=0.95, seed0=0):
    scns = [sample_scenario(jax.random.PRNGKey(seed0 + i), n,
                            capacity_factor=cf)
            for i, n in enumerate(ns)]
    return scns, stack_scenarios(scns)


# --------------------------------------------------------------------------
# Batched vs per-scenario loop equivalence
# --------------------------------------------------------------------------

def test_batch_matches_loop():
    """Every lane of solve_distributed_batch reproduces its single-instance
    solve_distributed trajectory, including ragged lanes (n_i < n_max)."""
    scns, batch = make_batch()
    bsol = solve_distributed_batch(batch)
    for b, scn in enumerate(scns):
        s = solve_distributed(scn)
        n = scn.n
        np.testing.assert_allclose(np.asarray(bsol.r[b][:n]),
                                   np.asarray(s.r), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(bsol.psi[b][:n]),
                                   np.asarray(s.psi), rtol=1e-6, atol=1e-9)
        assert float(bsol.total[b]) == pytest.approx(float(s.total), rel=1e-6)
        assert float(bsol.aux[b]) == pytest.approx(float(s.aux), rel=1e-6)
        assert int(bsol.iters[b]) == int(s.iters)
        assert bool(bsol.feasible[b]) == bool(s.feasible)


def test_mask_invariance_padding_inert():
    """Padded classes get r = sM = sR = 0 and never affect valid lanes:
    solving the same instances padded to a larger n_max changes nothing."""
    scns, batch = make_batch()
    wide = stack_scenarios(scns, n_max=batch.n_max + 13)
    sol = solve_distributed_batch(batch)
    sol_w = solve_distributed_batch(wide)
    # padded tails identically zero
    assert np.all(np.asarray(sol_w.r)[~np.asarray(wide.mask)] == 0.0)
    assert np.all(np.asarray(sol_w.sM)[~np.asarray(wide.mask)] == 0.0)
    for b, scn in enumerate(scns):
        n = scn.n
        np.testing.assert_allclose(np.asarray(sol_w.r[b][:n]),
                                   np.asarray(sol.r[b][:n]), rtol=1e-12)
        assert float(sol_w.total[b]) == pytest.approx(float(sol.total[b]),
                                                      rel=1e-12)
        assert int(sol_w.iters[b]) == int(sol.iters[b])


def test_batch_instance_roundtrip():
    scns, batch = make_batch()
    for b in (0, 4, 5):
        inst = batch.instance(b)
        assert inst.n == scns[b].n
        np.testing.assert_allclose(np.asarray(inst.r_up),
                                   np.asarray(scns[b].r_up), rtol=0)


# --------------------------------------------------------------------------
# RM sweep optimality vs a dense brute-force price grid
# --------------------------------------------------------------------------

def _rm_obj_at_price(scn, bids, rho):
    """Exact (P5) objective at a FIXED price rho: forced y + greedy LP fill."""
    p = np.asarray(scn.p)
    r_low, r_up = np.asarray(scn.r_low), np.asarray(scn.r_up)
    y = np.asarray(bids) >= rho
    r = r_low.copy()
    spare = float(scn.R) - r_low.sum()
    for i in np.argsort(-p):
        if y[i]:
            add = min(r_up[i] - r_low[i], spare)
            r[i] += add
            spare -= add
    return ((rho - float(scn.rho_bar)) * r.sum() + (p * r).sum()
            - (p * r_up).sum())


def _dense_grid_best(scn, bids, n_grid=4001):
    grid = np.linspace(float(scn.rho_bar), float(scn.rho_hat), n_grid)
    grid = np.concatenate([grid, np.asarray(bids)])
    return max(_rm_obj_at_price(scn, bids, rho) for rho in grid)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_rm_solve_dense_grid_optimal(seed):
    """The <= N+2 candidate sweep attains the dense-grid (P5) optimum."""
    scn = sample_scenario(jax.random.PRNGKey(seed), 7, capacity_factor=0.9)
    bids = jax.random.uniform(jax.random.PRNGKey(100 + seed), (7,),
                              scn.A.dtype, float(scn.rho_bar),
                              float(scn.rho_hat))
    _, _, obj = rm_solve(scn, bids)
    best = _dense_grid_best(scn, bids)
    assert float(obj) == pytest.approx(best, rel=1e-9, abs=1e-9)


def test_rm_batched_pallas_dense_grid_optimal():
    """The batched Pallas sweep path attains the same (P5) optimum (kernel in
    interpret mode off-TPU, compiled on a Pallas-capable backend)."""
    ns = [7, 5, 7, 4]
    scns = [sample_scenario(jax.random.PRNGKey(i), n, capacity_factor=0.9)
            for i, n in enumerate(ns)]
    batch = stack_scenarios(scns)
    dt = batch.scenarios.A.dtype
    bids = jnp.stack([
        jnp.pad(jax.random.uniform(jax.random.PRNGKey(100 + i), (n,), dt,
                                   float(s.rho_bar), float(s.rho_hat)),
                (0, batch.n_max - n))
        for i, (s, n) in enumerate(zip(scns, ns))])

    cand, inc, spare, p_sorted, order = jax.vmap(_rm_candidates)(
        batch.scenarios, bids, batch.mask)
    sweep = make_batched_sweep_fn(force_pallas=True)
    fill, sum_fill, p_fill = sweep(inc, spare, p_sorted)
    _, _, obj = jax.vmap(_rm_pick)(batch.scenarios, cand, fill.astype(dt),
                                   sum_fill.astype(dt), p_fill.astype(dt),
                                   order, batch.mask)
    for b, (scn, n) in enumerate(zip(scns, ns)):
        best = _dense_grid_best(scn, np.asarray(bids[b][:n]))
        assert float(obj[b]) == pytest.approx(best, rel=1e-4, abs=1e-4)


def test_batched_kernel_matches_batched_ref():
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(7), 3)
    B, Nc, N = 5, 37, 101
    inc = jax.random.uniform(k0, (B, Nc, N), jnp.float32, 0.0, 10.0)
    inc = inc * (jax.random.uniform(k1, (B, Nc, N)) > 0.4)
    p = jnp.sort(jax.random.uniform(k2, (B, N), jnp.float32, 0.1, 100.0),
                 axis=1)[:, ::-1]
    spare = 0.3 * inc.sum(axis=(1, 2)) / Nc
    out = rm_sweep_batched(inc, spare, p, block_c=16, block_n=32,
                           interpret=True)
    ref = reference_batched(inc, spare, p)
    for a, b, tol in zip(out, ref, (1e-4, 1e-3, 1e-2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=tol)


def test_batched_solve_with_pallas_sweep():
    scns, batch = make_batch(ns=[5, 17, 9, 12])
    ref = solve_distributed_batch(batch)
    pal = solve_distributed_batch(batch,
                                  sweep_fn=make_batched_sweep_fn(
                                      force_pallas=True))
    np.testing.assert_allclose(np.asarray(pal.r), np.asarray(ref.r),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(pal.iters),
                                  np.asarray(ref.iters))


# --------------------------------------------------------------------------
# Algorithm 4.2 rounding invariants on batched output
# --------------------------------------------------------------------------

def test_batch_rounding_invariants():
    scns, batch = make_batch()
    res = solve_batch(batch)
    it, frac = res.integer, res.fractional
    mask = np.asarray(batch.mask)
    r, sM, sR, h = map(np.asarray, (it.r, it.sM, it.sR, it.h))
    # integrality everywhere, padded classes identically zero
    for x in (r, sM, sR, h):
        np.testing.assert_array_equal(x, np.round(x))
        assert np.all(x[~mask] == 0.0)
    for b, scn in enumerate(scns):
        n = scn.n
        r_low, r_up = np.asarray(scn.r_low), np.asarray(scn.r_up)
        # r within the (integer-relaxed) allocation box
        assert np.all(r[b][:n] >= np.floor(r_low) - 1e-9)
        assert np.all(r[b][:n] <= np.ceil(r_up) + 1e-9)
        # capacity (Prop. 4.2)
        assert r[b][:n].sum() <= np.floor(float(scn.R)) + 1e-9
        # slot constraint (P2e)
        lhs = (sM[b][:n] / np.asarray(scn.cM)
               + sR[b][:n] / np.asarray(scn.cR))
        assert np.all(lhs <= r[b][:n] + 1e-9)
        # admission stays in the SLA box
        assert np.all(h[b][:n] >= np.asarray(scn.H_low) - 1e-9)
        assert np.all(h[b][:n] <= np.asarray(scn.H_up) + 1e-9)
        # chip cost loses at most the floor(R) slack (one chip)
        assert float(it.cost[b]) >= float(frac.cost[b]) \
            - float(scn.rho_bar) - 1e-9
        # Sec. 4.5: the only way rounding can *lower* the total is the relaxed
        # (P4d) admission quantization (h rounded up cuts the penalty) plus
        # the one-chip floor(R) slack; net of those terms it never improves.
        psi_int = 1.0 / np.maximum(h[b][:n], 1.0)
        admission_gain = float(np.sum(
            np.asarray(scn.alpha)
            * np.maximum(np.asarray(frac.psi[b][:n]) - psi_int, 0.0)))
        assert float(it.total[b]) >= float(frac.total[b]) \
            - float(scn.rho_bar) - admission_gain - 1e-6


def test_batch_rounding_matches_single_rounding():
    """Lane-wise batched rounding == single-instance round_solution."""
    from repro.core import round_solution
    scns, batch = make_batch()
    res = solve_batch(batch)
    for b, scn in enumerate(scns):
        s = solve_distributed(scn)
        single = round_solution(scn, s.r, s.sM, s.sR, s.psi)
        n = scn.n
        np.testing.assert_allclose(np.asarray(res.integer.r[b][:n]),
                                   np.asarray(single.r), rtol=0, atol=1e-9)
        np.testing.assert_allclose(np.asarray(res.integer.h[b][:n]),
                                   np.asarray(single.h), rtol=0, atol=1e-9)
        assert float(res.integer.total[b]) == pytest.approx(
            float(single.total), rel=1e-9)


# --------------------------------------------------------------------------
# Facade / fleet integration
# --------------------------------------------------------------------------

def test_solve_batch_accepts_scenario_list():
    scns, _ = make_batch(ns=[4, 9, 6])
    res = solve_batch(scns)
    assert res.batch_size == 3
    assert res.r.shape == (3, 9)


def test_fleet_epoch_batch_matches_single_epochs():
    """One batched multi-fleet epoch == each fleet's own (single) epoch."""
    from repro.cluster import FleetSimulator, TenantSpec, epoch_batch

    def tenants(k):
        return [TenantSpec(f"t{i}", "x", "train_4k", deadline_s=100.0,
                           H_up=10 + i, H_low=4, penalty_per_job=20000.0)
                for i in range(k)]

    profiles = {f"t{i}": (1.0 + 0.2 * i, 0.5, 1.0) for i in range(4)}
    mk = lambda chips, k: FleetSimulator(total_chips=chips,
                                         tenants=tenants(k))
    singles = [mk(800, 2), mk(1200, 4), mk(600, 3)]   # ragged tenant counts
    batched = [mk(800, 2), mk(1200, 4), mk(600, 3)]
    for f in singles + batched:
        f._profiles = profiles

    expected = [f.epoch() for f in singles]
    allocs = epoch_batch(batched)
    assert len(allocs) == 3
    for got, want, f in zip(allocs, expected, batched):
        assert got.chips == want.chips
        assert got.h == want.h
        assert got.meshes == want.meshes
        assert got.total_cost == pytest.approx(want.total_cost, rel=1e-9)
        assert f.history == [got]


def test_solve_batch_infeasible_raises():
    from repro.core import InfeasibleError
    good = sample_scenario(jax.random.PRNGKey(0), 8, capacity_factor=0.95)
    bad = sample_scenario(jax.random.PRNGKey(1), 8, capacity_factor=0.5)
    with pytest.raises(InfeasibleError, match=r"\[1\]"):
        solve_batch([good, bad])
    res = solve_batch([good, bad], check_feasible=False, integer=False)
    assert bool(res.feasible[0]) and not bool(res.feasible[1])
