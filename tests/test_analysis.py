"""Unit tests for the roofline HLO analysis (collective parser, terms)."""
import numpy as np

from repro.launch.analysis import (CostSummary, Roofline,
                                   collective_wire_bytes, roofline)

HLO = """
ENTRY %main {
  %ag = f32[16,1024]{1,0} all-gather(%p0), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = bf16[256,512]{1,0} all-reduce(%x), replica_groups=[2,256]<=[512], to_apply=%sum
  %rs = f32[8,128]{1,0} reduce-scatter(%y), replica_groups=[64,8]<=[512]
  %cp = f32[4,4]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %aa = f32[2,8]{1,0} all-to-all(%w), replica_groups={{0,1,2,3}}
  %done = f32[1]{0} all-reduce-done(%start)
  %normal = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_collective_wire_bytes_parses_ops():
    total, by_op = collective_wire_bytes(HLO)
    # all-gather: (n-1)/n * result = 15/16 * 16*1024*4
    ag = 15 / 16 * 16 * 1024 * 4
    # all-reduce: 2*(n-1)/n * result (bf16)
    ar = 2 * 255 / 256 * 256 * 512 * 2
    # reduce-scatter: (n-1) * shard
    rs = 7 * 8 * 128 * 4
    # permute: result; all-to-all with brace groups (n=4): 3/4 * result
    cp = 4 * 4 * 4
    aa = 3 / 4 * 2 * 8 * 4
    np.testing.assert_allclose(by_op["all-gather"], ag)
    np.testing.assert_allclose(by_op["all-reduce"], ar)
    np.testing.assert_allclose(by_op["reduce-scatter"], rs)
    np.testing.assert_allclose(by_op["collective-permute"], cp)
    np.testing.assert_allclose(by_op["all-to-all"], aa)
    np.testing.assert_allclose(total, ag + ar + rs + cp + aa)


def test_single_participant_groups_ignored():
    hlo = ("%ar = f32[8]{0} all-reduce(%x), replica_groups=[512,1]<=[512]")
    total, _ = collective_wire_bytes(hlo)
    assert total == 0.0


def test_roofline_terms_and_bottleneck():
    c = CostSummary(flops=197e12, bytes_accessed=819e9 * 2,
                    coll_bytes=50e9 * 0.5)
    r = roofline(c)
    np.testing.assert_allclose(r.t_compute, 1.0)
    np.testing.assert_allclose(r.t_memory, 2.0)
    np.testing.assert_allclose(r.t_collective, 0.5)
    assert r.bottleneck == "memory"
    np.testing.assert_allclose(r.compute_fraction, 0.5)


def test_cost_summary_algebra():
    a = CostSummary(1.0, 2.0, 3.0, {"all-reduce": 3.0})
    b = CostSummary(10.0, 20.0, 30.0, {"all-gather": 30.0})
    s = a + b.scaled(0.5)
    assert s.flops == 6.0 and s.bytes_accessed == 12.0
    assert s.coll_by_op == {"all-reduce": 3.0, "all-gather": 15.0}
