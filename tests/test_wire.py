"""Wire-protocol tests: codec hygiene + socket-tenant conformance.

Two layers, mirroring the module split:

* ``repro.serving.wire`` codec tests run without sockets — framing
  round-trips, strict size limits, malformed/truncated/partial frames,
  version rejection, and bit-exact array / scenario / event / report
  encodings.
* ``AllocServer`` / ``AllocClient`` socket tests pin the tentpole
  contract: a socket tenant's flush reports are BIT-EQUAL to an offline
  ``WindowSession.stream`` replay of its accepted subtrace — under
  randomized multi-tenant traces, mid-epoch disconnects, and per-tenant
  quota exhaustion (rejections carrying the paper's ``m * H_up``
  penalty).
"""
import asyncio
import json
import struct

import jax
import numpy as np
import pytest

from repro.core import (AdmissionWindow, CapacityEngine, CapacityChange,
                        ClassArrival, ClassDeparture, FlushPolicy, Policies,
                        RoundingPolicy, SLAEdit, SolverConfig, TenantQuota,
                        sample_class_params, sample_event_trace,
                        sample_scenario)
from repro.serving import wire
from repro.serving.allocd import AllocDaemon, rejection_penalty
from repro.serving.client import AllocClient
from repro.serving.server import AllocServer

B, N, N_MAX = 3, 4, 8          # one shared window shape: compile once


def make_engine(flush_k=3):
    return CapacityEngine(SolverConfig(),
                          Policies(flush=FlushPolicy(max_events=flush_k),
                                   rounding=RoundingPolicy(enabled=False)))


def make_lanes(seed):
    key = jax.random.PRNGKey(seed)
    return [sample_scenario(jax.random.fold_in(key, lane), N,
                            capacity_factor=1.3) for lane in range(B)]


def make_trace(seed, lanes, n_events=10):
    return sample_event_trace(seed, AdmissionWindow(lanes, n_max=N_MAX),
                              n_events)


def arrival(seed):
    params = dict(sample_class_params(jax.random.PRNGKey(seed)))
    return ClassArrival(lane=seed % B, params=params)


def offline_replay(lanes, events, flush_k=3):
    session = make_engine(flush_k).open_window(
        AdmissionWindow(lanes, n_max=N_MAX))
    return list(session.stream(events))


def assert_reports_bitequal(got, want, *, prefix=False):
    if prefix:
        assert len(got) <= len(want)
    else:
        assert len(got) == len(want)
    for a, b in zip(got, want):
        la = jax.tree_util.tree_flatten(a.fractional)[0]
        lb = jax.tree_util.tree_flatten(b.fractional)[0]
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(a.iters),
                                      np.asarray(b.iters))
        np.testing.assert_array_equal(np.asarray(a.mask),
                                      np.asarray(b.mask))


def feed_reader(data, *, chunk=None):
    """A StreamReader pre-loaded with `data` (optionally drip-fed)."""
    reader = asyncio.StreamReader()
    if chunk is None:
        reader.feed_data(data)
    else:
        for i in range(0, len(data), chunk):
            reader.feed_data(data[i:i + chunk])
    reader.feed_eof()
    return reader


# --------------------------------------------------------------------------
# Frame codec (no sockets)
# --------------------------------------------------------------------------

def test_frame_roundtrip_and_partial_reads():
    """A frame split into 1-byte chunks reassembles to the same message."""
    msg = {"type": "offer", "tenant": "t0", "cseq": 7}
    data = wire.encode_frame(msg)

    async def run():
        whole = await wire.read_frame(feed_reader(data))
        dripped = await wire.read_frame(feed_reader(data, chunk=1))
        return whole, dripped

    whole, dripped = asyncio.run(run())
    assert whole == dripped == {"v": wire.PROTOCOL_VERSION, **msg}


def test_oversized_frames_rejected_both_directions():
    """Size limit binds at write time and before buffering at read time."""
    big = {"type": "offer", "blob": "x" * 4096}
    with pytest.raises(wire.FrameTooLargeError):
        wire.encode_frame(big, max_frame=1024)

    # a hostile header declaring > max_frame is rejected without reading
    # the (absent) payload
    header = struct.pack(">I", wire.MAX_FRAME_BYTES + 1)

    async def run():
        with pytest.raises(wire.FrameTooLargeError):
            await wire.read_frame(feed_reader(header))

    asyncio.run(run())


@pytest.mark.parametrize("payload", [
    b"\x00\xff\xfenot json",                     # undecodable bytes
    json.dumps([1, 2, 3]).encode(),              # JSON but not an object
    json.dumps({"v": 1, "no_type": True}).encode(),   # object, no type
    json.dumps({"v": 1, "type": 42}).encode(),   # non-string type
])
def test_malformed_frames_rejected(payload):
    data = struct.pack(">I", len(payload)) + payload

    async def run():
        with pytest.raises(wire.MalformedFrameError):
            await wire.read_frame(feed_reader(data))

    asyncio.run(run())


def test_zero_length_frame_rejected():
    async def run():
        with pytest.raises(wire.MalformedFrameError):
            await wire.read_frame(feed_reader(struct.pack(">I", 0)))

    asyncio.run(run())


def test_unknown_version_rejected():
    payload = json.dumps({"v": 99, "type": "offer"}).encode()
    data = struct.pack(">I", len(payload)) + payload

    async def run():
        with pytest.raises(wire.ProtocolVersionError):
            await wire.read_frame(feed_reader(data))

    asyncio.run(run())


def test_truncated_frame_raises_incomplete_read():
    """Connection dying mid-frame surfaces as IncompleteReadError."""
    data = wire.encode_frame({"type": "offer", "cseq": 1})

    async def run():
        with pytest.raises(asyncio.IncompleteReadError):
            await wire.read_frame(feed_reader(data[:-3]))
        # ... and mid-header too
        with pytest.raises(asyncio.IncompleteReadError):
            await wire.read_frame(feed_reader(data[:2]))

    asyncio.run(run())


# --------------------------------------------------------------------------
# Value codecs: bit-exactness
# --------------------------------------------------------------------------

def test_array_codec_bitexact():
    rng = np.random.default_rng(0)
    for arr in [rng.standard_normal((3, 5)),
                rng.integers(0, 9, size=(4,), dtype=np.int32),
                np.float64(1 / 3),                     # 0-d
                np.asarray(True)]:
        out = wire.decode_array(wire.encode_array(arr))
        assert out.dtype == np.asarray(arr).dtype
        np.testing.assert_array_equal(out, np.asarray(arr))


def test_array_codec_rejects_inconsistent_payload():
    enc = wire.encode_array(np.arange(4.0))
    enc["shape"] = [3]                                  # byte count mismatch
    with pytest.raises(wire.MalformedFrameError):
        wire.decode_array(enc)
    with pytest.raises(wire.MalformedFrameError):
        wire.decode_array({"dtype": "<f8", "shape": [1], "data": "!!!"})


def test_scenario_roundtrip_bitexact():
    """Raw fields + deterministic re-derivation == bit-identical scenario."""
    for seed in range(3):
        scn = make_lanes(seed)[0]
        out = wire.decode_scenario(wire.encode_scenario(scn))
        la = jax.tree_util.tree_flatten(scn)[0]
        lb = jax.tree_util.tree_flatten(out)[0]
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_event_roundtrip_all_kinds():
    ev = arrival(5)
    out = wire.decode_event(wire.encode_event(ev))
    assert out.lane == ev.lane and out.params == ev.params
    for ev in [ClassDeparture(lane=1, slot=2),
               SLAEdit(lane=0, slot=1, updates={"H_up": 3.5}),
               CapacityChange(lane=2, R=17.0)]:
        out = wire.decode_event(wire.encode_event(ev))
        assert out == ev
    with pytest.raises(wire.MalformedFrameError):
        wire.decode_event({"kind": "warp", "lane": 0})


def test_report_roundtrip_bitexact():
    session = make_engine().open_window(
        AdmissionWindow(make_lanes(0), n_max=N_MAX))
    session.offer(arrival(1))
    report = session.flush()
    entries = [(1, 0)]
    out = wire.decode_report("t0", 0, wire.encode_report(report), entries)
    assert_reports_bitequal([out], [report])
    assert out.tickets == entries and out.error is None


# --------------------------------------------------------------------------
# Socket conformance (the tentpole contract)
# --------------------------------------------------------------------------

async def start_server(flush_k=3, queue_limit=256, **kw):
    server = AllocServer(AllocDaemon(make_engine(flush_k),
                                     queue_limit=queue_limit), **kw)
    await server.start()
    return server


@pytest.mark.parametrize("seed", [0, 1])
def test_socket_tenants_conformant_randomized(seed):
    """Multi-tenant random traces over the wire: client-side AND
    daemon-side reports bit-equal the offline replay per tenant."""
    names = [f"t{i}" for i in range(3)]
    lanes = {nm: make_lanes(seed * 10 + i) for i, nm in enumerate(names)}
    traces = {nm: make_trace(seed * 100 + i * 7, lanes[nm], 9)
              for i, nm in enumerate(names)}

    async def run():
        server = await start_server()
        client = await AllocClient.connect(*server.address)
        for nm in names:
            await client.register_tenant(nm, lanes[nm], n_max=N_MAX,
                                         quota=TenantQuota(max_queued=64))
        tickets = []
        for k in range(max(len(t) for t in traces.values())):
            for nm in names:                      # interleave across tenants
                if k < len(traces[nm]):
                    tickets.append(client.offer(nm, traces[nm][k]))
            await asyncio.sleep(0)
        for tk in tickets:
            assert await tk.ack() is True
        await client.drain()
        for tk in tickets:
            assert (await tk.result()) is not None
        got = ({nm: list(client.reports(nm)) for nm in names},
               {nm: list(server.daemon.reports(nm)) for nm in names})
        await client.close()
        await server.close()
        return got

    client_reports, daemon_reports = asyncio.run(run())
    for nm in names:
        want = offline_replay(lanes[nm], traces[nm])
        assert_reports_bitequal(client_reports[nm], want)
        assert_reports_bitequal(daemon_reports[nm], want)


def test_disconnect_mid_epoch_accepted_prefix_conformant():
    """A client dying mid-epoch leaves a drained, replay-equal tenant."""
    lanes = make_lanes(3)
    trace = make_trace(11, lanes, 8)
    cut = 5                        # flush_k=3: disconnect mid second epoch

    async def run():
        server = await start_server()
        client = await AllocClient.connect(*server.address)
        await client.register_tenant("t0", lanes, n_max=N_MAX)
        for ev in trace[:cut]:
            tk = client.offer("t0", ev)
            assert await tk.ack() is True
        await client.close()       # abrupt: no drain frame
        daemon = server.daemon
        for _ in range(500):       # let the handler's disconnect path run
            if daemon.reports("t0") and not daemon._tenants["t0"].queued:
                break
            await asyncio.sleep(0.01)
        got = list(daemon.reports("t0"))
        await server.close()
        return got

    got = asyncio.run(run())
    want = offline_replay(lanes, trace[:cut])
    assert_reports_bitequal(got, want)


def test_quota_exhaustion_rejects_with_paper_penalty():
    """Offers beyond TenantQuota.max_queued are rejected with m * H_up,
    and the accepted subtrace stays bit-equal to its offline replay."""
    lanes = make_lanes(4)
    events = [arrival(i) for i in range(6)]
    quota = TenantQuota(max_queued=2)

    async def run():
        server = await start_server(flush_k=100)   # nothing flushes early
        client = await AllocClient.connect(*server.address)
        await client.register_tenant("t0", lanes, n_max=N_MAX, quota=quota)
        tickets = [client.offer("t0", ev) for ev in events]
        acks = [await tk.ack() for tk in tickets]
        await client.drain()
        stats = server.daemon.tenant_stats("t0")
        got = list(client.reports("t0"))
        penalties = [tk.penalty for tk in tickets]
        await client.close()
        await server.close()
        return acks, penalties, stats, got

    acks, penalties, stats, got = asyncio.run(run())
    # un-flushed backlog (queued + folded-but-unflushed) caps at 2, and
    # flush_k=100 means nothing flushes before the drain: first 2 accepted
    assert acks == [True, True] + [False] * 4
    for ok, pen, ev in zip(acks, penalties, events):
        assert pen == (0.0 if ok else rejection_penalty(ev))
        if not ok:
            assert pen == abs(ev.params["m"]) * abs(ev.params["H_up"]) > 0
    assert stats["rejected"] == 4.0
    assert stats["rejection_cost"] == pytest.approx(
        sum(p for p in penalties if p))
    want = offline_replay(lanes, events[:2], flush_k=100)
    assert_reports_bitequal(got, want)


def test_flush_request_forces_epoch_boundary():
    """A wire flush == an explicit WindowSession.flush at that point."""
    lanes = make_lanes(5)
    evs = [arrival(7), arrival(8)]

    async def run():
        server = await start_server(flush_k=100)
        client = await AllocClient.connect(*server.address)
        await client.register_tenant("t0", lanes, n_max=N_MAX)
        for ev in evs:
            assert await client.offer("t0", ev).ack() is True
        report = await client.flush("t0")
        await client.close()
        await server.close()
        return report

    got = asyncio.run(run())
    offline = make_engine(flush_k=100).open_window(
        AdmissionWindow(lanes, n_max=N_MAX))
    for ev in evs:
        offline.offer(ev)
    want = offline.flush()
    assert_reports_bitequal([got], [want])
    assert [slot for _, slot in got.tickets] == list(offline.last_slots)


# --------------------------------------------------------------------------
# Server-side protocol rejection over real sockets
# --------------------------------------------------------------------------

async def raw_exchange(server, data):
    """Write raw bytes to the server, return (frames, eof_seen)."""
    reader, writer = await asyncio.open_connection(*server.address)
    writer.write(data)
    await writer.drain()
    frames, eof = [], False
    try:
        while True:
            frames.append(await wire.read_frame(reader))
    except (asyncio.IncompleteReadError, ConnectionError):
        eof = True
    writer.close()
    return frames, eof


@pytest.mark.parametrize("raw, code", [
    (struct.pack(">I", 2 * wire.MAX_FRAME_BYTES), "frame_too_large"),
    (struct.pack(">I", 9) + b"\xffgarbage!", "malformed_frame"),
    (lambda: (lambda p: struct.pack(">I", len(p)) + p)(
        json.dumps({"v": 42, "type": "offer"}).encode()), "bad_version"),
])
def test_server_rejects_protocol_violations_and_closes(raw, code):
    data = raw() if callable(raw) else raw

    async def run():
        server = await start_server()
        frames, eof = await raw_exchange(server, data)
        await server.close()
        return frames, eof

    frames, eof = asyncio.run(run())
    assert eof, "server must close the connection after a framing violation"
    assert len(frames) == 1
    assert frames[0]["type"] == "error" and frames[0]["code"] == code


def test_unknown_message_type_keeps_connection():
    """Frame boundaries survive an unknown type: error reply, then the
    connection still accepts a registration."""

    async def run():
        server = await start_server()
        client = await AllocClient.connect(*server.address)
        fut = client._expect("register_tenant")
        client._send({"type": "sudo"})
        client._send({"type": "register_tenant", "tenant": "t0",
                      "lanes": [wire.encode_scenario(s)
                                for s in make_lanes(0)],
                      "n_max": N_MAX, "quota": None})
        ack = await asyncio.wait_for(fut, 30)
        tenants = server.daemon.tenants
        await client.close()
        await server.close()
        return ack, tenants

    ack, tenants = asyncio.run(run())
    assert ack["type"] == "register_tenant" and ack["tenant"] == "t0"
    assert "t0" in tenants


def test_application_errors_keep_connection():
    """Unknown-tenant offers and duplicate registrations answer with
    error frames but do not kill the session."""

    async def run():
        server = await start_server()
        client = await AllocClient.connect(*server.address)
        lanes = make_lanes(1)
        with pytest.raises(wire.RemoteError):
            tk = client.offer("ghost", arrival(0))
            await tk.ack()
        await client.register_tenant("t0", lanes, n_max=N_MAX)
        with pytest.raises(wire.RemoteError) as exc:
            await client.register_tenant("t0", lanes, n_max=N_MAX)
        tk = client.offer("t0", arrival(1))     # still usable
        ok = await tk.ack()
        await client.drain()
        await client.close()
        await server.close()
        return ok, exc.value.code

    ok, code = asyncio.run(run())
    assert ok is True
    assert code == "ValueError"


def test_register_rejects_quota_violating_window():
    """An initial window wider than quota.max_lanes is refused at
    registration (engine-side QuotaExceededError surfaced as an error
    frame), and the tenant is not created."""

    async def run():
        server = await start_server()
        client = await AllocClient.connect(*server.address)
        with pytest.raises(wire.RemoteError) as exc:
            await client.register_tenant(
                "t0", make_lanes(2), n_max=N_MAX,
                quota=TenantQuota(max_lanes=B - 1))
        tenants = server.daemon.tenants
        await client.close()
        await server.close()
        return exc.value.code, tenants

    code, tenants = asyncio.run(run())
    assert code == "QuotaExceededError"
    assert "t0" not in tenants
