"""Multi-device tests (subprocesses with XLA host devices): shard_map MoE
vs the dense oracle, a miniature multi-pod dry-run, and elastic re-mesh
checkpoint restore."""
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_devices(code: str, n_devices: int = 8) -> str:
    pre = (f"import os\n"
           f"os.environ['XLA_FLAGS'] = "
           f"'--xla_force_host_platform_device_count={n_devices}'\n")
    r = subprocess.run([sys.executable, "-c", pre + code],
                       capture_output=True, text=True, timeout=900,
                       # JAX_PLATFORMS=cpu: forced host devices are CPU-only;
                       # skip the (minutes-long) TPU metadata probe on
                       # TPU-library machines.
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_shard_map_matches_dense_oracle():
    run_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.launch.mesh import make_mesh, dist_for
from repro.models import moe as moe_mod

cfg = reduced_config("deepseek-moe-16b").replace(
    moe=reduced_config("deepseek-moe-16b").moe.__class__(
        n_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
        first_k_dense=1, capacity_factor=16.0))
mesh = make_mesh((2, 4), ("data", "model"))
dist = dist_for(mesh, fsdp=False)
p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
gates, idx, _ = moe_mod.route(cfg, p, x)
out = jax.jit(lambda p, x, g, i: moe_mod.moe_apply(cfg, p, x, g, i, dist))(p, x, gates, idx)
ref = moe_mod.moe_dense_ref(cfg, p, x, gates, idx)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)
print("MOE_OK")
""")


def test_mini_multipod_dryrun_compiles():
    """2x2x2 'multi-pod' mesh, reduced arch, train + decode lower+compile."""
    run_devices("""
import jax, jax.numpy as jnp
from functools import partial
from repro.configs import reduced_config
from repro.configs.specs import input_specs
from repro.launch.mesh import make_mesh, dist_for
from repro.launch.steps import jit_train_step, jit_decode_step
from repro.models import init_params, init_cache
from repro.models.config import ShapeConfig
from repro.optim import OptConfig, adamw_init

cfg = reduced_config("qwen3-8b").replace(grad_accum=2)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
dist = dist_for(mesh, fsdp=True)
params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
shape = ShapeConfig("t", "train", 32, 8)
specs = input_specs(cfg, shape)
oc = OptConfig()
opt = jax.eval_shape(partial(adamw_init, oc=oc), params)
from repro.launch.analysis import cost_analysis_dict
c = jit_train_step(cfg, dist, oc, params, opt, specs["batch"]).lower(
    params, opt, specs["batch"]).compile()
assert cost_analysis_dict(c)["flops"] > 0
dshape = ShapeConfig("d", "decode", 32, 8)
dspecs = input_specs(cfg, dshape)
c2 = jit_decode_step(cfg, dist, params, dspecs["cache"]).lower(
    params, dspecs["cache"], dspecs["token"], dspecs["pos"]).compile()
print("DRYRUN_OK", cost_analysis_dict(c)["flops"])
""")


def test_elastic_remesh_checkpoint():
    """Train on a (1,2) mesh, checkpoint, restore on (2,2), verify identical
    loss trajectory continuation vs an uninterrupted run."""
    run_devices("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro import checkpoint as ckpt
from repro.configs import reduced_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh, dist_for
from repro.launch.steps import make_train_step, param_shardings
from repro.models import init_params
from repro.optim import OptConfig, adamw_init

cfg = reduced_config("qwen3-0.6b")
oc = OptConfig(lr=1e-3, total_steps=20, warmup_steps=1)
data = SyntheticLM(cfg.vocab, 32, 4, seed=0)

def run(mesh_shape, start, stop, params, opt):
    mesh = make_mesh(mesh_shape, ("data", "model"))
    dist = dist_for(mesh, fsdp=False)
    sh = param_shardings(cfg, params, dist)
    params = jax.device_put(params, sh)
    step = jax.jit(make_train_step(cfg, dist, oc))
    losses = []
    for s in range(start, stop):
        batch = jax.tree_util.tree_map(jnp.asarray, data(s))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    return params, opt, losses

params = init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params, oc)

# uninterrupted reference on (1,2)
p_ref, o_ref, l_ref = run((1, 2), 0, 6, params, opt)

# interrupted: 3 steps on (1,2), checkpoint, re-mesh to (2,2), 3 more steps
p1, o1, l1 = run((1, 2), 0, 3, params, opt)
d = tempfile.mkdtemp()
ckpt.save({"params": p1, "opt": o1}, 3, d)
state, _ = ckpt.restore({"params": p1, "opt": o1}, 3, d)
p2, o2, l2 = run((2, 2), 3, 6, state["params"], state["opt"])

# pre-checkpoint steps ran on the same mesh: tight; post-re-mesh steps
# differ by DP reduction order in f32: loose
np.testing.assert_allclose(l1, l_ref[:3], rtol=2e-5)
np.testing.assert_allclose(l2, l_ref[3:], rtol=2e-2)
print("ELASTIC_OK", l_ref)
""")
