import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Force a multi-device CPU topology BEFORE jax initializes its backend so the
# device-sharding layer (core/sharding.py, tests/test_sharding.py) is testable
# anywhere.  Only the CPU platform is affected; a machine whose XLA_FLAGS
# already pins a device count keeps it.
from repro._env import force_host_devices  # noqa: E402  (jax-free import)

force_host_devices()

import jax  # noqa: E402

# The allocator math (paper Sec. 3-4) is validated at f64; model code uses
# explicit f32/bf16 dtypes so enabling x64 here must not change model behavior
# (test_models asserts explicit dtypes).  The production dry-run path runs
# WITHOUT x64, as it would on TPU.
jax.config.update("jax_enable_x64", True)
