import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

# The allocator math (paper Sec. 3-4) is validated at f64; model code uses
# explicit f32/bf16 dtypes so enabling x64 here must not change model behavior
# (test_models asserts explicit dtypes).  The production dry-run path runs
# WITHOUT x64, as it would on TPU.
jax.config.update("jax_enable_x64", True)
